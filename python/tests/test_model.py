"""L2 model correctness: shapes, training signal, DP behaviour, round-trips."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

RNG = np.random.default_rng(7)


def _batch(b):
    x = jnp.asarray(RNG.normal(size=(b, model.INPUT_DIM)), jnp.float32)
    y = jnp.asarray(RNG.integers(0, model.NUM_CLASSES, size=(b,)), jnp.int32)
    return x, y


def _synthetic_task(b, seed=0):
    """Linearly separable toy task so a few SGD steps measurably reduce loss."""
    rng = np.random.default_rng(seed)
    protos = rng.normal(size=(model.NUM_CLASSES, model.INPUT_DIM)).astype(np.float32)
    y = rng.integers(0, model.NUM_CLASSES, size=(b,))
    x = protos[y] + 0.1 * rng.normal(size=(b, model.INPUT_DIM)).astype(np.float32)
    return jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.int32)


def test_param_counts():
    assert model.P == sum(i * o + o for i, o in model.LAYERS)
    assert model.P_PAD % 1024 == 0 and model.P_PAD >= model.P


def test_init_params_shape_and_padding():
    (flat,) = model.init_params(jnp.int32(42))
    assert flat.shape == (model.P_PAD,)
    assert np.all(np.asarray(flat[model.P :]) == 0.0)  # padding is canonical zero


def test_init_params_deterministic_and_seed_sensitive():
    (a,) = model.init_params(jnp.int32(1))
    (b,) = model.init_params(jnp.int32(1))
    (c,) = model.init_params(jnp.int32(2))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.allclose(np.asarray(a), np.asarray(c))


def test_flatten_unflatten_roundtrip():
    (flat,) = model.init_params(jnp.int32(3))
    again = model.flatten(model.unflatten(flat))
    np.testing.assert_allclose(np.asarray(again), np.asarray(flat), atol=0)


@pytest.mark.parametrize("b", model.TRAIN_BATCH_SIZES)
def test_train_step_shapes(b):
    (flat,) = model.init_params(jnp.int32(0))
    x, y = _batch(b)
    new, loss = model.train_step(flat, x, y, jnp.float32(1e-2))
    assert new.shape == (model.P_PAD,)
    assert np.isfinite(float(loss))
    assert np.all(np.asarray(new[model.P :]) == 0.0)  # padding untouched


def test_training_reduces_loss():
    (flat,) = model.init_params(jnp.int32(0))
    x, y = _synthetic_task(32)
    first = None
    for _ in range(30):
        flat, loss = model.train_step(flat, x, y, jnp.float32(5e-2))
        first = first if first is not None else float(loss)
    assert float(loss) < 0.5 * first


def test_eval_step_counts():
    (flat,) = model.init_params(jnp.int32(0))
    x, y = _batch(model.B_EVAL)
    loss_sum, correct = model.eval_step(flat, x, y)
    assert 0 <= int(correct) <= model.B_EVAL
    assert float(loss_sum) > 0.0


def test_eval_step_perfect_model():
    """A model trained to memorise a tiny task scores > random on eval."""
    (flat,) = model.init_params(jnp.int32(0))
    x, y = _synthetic_task(model.B_EVAL)
    for _ in range(60):
        flat, _ = model.train_step(flat, x[:32], y[:32], jnp.float32(5e-2))
    _, correct = model.eval_step(flat, x, y)
    assert int(correct) > model.B_EVAL // 2


def test_eval_pallas_forward_matches_jnp():
    (flat,) = model.init_params(jnp.int32(9))
    x, _ = _batch(64)
    np.testing.assert_allclose(
        model.forward(flat, x, use_pallas=True),
        model.forward(flat, x, use_pallas=False),
        rtol=2e-5,
        atol=1e-3,
    )


def test_dp_train_step_noise_and_clip():
    (flat,) = model.init_params(jnp.int32(0))
    x, y = _batch(32)
    a, _ = model.dp_train_step(flat, x, y, jnp.float32(1e-2), jnp.int32(1), jnp.float32(1.2), jnp.float32(0.4))
    b, _ = model.dp_train_step(flat, x, y, jnp.float32(1e-2), jnp.int32(2), jnp.float32(1.2), jnp.float32(0.4))
    assert not np.allclose(np.asarray(a), np.asarray(b))  # seed changes noise
    assert np.all(np.asarray(a[model.P :]) == 0.0)  # padding stays zero
    # zero noise reduces to clipped SGD: effective update norm <= lr * clip
    c, _ = model.dp_train_step(flat, x, y, jnp.float32(1e-2), jnp.int32(1), jnp.float32(1.2), jnp.float32(0.0))
    delta = np.linalg.norm(np.asarray(c - flat))
    assert delta <= 1e-2 * 1.2 + 1e-5


def test_aggregation_entry_points():
    stack = jnp.asarray(RNG.normal(size=(model.K, model.P_PAD)), jnp.float32)
    w = jnp.full((model.K,), 1.0 / model.K, jnp.float32)
    (agg,) = model.fedavg_agg(stack, w)
    np.testing.assert_allclose(np.asarray(agg), np.asarray(jnp.mean(stack, 0)), rtol=2e-5, atol=1e-4)
    (d,) = model.pairwise_dist(stack)
    (s,) = model.cosine_sim(stack)
    assert d.shape == (model.K, model.K) and s.shape == (model.K, model.K)
    clipped, norms = model.clip_updates(stack, jnp.float32(1.0))
    assert clipped.shape == stack.shape and norms.shape == (model.K,)


def test_grad_matches_finite_difference():
    """Spot-check jax.grad against central differences on a few coordinates."""
    (flat,) = model.init_params(jnp.int32(5))
    x, y = _batch(10)
    g = jax.grad(model._ce_loss)(flat, x, y)
    eps = 1e-3
    for idx in [0, 1000, model.P - 1]:
        e = jnp.zeros_like(flat).at[idx].set(eps)
        num = (model._ce_loss(flat + e, x, y) - model._ce_loss(flat - e, x, y)) / (2 * eps)
        np.testing.assert_allclose(float(g[idx]), float(num), rtol=5e-2, atol=1e-4)
