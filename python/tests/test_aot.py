"""AOT lowering: every entry point produces parseable HLO text + manifest."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_entry_point_specs_are_static():
    for name, fn, specs in aot.entry_points():
        assert name
        for s in specs:
            assert all(isinstance(d, int) for d in s.shape)


def test_lower_small_entry_produces_hlo_text():
    _, fn, specs = next(e for e in aot.entry_points() if e[0] == "fedavg_agg")
    text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_manifest_matches_model_constants():
    path = os.path.join(ART, "manifest.txt")
    if not os.path.exists(path):
        import pytest

        pytest.skip("artifacts not built (run `make artifacts`)")
    kv = dict(line.split("=", 1) for line in open(path).read().splitlines() if line)
    assert int(kv["P"]) == model.P
    assert int(kv["P_PAD"]) == model.P_PAD
    assert int(kv["K"]) == model.K
    assert int(kv["B_EVAL"]) == model.B_EVAL
    assert [int(b) for b in kv["TRAIN_BATCH_SIZES"].split(",")] == list(model.TRAIN_BATCH_SIZES)
    for name in kv["ARTIFACTS"].split(","):
        assert os.path.exists(os.path.join(ART, f"{name}.hlo.txt"))


def test_lowered_eval_step_runs_and_matches_eager():
    """Execute the jitted (to-be-lowered) eval_step and compare with eager."""
    rng = np.random.default_rng(0)
    (flat,) = model.init_params(jnp.int32(0))
    x = jnp.asarray(rng.normal(size=(model.B_EVAL, model.INPUT_DIM)), jnp.float32)
    y = jnp.asarray(rng.integers(0, model.NUM_CLASSES, size=(model.B_EVAL,)), jnp.int32)
    jit_loss, jit_correct = jax.jit(model.eval_step)(flat, x, y)
    loss, correct = model.eval_step(flat, x, y)
    np.testing.assert_allclose(float(jit_loss), float(loss), rtol=1e-5)
    assert int(jit_correct) == int(correct)
