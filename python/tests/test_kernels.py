"""Pallas kernels vs pure-jnp oracles (the core L1 correctness signal).

Fixed-case assertions plus hypothesis sweeps over shapes and value scales.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import axpy, dense, fedavg_agg, gram, ref

RNG = np.random.default_rng(1234)


def _stack(k, p, scale=1.0):
    return jnp.asarray(RNG.normal(size=(k, p)) * scale, jnp.float32)


# ---------------------------------------------------------------- fedavg_agg


@pytest.mark.parametrize("k,p", [(8, 1024), (8, 5000), (4, 235520), (2, 128), (8, 1)])
def test_fedavg_agg_matches_ref(k, p):
    s, w = _stack(k, p), jnp.asarray(RNG.random(k), jnp.float32)
    np.testing.assert_allclose(
        fedavg_agg.fedavg_agg(s, w), ref.fedavg_agg(s, w), rtol=2e-5, atol=1e-4
    )


def test_fedavg_agg_identity_weight():
    """Weight vector e_i returns exactly row i."""
    s = _stack(8, 4096)
    for i in range(8):
        w = jnp.zeros(8, jnp.float32).at[i].set(1.0)
        np.testing.assert_allclose(fedavg_agg.fedavg_agg(s, w), s[i], rtol=1e-6, atol=1e-6)


def test_fedavg_agg_uniform_weights_is_mean():
    s = _stack(8, 3000)
    w = jnp.full(8, 1.0 / 8.0, jnp.float32)
    np.testing.assert_allclose(fedavg_agg.fedavg_agg(s, w), jnp.mean(s, 0), rtol=2e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(1, 8),
    p=st.integers(1, 4096),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
    block=st.sampled_from([128, 512, 2048]),
)
def test_fedavg_agg_hypothesis(k, p, scale, block):
    s, w = _stack(k, p, scale), jnp.asarray(RNG.random(k), jnp.float32)
    got = fedavg_agg.fedavg_agg(s, w, block_p=block)
    np.testing.assert_allclose(got, ref.fedavg_agg(s, w), rtol=3e-5, atol=1e-4 * scale)


# ---------------------------------------------------------------------- gram


@pytest.mark.parametrize("k,p", [(8, 1024), (8, 235520), (3, 77), (1, 128)])
def test_gram_matches_ref(k, p):
    s = _stack(k, p)
    np.testing.assert_allclose(gram.gram(s), ref.gram(s), rtol=2e-5, atol=1e-2)


def test_pairwise_dist_properties():
    s = _stack(8, 8192)
    d = np.asarray(gram.pairwise_dist(s))
    np.testing.assert_allclose(d, ref.pairwise_dist(s), rtol=2e-4, atol=1e-2)
    np.testing.assert_allclose(d, d.T, atol=1e-3)  # symmetric
    np.testing.assert_allclose(np.diag(d), 0.0, atol=1e-2)  # zero diagonal
    assert (d >= -1e-3).all()  # non-negative


def test_cosine_sim_properties():
    s = _stack(8, 8192)
    c = np.asarray(gram.cosine_sim(s))
    np.testing.assert_allclose(c, ref.cosine_sim(s), rtol=2e-5, atol=1e-4)
    np.testing.assert_allclose(np.diag(c), 1.0, atol=1e-4)
    assert (np.abs(c) <= 1.0 + 1e-4).all()


def test_cosine_sim_detects_identical_rows():
    """Two identical (Sybil) updates have cosine similarity 1."""
    s = np.array(_stack(8, 2048), copy=True)
    s[3] = s[5]
    c = np.asarray(gram.cosine_sim(jnp.asarray(s)))
    assert c[3, 5] > 0.9999


def test_clip_updates():
    s = _stack(8, 4096, scale=3.0)
    clipped, norms = gram.clip_updates(s, 10.0)
    cr, nr = ref.clip_updates(s, 10.0)
    np.testing.assert_allclose(clipped, cr, rtol=2e-5, atol=1e-4)
    np.testing.assert_allclose(norms, nr, rtol=2e-5, atol=1e-4)
    out_norms = np.linalg.norm(np.asarray(clipped), axis=1)
    assert (out_norms <= 10.0 + 1e-3).all()


@settings(max_examples=20, deadline=None)
@given(k=st.integers(1, 8), p=st.integers(1, 3000))
def test_gram_hypothesis(k, p):
    s = _stack(k, p)
    np.testing.assert_allclose(gram.gram(s), ref.gram(s), rtol=3e-5, atol=1e-2)


# --------------------------------------------------------------------- dense


@pytest.mark.parametrize(
    "b,i,o,relu",
    [(256, 784, 256, True), (256, 256, 128, True), (256, 128, 10, False), (1, 1, 1, True), (7, 50, 3, False)],
)
def test_dense_matches_ref(b, i, o, relu):
    x = jnp.asarray(RNG.normal(size=(b, i)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(i, o)), jnp.float32)
    bias = jnp.asarray(RNG.normal(size=(o,)), jnp.float32)
    np.testing.assert_allclose(
        dense.dense(x, w, bias, relu=relu), ref.dense(x, w, bias, relu), rtol=2e-5, atol=1e-3
    )


def test_dense_relu_nonnegative():
    x = jnp.asarray(RNG.normal(size=(64, 32)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(32, 16)), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(16,)), jnp.float32)
    assert (np.asarray(dense.dense(x, w, b, relu=True)) >= 0.0).all()


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 300),
    i=st.integers(1, 200),
    o=st.integers(1, 200),
    relu=st.booleans(),
)
def test_dense_hypothesis(b, i, o, relu):
    x = jnp.asarray(RNG.normal(size=(b, i)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(i, o)), jnp.float32)
    bias = jnp.asarray(RNG.normal(size=(o,)), jnp.float32)
    np.testing.assert_allclose(
        dense.dense(x, w, bias, relu=relu), ref.dense(x, w, bias, relu), rtol=3e-5, atol=1e-3
    )


# ---------------------------------------------------------------------- axpy


@pytest.mark.parametrize("n", [1, 128, 4096, 235520, 5000])
def test_axpy_matches_ref(n):
    p = jnp.asarray(RNG.normal(size=(n,)), jnp.float32)
    g = jnp.asarray(RNG.normal(size=(n,)), jnp.float32)
    np.testing.assert_allclose(axpy.axpy(p, g, 0.01), ref.axpy(p, g, 0.01), rtol=1e-6, atol=1e-6)


def test_axpy_zero_lr_is_identity():
    p = jnp.asarray(RNG.normal(size=(9999,)), jnp.float32)
    g = jnp.asarray(RNG.normal(size=(9999,)), jnp.float32)
    np.testing.assert_allclose(axpy.axpy(p, g, 0.0), p, atol=0)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 10000), lr=st.floats(0.0, 1.0))
def test_axpy_hypothesis(n, lr):
    p = jnp.asarray(RNG.normal(size=(n,)), jnp.float32)
    g = jnp.asarray(RNG.normal(size=(n,)), jnp.float32)
    np.testing.assert_allclose(axpy.axpy(p, g, lr), ref.axpy(p, g, lr), rtol=1e-5, atol=1e-6)
