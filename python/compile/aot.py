"""AOT-lower every ScaleSFL entry point to HLO text for the Rust runtime.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 (what the
published ``xla`` 0.1.6 crate links) rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out ../artifacts

Writes one ``<name>.hlo.txt`` per entry point plus ``manifest.txt`` with the
static dimensions the Rust coordinator needs (parsed by rust/src/runtime/).
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*dims):
    return jax.ShapeDtypeStruct(dims, jnp.float32)


def i32(*dims):
    return jax.ShapeDtypeStruct(dims, jnp.int32)


def entry_points():
    """(name, fn, example-arg specs) for every lowered executable."""
    P, K, BE = model.P_PAD, model.K, model.B_EVAL
    D = model.INPUT_DIM
    eps = [
        ("init_params", model.init_params, (i32(),)),
        ("eval_step", model.eval_step, (f32(P), f32(BE, D), i32(BE))),
        ("fedavg_agg", model.fedavg_agg, (f32(K, P), f32(K))),
        ("pairwise_dist", model.pairwise_dist, (f32(K, P),)),
        ("cosine_sim", model.cosine_sim, (f32(K, P),)),
        ("clip_updates", model.clip_updates, (f32(K, P), f32())),
    ]
    for b in model.TRAIN_BATCH_SIZES:
        eps.append((f"train_step_b{b}", model.train_step, (f32(P), f32(b, D), i32(b), f32())))
    eps.append(
        (
            "dp_train_step_b32",
            model.dp_train_step,
            (f32(P), f32(32, D), i32(32), f32(), i32(), f32(), f32()),
        )
    )
    return eps


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated subset of entry points")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    names = []
    for name, fn, specs in entry_points():
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        names.append(name)
        print(f"wrote {path} ({len(text)} chars)")

    manifest = [
        f"P={model.P}",
        f"P_PAD={model.P_PAD}",
        f"K={model.K}",
        f"B_EVAL={model.B_EVAL}",
        f"B_EVAL_BLOCK={model.B_EVAL_BLOCK}",
        f"INPUT_DIM={model.INPUT_DIM}",
        f"NUM_CLASSES={model.NUM_CLASSES}",
        "HIDDEN=" + ",".join(str(h) for h in model.HIDDEN),
        "TRAIN_BATCH_SIZES=" + ",".join(str(b) for b in model.TRAIN_BATCH_SIZES),
        "ARTIFACTS=" + ",".join(names),
    ]
    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote manifest ({len(names)} artifacts)")


if __name__ == "__main__":
    main()
