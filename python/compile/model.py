"""Layer-2 JAX model for ScaleSFL: the FL workload's compute graph.

An MLP classifier (784 -> 256 -> 128 -> 10, ~235k params) standing in for the
paper's MNIST CNN (DESIGN.md §2 substitutions).  Parameters cross the
Rust <-> HLO boundary as ONE flat f32[P_PAD] vector so the coordinator treats
models opaquely (hash, store, aggregate) exactly like the paper's off-chain
model blobs.

Entry points lowered by aot.py (all shapes static):

- init_params(seed)                                  -> (params,)
- train_step(params, x, y, lr)                       -> (params', loss)
- dp_train_step(params, x, y, lr, seed, clip, nm)    -> (params', loss)
- eval_step(params, x, y)                            -> (loss_sum, correct)
- fedavg_agg / pairwise_dist / cosine_sim / clip_updates over f32[K, P_PAD]

The forward pass used by eval_step runs through the Pallas ``dense`` kernel
(the endorsement bottleneck); train_step's update runs through the Pallas
``axpy`` kernel.  Gradients use jax.grad over the pure-jnp forward (Pallas
interpret-mode calls are kept out of the differentiated path).
"""

import jax
import jax.numpy as jnp

from .kernels import axpy as k_axpy
from .kernels import dense as k_dense
from .kernels import fedavg_agg as k_agg
from .kernels import gram as k_gram

# Architecture: input -> hidden ... -> classes.  Matches the paper's MNIST
# scale (B in {10, 20, 32}, eta_k = 1e-2).
INPUT_DIM = 784
HIDDEN = (256, 128)
NUM_CLASSES = 10
LAYERS = tuple(zip((INPUT_DIM,) + HIDDEN, HIDDEN + (NUM_CLASSES,)))

P = sum(i * o + o for i, o in LAYERS)  # exact parameter count
P_PAD = (P + 1023) // 1024 * 1024  # lane-aligned flat vector seen by Rust

K = 8  # stacked updates per aggregation/defence call (committee size)
B_EVAL = 256  # endorsement evaluation batch
B_EVAL_BLOCK = 2048  # fused multi-batch endorsement evaluation (perf path)
TRAIN_BATCH_SIZES = (10, 20, 32)  # paper's B in {10, 20} + DP default 32


def unflatten(flat: jnp.ndarray):
    """Split the flat (padded) parameter vector into [(W, b)] per layer."""
    params, off = [], 0
    for i, o in LAYERS:
        w = flat[off : off + i * o].reshape(i, o)
        off += i * o
        b = flat[off : off + o]
        off += o
        params.append((w, b))
    return params


def flatten(params) -> jnp.ndarray:
    """Inverse of unflatten; re-pads to P_PAD with zeros."""
    parts = []
    for w, b in params:
        parts.append(w.reshape(-1))
        parts.append(b)
    flat = jnp.concatenate(parts)
    return jnp.pad(flat, (0, P_PAD - P))


def forward(flat: jnp.ndarray, x: jnp.ndarray, use_pallas: bool) -> jnp.ndarray:
    """MLP logits.  use_pallas routes each layer through the L1 dense kernel."""
    params = unflatten(flat)
    h = x
    for li, (w, b) in enumerate(params):
        relu = li < len(params) - 1
        if use_pallas:
            h = k_dense.dense(h, w, b, relu=relu)
        else:
            h = h @ w + b[None, :]
            if relu:
                h = jnp.maximum(h, 0.0)
    return h


def _ce_loss(flat: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Mean cross-entropy over the batch (paper Eq. 2)."""
    logits = forward(flat, x, use_pallas=False)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=1))


def init_params(seed: jnp.ndarray) -> tuple:
    """He-initialised parameters from an int32 seed.  -> (f32[P_PAD],)"""
    key = jax.random.PRNGKey(seed.astype(jnp.uint32))
    params = []
    for i, o in LAYERS:
        key, wk = jax.random.split(key)
        w = jax.random.normal(wk, (i, o), jnp.float32) * jnp.sqrt(2.0 / i)
        params.append((w, jnp.zeros((o,), jnp.float32)))
    return (flatten(params),)


def train_step(flat, x, y, lr) -> tuple:
    """One local SGD minibatch step (paper Eq. 3).  -> (params', loss)."""
    loss, g = jax.value_and_grad(_ce_loss)(flat, x, y)
    return k_axpy.axpy(flat, g, lr), loss


def dp_train_step(flat, x, y, lr, seed, clip, noise_mult) -> tuple:
    """DP-SGD minibatch step: clip the batch gradient to ``clip`` and add
    Gaussian noise scaled by ``noise_mult * clip / B``.

    Batch-level clipping approximates Opacus' per-sample clipping at equal
    noise calibration (documented substitution, DESIGN.md §2); the paper's
    settings are (eps, delta) = (5, 1e-5), noise 0.4, clip 1.2.
    """
    loss, g = jax.value_and_grad(_ce_loss)(flat, x, y)
    gnorm = jnp.sqrt(jnp.sum(g * g))
    g = g * jnp.minimum(1.0, clip / jnp.maximum(gnorm, 1e-12))
    key = jax.random.PRNGKey(seed.astype(jnp.uint32))
    noise = jax.random.normal(key, g.shape, jnp.float32)
    g = g + noise * (noise_mult * clip / x.shape[0])
    # Keep the padding region exactly zero so flat vectors stay canonical.
    mask = (jnp.arange(P_PAD) < P).astype(jnp.float32)
    return k_axpy.axpy(flat, g * mask, lr), loss


def eval_step(flat, x, y) -> tuple:
    """Endorsement-time evaluation on one batch -> (loss_sum, correct_count).

    Runs the Pallas dense kernel forward — this is the per-transaction cost
    the paper's throughput figures are bottlenecked on.
    """
    logits = forward(flat, x, use_pallas=True)
    logp = jax.nn.log_softmax(logits, axis=-1)
    y32 = y[:, None].astype(jnp.int32)
    loss_sum = -jnp.sum(jnp.take_along_axis(logp, y32, axis=1))
    correct = jnp.sum((jnp.argmax(logits, axis=-1).astype(jnp.int32) == y.astype(jnp.int32)).astype(jnp.int32))
    return loss_sum, correct


def fedavg_agg(stack, weights) -> tuple:
    """Weighted FedAvg aggregation over K stacked updates (Eq. 6-7)."""
    return (k_agg.fedavg_agg(stack, weights),)


def pairwise_dist(stack) -> tuple:
    """Multi-Krum squared-distance matrix over K stacked updates."""
    return (k_gram.pairwise_dist(stack),)


def cosine_sim(stack) -> tuple:
    """FoolsGold cosine-similarity matrix over K stacked updates."""
    return (k_gram.cosine_sim(stack),)


def clip_updates(stack, max_norm) -> tuple:
    """Norm-constraint clipping -> (clipped stack, per-row norms)."""
    clipped, norms = k_gram.clip_updates(stack, max_norm)
    return clipped, norms
