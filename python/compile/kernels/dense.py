"""Pallas kernel: fused dense + bias + ReLU tile.

The paper's endorsement bottleneck is the forward evaluation of a submitted
model on each endorsing peer's local test split; this kernel is that forward
pass's building block, fused so each (BB, BO) output tile is produced in one
VMEM-resident step.

TPU mapping: grid over (B/BB, O/BO) output tiles; each step loads an
(BB, I) activation tile and an (I, BO) weight tile (I is kept un-tiled — the
MLP's largest I=784 tile is ~0.4 MiB « VMEM), does one MXU matmul in f32,
adds the bias row and applies ReLU in-register before the VMEM->HBM writeback.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 256
BLOCK_O = 256


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, relu: bool):
    y = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    y = y + b_ref[...][None, :]
    o_ref[...] = jnp.maximum(y, 0.0) if relu else y


@functools.partial(jax.jit, static_argnames=("relu", "block_b", "block_o"))
def dense(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    relu: bool = False,
    block_b: int = BLOCK_B,
    block_o: int = BLOCK_O,
) -> jnp.ndarray:
    """relu?(x @ w + b) with x: f32[B, I], w: f32[I, O], b: f32[O].

    B, I, O need not be tile-aligned; inputs are zero-padded internally and
    the result sliced back (zero padding is exact for matmul+bias+ReLU as the
    padded bias entries are zero).
    """
    bsz, i = x.shape
    i2, o = w.shape
    assert i == i2 and b.shape == (o,)
    bb = min(block_b, _round_up(bsz, 8))
    bo = min(block_o, _round_up(o, 128))
    b_pad, o_pad = _round_up(bsz, bb), _round_up(o, bo)
    if b_pad != bsz:
        x = jnp.pad(x, ((0, b_pad - bsz), (0, 0)))
    if o_pad != o:
        w = jnp.pad(w, ((0, 0), (0, o_pad - o)))
        b = jnp.pad(b, (0, o_pad - o))
    out = pl.pallas_call(
        functools.partial(_dense_kernel, relu=relu),
        grid=(b_pad // bb, o_pad // bo),
        in_specs=[
            pl.BlockSpec((bb, i), lambda r, c: (r, 0)),
            pl.BlockSpec((i, bo), lambda r, c: (0, c)),
            pl.BlockSpec((bo,), lambda r, c: (c,)),
        ],
        out_specs=pl.BlockSpec((bb, bo), lambda r, c: (r, c)),
        out_shape=jax.ShapeDtypeStruct((b_pad, o_pad), jnp.float32),
        interpret=True,
    )(x, w, b)
    return out[:bsz, :o]


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m
