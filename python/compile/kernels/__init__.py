"""Layer-1 Pallas kernels for ScaleSFL.

All kernels run with ``interpret=True`` so the emitted HLO contains only
portable ops executable by the CPU PJRT client the Rust coordinator uses
(real-TPU lowering would emit Mosaic custom-calls the CPU plugin rejects).

Kernel inventory (see DESIGN.md §3):

- :mod:`fedavg_agg` — weighted aggregation of stacked flat updates (Eq. 6-7).
- :mod:`gram`       — tiled Gram-matrix accumulation powering the Multi-Krum
  pairwise distances, FoolsGold cosine similarities, and norm-constraint
  clipping used by the endorsement defence policies.
- :mod:`dense`      — fused dense+bias+ReLU tile used by the endorsement-time
  model evaluation forward pass (the paper's measured bottleneck).
- :mod:`axpy`       — elementwise SGD parameter update over flat params.
"""

from . import axpy, dense, fedavg_agg, gram, ref  # noqa: F401
