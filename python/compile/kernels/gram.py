"""Pallas kernel: tiled Gram-matrix accumulation over stacked flat updates.

G[i, j] = <u_i, u_j> for K flattened client updates f32[K, P].  One kernel
powers three endorsement-policy primitives (DESIGN.md §3):

- Multi-Krum pairwise squared distances  D = diag+diag^T-2G
- FoolsGold cosine similarities          S = G / (||u_i|| ||u_j||)
- norm-constraint clipping               ||u_k||^2 = G[k, k]

TPU mapping: the P axis is tiled into lane-aligned BLOCK_P chunks; each grid
step loads one (K, BLOCK_P) VMEM tile and accumulates an (K, K) MXU outer
product into the output block, which stays resident across the whole grid
(index_map pins it to (0, 0)).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_P = 131072


def _gram_kernel(x_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xb = x_ref[...]
    o_ref[...] += jnp.dot(xb, xb.T, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_p",))
def gram(stack: jnp.ndarray, block_p: int = BLOCK_P) -> jnp.ndarray:
    """Gram matrix of K stacked flat updates.  f32[K, P] -> f32[K, K]."""
    k, p = stack.shape
    block_p = min(block_p, _round_up(p, 128))
    p_pad = _round_up(p, block_p)
    if p_pad != p:
        stack = jnp.pad(stack, ((0, 0), (0, p_pad - p)))
    return pl.pallas_call(
        _gram_kernel,
        grid=(p_pad // block_p,),
        in_specs=[pl.BlockSpec((k, block_p), lambda i: (0, i))],
        out_specs=pl.BlockSpec((k, k), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, k), jnp.float32),
        interpret=True,
    )(stack)


def pairwise_dist(stack: jnp.ndarray) -> jnp.ndarray:
    """Squared-L2 distance matrix (Multi-Krum).  f32[K, P] -> f32[K, K]."""
    g = gram(stack)
    sq = jnp.diagonal(g)
    return jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * g, 0.0)


def cosine_sim(stack: jnp.ndarray, eps: float = 1e-9) -> jnp.ndarray:
    """Cosine-similarity matrix (FoolsGold).  f32[K, P] -> f32[K, K]."""
    g = gram(stack)
    n = jnp.sqrt(jnp.maximum(jnp.diagonal(g), 0.0))
    return g / (n[:, None] * n[None, :] + eps)


def clip_updates(stack: jnp.ndarray, max_norm) -> tuple:
    """Norm-constraint defence over stacked updates.

    Returns (clipped f32[K, P], norms f32[K]); rows whose L2 norm exceeds
    ``max_norm`` are scaled down to it.
    """
    norms = jnp.sqrt(jnp.maximum(jnp.diagonal(gram(stack)), 0.0))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norms, 1e-12))
    return stack * scale[:, None], norms


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m
