"""Pure-jnp oracles for every Pallas kernel.

These are the CORE correctness signal: pytest (and hypothesis sweeps in
``python/tests``) assert each Pallas kernel matches its oracle to
``assert_allclose`` tolerance across shapes and inputs.
"""

import jax.numpy as jnp


def fedavg_agg(stack: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """Weighted aggregation of K stacked flat updates.

    stack: f32[K, P], weights: f32[K] -> f32[P]  (Eq. 6-7 of the paper;
    weights are |D_k|/|D| shares normalised by the caller).
    """
    return jnp.einsum("k,kp->p", weights, stack)


def gram(stack: jnp.ndarray) -> jnp.ndarray:
    """Gram matrix G[i, j] = <stack[i], stack[j]>.  f32[K, P] -> f32[K, K]."""
    return stack @ stack.T


def pairwise_dist(stack: jnp.ndarray) -> jnp.ndarray:
    """Squared-L2 distance matrix (Multi-Krum).  f32[K, P] -> f32[K, K]."""
    g = gram(stack)
    sq = jnp.diagonal(g)
    d = sq[:, None] + sq[None, :] - 2.0 * g
    return jnp.maximum(d, 0.0)


def cosine_sim(stack: jnp.ndarray, eps: float = 1e-9) -> jnp.ndarray:
    """Cosine-similarity matrix (FoolsGold).  f32[K, P] -> f32[K, K]."""
    g = gram(stack)
    n = jnp.sqrt(jnp.maximum(jnp.diagonal(g), 0.0))
    return g / (n[:, None] * n[None, :] + eps)


def row_norms(stack: jnp.ndarray) -> jnp.ndarray:
    """L2 norm of each stacked update.  f32[K, P] -> f32[K]."""
    return jnp.sqrt(jnp.sum(stack * stack, axis=1))


def clip_updates(stack: jnp.ndarray, max_norm) -> tuple:
    """Norm-constraint defence: scale rows with ||row|| > max_norm down to it.

    Returns (clipped f32[K, P], norms f32[K]).
    """
    norms = row_norms(stack)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norms, 1e-12))
    return stack * scale[:, None], norms


def dense(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, relu: bool) -> jnp.ndarray:
    """Fused dense layer: relu?(x @ w + b).  f32[B,I] x f32[I,O] -> f32[B,O]."""
    y = x @ w + b[None, :]
    return jnp.maximum(y, 0.0) if relu else y


def axpy(p: jnp.ndarray, g: jnp.ndarray, lr) -> jnp.ndarray:
    """SGD update p - lr * g over flat parameter vectors.  f32[P] -> f32[P]."""
    return p - lr * g
