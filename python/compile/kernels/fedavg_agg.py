"""Pallas kernel: weighted aggregation of stacked flat model updates.

This is the shard/global aggregation hot-spot (paper Eq. 6-7): given K client
updates flattened to f32[K, P] and normalised weights |D_k|/|D| f32[K],
produce the aggregated flat update f32[P].

TPU mapping (DESIGN.md §Hardware-Adaptation): the flat parameter axis is tiled
into lane-aligned blocks of ``BLOCK_P`` (multiple of 128) streamed HBM->VMEM
via BlockSpec; K=8 rides the sublane dimension so each grid step is one
(8, BLOCK_P) VMEM tile and a (1,8)x(8,BLOCK_P) matvec on the MXU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_P = 131072


def _agg_kernel(x_ref, w_ref, o_ref):
    # (K,) . (K, BLOCK_P) -> (BLOCK_P,) weighted sum of client rows.
    o_ref[...] = jnp.dot(w_ref[...], x_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_p",))
def fedavg_agg(stack: jnp.ndarray, weights: jnp.ndarray, block_p: int = BLOCK_P) -> jnp.ndarray:
    """Aggregate K stacked flat updates with the given weights.

    stack: f32[K, P] (P need not be block-aligned; padded internally),
    weights: f32[K] -> f32[P].
    """
    k, p = stack.shape
    block_p = min(block_p, _round_up(p, 128))
    p_pad = _round_up(p, block_p)
    if p_pad != p:
        stack = jnp.pad(stack, ((0, 0), (0, p_pad - p)))
    out = pl.pallas_call(
        _agg_kernel,
        grid=(p_pad // block_p,),
        in_specs=[
            pl.BlockSpec((k, block_p), lambda i: (0, i)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_p,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p_pad,), jnp.float32),
        interpret=True,
    )(stack, weights)
    return out[:p]


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m
