"""Pallas kernel: elementwise SGD parameter update p - lr * g.

Applied to the flat f32[P] parameter vector each local minibatch step; tiled
into lane-aligned BLOCK_P chunks so the HBM->VMEM->HBM stream is the only
memory traffic (the update itself is a fused multiply-add on the VPU).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_P = 65536


def _axpy_kernel(p_ref, g_ref, lr_ref, o_ref):
    o_ref[...] = p_ref[...] - lr_ref[0] * g_ref[...]


@functools.partial(jax.jit, static_argnames=("block_p",))
def axpy(p: jnp.ndarray, g: jnp.ndarray, lr, block_p: int = BLOCK_P) -> jnp.ndarray:
    """SGD update over flat params: p - lr * g.  f32[P] -> f32[P]."""
    (n,) = p.shape
    block_p = min(block_p, _round_up(n, 128))
    n_pad = _round_up(n, block_p)
    lr_arr = jnp.asarray(lr, jnp.float32).reshape((1,))
    if n_pad != n:
        p = jnp.pad(p, (0, n_pad - n))
        g = jnp.pad(g, (0, n_pad - n))
    out = pl.pallas_call(
        _axpy_kernel,
        grid=(n_pad // block_p,),
        in_specs=[
            pl.BlockSpec((block_p,), lambda i: (i,)),
            pl.BlockSpec((block_p,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_p,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.float32),
        interpret=True,
    )(p, g, lr_arr)
    return out[:n]


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m
