//! Focused PJRT perf probe used by the §Perf optimization loop.
use std::time::Instant;
use scalesfl::util::prng::Prng;

fn time<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    f();
    let t = Instant::now();
    for _ in 0..iters { f(); }
    println!("{name:<36} {:>10.3} ms/iter", t.elapsed().as_secs_f64() / iters as f64 * 1e3);
}

fn main() {
    let ops = scalesfl::runtime::shared_ops().expect("artifacts");
    let params = ops.init_params(0).unwrap();
    let dim = ops.input_dim();
    let mut prng = Prng::new(11);
    let x: Vec<f32> = (0..32 * dim).map(|_| prng.normal() as f32).collect();
    let y: Vec<i32> = (0..32).map(|_| prng.below(10) as i32).collect();
    let mut p = params.clone();
    time("train_step (b=32)", 50, || { let (n, _) = ops.train_step(p.clone(), &x, &y, 0.01).unwrap(); p = n; });
    let ex: Vec<f32> = (0..2048 * dim).map(|_| prng.normal() as f32).collect();
    let ey: Vec<i32> = (0..2048).map(|_| prng.below(10) as i32).collect();
    time("eval (2048 samples)", 10, || { ops.evaluate(&params, &ex, &ey).unwrap(); });
    let refs: Vec<&Vec<f32>> = (0..ops.k()).map(|_| &params).collect();
    let w = vec![1.0f64; ops.k()];
    time("fedavg_agg (K=8)", 30, || { ops.fedavg_agg(&refs, &w).unwrap(); });
    time("pairwise_dist (K=8)", 30, || { ops.pairwise_dist(&refs).unwrap(); });
    time("cosine_sim (K=8)", 30, || { ops.cosine_sim(&refs).unwrap(); });
}
