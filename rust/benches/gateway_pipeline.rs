//! Closed-loop vs open-loop committed throughput through one shard.
//!
//! The closed-loop driver (sequential `submit_and_wait`) pays the full
//! batch-timeout + ordering latency per transaction; the open-loop driver
//! (`submit_all` at in-flight depths 1/8/64) keeps the mempool fed so the
//! orderer cuts full blocks back-to-back. Emits the committed-TPS
//! trajectory to `BENCH_gateway.json` (shed/reject counts reported, never
//! dropped) so the concurrency win is tracked across PRs — the depth-64
//! open loop is expected to clear 3x the closed-loop baseline on the same
//! topology. `--smoke` runs a shorter deterministic workload and writes
//! `target/smoke/BENCH_gateway.json` for the CI bench gate.
//!
//!     cargo bench --bench gateway_pipeline [-- --smoke]    (or `make bench`)

use std::sync::Arc;
use std::time::{Duration, Instant};

use scalesfl::crypto::msp::{CertificateAuthority, MemberId};
use scalesfl::fabric::chaincode::{Chaincode, TxContext};
use scalesfl::fabric::endorsement::EndorsementPolicy;
use scalesfl::fabric::orderer::{OrdererConfig, OrderingService};
use scalesfl::fabric::peer::Peer;
use scalesfl::fabric::{CommitOutcome, Gateway};
use scalesfl::ledger::tx::Proposal;
use scalesfl::util::json::Json;
use scalesfl::util::prng::Prng;

struct PutCc;
impl Chaincode for PutCc {
    fn name(&self) -> &str {
        "kv"
    }
    fn invoke(
        &self,
        ctx: &mut TxContext<'_>,
        _f: &str,
        args: &[String],
    ) -> Result<Vec<u8>, String> {
        ctx.put(&args[0], b"v".to_vec());
        Ok(vec![])
    }
}

/// One shard: 2 endorsing peers, default mempool, 16-tx blocks with a
/// 20 ms batch timeout (what a lone closed-loop tx always waits for).
fn shard() -> (Vec<Arc<Peer>>, Gateway) {
    let ca = CertificateAuthority::new();
    let mut rng = Prng::new(17);
    let peers: Vec<Arc<Peer>> = (0..2)
        .map(|i| {
            let cred = ca.enroll(MemberId::new(format!("org{i}.peer")), &mut rng);
            Peer::new(cred, ca.clone())
        })
        .collect();
    let members: Vec<MemberId> = peers.iter().map(|p| p.member.clone()).collect();
    for p in &peers {
        p.join_channel("shard0", EndorsementPolicy::MajorityOf(members.clone()));
        p.install_chaincode("shard0", Arc::new(PutCc)).unwrap();
    }
    let orderer = OrderingService::start(
        OrdererConfig {
            batch_size: 16,
            batch_timeout: Duration::from_millis(20),
            tick: Duration::from_millis(2),
            ..Default::default()
        },
        peers.clone(),
        17,
    );
    (peers.clone(), Gateway::new(peers, orderer))
}

fn proposal(run: &str, i: usize) -> Proposal {
    Proposal {
        channel: "shard0".into(),
        chaincode: "kv".into(),
        function: "Put".into(),
        args: vec![format!("{run}-k{i}")],
        creator: MemberId::new("bench-client"),
        nonce: i as u64,
    }
}

fn tally(name: &str, outcomes: &[CommitOutcome], wall: f64) -> Json {
    let committed = outcomes.iter().filter(|o| o.is_valid()).count();
    let shed = outcomes.iter().filter(|o| o.is_rejected()).count();
    let failed = outcomes.len() - committed - shed;
    let tps = committed as f64 / wall.max(1e-9);
    println!(
        "{name:<28} committed={committed:<4} shed={shed:<3} failed={failed:<3} wall={wall:>6.2}s   {tps:>8.1} committed-TPS"
    );
    Json::obj()
        .set("committed", committed)
        .set("shed", shed)
        .set("failed", failed)
        .set("wall_s", wall)
        .set("committed_tps", tps)
}

/// Sequential `submit_and_wait`: one transaction in flight, ever.
fn closed_loop(txs: usize) -> Json {
    let (_peers, gw) = shard();
    let t0 = Instant::now();
    let outcomes: Vec<CommitOutcome> =
        (0..txs).map(|i| gw.submit_and_wait(&proposal("closed", i))).collect();
    tally("closed-loop (submit_and_wait)", &outcomes, t0.elapsed().as_secs_f64())
}

/// `submit_all` with a bounded in-flight window on a fresh, identical
/// topology per depth (comparable chains, no cross-run dedup effects).
fn open_loop(txs: usize, depth: usize) -> Json {
    let (_peers, gw) = shard();
    let run = format!("open{depth}");
    let proposals: Vec<Proposal> = (0..txs).map(|i| proposal(&run, i)).collect();
    let t0 = Instant::now();
    let outcomes = gw.submit_all(&proposals, depth);
    let j = tally(
        &format!("open-loop depth={depth} (submit_all)"),
        &outcomes,
        t0.elapsed().as_secs_f64(),
    );
    j.set("depth", depth).set("in_flight_high_water", gw.in_flight_high_water())
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let txs = if smoke { 48 } else { 120 };
    println!(
        "# gateway pipeline bench{} — closed-loop vs open-loop submission\n",
        if smoke { " (smoke)" } else { "" }
    );
    let closed = closed_loop(txs);
    let depths = [1usize, 8, 64];
    let mut open = Vec::new();
    for &d in &depths {
        open.push(open_loop(txs, d));
    }

    let closed_tps = closed.get("committed_tps").and_then(|v| v.as_f64()).unwrap_or(0.0);
    let deep_tps =
        open.last().and_then(|j| j.get("committed_tps")).and_then(|v| v.as_f64()).unwrap_or(0.0);
    let speedup = deep_tps / closed_tps.max(1e-9);
    println!(
        "\nverdict: depth-64 open loop at {speedup:.1}x the closed-loop baseline (expect >= 3x)"
    );

    let headline = Json::Arr(vec![
        Json::obj()
            .set("metric", "speedup_depth64_vs_closed")
            .set("value", speedup)
            .set("higher_is_better", true),
        Json::obj()
            .set("metric", "closed_loop_committed_tps")
            .set("value", closed_tps)
            .set("higher_is_better", true),
    ]);
    let out = Json::obj()
        .set("bench", "gateway_pipeline")
        .set("mode", if smoke { "smoke" } else { "full" })
        .set("txs", txs)
        .set("closed_loop", closed)
        .set("open_loop", open)
        .set("speedup_depth64_vs_closed", speedup)
        .set("headline", headline);
    let path = if smoke {
        std::fs::create_dir_all("target/smoke").expect("create target/smoke");
        "target/smoke/BENCH_gateway.json"
    } else {
        "BENCH_gateway.json"
    };
    std::fs::write(path, format!("{out}\n")).expect("write BENCH_gateway.json");
    println!("wrote {path}");
}
