//! Fig. 8 reproduction: #caliper workers vs throughput + average latency
//! (200 txs, sent TPS at the observed maximum).
//!
//! Paper result: noisy but generally *downward* throughput trend with more
//! workers (single-threaded endorsement workers are the bottleneck; extra
//! load generators only add queueing), and latency trends upward; shard
//! count groups the latency curves.

use scalesfl::caliper::figures;

fn main() {
    let quick = !figures::full_requested();
    let Some(env) = figures::env(quick) else {
        eprintln!("artifacts not built — run `make artifacts` first");
        return;
    };
    println!("# Fig 8 — caliper workers vs throughput & latency");
    println!(
        "{:<8} {:<8} {:>12} {:>14} {:>8}",
        "shards", "workers", "tput(TPS)", "avgLat(s)", "fail"
    );
    for (shards, workers, r) in figures::fig8(&env) {
        println!(
            "{:<8} {:<8} {:>12.3} {:>14.3} {:>8}",
            shards,
            workers,
            r.throughput,
            r.avg_latency(),
            r.failed
        );
    }
    println!("# expected shape: no capacity gain from workers; latency up; shard count dominates");
}
