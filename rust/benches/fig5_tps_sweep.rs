//! Fig. 5 reproduction: sent TPS vs observed throughput and average
//! latency — the saturation knee per shard count.
//!
//! Paper result: throughput tracks sent TPS until the shard capacity, then
//! plateaus while average latency spikes; more shards move the knee right.

use scalesfl::caliper::figures;

fn main() {
    let quick = !figures::full_requested();
    let Some(env) = figures::env(quick) else {
        eprintln!("artifacts not built — run `make artifacts` first");
        return;
    };
    println!("# Fig 5 — sent TPS vs throughput & avg latency (calibrated eval_s = {:.4}s)", env.base.eval_s);
    println!(
        "{:<8} {:>12} {:>12} {:>14} {:>8}",
        "shards", "sent(TPS)", "tput(TPS)", "avgLat(s)", "fail"
    );
    for (shards, sent, r) in figures::fig5(&env) {
        println!(
            "{:<8} {:>12.3} {:>12.3} {:>14.3} {:>8}",
            shards,
            sent,
            r.throughput,
            r.avg_latency(),
            r.failed
        );
    }
    println!("# expected shape: tput == sent below the knee, then flat; latency jumps at the knee");
}
