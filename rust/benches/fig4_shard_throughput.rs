//! Fig. 4 reproduction: #shards vs system throughput (TPS).
//!
//! Paper result: throughput scales linearly with the number of shards
//! (each shard's committee evaluates its own transactions in parallel).
//! DES with service times calibrated from live PJRT endorsement evals.
//!
//! Run: `cargo bench --bench fig4_shard_throughput` (SCALESFL_FULL=1 for
//! paper-scale workloads).

use scalesfl::caliper::figures;

fn main() {
    let quick = !figures::full_requested();
    let Some(env) = figures::env(quick) else {
        eprintln!("artifacts not built — run `make artifacts` first");
        return;
    };
    println!(
        "# Fig 4 — #shards vs throughput (calibrated eval_s = {:.4}s over {} samples)",
        env.base.eval_s, env.cal.samples
    );
    let rows = figures::fig4(&env);
    println!("{:<8} {:>12} {:>12} {:>10}", "shards", "tput(TPS)", "sent(TPS)", "fail");
    let t1 = rows[0].1.throughput;
    for (shards, r) in &rows {
        println!(
            "{:<8} {:>12.3} {:>12.3} {:>10}   (x{:.2} vs 1 shard)",
            shards,
            r.throughput,
            r.send_tps,
            r.failed,
            r.throughput / t1
        );
    }
    let t8 = rows.last().unwrap().1.throughput;
    println!("# linear-scaling check: 8-shard/1-shard throughput ratio = {:.2} (paper: ~8)", t8 / t1);
}
