//! Consensus bench: replica clusters driven over the simulated network in
//! virtual time. Emits `BENCH_consensus.json` (or
//! `target/smoke/BENCH_consensus.json` in `--smoke` mode — the fast
//! deterministic configuration the CI bench gate runs and compares against
//! `bench-baselines/`).
//!
//! Because every scenario advances a virtual clock through the
//! `consensus::transport` latency oracle, the reported numbers are
//! *simulated* seconds — a function of link latency, election timers, and
//! protocol round-trips, not of host speed. That makes the headlines
//! machine-independent and tight enough to gate at 20%:
//!
//! * steady-state commit latency vs shard count (independent Raft shards on
//!   WAN links — the paper's claim that sharding scales throughput while
//!   per-shard latency stays flat),
//! * leader-crash-mid-surge recovery time (election + re-proposal until the
//!   first post-crash commit),
//! * a PBFT fault sweep at f of 3f+1: crashed backups, a crashed primary
//!   (view-change recovery), an equivocating primary (containment), and the
//!   f+1 over-budget stall that must *not* commit.
//!
//! Every scenario also proves the zero-loss transport invariant: sent =
//! delivered + fault_dropped + in_flight, i.e. the driver never drops a
//! replica message on the floor.
//!
//!     cargo bench --bench consensus [-- --smoke]    (or `make bench`)

use std::collections::HashSet;

use scalesfl::consensus::pbft::{self, Pbft, PbftConfig};
use scalesfl::consensus::raft::{Raft, RaftConfig};
use scalesfl::consensus::{Cluster, ClusterStats, ConsensusNode, Fault, FaultPlan, TransportConfig};
use scalesfl::util::json::Json;
use scalesfl::util::prng::Prng;

const SEED: u64 = 0xC0D5EED;
/// Virtual driver tick, mirroring the orderer's real 2ms cadence but in
/// simulated time.
const TICK_S: f64 = 0.005;

fn raft_cluster(n: usize, seed: u64, net: &TransportConfig, plan: &FaultPlan) -> Cluster<Raft> {
    let mut rng = Prng::new(seed);
    let nodes: Vec<Raft> = (0..n)
        .map(|i| Raft::new(i, n, RaftConfig::default(), rng.fork(i as u64)))
        .collect();
    Cluster::new(nodes, net, plan)
}

fn pbft_cluster(n: usize, net: &TransportConfig, plan: &FaultPlan) -> Cluster<Pbft> {
    let nodes: Vec<Pbft> = (0..n).map(|i| Pbft::new(i, n, PbftConfig::default())).collect();
    let mut cluster = Cluster::new(nodes, net, plan);
    if plan.has_equivocation() {
        cluster.set_mutator(Box::new(pbft::equivocate));
    }
    cluster
}

struct Outcome {
    /// Unique scheduled payloads that committed.
    committed: usize,
    /// Committed payloads that were never scheduled (an equivocator's
    /// forged variants — contained garbage, counted but not delivered).
    alien: usize,
    commit_p95_ms: f64,
    /// Time from `mark` until the cluster has a usable leader again after
    /// losing it — the election / view-change window itself, which is far
    /// more stable to gate on than commit gaps (entries already in flight
    /// at a crash can still commit moments later). 0 when leadership was
    /// never lost after the mark (steady rows, the over-budget stall).
    recovery_s: f64,
    /// Virtual time of the last scheduled commit.
    last_commit_s: f64,
    stats: ClusterStats,
}

/// Drive one cluster through a submission schedule in virtual time,
/// mimicking the orderer driver: queue while leaderless (broadcasting the
/// request so PBFT backups' timers run), propose when a leader exists, and
/// re-propose everything uncommitted after an epoch change. Duplicate
/// commits from re-proposals are deduped exactly like the committer's
/// DuplicateTxId verdicts collapse replays.
fn drive<C: ConsensusNode>(
    cluster: &mut Cluster<C>,
    channel: &str,
    schedule: &[(f64, Vec<u8>)],
    until: f64,
    mark: f64,
) -> Outcome {
    let scheduled: HashSet<Vec<u8>> = schedule.iter().map(|(_, p)| p.clone()).collect();
    let mut committed: HashSet<Vec<u8>> = HashSet::new();
    let mut alien = 0usize;
    let mut recovery_s = 0.0f64;
    let mut leader_was_absent = false;
    let mut last_commit_s = 0.0f64;
    let mut next = 0usize;
    let mut unproposed: Vec<Vec<u8>> = Vec::new();
    let mut proposed: Vec<Vec<u8>> = Vec::new();
    let mut reproposed_epoch = 0u64;

    let mut now = 0.0f64;
    while now <= until {
        now += TICK_S;
        cluster.tick(now);
        while next < schedule.len() && schedule[next].0 <= now {
            unproposed.push(schedule[next].1.clone());
            next += 1;
        }
        if now >= mark && recovery_s == 0.0 {
            if cluster.leader().is_none() {
                leader_was_absent = true;
            } else if leader_was_absent {
                recovery_s = now - mark;
            }
        }
        let epoch = cluster.epoch();
        if epoch > reproposed_epoch {
            // Leadership moved: everything accepted-but-uncommitted goes
            // back through propose on the new leader.
            unproposed.append(&mut proposed);
            reproposed_epoch = epoch;
        }
        if cluster.leader().is_some() {
            while let Some(payload) = unproposed.first().cloned() {
                if cluster.propose(channel, payload.clone(), now).is_err() {
                    break;
                }
                unproposed.remove(0);
                proposed.push(payload);
            }
        } else {
            // Client broadcast: lets PBFT backups see the pending request
            // (their timers force the view change); Raft replicas ignore it.
            for payload in &unproposed {
                cluster.broadcast_request(channel, payload.clone(), now);
            }
        }
        for data in cluster.take_committed(now) {
            if !scheduled.contains(&data) {
                alien += 1;
                continue;
            }
            if committed.insert(data.clone()) {
                last_commit_s = now;
                unproposed.retain(|p| *p != data);
                proposed.retain(|p| *p != data);
            }
        }
        if committed.len() == scheduled.len() && next == schedule.len() {
            break;
        }
    }

    let stats = cluster.stats();
    assert_eq!(stats.driver_lost(), 0, "transport lost messages: {stats:?}");
    assert_eq!(stats.divergence, 0, "replicas diverged on a committed slot: {stats:?}");
    Outcome {
        committed: committed.len(),
        alien,
        commit_p95_ms: cluster.commit_latency_p95(channel).unwrap_or(0.0) * 1e3,
        recovery_s,
        last_commit_s,
        stats,
    }
}

fn paced(label: &str, n: usize, start: f64, gap: f64) -> Vec<(f64, Vec<u8>)> {
    (0..n)
        .map(|i| (start + gap * i as f64, format!("tx-{label}-{i}").into_bytes()))
        .collect()
}

/// Steady-state: `shards` independent 5-node Raft shards on WAN links, each
/// ordering its own paced stream. Latency is per-shard (flat in the shard
/// count); simulated throughput scales with it.
fn sharding_row(shards: usize, per_shard: usize) -> (f64, f64, Json) {
    let mut worst_p95 = 0.0f64;
    let mut last_commit = 0.0f64;
    let mut sent = 0u64;
    let mut lost = 0u64;
    for s in 0..shards {
        let seed = SEED ^ (s as u64).wrapping_mul(0x9E37);
        let net = TransportConfig::wan(seed);
        let mut cluster = raft_cluster(5, seed, &net, &FaultPlan::default());
        let schedule = paced(&format!("s{shards}x{s}"), per_shard, 0.5, 0.05);
        let out = drive(&mut cluster, "shard", &schedule, 30.0, f64::INFINITY);
        assert_eq!(out.committed, per_shard, "shard {s}/{shards} lost transactions");
        worst_p95 = worst_p95.max(out.commit_p95_ms);
        last_commit = last_commit.max(out.last_commit_s);
        sent += out.stats.transport.sent;
        lost += out.stats.driver_lost();
    }
    let tps = (shards * per_shard) as f64 / last_commit;
    println!(
        "shards={shards:<2} txs={:<4} worst p95={worst_p95:>6.1}ms sim_tps={tps:>7.1} sent={sent}",
        shards * per_shard
    );
    let row = Json::obj()
        .set("shards", shards)
        .set("nodes_per_shard", 5usize)
        .set("txs", shards * per_shard)
        .set("commit_p95_ms", worst_p95)
        .set("sim_tps", tps)
        .set("messages_sent", sent)
        .set("driver_lost", lost);
    (worst_p95, tps, row)
}

/// Leader crash in the middle of a paced surge: recovery time is the
/// window from the crash until the survivors elect a usable leader again;
/// the tail of the surge (plus everything stranded uncommitted in the dead
/// leader's log) must still commit through re-proposal.
fn leader_crash_row(txs: usize) -> (f64, Json) {
    let crash_at = 1.0;
    let net = TransportConfig::wan(SEED ^ 0xCAFE);
    let plan = FaultPlan::new(SEED).at(crash_at, Fault::CrashLeader);
    let mut cluster = raft_cluster(5, SEED ^ 0xCAFE, &net, &plan);
    let schedule = paced("crash", txs, 0.3, 0.025);
    let out = drive(&mut cluster, "surge", &schedule, 30.0, crash_at);
    assert_eq!(out.committed, txs, "surge transactions lost across the crash");
    assert!(out.stats.epoch_changes >= 2, "crash must force a new election: {:?}", out.stats);
    assert!(out.recovery_s > 0.0, "leadership was never observed lost after the crash");
    println!(
        "leader-crash n=5 txs={txs:<3} recovery={:>5.3}s p95={:>6.1}ms elections={}",
        out.recovery_s,
        out.commit_p95_ms,
        out.stats.epoch_changes
    );
    let recovery = out.recovery_s;
    let row = Json::obj()
        .set("scenario", "leader_crash_mid_surge")
        .set("nodes", 5usize)
        .set("txs", txs)
        .set("committed", out.committed)
        .set("recovery_s", recovery)
        .set("commit_p95_ms", out.commit_p95_ms)
        .set("epoch_changes", out.stats.epoch_changes)
        .set("driver_lost", out.stats.driver_lost());
    (recovery, row)
}

struct PbftCase {
    scenario: &'static str,
    n: usize,
    crash: Vec<Fault>,
    equivocate: bool,
    txs: usize,
    expect_commit: bool,
}

/// One PBFT fault-sweep row. Crashes land at t=0.3 (before any ordering at
/// the 0.35 submission start), so recovery always measures the protocol's
/// way back, not a lucky pre-fault commit.
fn pbft_row(case: &PbftCase) -> (f64, f64, Json) {
    let f = (case.n - 1) / 3;
    let mark = 0.35;
    let mut plan = FaultPlan::new(SEED ^ case.n as u64);
    if case.equivocate {
        plan = plan.at(0.0, Fault::Equivocate(0));
    }
    for fault in &case.crash {
        plan = plan.at(0.3, fault.clone());
    }
    let crashed = case.crash.len();
    let net = TransportConfig::lan(SEED ^ 0x9B ^ case.n as u64);
    let mut cluster = pbft_cluster(case.n, &net, &plan);
    let schedule = paced(case.scenario, case.txs, mark, 0.05);
    let out = drive(&mut cluster, "pbft", &schedule, 12.0, mark);
    if case.expect_commit {
        assert_eq!(out.committed, case.txs, "{}: transactions lost", case.scenario);
    } else {
        assert_eq!(out.committed, 0, "{}: committed past the f fault budget", case.scenario);
    }
    println!(
        "pbft {:<18} n={} f={f} crashed={crashed} committed={:<3} p95={:>7.1}ms \
         recovery={:>5.3}s view_changes={}",
        case.scenario,
        case.n,
        out.committed,
        out.commit_p95_ms,
        out.recovery_s,
        out.stats.epoch_changes
    );
    let row = Json::obj()
        .set("scenario", case.scenario)
        .set("n", case.n)
        .set("f", f)
        .set("crashed", crashed)
        .set("txs", case.txs)
        .set("committed", out.committed)
        .set("alien", out.alien)
        .set("commit_p95_ms", out.commit_p95_ms)
        .set("recovery_s", out.recovery_s)
        .set("view_changes", out.stats.epoch_changes)
        .set("driver_lost", out.stats.driver_lost());
    (out.commit_p95_ms, out.recovery_s, row)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let shard_counts: &[usize] = if smoke { &[1, 2, 4] } else { &[1, 2, 4, 8] };
    let per_shard = if smoke { 12 } else { 40 };
    let surge_txs = if smoke { 30 } else { 60 };
    let pbft_txs = if smoke { 8 } else { 20 };
    println!(
        "# consensus bench{} — virtual-time clusters over simnet links, \
         tick {:.0}ms, seed {SEED:#x}\n",
        if smoke { " (smoke)" } else { "" },
        TICK_S * 1e3
    );

    let mut sharding_rows: Vec<Json> = Vec::new();
    let mut steady_p95 = 0.0f64;
    let mut steady_tps = 0.0f64;
    for &shards in shard_counts {
        let (p95, tps, row) = sharding_row(shards, per_shard);
        steady_p95 = p95; // headline: the largest shard count in this mode
        steady_tps = tps;
        sharding_rows.push(row);
    }

    println!();
    let (crash_recovery, crash_row) = leader_crash_row(surge_txs);

    println!();
    let mut cases = vec![
        PbftCase {
            scenario: "crash_f_backups",
            n: 4,
            crash: vec![Fault::Crash(3)],
            equivocate: false,
            txs: pbft_txs,
            expect_commit: true,
        },
        PbftCase {
            scenario: "crash_primary",
            n: 4,
            crash: vec![Fault::Crash(0)],
            equivocate: false,
            txs: pbft_txs,
            expect_commit: true,
        },
        PbftCase {
            scenario: "equivocating_primary",
            n: 4,
            crash: vec![],
            equivocate: true,
            txs: pbft_txs.min(6),
            expect_commit: true,
        },
        PbftCase {
            scenario: "crash_over_budget",
            n: 4,
            crash: vec![Fault::Crash(2), Fault::Crash(3)],
            equivocate: false,
            txs: pbft_txs.min(4),
            expect_commit: false,
        },
    ];
    if !smoke {
        cases.push(PbftCase {
            scenario: "crash_f_backups_n7",
            n: 7,
            crash: vec![Fault::Crash(5), Fault::Crash(6)],
            equivocate: false,
            txs: pbft_txs,
            expect_commit: true,
        });
    }
    let mut pbft_rows: Vec<Json> = Vec::new();
    let mut pbft_f1_p95 = 0.0f64;
    let mut view_change_recovery = 0.0f64;
    for case in &cases {
        let (p95, recovery, row) = pbft_row(case);
        if case.scenario == "crash_f_backups" {
            pbft_f1_p95 = p95;
        }
        if case.scenario == "crash_primary" {
            view_change_recovery = recovery;
        }
        pbft_rows.push(row);
    }

    println!(
        "\nverdict: steady p95 {steady_p95:.1}ms at {} shards ({steady_tps:.0} sim tps), \
         leader-crash recovery {crash_recovery:.3}s, \
         pbft view-change recovery {view_change_recovery:.3}s, zero driver loss",
        shard_counts.last().unwrap()
    );

    let headline = Json::Arr(vec![
        Json::obj()
            .set("metric", "steady_commit_p95_ms")
            .set("value", steady_p95)
            .set("higher_is_better", false),
        Json::obj()
            .set("metric", "sim_throughput_tps")
            .set("value", steady_tps)
            .set("higher_is_better", true),
        Json::obj()
            .set("metric", "leader_crash_recovery_s")
            .set("value", crash_recovery)
            .set("higher_is_better", false),
        Json::obj()
            .set("metric", "pbft_f1_commit_p95_ms")
            .set("value", pbft_f1_p95)
            .set("higher_is_better", false),
        Json::obj()
            .set("metric", "pbft_view_change_recovery_s")
            .set("value", view_change_recovery)
            .set("higher_is_better", false),
        Json::obj()
            .set("metric", "driver_lost_messages")
            .set("value", 0.0)
            .set("higher_is_better", false),
    ]);
    let out = Json::obj()
        .set("bench", "consensus")
        .set("mode", if smoke { "smoke" } else { "full" })
        .set(
            "config",
            Json::obj()
                .set("tick_ms", TICK_S * 1e3)
                .set("per_shard_txs", per_shard)
                .set("surge_txs", surge_txs)
                .set("pbft_txs", pbft_txs)
                .set("seed", SEED),
        )
        .set("sharding", Json::Arr(sharding_rows))
        .set("raft_faults", Json::Arr(vec![crash_row]))
        .set("pbft", Json::Arr(pbft_rows))
        .set("headline", headline);
    let path = if smoke {
        std::fs::create_dir_all("target/smoke").expect("create target/smoke");
        "target/smoke/BENCH_consensus.json"
    } else {
        "BENCH_consensus.json"
    };
    std::fs::write(path, format!("{out}\n")).expect("write BENCH_consensus.json");
    println!("wrote {path}");
}
