//! Fig. 9 + Table 2 reproduction: training loss and test accuracy of
//! ScaleSFL (S shards x K clients, on-chain validated) vs flat FedAvg
//! (S*K clients), non-IID split, eta = 1e-2, over the B x E grid.
//!
//! Paper result: ScaleSFL converges faster than FedAvg and reaches ~0.98
//! accuracy within 15 global epochs; Table 2 shows ScaleSFL's best accuracy
//! beating FedAvg in every (B, E) cell.
//!
//! This bench runs REAL federated training through the full blockchain
//! pipeline (PJRT train/eval/aggregate executables). Quick mode runs a
//! 2-cell subset; SCALESFL_FULL=1 runs the paper's full 6-cell grid.

use scalesfl::caliper::figures;

fn main() {
    let quick = !figures::full_requested();
    let Some(ops) = scalesfl::runtime::shared_ops() else {
        eprintln!("artifacts not built — run `make artifacts` first");
        return;
    };
    println!("# Fig 9 — train loss / test accuracy per global epoch (non-IID, eta=1e-2)");
    let cells = figures::fig9_table2(&ops, quick).expect("fig9 run");
    for c in &cells {
        println!("\n## B={} E={}", c.batch, c.epochs);
        println!(
            "{:<7} {:>16} {:>14} {:>16} {:>14}",
            "epoch", "ScaleSFL loss", "ScaleSFL acc", "FedAvg loss", "FedAvg acc"
        );
        for i in 0..c.scalesfl.len() {
            let s = &c.scalesfl[i];
            let f = &c.fedavg[i];
            println!(
                "{:<7} {:>16.4} {:>14.4} {:>16.4} {:>14.4}",
                s.0, s.1, s.2, f.1, f.2
            );
        }
    }
    figures::print_table2(&cells);
    let wins = cells.iter().filter(|c| c.best_scalesfl() >= c.best_fedavg()).count();
    println!("\n# ScaleSFL >= FedAvg in {}/{} cells (paper: 6/6)", wins, cells.len());
}
