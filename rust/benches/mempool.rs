//! Mempool micro-bench + orderer surge baseline.
//!
//! Measures the ingress hot path (admission with and without signature
//! prechecks — serial `submit_shared` and batched `submit_batch` over
//! pre-encoded shared envelopes — plus batch pulls) and drives the
//! *real* orderer at 2x its
//! configured block-production knee to show the bounded pool shedding
//! load while committed-tx latency stays bounded. Emits the baseline to
//! `BENCH_mempool.json` for regression tracking — or, with `--smoke`, a
//! seconds-scale deterministic run to `target/smoke/BENCH_mempool.json`
//! that the CI bench gate (`bench_check`) compares against
//! `bench-baselines/`. Micro metrics take the best of three repetitions
//! so a noisy scheduler tick cannot fake a regression.
//!
//!     cargo bench --bench mempool [-- --smoke]    (or `make bench`)

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use scalesfl::crypto::msp::{CertificateAuthority, MemberId};
use scalesfl::fabric::chaincode::{Chaincode, TxContext};
use scalesfl::fabric::endorsement::EndorsementPolicy;
use scalesfl::fabric::orderer::{OrdererConfig, OrderingService};
use scalesfl::fabric::peer::Peer;
use scalesfl::fabric::validator::BlockValidator;
use scalesfl::ledger::envelope::SharedEnvelope;
use scalesfl::ledger::tx::{endorsement_payload, Endorsement, Envelope, Proposal, RwSet, TxId};
use scalesfl::mempool::{MempoolConfig, MempoolRegistry, Reject, ShardMempool};
use scalesfl::util::histogram::Histogram;
use scalesfl::util::json::Json;
use scalesfl::util::prng::Prng;

fn plain_envelope(nonce: u64) -> Envelope {
    Envelope {
        proposal: Proposal {
            channel: "shard0".into(),
            chaincode: "models".into(),
            function: "CreateModelUpdate".into(),
            args: vec![
                "1".into(),
                format!("client{nonce}"),
                "ab".repeat(32),
                "sim://blob".into(),
                "100".into(),
            ],
            creator: MemberId::new(format!("client{}", nonce % 64)),
            nonce,
        },
        rw_set: RwSet::default(),
        endorsements: Vec::new(),
    }
}

/// Admission throughput without signature prechecks.
fn bench_admit(n: usize) -> (f64, f64) {
    let pool = ShardMempool::new(
        "shard0",
        MempoolConfig { lane_capacity: n, ..Default::default() },
    );
    let envs: Vec<Envelope> = (0..n as u64).map(plain_envelope).collect();
    let t0 = Instant::now();
    for env in envs {
        pool.submit(env).expect("admit");
    }
    let per = t0.elapsed().as_secs_f64() / n as f64;
    println!(
        "{:<44} {:>10.0} ns/op   {:>12.0} tx/s",
        "admit (dedup+lanes+caps)",
        per * 1e9,
        1.0 / per
    );
    (per * 1e9, 1.0 / per)
}

/// A verified-admission fixture: a pool with endorsement prechecks on and
/// `n` pre-encoded, pre-endorsed [`SharedEnvelope`]s. Building the
/// envelopes (encode + 2 HMAC signs + view hashing) happens here, outside
/// any timed window — the gateway does that work once per transaction at
/// decode time, so admission benches must not re-pay it per submit.
fn verified_fixture(n: usize) -> (ShardMempool, Vec<SharedEnvelope>) {
    let ca = CertificateAuthority::new();
    let mut rng = Prng::new(7);
    let creds: Vec<_> = (0..2)
        .map(|i| ca.enroll(MemberId::new(format!("org{i}.peer")), &mut rng))
        .collect();
    let members: Vec<MemberId> = creds.iter().map(|c| c.member.clone()).collect();
    let pool = ShardMempool::with_parts(
        "shard0",
        MempoolConfig {
            lane_capacity: n,
            verify_endorsements: true,
            ..Default::default()
        },
        scalesfl::util::clock::SystemClock::shared(),
        Some(ca),
    );
    pool.set_policy(EndorsementPolicy::MajorityOf(members));
    let envs: Vec<SharedEnvelope> = (0..n as u64)
        .map(|nonce| {
            let mut env = plain_envelope(nonce);
            let payload = endorsement_payload(&env.tx_id(), &env.rw_set.digest());
            for c in &creds {
                env.endorsements.push(Endorsement {
                    endorser: c.member.clone(),
                    signature: c.sign(&payload),
                });
            }
            let shared = SharedEnvelope::from(env);
            // Warm the cached views (tx_id / rw-set digest / envelope
            // digest) the way gateway decode does.
            let _ = shared.digest();
            shared
        })
        .collect();
    (pool, envs)
}

/// Serial verified admission: one `submit_shared` per envelope (the
/// relay / single-tx gateway path — dedup, lanes, caps, 2-HMAC policy
/// precheck per call).
fn bench_admit_verified(n: usize) -> (f64, f64) {
    let (pool, envs) = verified_fixture(n);
    let t0 = Instant::now();
    for env in envs {
        pool.submit_shared(env).expect("admit verified");
    }
    let per = t0.elapsed().as_secs_f64() / n as f64;
    println!(
        "{:<44} {:>10.0} ns/op   {:>12.0} tx/s",
        "admit + policy precheck (2 HMAC sigs)",
        per * 1e9,
        1.0 / per
    );
    (per * 1e9, 1.0 / per)
}

/// Batched verified admission: `submit_batch` over `chunk`-sized pulls
/// with the admission crypto fanned out over a shared [`BlockValidator`]
/// (the batch-pull gossip path). Amortizes the MSP registry lock and
/// policy lookup across the chunk and seeds the commit-path verdict
/// cache as a side effect.
fn bench_admit_verified_batch(n: usize, chunk: usize) -> (f64, f64) {
    let (pool, envs) = verified_fixture(n);
    pool.set_validator(Arc::new(BlockValidator::new(4)));
    let chunks: Vec<Vec<SharedEnvelope>> =
        envs.chunks(chunk).map(|c| c.to_vec()).collect();
    let t0 = Instant::now();
    let mut admitted = 0usize;
    for batch in chunks {
        admitted += pool.submit_batch(batch).iter().filter(|r| r.is_ok()).count();
    }
    let per = t0.elapsed().as_secs_f64() / n as f64;
    assert_eq!(admitted, n, "every pre-endorsed envelope must admit");
    println!(
        "{:<44} {:>10.0} ns/op   {:>12.0} tx/s",
        format!("admit batch x{chunk} (validator, 4 workers)"),
        per * 1e9,
        1.0 / per
    );
    (per * 1e9, 1.0 / per)
}

/// Batch-pull throughput (the orderer's side of the pipeline).
fn bench_take_batch(n: usize) -> f64 {
    let pool = ShardMempool::new(
        "shard0",
        MempoolConfig { lane_capacity: n, ..Default::default() },
    );
    for nonce in 0..n as u64 {
        pool.submit(plain_envelope(nonce)).expect("fill");
    }
    let t0 = Instant::now();
    let mut pulled = 0usize;
    while pulled < n {
        let batch = pool.take_batch(256, 0);
        if batch.is_empty() {
            break;
        }
        pulled += batch.len();
    }
    let per = t0.elapsed().as_secs_f64() / pulled.max(1) as f64;
    println!(
        "{:<44} {:>10.0} ns/tx   ({} txs in 256-tx batches)",
        "take_batch (priority drain)",
        per * 1e9,
        pulled
    );
    per * 1e9
}

struct PutCc;
impl Chaincode for PutCc {
    fn name(&self) -> &str {
        "kv"
    }
    fn invoke(
        &self,
        ctx: &mut TxContext<'_>,
        _f: &str,
        args: &[String],
    ) -> Result<Vec<u8>, String> {
        ctx.put(&args[0], b"v".to_vec());
        Ok(vec![])
    }
}

/// Drive the real orderer at 2x its block-production knee with a bounded
/// pool: the queue must stay bounded, overload must shed, and committed-tx
/// latency must stay flat instead of growing with the backlog.
fn surge_2x(offered: usize) -> Json {
    let lane_capacity = 128usize;
    let batch_size = 16usize;
    let min_block_interval = Duration::from_millis(20);
    // Knee: one 16-tx block per 20 ms = 800 tx/s of ordering bandwidth.
    let knee_tps = batch_size as f64 / min_block_interval.as_secs_f64();
    let offered_tps = knee_tps * 2.0;

    let ca = CertificateAuthority::new();
    let mut rng = Prng::new(3);
    let peers: Vec<Arc<Peer>> = (0..2)
        .map(|i| {
            let cred = ca.enroll(MemberId::new(format!("org{i}.peer")), &mut rng);
            Peer::new(cred, ca.clone())
        })
        .collect();
    let members: Vec<MemberId> = peers.iter().map(|p| p.member.clone()).collect();
    for p in &peers {
        p.join_channel("ch", EndorsementPolicy::MajorityOf(members.clone()));
        p.install_chaincode("ch", Arc::new(PutCc)).unwrap();
    }
    let mempool = MempoolRegistry::new(MempoolConfig {
        lane_capacity,
        ..Default::default()
    });
    let orderer = OrderingService::start_with_mempool(
        OrdererConfig {
            batch_size,
            batch_timeout: Duration::from_millis(10),
            min_block_interval,
            tick: Duration::from_millis(1),
            ..Default::default()
        },
        peers.clone(),
        42,
        mempool,
    );
    let rx = peers[0].subscribe("ch").unwrap();

    // Pre-endorse outside the timed window.
    let envs: Vec<Envelope> = (0..offered as u64)
        .map(|nonce| {
            let prop = Proposal {
                channel: "ch".into(),
                chaincode: "kv".into(),
                function: "Put".into(),
                args: vec![format!("k{nonce}")],
                creator: MemberId::new("stress-client"),
                nonce,
            };
            let mut endorsements = Vec::new();
            let mut rw = None;
            for p in &peers {
                let (r, e, _) = p.endorse(&prop).unwrap();
                rw = Some(r);
                endorsements.push(e);
            }
            Envelope { proposal: prop, rw_set: rw.unwrap(), endorsements }
        })
        .collect();

    let start = Instant::now();
    let mut submit_at: HashMap<TxId, Instant> = HashMap::new();
    let mut admitted = 0usize;
    let mut shed = 0usize;
    for (i, env) in envs.into_iter().enumerate() {
        // Burst-of-8 pacing keeps the mean rate despite coarse sleeps.
        if i % 8 == 0 {
            let due = start + Duration::from_secs_f64(i as f64 / offered_tps);
            if let Some(wait) = due.checked_duration_since(Instant::now()) {
                std::thread::sleep(wait);
            }
        }
        let tx_id = env.tx_id();
        match orderer.submit(env) {
            Ok(()) => {
                submit_at.insert(tx_id, Instant::now());
                admitted += 1;
            }
            Err(Reject::PoolFull) => shed += 1,
            Err(other) => panic!("unexpected reject: {other:?}"),
        }
    }
    let send_wall = start.elapsed().as_secs_f64();

    let mut latency = Histogram::default();
    let mut committed = 0usize;
    while committed < admitted {
        let ev = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("commit event within 30s — queue must stay bounded");
        if let Some(at) = submit_at.get(&ev.tx_id) {
            latency.record(at.elapsed().as_secs_f64());
            committed += 1;
        }
    }
    let total_wall = start.elapsed().as_secs_f64();
    let stats = orderer.mempool().snapshot();

    println!("\n# surge at 2x knee ({offered} txs offered at {offered_tps:.0} tx/s, knee {knee_tps:.0} tx/s)");
    println!(
        "admitted={admitted} shed={shed} committed={committed} depth_high_water={} (lane cap {lane_capacity})",
        stats.depth_high_water
    );
    println!(
        "commit latency: avg {:.3}s p95 {:.3}s max {:.3}s | blocks {} | wall {:.2}s",
        latency.mean(),
        latency.quantile(0.95).unwrap_or(0.0),
        latency.max(),
        orderer.blocks_cut(),
        total_wall
    );
    let bounded = stats.depth_high_water <= lane_capacity as u64;
    let shed_nonzero = shed > 0;
    println!(
        "verdict: bounded_queue={} nonzero_shed={} (expect true/true past the knee)",
        bounded, shed_nonzero
    );

    Json::obj()
        .set("offered", offered)
        .set("offered_tps", offered_tps)
        .set("knee_tps", knee_tps)
        .set("lane_capacity", lane_capacity)
        .set("admitted", admitted)
        .set("shed", shed)
        .set("committed", committed)
        .set("depth_high_water", stats.depth_high_water)
        .set("blocks_cut", orderer.blocks_cut())
        .set("avg_commit_latency_s", latency.mean())
        .set("p95_commit_latency_s", latency.quantile(0.95).unwrap_or(0.0))
        .set("max_commit_latency_s", latency.max())
        .set("send_wall_s", send_wall)
        .set("total_wall_s", total_wall)
        .set("bounded_queue", bounded)
        .set("nonzero_shed", shed_nonzero)
}

/// Best of `reps` repetitions of a (ns_per_op, tx_per_s) micro bench.
fn best_of(reps: usize, mut run: impl FnMut() -> (f64, f64)) -> (f64, f64) {
    (0..reps.max(1))
        .map(|_| run())
        .fold((f64::INFINITY, 0.0f64), |acc, x| (acc.0.min(x.0), acc.1.max(x.1)))
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (n_admit, n_verified, n_take, n_surge) =
        if smoke { (5_000, 1_000, 5_000, 400) } else { (20_000, 5_000, 20_000, 2_000) };
    println!(
        "# mempool benches{} — ingress hot path + orderer surge\n",
        if smoke { " (smoke)" } else { "" }
    );
    let batch_chunk = 256usize;
    let (admit_ns, admit_tps) = best_of(3, || bench_admit(n_admit));
    let (verified_ns, verified_tps) = best_of(3, || bench_admit_verified(n_verified));
    let (batch_ns, batch_tps) =
        best_of(3, || bench_admit_verified_batch(n_verified, batch_chunk));
    let (take_ns, _) = best_of(3, || (bench_take_batch(n_take), 0.0));
    let surge = surge_2x(n_surge);
    let surge_p95 =
        surge.get("p95_commit_latency_s").and_then(|v| v.as_f64()).unwrap_or(0.0);

    let headline = Json::Arr(vec![
        Json::obj()
            .set("metric", "admit_ns_per_op")
            .set("value", admit_ns)
            .set("higher_is_better", false),
        Json::obj()
            .set("metric", "admit_verified_ns_per_op")
            .set("value", verified_ns)
            .set("higher_is_better", false),
        Json::obj()
            .set("metric", "admit_verified_batch_tx_per_s")
            .set("value", batch_tps)
            .set("higher_is_better", true),
        Json::obj()
            .set("metric", "take_batch_ns_per_tx")
            .set("value", take_ns)
            .set("higher_is_better", false),
        Json::obj()
            .set("metric", "surge_p95_commit_latency_s")
            .set("value", surge_p95)
            .set("higher_is_better", false),
    ]);
    let out = Json::obj()
        .set("bench", "mempool")
        .set("mode", if smoke { "smoke" } else { "full" })
        .set(
            "admit",
            Json::obj().set("ns_per_op", admit_ns).set("tx_per_s", admit_tps),
        )
        .set(
            "admit_verified",
            Json::obj().set("ns_per_op", verified_ns).set("tx_per_s", verified_tps),
        )
        .set(
            "admit_verified_batch",
            Json::obj()
                .set("ns_per_op", batch_ns)
                .set("tx_per_s", batch_tps)
                .set("chunk", batch_chunk),
        )
        .set("take_batch", Json::obj().set("ns_per_tx", take_ns))
        .set("surge_2x", surge)
        .set("headline", headline);
    let path = if smoke {
        std::fs::create_dir_all("target/smoke").expect("create target/smoke");
        "target/smoke/BENCH_mempool.json"
    } else {
        "BENCH_mempool.json"
    };
    std::fs::write(path, format!("{out}\n")).expect("write BENCH_mempool.json");
    println!("\nwrote {path}");
}
