//! Figs. 6 + 7 reproduction: usage surge — transaction count vs average
//! latency + failure/shed counts (Fig 6) and vs throughput (Fig 7), at a
//! sent TPS just above the maximum, 30 s timeout.
//!
//! Paper result: once the queue outgrows what 30 s of capacity can absorb,
//! latency climbs toward ~16 s (mean of timeout-bound and service-bound
//! requests), failures appear, and observed throughput *decreases*.
//!
//! With the sharded mempool in the ingress path the overload surfaces as
//! *shed* transactions (explicit backpressure) instead of unbounded queue
//! growth: committed-tx latency stays bounded and throughput holds at
//! capacity while the shed column grows with the surge size.

use scalesfl::caliper::figures;

fn main() {
    let quick = !figures::full_requested();
    let Some(env) = figures::env(quick) else {
        eprintln!("artifacts not built — run `make artifacts` first");
        return;
    };
    println!("# Figs 6+7 — surge behaviour (2 shards, sent = 1.3x capacity, 30s timeout)");
    println!(
        "{:<8} {:>14} {:>10} {:>10} {:>12} {:>12}",
        "txs", "avgLat(s)", "fail", "shed", "tput(TPS)", "p95Lat(s)"
    );
    for (txs, r) in figures::fig6_7(&env) {
        println!(
            "{:<8} {:>14.3} {:>10} {:>10} {:>12.3} {:>12.3}",
            txs,
            r.avg_latency(),
            r.failed,
            r.shed,
            r.throughput,
            r.latency.quantile(0.95).unwrap_or(0.0)
        );
    }
    println!("# expected shape: shed load rises with tx count; committed latency stays bounded");
}
