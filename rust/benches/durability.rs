//! Durability bench: committed throughput under each [`DurabilityMode`]
//! (`Off` / `Group` / `Strict`) and cold-start recovery rate vs chain
//! length. Emits the baseline to `BENCH_durability.json` (or
//! `target/smoke/BENCH_durability.json` in `--smoke` mode — the fast
//! deterministic configuration the CI bench gate runs and compares
//! against `bench-baselines/`).
//!
//! Endorsement happens up front, so the timed loop is exactly the commit
//! path the durability mode taxes: serial validate + apply + log append
//! (+ fsync per the mode, + periodic snapshot writes). `Group` pays one
//! final `sync()` inside the timed region so its number includes the
//! cost of making the tail durable; `Off` keeps its never-fsync contract.
//! Recovery timing measures `Peer::attach_store` on a fresh peer — full
//! log replay through the validator, and the snapshot-anchored variant
//! that only replays the suffix.
//!
//!     cargo bench --bench durability [-- --smoke]    (or `make bench`)

use std::sync::Arc;
use std::time::{Duration, Instant};

use scalesfl::crypto::msp::{CertificateAuthority, Credential, MemberId};
use scalesfl::fabric::chaincode::{Chaincode, TxContext};
use scalesfl::fabric::endorsement::EndorsementPolicy;
use scalesfl::fabric::peer::Peer;
use scalesfl::ledger::store::{DurabilityMode, LedgerConfig};
use scalesfl::ledger::tx::{Envelope, Proposal};
use scalesfl::util::json::Json;
use scalesfl::util::prng::Prng;
use scalesfl::util::tempdir::TempDir;

const BATCH: usize = 8;
const GROUP_WINDOW_MS: u64 = 5;
const SNAPSHOT_EVERY: u64 = 16;

struct PutCc;
impl Chaincode for PutCc {
    fn name(&self) -> &str {
        "kv"
    }
    fn invoke(
        &self,
        ctx: &mut TxContext<'_>,
        _f: &str,
        args: &[String],
    ) -> Result<Vec<u8>, String> {
        ctx.put(&args[0], b"v".to_vec());
        Ok(vec![])
    }
}

/// Single-peer rig: the bench isolates the per-replica commit/persist
/// path, so one peer with an `AnyOf(1)` policy is the whole network.
fn rig(seed: u64) -> (CertificateAuthority, Credential) {
    let ca = CertificateAuthority::new();
    let mut rng = Prng::new(seed);
    let cred = ca.enroll(MemberId::new("org0.peer"), &mut rng);
    (ca, cred)
}

fn spawn_peer(ca: &CertificateAuthority, cred: &Credential) -> Arc<Peer> {
    let p = Peer::new(cred.clone(), ca.clone());
    p.join_channel("ch", EndorsementPolicy::AnyOf(1, vec![cred.member.clone()]));
    p.install_chaincode("ch", Arc::new(PutCc)).unwrap();
    p
}

/// Pre-endorsed batches of `BATCH` distinct-key Puts per block.
fn build_batches(peer: &Peer, prefix: &str, blocks: usize, nonce: &mut u64) -> Vec<Vec<Envelope>> {
    (0..blocks)
        .map(|b| {
            (0..BATCH)
                .map(|i| {
                    *nonce += 1;
                    let prop = Proposal {
                        channel: "ch".into(),
                        chaincode: "kv".into(),
                        function: "Put".into(),
                        args: vec![format!("{prefix}{b}x{i}")],
                        creator: MemberId::new("bench-client"),
                        nonce: *nonce,
                    };
                    let (rw_set, endorsement, _) = peer.endorse(&prop).unwrap();
                    Envelope { proposal: prop, rw_set, endorsements: vec![endorsement] }
                })
                .collect()
        })
        .collect()
}

fn mode_tag(mode: DurabilityMode) -> &'static str {
    match mode {
        DurabilityMode::Off => "off",
        DurabilityMode::Group(_) => "group",
        DurabilityMode::Strict => "strict",
    }
}

/// Committed TPS for one durability mode: time `blocks` back-to-back
/// `commit_batch` calls against a store in that mode.
fn commit_scenario(mode: DurabilityMode, blocks: usize, seed: u64) -> (f64, Json) {
    let tag = mode_tag(mode);
    let tmp = TempDir::new(&format!("dur-bench-{tag}"));
    let (ca, cred) = rig(seed);
    let peer = spawn_peer(&ca, &cred);
    let lcfg = LedgerConfig {
        dir: tmp.path().to_path_buf(),
        durability: mode,
        snapshot_every: SNAPSHOT_EVERY,
    };
    peer.attach_store("ch", &lcfg).unwrap();
    let mut nonce = 0u64;
    let batches = build_batches(&peer, tag, blocks, &mut nonce);
    let ch = peer.channel("ch").unwrap();
    let store = ch.store().unwrap();

    let t0 = Instant::now();
    for envs in batches {
        peer.commit_batch("ch", envs).unwrap();
    }
    if matches!(mode, DurabilityMode::Group(_)) {
        store.sync();
    }
    let secs = t0.elapsed().as_secs_f64();

    assert_eq!(ch.height(), blocks as u64, "every batch must commit one block");
    assert_eq!(store.height(), ch.height(), "log must track the chain");
    let s = store.stats();
    let tps = (blocks * BATCH) as f64 / secs;
    println!(
        "mode={tag:<6} blocks={blocks:<5} tps={tps:>9.0} fsyncs={:<5} \
         fsync_mean={:.3}ms snapshots={}",
        s.fsyncs,
        s.fsync_mean_s * 1e3,
        s.snapshots_written
    );
    let json = Json::obj()
        .set("mode", tag)
        .set("blocks", blocks)
        .set("batch", BATCH)
        .set("committed_tps", tps)
        .set("wall_s", secs)
        .set("fsyncs", s.fsyncs)
        .set("fsync_mean_ms", s.fsync_mean_s * 1e3)
        .set("snapshots_written", s.snapshots_written);
    (tps, json)
}

/// Cold-start recovery rate: persist a chain of `blocks` blocks, kill the
/// peer, and time `attach_store` on a fresh one. `snapshot_every = 0`
/// forces a full log replay; a nonzero cadence recovers from the latest
/// snapshot plus a short suffix.
fn recovery_scenario(blocks: usize, snapshot_every: u64, seed: u64) -> (f64, Json) {
    let tmp = TempDir::new("dur-bench-recover");
    let (ca, cred) = rig(seed);
    let lcfg = LedgerConfig {
        dir: tmp.path().to_path_buf(),
        durability: DurabilityMode::Off,
        snapshot_every,
    };
    {
        let peer = spawn_peer(&ca, &cred);
        peer.attach_store("ch", &lcfg).unwrap();
        let mut nonce = 0u64;
        for envs in build_batches(&peer, "r", blocks, &mut nonce) {
            peer.commit_batch("ch", envs).unwrap();
        }
    }

    let peer = spawn_peer(&ca, &cred);
    let t0 = Instant::now();
    let rep = peer.attach_store("ch", &lcfg).unwrap();
    let secs = t0.elapsed().as_secs_f64();

    assert_eq!(rep.height, blocks as u64, "recovery must reach the full height");
    assert_eq!(rep.truncated_bytes, 0, "clean log must not be truncated");
    let rate = blocks as f64 / secs;
    println!(
        "recover blocks={blocks:<5} snapshot_every={snapshot_every:<3} \
         in {:>7.1}ms ({rate:>8.0} blocks/s, snapshot at {}, replayed {})",
        secs * 1e3,
        rep.snapshot_height,
        rep.replayed_blocks
    );
    let json = Json::obj()
        .set("chain_blocks", blocks)
        .set("snapshot_every", snapshot_every)
        .set("recover_ms", secs * 1e3)
        .set("blocks_per_s", rate)
        .set("snapshot_height", rep.snapshot_height)
        .set("replayed_blocks", rep.replayed_blocks);
    (rate, json)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let commit_blocks = if smoke { 24 } else { 256 };
    let recovery_lens: &[usize] = if smoke { &[64] } else { &[256, 1024] };
    println!(
        "# durability bench{} — {BATCH} txs/block, {commit_blocks} blocks/mode, \
         group window {GROUP_WINDOW_MS} ms, snapshot every {SNAPSHOT_EVERY}\n",
        if smoke { " (smoke)" } else { "" }
    );

    let modes = [
        DurabilityMode::Off,
        DurabilityMode::Group(Duration::from_millis(GROUP_WINDOW_MS)),
        DurabilityMode::Strict,
    ];
    let mut commit_scenarios: Vec<Json> = Vec::new();
    let mut tps_by_mode = [0.0f64; 3];
    for (i, &mode) in modes.iter().enumerate() {
        let (tps, json) = commit_scenario(mode, commit_blocks, 11 + i as u64);
        tps_by_mode[i] = tps;
        commit_scenarios.push(json);
    }

    println!();
    let mut recovery_scenarios: Vec<Json> = Vec::new();
    let mut headline_recovery = 0.0f64;
    for (i, &len) in recovery_lens.iter().enumerate() {
        // Full replay first (the headline), then the snapshot-anchored run.
        let (rate, json) = recovery_scenario(len, 0, 31 + i as u64);
        if i == 0 {
            headline_recovery = rate;
        }
        recovery_scenarios.push(json);
        let (_, json) = recovery_scenario(len, SNAPSHOT_EVERY, 41 + i as u64);
        recovery_scenarios.push(json);
    }

    println!(
        "\nverdict: group commit holds {:.0}% of Off throughput (strict: {:.0}%), \
         full-replay recovery at {headline_recovery:.0} blocks/s",
        100.0 * tps_by_mode[1] / tps_by_mode[0],
        100.0 * tps_by_mode[2] / tps_by_mode[0],
    );

    let headline = Json::Arr(vec![
        Json::obj()
            .set("metric", "commit_tps_off")
            .set("value", tps_by_mode[0])
            .set("higher_is_better", true),
        Json::obj()
            .set("metric", "commit_tps_group")
            .set("value", tps_by_mode[1])
            .set("higher_is_better", true),
        Json::obj()
            .set("metric", "commit_tps_strict")
            .set("value", tps_by_mode[2])
            .set("higher_is_better", true),
        Json::obj()
            .set("metric", "recovery_blocks_per_s")
            .set("value", headline_recovery)
            .set("higher_is_better", true),
    ]);
    let out = Json::obj()
        .set("bench", "durability")
        .set("mode", if smoke { "smoke" } else { "full" })
        .set(
            "config",
            Json::obj()
                .set("batch", BATCH)
                .set("commit_blocks", commit_blocks)
                .set("group_window_ms", GROUP_WINDOW_MS)
                .set("snapshot_every", SNAPSHOT_EVERY),
        )
        .set("commit", Json::Arr(commit_scenarios))
        .set("recovery", Json::Arr(recovery_scenarios))
        .set("headline", headline);
    let path = if smoke {
        std::fs::create_dir_all("target/smoke").expect("create target/smoke");
        "target/smoke/BENCH_durability.json"
    } else {
        "BENCH_durability.json"
    };
    std::fs::write(path, format!("{out}\n")).expect("write BENCH_durability.json");
    println!("wrote {path}");
}
