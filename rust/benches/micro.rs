//! Micro-benchmarks of the substrate hot paths (hand-rolled harness;
//! criterion is unavailable in the offline vendor set).
//!
//! Covers: Raft ordering throughput, PBFT ordering throughput, MVCC
//! validate+commit, merkle root, endorsement-policy verification, envelope
//! codec, and the PJRT executables (eval / train / aggregate / distance) —
//! plus a real-vs-DES cross-check on a small fabric deployment.

use std::sync::Arc;
use std::time::{Duration, Instant};

use scalesfl::caliper::des::{run_des, DesConfig};
use scalesfl::caliper::real::run_real;
use scalesfl::caliper::Workload;
use scalesfl::consensus::pbft::{Pbft, PbftConfig};
use scalesfl::consensus::raft::{Raft, RaftConfig};
use scalesfl::consensus::ConsensusNode;
use scalesfl::crypto::msp::{CertificateAuthority, MemberId};
use scalesfl::crypto::{merkle, sha256};
use scalesfl::fabric::chaincode::{Chaincode, TxContext};
use scalesfl::fabric::endorsement::EndorsementPolicy;
use scalesfl::fabric::orderer::{OrdererConfig, OrderingService};
use scalesfl::fabric::peer::Peer;
use scalesfl::fabric::Gateway;
use scalesfl::ledger::state::{Version, WorldState};
use scalesfl::ledger::tx::{endorsement_payload, Endorsement, Envelope, Proposal, RwSet};
use scalesfl::network::simnet::SimNet;
use scalesfl::util::prng::Prng;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // Warm-up.
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    let (value, unit) = if per >= 1.0 {
        (per, "s")
    } else if per >= 1e-3 {
        (per * 1e3, "ms")
    } else {
        (per * 1e6, "us")
    };
    println!("{name:<44} {value:>10.3} {unit}/iter   ({iters} iters)");
    per
}

fn bench_raft_ordering() {
    // 3-node raft over the simnet; measure committed entries per second.
    let mut rng = Prng::new(1);
    let mut nodes: Vec<Raft> =
        (0..3).map(|i| Raft::new(i, 3, RaftConfig::default(), rng.fork(i as u64))).collect();
    let mut net = SimNet::new(0.0005, 0.001, 0.0, rng.fork(99));
    // settle election
    let mut now = 0.0;
    let drive = |nodes: &mut Vec<Raft>, net: &mut SimNet<_>, now: &mut f64, until: f64| {
        while *now < until {
            *now += 0.005;
            for i in 0..nodes.len() {
                for (to, m) in nodes[i].tick(*now) {
                    net.send(i, to, m, *now);
                }
            }
            for (f, t, m) in net.deliver_until(*now) {
                for (to, out) in nodes[t].handle(f, m, *now) {
                    net.send(t, to, out, *now);
                }
            }
        }
    };
    drive(&mut nodes, &mut net, &mut now, 2.0);
    let leader = nodes.iter().position(|n| n.is_leader()).expect("leader");
    let t0 = Instant::now();
    let entries = 5_000usize;
    for i in 0..entries {
        nodes[leader].propose(vec![(i % 256) as u8; 64], now).unwrap();
        if i % 64 == 0 {
            let target = now + 0.05;
            drive(&mut nodes, &mut net, &mut now, target);
        }
    }
    let target = now + 1.0;
    drive(&mut nodes, &mut net, &mut now, target);
    let committed = nodes[leader].take_committed().len();
    println!(
        "{:<44} {:>10.0} entries/s  (committed {committed}/{entries}, wall {:.2}s)",
        "raft 3-node ordering throughput",
        committed as f64 / t0.elapsed().as_secs_f64(),
        t0.elapsed().as_secs_f64()
    );
}

fn bench_pbft_ordering() {
    let mut nodes: Vec<Pbft> = (0..4).map(|i| Pbft::new(i, 4, PbftConfig::default())).collect();
    let mut rng = Prng::new(2);
    let mut net = SimNet::new(0.0005, 0.001, 0.0, rng.fork(1));
    let mut now = 0.0;
    let entries = 2_000usize;
    let t0 = Instant::now();
    for i in 0..entries {
        nodes[0].propose(vec![(i % 256) as u8; 64], now).unwrap();
        for (to, m) in nodes[0].take_outbound() {
            net.send(0, to, m, now);
        }
        if i % 32 == 0 {
            let until = now + 0.05;
            while now < until {
                now += 0.005;
                for (f, t, m) in net.deliver_until(now) {
                    for (to, out) in nodes[t].handle(f, m, now) {
                        net.send(t, to, out, now);
                    }
                }
            }
        }
    }
    let until = now + 1.0;
    while now < until {
        now += 0.005;
        for (f, t, m) in net.deliver_until(now) {
            for (to, out) in nodes[t].handle(f, m, now) {
                net.send(t, to, out, now);
            }
        }
    }
    let committed = nodes[1].take_committed().len();
    println!(
        "{:<44} {:>10.0} entries/s  (committed {committed}/{entries}, wall {:.2}s)",
        "pbft 4-replica ordering throughput",
        committed as f64 / t0.elapsed().as_secs_f64(),
        t0.elapsed().as_secs_f64()
    );
}

struct PutCc;
impl Chaincode for PutCc {
    fn name(&self) -> &str {
        "kv"
    }
    fn invoke(&self, ctx: &mut TxContext<'_>, _f: &str, args: &[String]) -> Result<Vec<u8>, String> {
        ctx.put(&args[0], b"v".to_vec());
        Ok(vec![])
    }
}

fn bench_real_vs_des() {
    // Small real fabric deployment with a cheap chaincode: compare the real
    // harness against the DES parameterised with the measured service time.
    let ca = CertificateAuthority::new();
    let mut rng = Prng::new(3);
    let peers: Vec<Arc<Peer>> = (0..2)
        .map(|i| {
            let cred = ca.enroll(MemberId::new(format!("org{i}.peer")), &mut rng);
            Peer::new(cred, ca.clone())
        })
        .collect();
    let members: Vec<MemberId> = peers.iter().map(|p| p.member.clone()).collect();
    for p in &peers {
        p.join_channel("ch", EndorsementPolicy::MajorityOf(members.clone()));
        p.install_chaincode("ch", Arc::new(PutCc)).unwrap();
    }
    let orderer = OrderingService::start(
        OrdererConfig { batch_timeout: Duration::from_millis(10), ..Default::default() },
        peers.clone(),
        5,
    );
    let gw = Arc::new(Gateway::new(peers, orderer));
    // Cap the open-loop window at the worker count so the real run stays
    // comparable with the DES's closed-loop worker model.
    let wl = Workload { txs: 120, send_tps: 400.0, workers: 4, timeout_s: 10.0, max_in_flight: 4 };
    let real = run_real("real/kv", &wl, &[gw], |i| Proposal {
        channel: "ch".into(),
        chaincode: "kv".into(),
        function: "Put".into(),
        args: vec![format!("k{i}")],
        creator: MemberId::new("client"),
        nonce: i as u64,
    });
    println!("{}", real.row());
    let des_cfg = DesConfig {
        shards: 1,
        endorsers_per_shard: 2,
        quorum: 2,
        eval_s: 0.0002, // cheap chaincode
        order_s: 0.012,
        batch_timeout_s: 0.01,
        worker_overhead_s: 0.0005,
        ..Default::default()
    };
    let des = run_des(&des_cfg, &wl, 77);
    println!("{}", des.row());
    println!(
        "# real-vs-DES cross-check: tput {:.1} vs {:.1} TPS, avgLat {:.3}s vs {:.3}s",
        real.throughput,
        des.throughput,
        real.avg_latency(),
        des.avg_latency()
    );
}

fn main() {
    println!("# micro benches — substrate hot paths\n");
    bench_raft_ordering();
    bench_pbft_ordering();

    // MVCC validate + commit.
    let mut state = WorldState::new();
    let mut n = 0u64;
    bench("mvcc validate+apply (1 read, 1 write)", 200_000, || {
        let rw = RwSet {
            reads: vec![(format!("k{}", n % 512), None)],
            writes: vec![(format!("k{}", n % 512), Some(vec![0u8; 32]))],
        };
        let _ = state.mvcc_valid(&rw);
        state.apply(&rw, Version { block: n, tx: 0 });
        n += 1;
    });

    // Merkle root of a 100-tx block.
    let leaves: Vec<_> = (0..100).map(|i: u64| sha256(&i.to_le_bytes())).collect();
    bench("merkle root (100 txs)", 20_000, || {
        let _ = merkle::root(&leaves);
    });

    // Endorsement policy verification (3 HMAC signatures).
    let ca = CertificateAuthority::new();
    let mut rng = Prng::new(9);
    let creds: Vec<_> =
        (0..3).map(|i| ca.enroll(MemberId::new(format!("org{i}.peer")), &mut rng)).collect();
    let members: Vec<MemberId> = creds.iter().map(|c| c.member.clone()).collect();
    let policy = EndorsementPolicy::MajorityOf(members);
    let tx_id = sha256(b"tx");
    let rw = RwSet { reads: vec![], writes: vec![("k".into(), Some(vec![0u8; 64]))] };
    let payload = endorsement_payload(&tx_id, &rw.digest());
    let ends: Vec<Endorsement> = creds
        .iter()
        .map(|c| Endorsement { endorser: c.member.clone(), signature: c.sign(&payload) })
        .collect();
    bench("endorsement policy check (3 sigs)", 100_000, || {
        assert!(policy.satisfied(&tx_id, &rw, &ends, &ca));
    });

    // Envelope codec.
    let env = Envelope {
        proposal: Proposal {
            channel: "shard0".into(),
            chaincode: "models".into(),
            function: "CreateModelUpdate".into(),
            args: vec!["1".into(), "client1".into(), "ab".repeat(32), "sim://x".into(), "100".into()],
            creator: MemberId::new("client"),
            nonce: 1,
        },
        rw_set: rw.clone(),
        endorsements: ends.clone(),
    };
    bench("envelope encode+decode", 100_000, || {
        let mut w = scalesfl::ledger::codec::Writer::new();
        scalesfl::fabric::wire::encode_envelope(&env, &mut w);
        let buf = w.finish();
        let mut r = scalesfl::ledger::codec::Reader::new(&buf);
        let _ = scalesfl::fabric::wire::decode_envelope(&mut r).unwrap();
    });

    bench_real_vs_des();

    // PJRT executables.
    let Some(ops) = scalesfl::runtime::shared_ops() else {
        eprintln!("\nartifacts not built — skipping PJRT benches");
        return;
    };
    println!("\n# PJRT executables (P_PAD = {}, K = {})", ops.p_pad(), ops.k());
    let params = ops.init_params(0).unwrap();
    let dim = ops.input_dim();
    let mut prng = Prng::new(11);
    let x: Vec<f32> = (0..32 * dim).map(|_| prng.normal() as f32).collect();
    let y: Vec<i32> = (0..32).map(|_| prng.below(10) as i32).collect();
    let mut p = params.clone();
    bench("train_step (b=32)", 50, || {
        let (next, _) = ops.train_step(p.clone(), &x, &y, 0.01).unwrap();
        p = next;
    });
    let ex: Vec<f32> = (0..2048 * dim).map(|_| prng.normal() as f32).collect();
    let ey: Vec<i32> = (0..2048).map(|_| prng.below(10) as i32).collect();
    bench("endorsement eval (2048 samples)", 10, || {
        let _ = ops.evaluate(&params, &ex, &ey).unwrap();
    });
    let refs: Vec<&Vec<f32>> = (0..ops.k()).map(|_| &params).collect();
    let w = vec![1.0f64; ops.k()];
    bench("fedavg_agg (K=8 stacked)", 30, || {
        let _ = ops.fedavg_agg(&refs, &w).unwrap();
    });
    bench("pairwise_dist (K=8)", 30, || {
        let _ = ops.pairwise_dist(&refs).unwrap();
    });
    bench("cosine_sim (K=8)", 30, || {
        let _ = ops.cosine_sim(&refs).unwrap();
    });
    let (execs, mean_s) = ops.runtime().stats();
    println!("\n# runtime totals: {execs} executions, mean service {:.3} ms", mean_s * 1e3);
}
