//! Telemetry instrumentation-overhead bench — the observability layer's
//! own regression gate.
//!
//! The lifecycle tracer rides the admission hot path (every `submit`
//! stamps `Stage::Admit` into the lock-free span table), so the telemetry
//! PR's acceptance criterion is that instrumentation costs almost nothing:
//! admitted-tx throughput with telemetry **enabled** must stay within 5%
//! of throughput with telemetry **disabled**. This bench measures both
//! arms interleaved (on/off per repetition, so slow drift hits both
//! equally) over the same admission loop as `benches/mempool.rs`, compares
//! the **median** per-arm throughput (robust to a scheduler tick or noisy
//! CI neighbour perturbing a minority of reps, where a best-of gate could
//! flip on one bad rep), and emits the verdict as a boolean headline
//! metric (`1` = within 5%) that `bench_check` gates in CI — a tracer
//! change that makes stamping expensive fails the build, not a code
//! review.
//!
//! The span table is drained with `Tracer::reset()` between repetitions so
//! every arm sees the same slot-occupancy profile (claim-heavy up to the
//! table capacity, steal-path beyond it — both are part of the measured
//! cost).
//!
//!     cargo bench --bench telemetry [-- --smoke]    (or `make bench`)

use std::time::Instant;

use scalesfl::crypto::msp::MemberId;
use scalesfl::ledger::tx::{Envelope, Proposal, RwSet};
use scalesfl::mempool::{MempoolConfig, ShardMempool};
use scalesfl::telemetry;
use scalesfl::util::json::Json;

fn plain_envelope(nonce: u64) -> Envelope {
    Envelope {
        proposal: Proposal {
            channel: "shard0".into(),
            chaincode: "models".into(),
            function: "CreateModelUpdate".into(),
            args: vec![
                "1".into(),
                format!("client{nonce}"),
                "ab".repeat(32),
                "sim://blob".into(),
                "100".into(),
            ],
            creator: MemberId::new(format!("client{}", nonce % 64)),
            nonce,
        },
        rw_set: RwSet::default(),
        endorsements: Vec::new(),
    }
}

/// One timed admission run of `n` transactions into a fresh pool; returns
/// (ns_per_op, tx_per_s). The telemetry on/off state is whatever the
/// caller set on the global facade.
fn admit_run(n: usize) -> (f64, f64) {
    let pool = ShardMempool::new(
        "shard0",
        MempoolConfig { lane_capacity: n, ..Default::default() },
    );
    let envs: Vec<Envelope> = (0..n as u64).map(plain_envelope).collect();
    let t0 = Instant::now();
    for env in envs {
        pool.submit(env).expect("admit");
    }
    let per = t0.elapsed().as_secs_f64() / n as f64;
    // Free the span slots the run claimed so the next repetition (either
    // arm) starts from an empty table.
    telemetry::global().tracer().reset();
    (per * 1e9, 1.0 / per)
}

/// Median of a per-rep sample list (averaging the middle pair when even).
fn median(samples: &[f64]) -> f64 {
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let mid = sorted.len() / 2;
    if sorted.len() % 2 == 0 {
        (sorted[mid - 1] + sorted[mid]) / 2.0
    } else {
        sorted[mid]
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (n, reps) = if smoke { (5_000, 5) } else { (20_000, 5) };
    println!(
        "# telemetry bench{} — admission throughput, tracer on vs off\n",
        if smoke { " (smoke)" } else { "" }
    );

    // Interleave the arms rep-by-rep so slow drift (thermal, competing
    // load) hits both equally; the per-arm median tolerates a minority of
    // perturbed reps on either side without flipping the verdict.
    let (mut on_ns, mut on_tps) = (Vec::new(), Vec::new());
    let (mut off_ns, mut off_tps) = (Vec::new(), Vec::new());
    for rep in 0..reps {
        telemetry::global().set_enabled(true);
        let a = admit_run(n);
        telemetry::global().set_enabled(false);
        let b = admit_run(n);
        println!(
            "rep {rep}: on {:>8.0} ns/op ({:>10.0} tx/s)   off {:>8.0} ns/op ({:>10.0} tx/s)",
            a.0, a.1, b.0, b.1
        );
        on_ns.push(a.0);
        on_tps.push(a.1);
        off_ns.push(b.0);
        off_tps.push(b.1);
    }
    telemetry::global().set_enabled(true);

    let on = (median(&on_ns), median(&on_tps));
    let off = (median(&off_ns), median(&off_tps));
    // Overhead of the enabled tracer relative to the disabled gate, by
    // median throughput. Negative = noise in telemetry's favour.
    let overhead = (off.1 - on.1) / off.1;
    let within = overhead <= 0.05;
    println!(
        "\nmedian-of-{reps}: on {:.0} tx/s, off {:.0} tx/s, overhead {:+.2}% -> {}",
        on.1,
        off.1,
        overhead * 100.0,
        if within { "within 5% budget" } else { "OVER the 5% budget" }
    );

    let headline = Json::Arr(vec![Json::obj()
        .set("metric", "telemetry_overhead_within_5pct")
        .set("value", if within { 1.0 } else { 0.0 })
        .set("higher_is_better", true)]);
    let out = Json::obj()
        .set("bench", "telemetry")
        .set("mode", if smoke { "smoke" } else { "full" })
        .set("txs_per_rep", n)
        .set("reps", reps)
        .set(
            "telemetry_on",
            Json::obj().set("ns_per_op", on.0).set("tx_per_s", on.1),
        )
        .set(
            "telemetry_off",
            Json::obj().set("ns_per_op", off.0).set("tx_per_s", off.1),
        )
        .set("overhead_pct", overhead * 100.0)
        .set("within_5pct", within)
        .set("headline", headline);
    let path = if smoke {
        std::fs::create_dir_all("target/smoke").expect("create target/smoke");
        "target/smoke/BENCH_telemetry.json"
    } else {
        "BENCH_telemetry.json"
    };
    std::fs::write(path, format!("{out}\n")).expect("write BENCH_telemetry.json");
    println!("wrote {path}");
}
