//! §3.2 claim verification: sharding reduces endorsement computations from
//! C x P_E (flat) to C x P_E / S^2 per shard and C x P_E / S globally.
//!
//! Two measurements:
//! 1. the closed-form counts across S = 1..8 (the paper's formula), and
//! 2. the *measured* evaluation-invocation counter from a real ScaleSFL
//!    round, confirming the workflow performs exactly C/S x P_E/S
//!    endorsement evaluations per shard.

use scalesfl::caliper::figures::ablation_eval_count;
use scalesfl::fl::client::TrainConfig;
use scalesfl::sim::{Partition, ScaleSfl, SimConfig};

fn main() {
    println!("# Ablation — endorsement computations per round (C=64 clients, P_E=8 endorsers)");
    println!("{:<8} {:>12} {:>16} {:>14}", "shards", "flat CxPE", "per-shard", "global");
    for s in [1usize, 2, 4, 8] {
        let (flat, per_shard, global) = ablation_eval_count(64, 8, s);
        println!("{:<8} {:>12} {:>16} {:>14}", s, flat, per_shard, global);
    }

    let Some(ops) = scalesfl::runtime::shared_ops() else {
        eprintln!("artifacts not built — skipping measured section");
        return;
    };
    println!("\n# Measured: evaluation invocations in one real round");
    println!("{:<8} {:>10} {:>12} {:>16}", "shards", "clients", "endorsers", "measured evals");
    for shards in [1usize, 2, 4] {
        let cfg = SimConfig {
            shards,
            peers_per_shard: 2,
            clients_per_shard: 8 / shards,
            samples_per_client: 40,
            eval_samples: 16,
            test_samples: 64,
            train: TrainConfig { batch: 10, epochs: 1, lr: 0.05, dp: None },
            partition: Partition::Iid,
            verify_aggregate: false,
            seed: 7,
            ..Default::default()
        };
        let mut net = ScaleSfl::build(cfg, ops.clone()).expect("build");
        net.eval_invocations = 0;
        net.run_round().expect("round");
        println!(
            "{:<8} {:>10} {:>12} {:>16}",
            shards,
            8,
            2 * shards,
            net.eval_invocations
        );
    }
    println!("# expected: measured = (C/S) x P_E per shard x S shards; decreases per shard as S grows");
}
