//! Wire/transport bench — the multi-process fabric's regression gate.
//!
//! Two layers are measured:
//!
//! - **codec**: `encode_frame`/`decode_frame` ns per frame over a
//!   realistically endorsed `Submit` request (the hot frame on the submit
//!   path). The hardened decoder validates every length against the
//!   remaining buffer; this gate catches that validation getting
//!   accidentally expensive.
//! - **loopback TCP**: a full orderer-with-peers node served in-process
//!   over `tcp:127.0.0.1:0`, driven by [`RemoteGateway`] — one closed-loop
//!   arm for commit latency percentiles, one pipelined arm (submit all,
//!   then drain the handles) for end-to-end socket throughput. Every
//!   submitted transaction must come back committed: lost commits are a
//!   zero-baselined headline, so a demux or framing regression that drops
//!   events fails CI even if the timing numbers survive.
//!
//!     cargo bench --bench wire [-- --smoke]    (or `make bench`)

use std::time::Instant;

use scalesfl::crypto::msp::MemberId;
use scalesfl::fabric::wire::{decode_frame, encode_frame, Frame, Request};
use scalesfl::fabric::CommitOutcome;
use scalesfl::ledger::tx::Proposal;
use scalesfl::network::node::{bind_and_serve, FabricNode, NodeConfig};
use scalesfl::network::transport::Endpoint;
use scalesfl::network::RemoteGateway;
use scalesfl::util::json::Json;

fn proposal(key: &str, nonce: u64) -> Proposal {
    Proposal {
        channel: "ch".into(),
        chaincode: "kv".into(),
        function: "Put".into(),
        args: vec![key.into(), "ab".repeat(32)],
        creator: MemberId::new("client"),
        nonce,
    }
}

/// Percentile over a sorted copy of `samples` (nearest-rank).
fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (codec_iters, closed_txs, pipelined_txs) =
        if smoke { (10_000u64, 24u64, 64u64) } else { (200_000, 200, 1_000) };
    println!(
        "# wire bench{} — frame codec + loopback TCP fabric\n",
        if smoke { " (smoke)" } else { "" }
    );

    // ---- codec arm: one endorsed Submit frame, encoded/decoded in a loop.
    let node = FabricNode::build(&NodeConfig::default());
    let envelope = node.gateway.endorse(&proposal("codec", 0)).expect("endorse codec envelope");
    let frame = Frame::Request(Request::Submit { id: 42, envelope });
    let bytes = encode_frame(&frame);
    let frame_bytes = bytes.len();

    // The checksum keeps the optimizer honest: both loops feed an assert.
    let mut sink = 0usize;
    let t0 = Instant::now();
    for _ in 0..codec_iters {
        sink += encode_frame(&frame).len();
    }
    let encode_ns = t0.elapsed().as_secs_f64() * 1e9 / codec_iters as f64;
    let t0 = Instant::now();
    for _ in 0..codec_iters {
        let decoded = decode_frame(&bytes).expect("decode");
        sink += usize::from(matches!(decoded, Frame::Request(_)));
    }
    let decode_ns = t0.elapsed().as_secs_f64() * 1e9 / codec_iters as f64;
    assert_eq!(sink, codec_iters as usize * (frame_bytes + 1));
    println!("codec: {frame_bytes} B/frame, encode {encode_ns:.0} ns, decode {decode_ns:.0} ns");

    // ---- loopback arms: a real served node, driven over the socket.
    let ep = Endpoint::parse("tcp:127.0.0.1:0").expect("loopback endpoint");
    let (local, _accept) =
        bind_and_serve(FabricNode::build(&NodeConfig::default()), &ep).expect("bind loopback");
    let gw = RemoteGateway::connect(&local).expect("connect loopback");

    // Closed loop: one tx in flight, per-commit latency.
    let mut latencies_ms = Vec::with_capacity(closed_txs as usize);
    let mut committed = 0u64;
    for i in 0..closed_txs {
        let out = gw.submit_and_wait(&proposal(&format!("closed{i}"), i));
        if let CommitOutcome::Committed { latency, .. } = out {
            committed += 1;
            latencies_ms.push(latency.as_secs_f64() * 1e3);
        }
    }
    let p50 = percentile(&latencies_ms, 50.0);
    let p95 = percentile(&latencies_ms, 95.0);
    println!("closed loop: {committed}/{closed_txs} committed, p50 {p50:.2} ms, p95 {p95:.2} ms");

    // Pipelined: submit everything, then drain the handles.
    let t0 = Instant::now();
    let handles: Vec<_> = (0..pipelined_txs)
        .map(|i| gw.submit(&proposal(&format!("pipe{i}"), closed_txs + i)))
        .collect();
    let mut pipelined_committed = 0u64;
    for h in handles {
        if h.wait().is_valid() {
            pipelined_committed += 1;
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let tps = pipelined_committed as f64 / wall_s;
    println!(
        "pipelined: {pipelined_committed}/{pipelined_txs} committed in {wall_s:.2} s ({tps:.0} tx/s)"
    );
    let lost = (closed_txs - committed) + (pipelined_txs - pipelined_committed);

    let headline = Json::Arr(vec![
        Json::obj()
            .set("metric", "frame_encode_ns")
            .set("value", encode_ns)
            .set("higher_is_better", false),
        Json::obj()
            .set("metric", "frame_decode_ns")
            .set("value", decode_ns)
            .set("higher_is_better", false),
        Json::obj()
            .set("metric", "loopback_pipelined_tps")
            .set("value", tps)
            .set("higher_is_better", true),
        Json::obj()
            .set("metric", "remote_commits_lost")
            .set("value", lost as f64)
            .set("higher_is_better", false),
    ]);
    let out = Json::obj()
        .set("bench", "wire")
        .set("mode", if smoke { "smoke" } else { "full" })
        .set(
            "config",
            Json::obj()
                .set("codec_iters", codec_iters)
                .set("closed_txs", closed_txs)
                .set("pipelined_txs", pipelined_txs),
        )
        .set(
            "codec",
            Json::obj()
                .set("frame_bytes", frame_bytes)
                .set("encode_ns", encode_ns)
                .set("decode_ns", decode_ns),
        )
        .set(
            "closed_loop",
            Json::obj()
                .set("txs", closed_txs)
                .set("committed", committed)
                .set("commit_p50_ms", p50)
                .set("commit_p95_ms", p95),
        )
        .set(
            "pipelined",
            Json::obj()
                .set("txs", pipelined_txs)
                .set("committed", pipelined_committed)
                .set("wall_s", wall_s)
                .set("tps", tps),
        )
        .set("headline", headline);
    let path = if smoke {
        std::fs::create_dir_all("target/smoke").expect("create target/smoke");
        "target/smoke/BENCH_wire.json"
    } else {
        "BENCH_wire.json"
    };
    std::fs::write(path, format!("{out}\n")).expect("write BENCH_wire.json");
    println!("wrote {path}");
}
