//! Cross-shard relay bench: local admission vs 1-hop forwarding vs
//! shard→mainchain checkpoint relay, at 2/4/8 shards. Emits the baseline
//! to `BENCH_relay.json` (or `target/smoke/BENCH_relay.json` in `--smoke`
//! mode — the fast deterministic configuration the CI bench gate runs and
//! compares against `bench-baselines/`).
//!
//! Every wave submits fewer transactions than the batch size, so blocks
//! cut on the batch *timeout*: commit latency is timer-dominated
//! (≈ batch_timeout + delivery), which keeps the medians stable across
//! hosts, and the forwarding overhead isolates the relay's per-link
//! simnet latency. Acceptance: the 1-hop forward path adds **less than
//! one block interval** of commit latency at the median, while every
//! cross-shard transaction commits exactly once (dedup scenario
//! included).
//!
//!     cargo bench --bench relay [-- --smoke]    (or `make bench`)

use std::sync::Arc;
use std::time::Duration;

use scalesfl::crypto::msp::{CertificateAuthority, MemberId};
use scalesfl::fabric::chaincode::{Chaincode, TxContext};
use scalesfl::fabric::endorsement::EndorsementPolicy;
use scalesfl::fabric::orderer::{OrdererConfig, OrderingService};
use scalesfl::fabric::peer::Peer;
use scalesfl::fabric::{CommitOutcome, Gateway};
use scalesfl::ledger::block::ValidationCode;
use scalesfl::ledger::tx::Proposal;
use scalesfl::mempool::RelayConfig;
use scalesfl::util::json::Json;
use scalesfl::util::prng::Prng;

const BATCH_TIMEOUT_MS: u64 = 40;
const RELAY_BASE_MS: u64 = 8;
const RELAY_SPREAD_MS: u64 = 8;
const RELAY_JITTER_MS: u64 = 2;
const WAVE_TXS: usize = 8;

struct PutCc(&'static str);
impl Chaincode for PutCc {
    fn name(&self) -> &str {
        self.0
    }
    fn invoke(
        &self,
        ctx: &mut TxContext<'_>,
        _f: &str,
        args: &[String],
    ) -> Result<Vec<u8>, String> {
        ctx.put(&args[0], b"v".to_vec());
        Ok(vec![])
    }
}

/// S shards x 2 peers; every peer also joins the mainchain. Policies are
/// AnyOf(1) so endorsement crypto stays negligible next to the timers.
struct Net {
    shards: usize,
    peers: Vec<Vec<Arc<Peer>>>,
    orderer: Arc<OrderingService>,
}

fn build(shards: usize, seed: u64) -> Net {
    let ca = CertificateAuthority::new();
    let mut rng = Prng::new(seed);
    let mut peers: Vec<Vec<Arc<Peer>>> = Vec::with_capacity(shards);
    let mut all_members = Vec::new();
    for s in 0..shards {
        let shard_peers: Vec<Arc<Peer>> = (0..2)
            .map(|p| {
                let cred = ca.enroll(MemberId::new(format!("org{s}x{p}.peer")), &mut rng);
                Peer::new(cred, ca.clone())
            })
            .collect();
        all_members.extend(shard_peers.iter().map(|p| p.member.clone()));
        peers.push(shard_peers);
    }
    let main_policy = EndorsementPolicy::AnyOf(1, all_members);
    for (s, shard_peers) in peers.iter().enumerate() {
        let members: Vec<MemberId> = shard_peers.iter().map(|p| p.member.clone()).collect();
        let policy = EndorsementPolicy::AnyOf(1, members);
        for p in shard_peers {
            p.join_channel(&format!("shard{s}"), policy.clone());
            p.install_chaincode(&format!("shard{s}"), Arc::new(PutCc("kv"))).unwrap();
            p.join_channel("mainchain", main_policy.clone());
            p.install_chaincode("mainchain", Arc::new(PutCc("catalyst"))).unwrap();
        }
    }
    let all_peers: Vec<Arc<Peer>> = peers.iter().flatten().cloned().collect();
    let orderer = OrderingService::start(
        OrdererConfig {
            batch_size: 16,
            batch_timeout: Duration::from_millis(BATCH_TIMEOUT_MS),
            tick: Duration::from_millis(2),
            relay: Some(RelayConfig {
                base_latency: Duration::from_millis(RELAY_BASE_MS),
                latency_spread: Duration::from_millis(RELAY_SPREAD_MS),
                jitter: Duration::from_millis(RELAY_JITTER_MS),
                seed,
            }),
            ..Default::default()
        },
        all_peers,
        seed,
    );
    Net { shards, peers, orderer }
}

impl Net {
    /// Gateway endorsing with shard `s`, entering at shard `ingress`.
    fn shard_gateway(&self, s: usize, ingress: usize) -> Gateway {
        let mut gw = Gateway::new(self.peers[s].clone(), Arc::clone(&self.orderer));
        gw.ingress = Some(format!("shard{ingress}"));
        gw
    }

    /// Mainchain checkpoint uplink entering at shard `s`'s ingress.
    fn checkpoint_gateway(&self, s: usize) -> Gateway {
        let mut gw = Gateway::new(vec![Arc::clone(&self.peers[s][0])], Arc::clone(&self.orderer));
        gw.ingress = Some(format!("shard{s}"));
        gw
    }
}

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    /// Home ingress: no relay hop.
    Local,
    /// Neighbour ingress: one forwarding hop home.
    Forward,
    /// Shard-produced catalyst tx relayed to the mainchain channel.
    Checkpoint,
}

impl Mode {
    fn key_prefix(self, shards: usize) -> String {
        match self {
            Mode::Local => format!("loc{shards}-"),
            Mode::Forward => format!("fwd{shards}-"),
            Mode::Checkpoint => format!("ck{shards}-"),
        }
    }
}

fn median(sorted: &[f64]) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[sorted.len() / 2]
}

fn quantile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let i = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[i]
}

/// Run `waves` waves of WAVE_TXS transactions in `mode`; each wave's
/// handles are all in flight together and drained before the next wave,
/// so every block cuts on the batch timeout. Returns sorted commit
/// latencies in milliseconds.
fn run_mode(net: &Net, mode: Mode, waves: usize, nonce: &mut u64) -> Vec<f64> {
    let prefix = mode.key_prefix(net.shards);
    let gateways: Vec<Gateway> = (0..net.shards)
        .map(|s| match mode {
            Mode::Local => net.shard_gateway(s, s),
            Mode::Forward => net.shard_gateway(s, (s + 1) % net.shards),
            Mode::Checkpoint => net.checkpoint_gateway(s),
        })
        .collect();
    let mut latencies = Vec::with_capacity(waves * WAVE_TXS);
    for wave in 0..waves {
        let handles: Vec<_> = (0..WAVE_TXS)
            .map(|i| {
                let s = i % net.shards;
                *nonce += 1;
                let (channel, chaincode) = match mode {
                    Mode::Checkpoint => ("mainchain".to_string(), "catalyst"),
                    _ => (format!("shard{s}"), "kv"),
                };
                let prop = Proposal {
                    channel,
                    chaincode: chaincode.into(),
                    function: "Put".into(),
                    args: vec![format!("{prefix}w{wave}i{i}")],
                    creator: MemberId::new("bench-client"),
                    nonce: *nonce,
                };
                gateways[s].submit(&prop)
            })
            .collect();
        for h in handles {
            let out = h.wait();
            match &out {
                CommitOutcome::Committed { code: ValidationCode::Valid, latency } => {
                    latencies.push(latency.as_secs_f64() * 1e3);
                }
                _ => panic!("tx failed in wave {wave}: {out:?}"),
            }
        }
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    latencies
}

/// Every submitted key is committed exactly once: the aggregate count
/// across home channels matches the submission count (state scans dedupe
/// keys, commit-side DuplicateTxId blocks replays, and a lost tx would
/// leave the count short).
fn committed_once(net: &Net, mode: Mode, expected: usize) -> bool {
    let prefix = mode.key_prefix(net.shards);
    let total: usize = if mode == Mode::Checkpoint {
        net.peers[0][0].channel("mainchain").unwrap().scan(&prefix).len()
    } else {
        net.peers
            .iter()
            .enumerate()
            .map(|(s, shard_peers)| {
                shard_peers[0].channel(&format!("shard{s}")).unwrap().scan(&prefix).len()
            })
            .sum()
    };
    total == expected
}

/// The same transaction submitted at two ingress pools commits once.
fn dedup_scenario(net: &Net, nonce: &mut u64) -> Json {
    *nonce += 1;
    let prop = Proposal {
        channel: "shard0".into(),
        chaincode: "kv".into(),
        function: "Put".into(),
        args: vec![format!("dup{}-{}", net.shards, *nonce)],
        creator: MemberId::new("bench-client"),
        nonce: *nonce,
    };
    let before = net.orderer.relay().expect("relay on").snapshot();
    let direct = net.shard_gateway(0, 0);
    let detour = net.shard_gateway(0, 1 % net.shards);
    let h1 = direct.submit(&prop);
    let h2 = detour.submit(&prop);
    let o1 = h1.wait();
    let o2 = h2.wait();
    assert!(o1.is_valid(), "direct copy must commit: {o1:?}");
    assert!(o2.is_valid(), "gossiped copy resolves off the same commit: {o2:?}");
    let after = net.orderer.relay().unwrap().snapshot();
    let committed = net.peers[0][0].channel("shard0").unwrap().scan(&prop.args[0]).len();
    assert_eq!(committed, 1, "gossiped duplicate must commit exactly once");
    Json::obj()
        .set("deduped_hops", after.deduped - before.deduped)
        .set("committed", committed)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (shard_counts, waves): (&[usize], usize) =
        if smoke { (&[2, 4], 3) } else { (&[2, 4, 8], 6) };
    println!(
        "# relay bench{} — {} txs/wave, {waves} waves/mode, batch timeout {BATCH_TIMEOUT_MS} ms, \
         link {RELAY_BASE_MS}+{RELAY_SPREAD_MS}ms (+{RELAY_JITTER_MS}ms jitter)\n",
        if smoke { " (smoke)" } else { "" },
        WAVE_TXS
    );

    let mut nonce = 0u64;
    let mut scenarios: Vec<Json> = Vec::new();
    let mut headline_local = 0.0f64;
    let mut headline_overhead = 0.0f64;
    let mut headline_checkpoint = 0.0f64;
    let mut dedup = Json::obj();
    for (ci, &shards) in shard_counts.iter().enumerate() {
        let net = build(shards, 7 + shards as u64);
        let expected = waves * WAVE_TXS;
        let local = run_mode(&net, Mode::Local, waves, &mut nonce);
        let forward = run_mode(&net, Mode::Forward, waves, &mut nonce);
        let checkpoint = run_mode(&net, Mode::Checkpoint, waves, &mut nonce);
        let (lm, fm, cm) = (median(&local), median(&forward), median(&checkpoint));
        let overhead = fm - lm;
        let interval_ms = BATCH_TIMEOUT_MS as f64;
        let within = overhead < interval_ms;
        let once = committed_once(&net, Mode::Local, expected)
            && committed_once(&net, Mode::Forward, expected)
            && committed_once(&net, Mode::Checkpoint, expected);
        let relay = net.orderer.relay().unwrap().snapshot();
        println!(
            "shards={shards:<2} local={lm:>7.1}ms forward={fm:>7.1}ms (+{overhead:.1}ms) \
             checkpoint={cm:>7.1}ms | forwarded={} delivered={} dropped={}",
            relay.forwarded, relay.delivered, relay.dropped
        );
        assert!(once, "every cross-shard tx must commit exactly once");
        assert_eq!(relay.dropped, 0, "no relay losses expected");
        assert!(
            within,
            "forwarding added {overhead:.1}ms — more than one {interval_ms:.0}ms block interval"
        );
        if ci == 0 {
            headline_local = lm;
            headline_overhead = overhead;
            headline_checkpoint = cm;
            dedup = dedup_scenario(&net, &mut nonce);
        }
        scenarios.push(
            Json::obj()
                .set("shards", shards)
                .set(
                    "local_ms",
                    Json::obj().set("median", lm).set("p95", quantile(&local, 0.95)),
                )
                .set(
                    "forward_ms",
                    Json::obj().set("median", fm).set("p95", quantile(&forward, 0.95)),
                )
                .set(
                    "checkpoint_ms",
                    Json::obj().set("median", cm).set("p95", quantile(&checkpoint, 0.95)),
                )
                .set("forward_overhead_ms", overhead)
                .set("mean_hop_latency_ms", relay.mean_hop_latency_s() * 1e3)
                .set("within_one_interval", within)
                .set("committed_once", once),
        );
    }
    println!(
        "\nverdict: forward overhead {headline_overhead:.1}ms at the median \
         (acceptance: < {BATCH_TIMEOUT_MS} ms block interval), cross-shard txs commit exactly once"
    );

    let headline = Json::Arr(vec![
        Json::obj()
            .set("metric", "local_commit_ms_median")
            .set("value", headline_local)
            .set("higher_is_better", false),
        Json::obj()
            .set("metric", "forward_overhead_ms_median")
            .set("value", headline_overhead)
            .set("higher_is_better", false),
        Json::obj()
            .set("metric", "checkpoint_commit_ms_median")
            .set("value", headline_checkpoint)
            .set("higher_is_better", false),
    ]);
    let out = Json::obj()
        .set("bench", "relay")
        .set("mode", if smoke { "smoke" } else { "full" })
        .set(
            "config",
            Json::obj()
                .set("wave_txs", WAVE_TXS)
                .set("waves", waves)
                .set("batch_timeout_ms", BATCH_TIMEOUT_MS)
                .set("relay_base_ms", RELAY_BASE_MS)
                .set("relay_spread_ms", RELAY_SPREAD_MS)
                .set("relay_jitter_ms", RELAY_JITTER_MS),
        )
        .set("scenarios", Json::Arr(scenarios))
        .set("dedup", dedup)
        .set("headline", headline);
    let path = if smoke {
        std::fs::create_dir_all("target/smoke").expect("create target/smoke");
        "target/smoke/BENCH_relay.json"
    } else {
        "BENCH_relay.json"
    };
    std::fs::write(path, format!("{out}\n")).expect("write BENCH_relay.json");
    println!("wrote {path}");
}
