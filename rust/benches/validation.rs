//! Staged block-validation bench: serial vs parallel pre-validation on
//! signature-heavy blocks, the cross-peer verdict cache, and the MVCC
//! stale-shed path. Emits the baseline to `BENCH_validation.json` — or,
//! with `--smoke`, a reduced deterministic configuration to
//! `target/smoke/BENCH_validation.json` for the CI bench gate.
//!
//! Two framings are measured, both over the same signature-heavy block
//! (O(txs × endorsements) HMAC verifications — 256 txs × 8 endorsements
//! full, 64 × 4 smoke):
//!
//! - `single_peer`: one replica commits the block through a fresh
//!   validator at each worker count — the pure fan-out win, bounded by
//!   the host's core count.
//! - `replicated`: four replicas commit the same block the way the
//!   orderer's committer does — through ONE shared validator — so the
//!   first replica pays the (parallel) crypto and the rest hit the
//!   verdict cache. This is the system's actual commit path and the
//!   acceptance figure: >= 2x over the pre-refactor baseline (per-peer
//!   serial validators, no sharing) at 4 workers.
//!
//! Every run cross-checks the `ValidationCode` sequence and block hash
//! against the serial baseline (determinism).
//!
//!     cargo bench --bench validation [-- --smoke]    (or `make bench`)

use std::sync::Arc;
use std::time::Instant;

use scalesfl::crypto::msp::{CertificateAuthority, Credential, MemberId};
use scalesfl::fabric::endorsement::EndorsementPolicy;
use scalesfl::fabric::peer::Peer;
use scalesfl::fabric::validator::BlockValidator;
use scalesfl::ledger::block::ValidationCode;
use scalesfl::ledger::state::StateView;
use scalesfl::ledger::tx::{endorsement_payload, Endorsement, Envelope, Proposal, RwSet};
use scalesfl::mempool::{MempoolConfig, ShardMempool};
use scalesfl::util::json::Json;
use scalesfl::util::prng::Prng;

/// Workload shape; `--smoke` shrinks it to seconds while keeping the
/// same structure (and JSON schema, so baselines stay comparable).
#[derive(Clone, Copy)]
struct BenchCfg {
    block_txs: usize,
    endorsers: usize,
    replicas: usize,
    reps: usize,
    /// Contended txs in the stale-shed scenario.
    contended: usize,
}

const FULL: BenchCfg =
    BenchCfg { block_txs: 256, endorsers: 8, replicas: 4, reps: 5, contended: 64 };
const SMOKE: BenchCfg =
    BenchCfg { block_txs: 64, endorsers: 4, replicas: 4, reps: 2, contended: 16 };

struct Fixture {
    ca: CertificateAuthority,
    creds: Vec<Credential>,
    policy: EndorsementPolicy,
    envs: Vec<Envelope>,
}

/// A signature-heavy block: every tx carries `cfg.endorsers` HMAC
/// endorsements and the majority policy verifies all of them.
fn fixture(cfg: BenchCfg) -> Fixture {
    let ca = CertificateAuthority::new();
    let mut rng = Prng::new(42);
    let creds: Vec<_> = (0..cfg.endorsers)
        .map(|i| ca.enroll(MemberId::new(format!("org{i}.peer")), &mut rng))
        .collect();
    let members: Vec<MemberId> = creds.iter().map(|c| c.member.clone()).collect();
    let policy = EndorsementPolicy::MajorityOf(members);
    let envs: Vec<Envelope> = (0..cfg.block_txs as u64)
        .map(|nonce| {
            let proposal = Proposal {
                channel: "ch".into(),
                chaincode: "models".into(),
                function: "CreateModelUpdate".into(),
                args: vec![format!("k{nonce}"), "ab".repeat(32)],
                creator: MemberId::new("client"),
                nonce,
            };
            let rw_set = RwSet {
                reads: vec![],
                writes: vec![(format!("k{nonce}"), Some(b"v".to_vec()))],
            };
            let mut env = Envelope { proposal, rw_set, endorsements: Vec::new() };
            let payload = endorsement_payload(&env.tx_id(), &env.rw_set.digest());
            for c in &creds {
                env.endorsements
                    .push(Endorsement { endorser: c.member.clone(), signature: c.sign(&payload) });
            }
            env
        })
        .collect();
    Fixture { ca, creds, policy, envs }
}

fn fresh_peers(fx: &Fixture, n: usize, seed: u64) -> Vec<Arc<Peer>> {
    let mut rng = Prng::new(seed);
    (0..n)
        .map(|i| {
            let cred = fx.ca.enroll(MemberId::new(format!("replica{seed}x{i}.peer")), &mut rng);
            let p = Peer::new(cred, fx.ca.clone());
            p.join_channel("ch", fx.policy.clone());
            p
        })
        .collect()
}

/// Commit the block on `replicas` fresh peers. `shared_workers == None`
/// reproduces the pre-refactor baseline (each peer a private serial
/// validator, crypto paid per replica); `Some(w)` is the pipelined path
/// (one shared validator, `w` workers + verdict cache). Returns the best
/// wall time over `cfg.reps` repetitions plus the first run's codes.
fn commit_block(
    fx: &Fixture,
    cfg: BenchCfg,
    replicas: usize,
    shared_workers: Option<usize>,
    seed: u64,
) -> (f64, Vec<ValidationCode>, u64) {
    let mut best = f64::INFINITY;
    let mut codes: Vec<ValidationCode> = Vec::new();
    let mut cache_hits = 0u64;
    for rep in 0..cfg.reps {
        // Fresh peers each rep: replays would hit the duplicate check.
        let peers = fresh_peers(fx, replicas, seed * 100 + rep as u64);
        let shared = shared_workers.map(BlockValidator::new);
        let t0 = Instant::now();
        let mut blocks = Vec::with_capacity(replicas);
        for p in &peers {
            let block = match &shared {
                Some(v) => p.commit_batch_with(v, "ch", fx.envs.clone()).expect("commit"),
                None => p.commit_batch("ch", fx.envs.clone()).expect("commit"),
            };
            blocks.push(block);
        }
        let elapsed = t0.elapsed().as_secs_f64();
        best = best.min(elapsed);
        for b in &blocks[1..] {
            assert_eq!(b.hash(), blocks[0].hash(), "replica divergence");
            assert_eq!(b.validation, blocks[0].validation);
        }
        if rep == 0 {
            codes = blocks[0].validation.clone();
        }
        if let Some(v) = &shared {
            cache_hits = v.snapshot().cache_hits;
        }
    }
    (best, codes, cache_hits)
}

/// Contended-key scenario: K txs all endorsed against the same version of
/// one key, driven through a mempool with and without MVCC hinting, one
/// tx per block. Returns (commit MvccConflicts, stale_dropped) per mode.
fn stale_shed_scenario(fx: &Fixture, cfg: BenchCfg) -> Json {
    let contended = cfg.contended;
    let run = |hinted: bool, seed: u64| -> (u64, u64) {
        let peers = fresh_peers(fx, 1, seed);
        let ch = peers[0].channel("ch").unwrap();
        let pool = ShardMempool::new("ch", MempoolConfig::default());
        if hinted {
            pool.set_state_view(Arc::clone(&ch) as Arc<dyn StateView>);
        }
        // All read the contended key at version None; first committer wins.
        for nonce in 0..contended as u64 {
            let proposal = Proposal {
                channel: "ch".into(),
                chaincode: "kv".into(),
                function: "Put".into(),
                args: vec!["ctr".into()],
                creator: MemberId::new("client"),
                nonce,
            };
            let rw_set = RwSet {
                reads: vec![("ctr".into(), None)],
                writes: vec![("ctr".into(), Some(nonce.to_le_bytes().to_vec()))],
            };
            let mut env = Envelope { proposal, rw_set, endorsements: Vec::new() };
            // Policy is majority-of-endorsers; the fixture's creds sign.
            let payload = endorsement_payload(&env.tx_id(), &env.rw_set.digest());
            for cred in &fx.creds {
                env.endorsements.push(Endorsement {
                    endorser: cred.member.clone(),
                    signature: cred.sign(&payload),
                });
            }
            pool.submit(env).expect("admit");
        }
        let mut conflicts = 0u64;
        loop {
            let batch = pool.take_batch(1, 0);
            if batch.is_empty() {
                break;
            }
            let block = peers[0].commit_batch("ch", batch).expect("commit");
            conflicts += block
                .validation
                .iter()
                .filter(|c| **c == ValidationCode::MvccConflict)
                .count() as u64;
        }
        (conflicts, pool.stats().stale_dropped)
    };
    let (old_conflicts, old_dropped) = run(false, 7_000);
    let (new_conflicts, new_dropped) = run(true, 8_000);
    println!(
        "\n# stale shed ({contended} contended txs, 1 tx/block)\n\
         pre-refactor: {old_conflicts} MvccConflicts at commit, {old_dropped} shed early\n\
         hinted:       {new_conflicts} MvccConflicts at commit, {new_dropped} shed early"
    );
    assert!(new_dropped > 0, "hinted pool must shed stale txs");
    assert!(new_conflicts < old_conflicts, "hinting must cut commit conflicts");
    Json::obj()
        .set("contended_txs", contended)
        .set("old_mvcc_conflicts", old_conflicts)
        .set("old_stale_dropped", old_dropped)
        .set("new_mvcc_conflicts", new_conflicts)
        .set("new_stale_dropped", new_dropped)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let cfg = if smoke { SMOKE } else { FULL };
    let worker_counts: &[usize] = if smoke { &[1, 2, 4] } else { &[1, 2, 4, 8] };
    println!(
        "# validation bench{} — {} txs x {} endorsements, {} replicas\n",
        if smoke { " (smoke)" } else { "" },
        cfg.block_txs,
        cfg.endorsers,
        cfg.replicas
    );
    let fx = fixture(cfg);

    // Single replica: pure fan-out (bounded by host cores).
    let (serial_1p, serial_codes, _) = commit_block(&fx, cfg, 1, None, 10);
    println!("{:<36} {:>9.2} ms", "single peer, serial (baseline)", serial_1p * 1e3);
    let mut single = Json::obj().set("serial_s", serial_1p);
    for &w in worker_counts {
        let (t, codes, _) = commit_block(&fx, cfg, 1, Some(w), 20 + w as u64);
        assert_eq!(codes, serial_codes, "worker count changed validation codes");
        let label = format!("single peer, {w} workers");
        println!("{:<36} {:>9.2} ms   {:>5.2}x", label, t * 1e3, serial_1p / t);
        single = single.set(&format!("workers_{w}_s"), t);
    }

    // Replicated: the committer's path — serial baseline is per-peer
    // private validators (pre-refactor), pipelined is one shared
    // validator (fan-out + cross-peer verdict cache).
    let (serial_rep, rep_codes, _) = commit_block(&fx, cfg, cfg.replicas, None, 30);
    assert_eq!(rep_codes, serial_codes);
    let label = format!("{} replicas, per-peer serial", cfg.replicas);
    println!("\n{:<36} {:>9.2} ms", label, serial_rep * 1e3);
    let mut replicated = Json::obj().set("serial_s", serial_rep);
    let mut speedup_at_4 = 0.0;
    for &w in worker_counts {
        let (t, codes, hits) = commit_block(&fx, cfg, cfg.replicas, Some(w), 40 + w as u64);
        assert_eq!(codes, serial_codes, "worker count changed validation codes");
        let speedup = serial_rep / t;
        if w == 4 {
            speedup_at_4 = speedup;
        }
        println!(
            "{:<36} {:>9.2} ms   {:>5.2}x   cache_hits={hits}",
            format!("{} replicas, shared, {w} workers", cfg.replicas),
            t * 1e3,
            speedup
        );
        assert_eq!(
            hits,
            ((cfg.replicas - 1) * cfg.block_txs) as u64,
            "cache must serve replicas 2..N"
        );
        replicated = replicated.set(&format!("workers_{w}_s"), t);
    }
    replicated = replicated.set("speedup_at_4_workers", speedup_at_4);
    println!(
        "\nverdict: speedup_at_4_workers={speedup_at_4:.2}x (acceptance: >= 2x), determinism ok"
    );

    let stale = stale_shed_scenario(&fx, cfg);

    let headline = Json::Arr(vec![
        Json::obj()
            .set("metric", "replicated_speedup_at_4_workers")
            .set("value", speedup_at_4)
            .set("higher_is_better", true),
        Json::obj()
            .set("metric", "single_peer_serial_ms")
            .set("value", serial_1p * 1e3)
            .set("higher_is_better", false),
    ]);
    let out = Json::obj()
        .set("bench", "validation")
        .set("mode", if smoke { "smoke" } else { "full" })
        .set(
            "block",
            Json::obj()
                .set("txs", cfg.block_txs)
                .set("endorsements_per_tx", cfg.endorsers)
                .set("replicas", cfg.replicas)
                .set("reps", cfg.reps),
        )
        .set("single_peer", single)
        .set("replicated", replicated)
        .set("determinism_ok", true)
        .set("speedup_ok", speedup_at_4 >= 2.0)
        .set("stale_shed", stale)
        .set("headline", headline);
    let path = if smoke {
        std::fs::create_dir_all("target/smoke").expect("create target/smoke");
        "target/smoke/BENCH_validation.json"
    } else {
        "BENCH_validation.json"
    };
    std::fs::write(path, format!("{out}\n")).expect("write BENCH_validation.json");
    println!("\nwrote {path}");
}
