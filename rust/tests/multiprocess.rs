//! Multi-process fabric integration: real `scalesfl node` child processes
//! over real sockets.
//!
//! The acceptance test for the multi-process split: a 2-shard topology —
//! two orderer processes plus a gateway process fronting them — is
//! spawned as OS children of this test, driven through the remote client
//! over loopback TCP, and must commit the **exact same blocks** (height,
//! tip hash, state root) as the same proposals submitted through an
//! in-process `FabricNode` built from the same config. A second test runs
//! the whole exchange over a Unix-domain socket.
//!
//! Children are guarded: on any panic the `ChildNode` drop kills the
//! process, so a failing assertion never leaks orphaned servers into the
//! test host. Graceful shutdown is the production path — closing the
//! child's stdin — and the tests assert the child actually exits 0 that
//! way.

use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use scalesfl::crypto::msp::MemberId;
use scalesfl::ledger::tx::Proposal;
use scalesfl::network::node::{FabricNode, NodeConfig};
use scalesfl::network::transport::Endpoint;
use scalesfl::network::RemoteGateway;
use scalesfl::util::tempdir::TempDir;

/// One spawned `scalesfl node` child plus the endpoint it announced.
/// Dropping it kills the process — cleanup happens even when an assertion
/// fails mid-test.
struct ChildNode {
    child: Child,
    endpoint: Endpoint,
}

impl ChildNode {
    /// Spawn `scalesfl node <args>` and parse the `LISTENING <endpoint>`
    /// line it prints once bound (port 0 resolves to an ephemeral port).
    fn spawn(args: &[&str]) -> ChildNode {
        let mut child = Command::new(env!("CARGO_BIN_EXE_scalesfl"))
            .arg("node")
            .args(args)
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()
            .expect("spawn scalesfl node child");
        let stdout = child.stdout.take().expect("child stdout piped");
        let mut line = String::new();
        BufReader::new(stdout).read_line(&mut line).expect("read child banner");
        let ep = line
            .trim()
            .strip_prefix("LISTENING ")
            .unwrap_or_else(|| panic!("unexpected child banner: {line:?}"))
            .to_string();
        let endpoint = Endpoint::parse(&ep).expect("parse child endpoint");
        ChildNode { child, endpoint }
    }

    /// The production shutdown path: close the child's stdin and wait for
    /// it to exit on its own. Panics if it doesn't exit cleanly in time
    /// (the drop guard then kills it).
    fn stop(mut self) {
        drop(self.child.stdin.take());
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            match self.child.try_wait().expect("poll child") {
                Some(status) => {
                    assert!(status.success(), "child exited with {status}");
                    return;
                }
                None if Instant::now() < deadline => {
                    std::thread::sleep(Duration::from_millis(20))
                }
                None => panic!("child did not exit after stdin EOF"),
            }
        }
    }
}

impl Drop for ChildNode {
    fn drop(&mut self) {
        // Already-reaped children make kill a no-op error; ignore it.
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn proposal(channel: &str, key: &str, nonce: u64) -> Proposal {
    Proposal {
        channel: channel.into(),
        chaincode: "kv".into(),
        function: "Put".into(),
        args: vec![key.into(), format!("value-{nonce}")],
        creator: MemberId::new("client"),
        nonce,
    }
}

/// The in-process reference stack matching `scalesfl node orderer
/// --channels <channel> --seed <seed>` (all other flags at defaults).
fn reference(channel: &str, seed: u64) -> FabricNode {
    FabricNode::build(&NodeConfig {
        channels: vec![channel.to_string()],
        seed,
        ..NodeConfig::default()
    })
}

/// Drive the same proposal stream through a remote connection and a local
/// gateway, then assert the chains are byte-identical.
fn assert_remote_matches_local(gw: &RemoteGateway, local: &FabricNode, channel: &str, txs: u64) {
    for i in 0..txs {
        let p = proposal(channel, &format!("{channel}/k{i}"), i);
        let out = gw.submit_and_wait(&p);
        assert!(out.is_valid(), "remote tx {i} on {channel}: {out:?}");
        let out = local.gateway.submit_and_wait(&p);
        assert!(out.is_valid(), "local tx {i} on {channel}: {out:?}");
    }
    let remote = gw.status(channel).expect("remote status");
    let (height, tip, root) = local.status(channel).expect("local status");
    assert_eq!(remote.height, height, "height diverged on {channel}");
    assert_eq!(remote.tip, tip, "tip hash diverged on {channel}");
    assert_eq!(remote.state_root, root, "state root diverged on {channel}");
    assert_eq!(remote.height, txs, "batch_size 1 cuts one block per tx");
}

/// Tentpole acceptance: 2 shards as separate OS processes behind a
/// gateway process, compared block-for-block against in-process runs.
#[test]
fn two_shard_process_topology_matches_in_process_chains() {
    let s0 = ChildNode::spawn(&["orderer", "--channels", "s0", "--seed", "7"]);
    let s1 = ChildNode::spawn(&["orderer", "--channels", "s1", "--seed", "8"]);
    let upstream = format!("s0={},s1={}", s0.endpoint, s1.endpoint);
    let gw_proc = ChildNode::spawn(&["gateway", "--upstream", &upstream]);

    let gw = RemoteGateway::connect(&gw_proc.endpoint).expect("connect gateway");
    let (ref0, ref1) = (reference("s0", 7), reference("s1", 8));
    assert_remote_matches_local(&gw, &ref0, "s0", 3);
    assert_remote_matches_local(&gw, &ref1, "s1", 3);
    assert_eq!(gw.in_flight(), 0);

    // A channel no shard owns fails cleanly through the whole topology.
    let err = gw.status("s9").expect_err("unroutable channel");
    assert!(err.contains("no upstream"), "{err}");

    drop(gw);
    gw_proc.stop();
    s0.stop();
    s1.stop();
}

/// The same wire exchange over a Unix-domain socket, straight to one
/// orderer process (no gateway tier).
#[test]
fn uds_orderer_process_matches_in_process_chain() {
    let dir = TempDir::new("mp-uds");
    let sock = dir.join("node.sock");
    let listen = format!("uds:{}", sock.display());
    let node =
        ChildNode::spawn(&["orderer", "--listen", &listen, "--channels", "ch", "--seed", "7"]);
    assert!(matches!(node.endpoint, Endpoint::Uds(_)), "{:?}", node.endpoint);

    let gw = RemoteGateway::connect(&node.endpoint).expect("connect over uds");
    let local = reference("ch", 7);
    assert_remote_matches_local(&gw, &local, "ch", 2);

    drop(gw);
    node.stop();
    // The listener unlinks its socket file on shutdown.
    assert!(!sock.exists(), "stale socket file left behind");
}
