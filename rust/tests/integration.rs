//! Cross-module integration tests: whole-system behaviours that unit tests
//! can't cover — multi-round on-chain FL with DP, aggregation defences
//! end-to-end, byzantine shard servers vs mainchain verification, and
//! replica agreement across the full pipeline.

use scalesfl::chaincode::ModelMeta;
use scalesfl::fl::client::{Behavior, DpConfig, TrainConfig};
use scalesfl::fl::dp;
use scalesfl::sim::network::MAINCHAIN;
use scalesfl::sim::{AggDefense, DefenseChoice, Partition, ScaleSfl, SimConfig};

fn quick_cfg() -> SimConfig {
    SimConfig {
        shards: 2,
        peers_per_shard: 2,
        clients_per_shard: 3,
        samples_per_client: 60,
        eval_samples: 40,
        test_samples: 128,
        train: TrainConfig { batch: 10, epochs: 1, lr: 0.05, dp: None },
        partition: Partition::Iid,
        verify_aggregate: false,
        seed: 777,
        ..Default::default()
    }
}

#[test]
fn dp_training_round_with_accountant() {
    let Some(ops) = scalesfl::runtime::shared_ops() else { return };
    let mut cfg = quick_cfg();
    cfg.train = TrainConfig {
        batch: 32,
        epochs: 1,
        lr: 0.02,
        dp: Some(DpConfig { clip: 1.2, noise_mult: 0.4, delta: 1e-5 }),
    };
    let mut net = ScaleSfl::build(cfg, ops).unwrap();
    let r1 = net.run_round().unwrap();
    let r2 = net.run_round().unwrap();
    assert_eq!(r1.rejected_updates, 0);
    assert!(r2.global_eval.accuracy >= r1.global_eval.accuracy * 0.8);
    // Accountant over the worst-case client.
    let steps = net
        .shards
        .iter()
        .flat_map(|s| s.clients.iter().map(|c| c.dp_steps))
        .max()
        .unwrap();
    assert!(steps >= 2, "dp steps {steps}");
    let eps = dp::epsilon(32.0 / 60.0, 0.4, steps, 1e-5);
    assert!(eps.is_finite() && eps > 0.0);
}

#[test]
fn multikrum_excludes_boosted_updates_from_aggregate() {
    let Some(ops) = scalesfl::runtime::shared_ops() else { return };
    let mut cfg = quick_cfg();
    cfg.clients_per_shard = 4;
    cfg.agg_defense = AggDefense::MultiKrum { f: 1 };
    let mut net = ScaleSfl::build(cfg, ops.clone()).unwrap();
    // One booster per shard: endorsement has no norm check, so it lands
    // on-chain; Multi-Krum must drop it at aggregation time.
    net.set_behavior(0, Behavior::Boost(200));
    net.set_behavior(4, Behavior::Boost(200));
    let r = net.run_round().unwrap();
    assert_eq!(r.accepted_updates, 8, "boosters are endorsed (no norm defence)");
    // Global model should stay sane: accuracy clearly above random despite
    // two 200x-boosted updates in the committed set.
    assert!(
        r.global_eval.accuracy > 0.3,
        "krum failed to exclude boosters: acc {}",
        r.global_eval.accuracy
    );
    // Control: without the defence the same attack wrecks the global model.
    let mut cfg2 = quick_cfg();
    cfg2.clients_per_shard = 4;
    cfg2.agg_defense = AggDefense::None;
    let mut net2 = ScaleSfl::build(cfg2, ops).unwrap();
    net2.set_behavior(0, Behavior::Boost(200));
    net2.set_behavior(4, Behavior::Boost(200));
    let r2 = net2.run_round().unwrap();
    assert!(
        r2.global_eval.accuracy < r.global_eval.accuracy,
        "defence-less run should be worse: {} vs {}",
        r2.global_eval.accuracy,
        r.global_eval.accuracy
    );
}

#[test]
fn byzantine_shard_server_caught_by_mainchain_verification() {
    let Some(ops) = scalesfl::runtime::shared_ops() else { return };
    let mut cfg = quick_cfg();
    cfg.verify_aggregate = true;
    let mut net = ScaleSfl::build(cfg, ops.clone()).unwrap();
    net.run_round().unwrap();
    // A lying shard server posts a bogus "global" for round 2 directly.
    let bogus = ops.init_params(999).unwrap();
    let (digest, uri) = net.store.put(bogus);
    let proposal = scalesfl::ledger::tx::Proposal {
        channel: MAINCHAIN.into(),
        chaincode: "catalyst".into(),
        function: "FinalizeGlobal".into(),
        args: vec!["2".into(), digest.hex(), uri, "2".into()],
        creator: net.all_peers[0].member.clone(),
        nonce: 12345,
    };
    let gw = scalesfl::fabric::Gateway::new(
        net.all_peers.clone(),
        std::sync::Arc::clone(&net.orderer),
    );
    let outcome = gw.submit(&proposal).wait();
    // Round 2 has no shard models yet -> endorsement must fail.
    assert!(
        matches!(outcome, scalesfl::fabric::CommitOutcome::EndorsementFailed { .. }),
        "{outcome:?}"
    );
}

#[test]
fn replicas_agree_after_multiple_rounds() {
    let Some(ops) = scalesfl::runtime::shared_ops() else { return };
    let mut net = ScaleSfl::build(quick_cfg(), ops).unwrap();
    for _ in 0..2 {
        net.run_round().unwrap();
    }
    for shard in &net.shards {
        let chains: Vec<_> = shard
            .peers
            .iter()
            .map(|p| {
                let ch = p.channel(&shard.channel).unwrap();
                let chain = ch.chain.lock().unwrap();
                chain.verify().unwrap();
                (chain.height(), chain.tip_hash())
            })
            .collect();
        assert!(chains.windows(2).all(|w| w[0] == w[1]), "replica divergence: {chains:?}");
    }
    // Mainchain agreement across every peer in the network.
    let tips: Vec<_> = net
        .all_peers
        .iter()
        .map(|p| {
            let ch = p.channel(MAINCHAIN).unwrap();
            let chain = ch.chain.lock().unwrap();
            (chain.height(), chain.tip_hash())
        })
        .collect();
    assert!(tips.windows(2).all(|w| w[0] == w[1]));
}

#[test]
fn ledger_records_are_decodable_and_consistent() {
    let Some(ops) = scalesfl::runtime::shared_ops() else { return };
    let mut net = ScaleSfl::build(quick_cfg(), ops).unwrap();
    let r = net.run_round().unwrap();
    // Every committed model record decodes and its blob hash verifies.
    let shard = &net.shards[0];
    let ch = shard.peers[0].channel(&shard.channel).unwrap();
    let records = ch.scan("models/00000001/");
    assert_eq!(records.len(), r.accepted_updates / net.shards.len());
    for (_, raw) in records {
        let meta = ModelMeta::decode(&raw).unwrap();
        let digest = scalesfl::crypto::Digest::from_hex(&meta.hash).unwrap();
        let blob = net.store.get_verified(&meta.uri, &digest).unwrap();
        assert_eq!(blob.len(), net.ops.p_pad());
    }
    // The finalised global on the mainchain matches our in-memory global.
    let main = net.all_peers[0].channel(MAINCHAIN).unwrap();
    let meta = ModelMeta::decode(&main.query("global/00000001").unwrap()).unwrap();
    let digest = scalesfl::crypto::Digest::from_hex(&meta.hash).unwrap();
    let blob = net.store.get_verified(&meta.uri, &digest).unwrap();
    assert_eq!(*blob, net.global);
}

#[test]
fn committee_election_rotates_endorsers() {
    let Some(ops) = scalesfl::runtime::shared_ops() else { return };
    let mut cfg = quick_cfg();
    cfg.peers_per_shard = 4;
    cfg.committee_size = Some(2);
    let mut net = ScaleSfl::build(cfg, ops).unwrap();
    let r1 = net.run_round().unwrap();
    assert_eq!(r1.rejected_updates, 0);
    // Each tx endorsed by the 2-member committee only: eval invocations =
    // clients x committee (not clients x peers).
    assert_eq!(net.eval_invocations, (2 * 3 * 2) as u64);
    let r2 = net.run_round().unwrap();
    assert_eq!(r2.rejected_updates, 0);
    assert!(r2.global_eval.accuracy >= r1.global_eval.accuracy * 0.8);
}

#[test]
fn provenance_restore_recovers_checkpoint() {
    let Some(ops) = scalesfl::runtime::shared_ops() else { return };
    let mut net = ScaleSfl::build(quick_cfg(), ops).unwrap();
    net.run_round().unwrap();
    let checkpoint = net.global.clone();
    net.run_round().unwrap();
    assert_ne!(net.global, checkpoint);
    // Roll back to the round-1 pinned model (paper §5 disaster recovery).
    net.restore_from_round(1).unwrap();
    assert_eq!(net.global, checkpoint);
    assert!(net.restore_from_round(99).is_err());
}

#[test]
fn writer_partition_end_to_end() {
    let Some(ops) = scalesfl::runtime::shared_ops() else { return };
    let mut cfg = quick_cfg();
    cfg.partition = Partition::Writer;
    let mut net = ScaleSfl::build(cfg, ops).unwrap();
    let r = net.run_round().unwrap();
    assert_eq!(r.rejected_updates, 0);
    assert!(r.global_eval.accuracy > 0.15, "acc {}", r.global_eval.accuracy);
}

#[test]
fn roni_defense_composes_with_multikrum() {
    let Some(ops) = scalesfl::runtime::shared_ops() else { return };
    let mut cfg = quick_cfg();
    cfg.clients_per_shard = 4;
    cfg.defense = DefenseChoice::Roni { max_degradation: 0.15 };
    cfg.agg_defense = AggDefense::Both { f: 1 };
    let mut net = ScaleSfl::build(cfg, ops).unwrap();
    net.set_behavior(1, Behavior::NoiseUpdate);
    let mut last = None;
    for _ in 0..2 {
        last = Some(net.run_round().unwrap());
    }
    let r = last.unwrap();
    // The noise client is rejected at endorsement (RONI) in round >= 2.
    assert!(r.rejected_updates >= 1, "{r:?}");
    assert!(r.global_eval.accuracy > 0.5, "acc {}", r.global_eval.accuracy);
}
