//! Durability integration tests: kill a running network mid-surge and
//! prove the replicas come back bit-identical from disk — same tip hash,
//! same Merkle state root — then resume committing on top of the
//! recovered chain. A torn-write variant truncates a peer's block log
//! mid-record and checks recovery degrades to the longest verified
//! prefix instead of failing.

use std::fs::OpenOptions;
use std::sync::Arc;
use std::time::Duration;

use scalesfl::crypto::msp::{CertificateAuthority, Credential, MemberId};
use scalesfl::fabric::chaincode::{Chaincode, TxContext};
use scalesfl::fabric::endorsement::EndorsementPolicy;
use scalesfl::fabric::orderer::{OrdererConfig, OrderingService};
use scalesfl::fabric::peer::Peer;
use scalesfl::fabric::Gateway;
use scalesfl::ledger::store::{DurabilityMode, LedgerConfig};
use scalesfl::ledger::tx::{Envelope, Proposal};
use scalesfl::util::prng::Prng;
use scalesfl::util::tempdir::TempDir;

struct PutCc;
impl Chaincode for PutCc {
    fn name(&self) -> &str {
        "kv"
    }
    fn invoke(
        &self,
        ctx: &mut TxContext<'_>,
        _f: &str,
        args: &[String],
    ) -> Result<Vec<u8>, String> {
        ctx.put(&args[0], b"v".to_vec());
        Ok(vec![])
    }
}

/// Fresh peer processes for the same enrolled identities: after a "crash"
/// the replicas restart with the credentials they already hold, not new
/// enrollments (a new secret would invalidate every logged endorsement).
fn spawn_peers(creds: &[Credential], ca: &CertificateAuthority) -> Vec<Arc<Peer>> {
    let peers: Vec<Arc<Peer>> =
        creds.iter().map(|c| Peer::new(c.clone(), ca.clone())).collect();
    let members: Vec<MemberId> = peers.iter().map(|p| p.member.clone()).collect();
    for p in &peers {
        p.join_channel("ch", EndorsementPolicy::MajorityOf(members.clone()));
        p.install_chaincode("ch", Arc::new(PutCc)).unwrap();
    }
    peers
}

fn put_proposal(key: &str, nonce: u64) -> Proposal {
    Proposal {
        channel: "ch".into(),
        chaincode: "kv".into(),
        function: "Put".into(),
        args: vec![key.into()],
        creator: MemberId::new("client"),
        nonce,
    }
}

/// Submit `n` Put transactions with all handles in flight together (a
/// surge, so blocks cut on size and the log sees multi-tx blocks), and
/// require every one of them to commit Valid.
fn surge(gw: &Gateway, prefix: &str, n: u64, nonce: &mut u64) {
    let handles: Vec<_> = (0..n)
        .map(|i| {
            *nonce += 1;
            gw.submit(&put_proposal(&format!("{prefix}{i}"), *nonce))
        })
        .collect();
    for (i, h) in handles.into_iter().enumerate() {
        let out = h.wait();
        assert!(out.is_valid(), "{prefix}{i} failed: {out:?}");
    }
}

fn tip_of(peer: &Peer) -> (u64, scalesfl::crypto::Digest, scalesfl::crypto::Digest) {
    let ch = peer.channel("ch").unwrap();
    let tip = ch.chain.lock().unwrap().tip_hash();
    (ch.height(), tip, ch.state_root())
}

#[test]
fn kill_and_restart_mid_surge_recovers_identical_state() {
    let tmp = TempDir::new("dur-restart");
    let ca = CertificateAuthority::new();
    let mut rng = Prng::new(42);
    let creds: Vec<Credential> = (0..2)
        .map(|i| ca.enroll(MemberId::new(format!("org{i}.peer")), &mut rng))
        .collect();
    let lcfg = LedgerConfig {
        dir: tmp.path().to_path_buf(),
        durability: DurabilityMode::Group(Duration::from_millis(2)),
        snapshot_every: 2,
    };
    let ordcfg = || OrdererConfig {
        batch_size: 4,
        batch_timeout: Duration::from_millis(10),
        tick: Duration::from_millis(1),
        ledger: Some(lcfg.clone()),
        ..OrdererConfig::default()
    };
    let mut nonce = 0u64;

    // Epoch 1: commit a surge, then kill the whole network (drop order is
    // gateway -> orderer -> peers; the orderer drop drains the committer,
    // the store drops flush the final group-commit window).
    let (height, tip, root) = {
        let peers = spawn_peers(&creds, &ca);
        let orderer = OrderingService::start(ordcfg(), peers.clone(), 7);
        let gw = Gateway::new(peers.clone(), Arc::clone(&orderer));
        surge(&gw, "a", 18, &mut nonce);
        let snap = tip_of(&peers[0]);
        assert_eq!(snap, tip_of(&peers[1]), "replicas diverged before the crash");
        assert!(snap.0 >= 5, "18 txs at batch_size 4 must cut >= 5 blocks");
        snap
    };

    // Epoch 2: fresh peer processes recover the channel purely from disk.
    let peers = spawn_peers(&creds, &ca);
    for p in &peers {
        let rep = p.attach_store("ch", &lcfg).unwrap();
        assert_eq!(rep.height, height, "{}: wrong recovered height", p.member);
        assert_eq!(rep.state_root, root, "{}: wrong recovered state root", p.member);
        assert_eq!(rep.truncated_bytes, 0, "clean shutdown must not leave torn tails");
        assert!(!rep.snapshot_fallback);
        // snapshot_every = 2 and height >= 5: recovery must have gone
        // through a snapshot plus a strict log suffix, not a full replay.
        assert!(rep.snapshot_height >= 2, "no snapshot taken: {rep:?}");
        assert_eq!(rep.snapshot_height + rep.replayed_blocks, height);
        assert_eq!(tip_of(p), (height, tip, root), "{}: tip mismatch", p.member);
    }
    for p in &peers {
        let ch = p.channel("ch").unwrap();
        for i in 0..18 {
            assert!(ch.query(&format!("a{i}")).is_some(), "lost a{i} on {}", p.member);
        }
    }

    // Epoch 3: the recovered replicas resume committing on top.
    let orderer = OrderingService::start(ordcfg(), peers.clone(), 8);
    let gw = Gateway::new(peers.clone(), Arc::clone(&orderer));
    surge(&gw, "b", 12, &mut nonce);
    let after = tip_of(&peers[0]);
    assert_eq!(after, tip_of(&peers[1]), "replicas diverged after recovery");
    assert!(after.0 > height);
    for p in &peers {
        let ch = p.channel("ch").unwrap();
        // The first post-restart block chains off the recovered tip.
        let chain = ch.chain.lock().unwrap();
        assert_eq!(chain.get(height).unwrap().header.prev_hash, tip);
        chain.verify().unwrap();
        drop(chain);
        for i in 0..12 {
            assert!(ch.query(&format!("b{i}")).is_some(), "lost b{i} on {}", p.member);
        }
    }
}

#[test]
fn torn_log_tail_is_truncated_and_recovery_keeps_verified_prefix() {
    let tmp = TempDir::new("dur-torn");
    let ca = CertificateAuthority::new();
    let mut rng = Prng::new(9);
    let cred = ca.enroll(MemberId::new("org0.peer"), &mut rng);
    let member = cred.member.clone();
    let lcfg = LedgerConfig {
        dir: tmp.path().to_path_buf(),
        durability: DurabilityMode::Strict,
        snapshot_every: 0, // log only: recovery is a full replay
    };
    let make_peer = || {
        let p = Peer::new(cred.clone(), ca.clone());
        p.join_channel("ch", EndorsementPolicy::AnyOf(1, vec![member.clone()]));
        p.install_chaincode("ch", Arc::new(PutCc)).unwrap();
        p
    };
    let commit_one = |p: &Arc<Peer>, key: &str, nonce: u64| {
        let prop = put_proposal(key, nonce);
        let (rw_set, endorsement, _) = p.endorse(&prop).unwrap();
        let env = Envelope { proposal: prop, rw_set, endorsements: vec![endorsement] };
        p.commit_batch("ch", vec![env]).unwrap();
    };

    // 6 single-tx blocks, then note the tip the chain had at height 5.
    let peer = make_peer();
    peer.attach_store("ch", &lcfg).unwrap();
    for i in 0..6u64 {
        commit_one(&peer, &format!("k{i}"), i);
    }
    let tip5 = peer.channel("ch").unwrap().chain.lock().unwrap().get(4).unwrap().hash();
    drop(peer);

    // Tear the last record: chop 3 bytes off the log, as a crash mid-write
    // would. The final block must vanish; everything below it survives.
    let log = tmp.path().join("org0.peer").join("ch").join("blocks.log");
    let f = OpenOptions::new().write(true).open(&log).unwrap();
    let len = f.metadata().unwrap().len();
    f.set_len(len - 3).unwrap();
    drop(f);

    let peer = make_peer();
    let rep = peer.attach_store("ch", &lcfg).unwrap();
    assert_eq!(rep.height, 5, "torn tail must roll back exactly one block");
    assert_eq!(rep.replayed_blocks, 5);
    assert!(rep.truncated_bytes > 0, "the torn record counts as truncated");
    let ch = peer.channel("ch").unwrap();
    assert_eq!(ch.chain.lock().unwrap().tip_hash(), tip5);
    assert!(ch.query("k4").is_some());
    assert!(ch.query("k5").is_none(), "the torn block's write must be gone");

    // The lost transaction can be re-committed on the truncated chain...
    commit_one(&peer, "k5", 100);
    assert_eq!(ch.height(), 6);
    ch.chain.lock().unwrap().verify().unwrap();
    drop(ch);
    drop(peer);

    // ...and the repaired log reopens cleanly, no further truncation.
    let peer = make_peer();
    let rep = peer.attach_store("ch", &lcfg).unwrap();
    assert_eq!((rep.height, rep.truncated_bytes), (6, 0));
    assert!(peer.channel("ch").unwrap().query("k5").is_some());
}
