//! Offline vendored `anyhow`: the API-compatible subset this repo uses —
//! a string-backed `Error`, the `anyhow!`/`bail!` macros, the `Context`
//! extension trait for `Result`/`Option`, and the `Result<T>` alias.
//!
//! The real crate wraps arbitrary `std::error::Error` trait objects; this
//! vendored copy flattens everything to the rendered message chain, which
//! is all the repo's call sites observe (`{e}` formatting and `is_err()`
//! checks). No backtraces, no downcasting.

use std::fmt;

/// String-backed error value.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context layer, `anyhow`-style (`context: cause`).
    pub fn context<C: fmt::Display>(self, ctx: C) -> Error {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error { msg: s }
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error { msg: s.to_string() }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e)
    }
}

impl From<std::fmt::Error> for Error {
    fn from(e: std::fmt::Error) -> Error {
        Error::msg(e)
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Error {
        Error::msg(e)
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Error {
        Error::msg(e)
    }
}

impl From<std::string::FromUtf8Error> for Error {
    fn from(e: std::string::FromUtf8Error) -> Error {
        Error::msg(e)
    }
}

impl From<std::array::TryFromSliceError> for Error {
    fn from(e: std::array::TryFromSliceError) -> Error {
        Error::msg(e)
    }
}

/// `anyhow`-style result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        if flag {
            bail!("flag was {}", flag);
        }
        Ok(7)
    }

    #[test]
    fn macros_and_alias() {
        assert_eq!(fails(false).unwrap(), 7);
        let e = fails(true).unwrap_err();
        assert_eq!(e.to_string(), "flag was true");
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
        let owned: Error = anyhow!(String::from("owned"));
        assert_eq!(owned.to_string(), "owned");
        let fmt = anyhow!("x = {}", 3);
        assert_eq!(fmt.to_string(), "x = 3");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), String> = Err("cause".into());
        let e = r.context("ctx").unwrap_err();
        assert_eq!(e.to_string(), "ctx: cause");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
        assert_eq!(Some(3u32).context("never").unwrap(), 3);
    }

    #[test]
    fn question_mark_conversions() {
        fn io() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(io().is_err());
        fn parse() -> Result<i32> {
            Ok("12x".parse::<i32>()?)
        }
        assert!(parse().is_err());
    }
}
