//! Offline vendored HMAC (RFC 2104) over the vendored SHA-256: the
//! API-compatible subset of the `hmac` crate this repo uses
//! (`Hmac<Sha256>` driven through the `Mac` trait).

#![allow(clippy::needless_range_loop)]

use std::marker::PhantomData;

use sha2::{Digest, Sha256};

const BLOCK: usize = 64;

/// Key error (never returned by this HMAC — any key length is valid —
/// but kept for API compatibility).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct InvalidLength;

/// Tag verification failure.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MacError;

/// Finalized MAC tag.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CtOutput([u8; 32]);

impl CtOutput {
    pub fn into_bytes(self) -> [u8; 32] {
        self.0
    }
}

/// Keyed-MAC trait (subset of `digest::Mac`).
pub trait Mac: Sized {
    fn new_from_slice(key: &[u8]) -> Result<Self, InvalidLength>;
    fn update(&mut self, data: &[u8]);
    fn finalize(self) -> CtOutput;
    fn verify_slice(self, tag: &[u8]) -> Result<(), MacError>;
}

/// HMAC instance; only `Hmac<Sha256>` is implemented in this vendored copy.
#[derive(Clone)]
pub struct Hmac<D> {
    inner: Sha256,
    opad_key: [u8; BLOCK],
    _digest: PhantomData<D>,
}

impl Mac for Hmac<Sha256> {
    fn new_from_slice(key: &[u8]) -> Result<Self, InvalidLength> {
        let mut k0 = [0u8; BLOCK];
        if key.len() > BLOCK {
            k0[..32].copy_from_slice(&Sha256::digest_of(key));
        } else {
            k0[..key.len()].copy_from_slice(key);
        }
        let mut ipad = [0u8; BLOCK];
        let mut opad = [0u8; BLOCK];
        for i in 0..BLOCK {
            ipad[i] = k0[i] ^ 0x36;
            opad[i] = k0[i] ^ 0x5c;
        }
        let mut inner = Sha256::new();
        inner.update(ipad);
        Ok(Hmac { inner, opad_key: opad, _digest: PhantomData })
    }

    fn update(&mut self, data: &[u8]) {
        self.inner.update(data);
    }

    fn finalize(self) -> CtOutput {
        let inner_hash: [u8; 32] = self.inner.finalize().into();
        let mut outer = Sha256::new();
        outer.update(self.opad_key);
        outer.update(inner_hash);
        CtOutput(outer.finalize().into())
    }

    fn verify_slice(self, tag: &[u8]) -> Result<(), MacError> {
        let computed = self.finalize().into_bytes();
        if tag.len() != computed.len() {
            return Err(MacError);
        }
        // Constant-time-style comparison.
        let mut diff = 0u8;
        for (a, b) in computed.iter().zip(tag) {
            diff |= a ^ b;
        }
        if diff == 0 {
            Ok(())
        } else {
            Err(MacError)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hmac_hex(key: &[u8], msg: &[u8]) -> String {
        let mut mac = Hmac::<Sha256>::new_from_slice(key).unwrap();
        mac.update(msg);
        mac.finalize().into_bytes().iter().map(|b| format!("{b:02x}")).collect()
    }

    #[test]
    fn rfc4231_test_vectors() {
        // RFC 4231 test case 1.
        assert_eq!(
            hmac_hex(&[0x0b; 20], b"Hi There"),
            "b0344c61d8db38535ca8afceaf0bf12b881dc200c9833da726e9376c2e32cff7"
        );
        // RFC 4231 test case 2 ("Jefe").
        assert_eq!(
            hmac_hex(b"Jefe", b"what do ya want for nothing?"),
            "5bdcc146bf60754e6a042426089575c75a003f089d2739839dec58b964ec3843"
        );
        // RFC 4231 test case 6: key longer than one block.
        assert_eq!(
            hmac_hex(
                &[0xaa; 131],
                b"Test Using Larger Than Block-Size Key - Hash Key First"
            ),
            "60e431591ee0b67f0d8a26aacbf5b77f8e0bc6213728c5140546040f0ee37f54"
        );
    }

    #[test]
    fn verify_accepts_good_rejects_bad() {
        let mut mac = Hmac::<Sha256>::new_from_slice(b"secret").unwrap();
        mac.update(b"payload");
        let tag = mac.clone().finalize().into_bytes();
        assert!(mac.clone().verify_slice(&tag).is_ok());
        let mut bad = tag;
        bad[0] ^= 1;
        assert!(mac.clone().verify_slice(&bad).is_err());
        assert!(mac.verify_slice(&tag[..16]).is_err());
    }
}
