//! Offline stub of the `xla` (PJRT) crate.
//!
//! The real crate FFI-binds XLA's PJRT CPU client; this container has no
//! XLA toolchain, so the stub keeps the API surface the repo compiles
//! against while gating execution:
//!
//! - [`Literal`] is implemented for real on host memory (construction,
//!   reshape, readback) — `runtime::tensor` round-trips work.
//! - [`PjRtClient::compile`] / [`PjRtLoadedExecutable::execute`] /
//!   [`HloModuleProto::from_text_file`] return errors, so anything needing
//!   compiled artifacts fails fast with a clear message. Callers already
//!   skip gracefully when `artifacts/manifest.txt` is absent.
//!
//! Swap in the real `xla` crate via a `[patch]` entry when building on a
//! host with the XLA runtime available.

use anyhow::{bail, Result};

/// Element dtypes the repo's tensors use (plus spares so `match` arms with
/// a catch-all stay reachable).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    F64,
    U8,
    Pred,
}

/// Host tensor storage for the stub literal.
#[doc(hidden)]
#[derive(Clone, Debug, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Scalar types storable in a [`Literal`].
pub trait NativeType: Copy + Sized {
    #[doc(hidden)]
    const TY: ElementType;
    #[doc(hidden)]
    fn wrap(v: Vec<Self>) -> Data;
    #[doc(hidden)]
    fn unwrap(d: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn wrap(v: Vec<f32>) -> Data {
        Data::F32(v)
    }
    fn unwrap(d: &Data) -> Option<Vec<f32>> {
        match d {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn wrap(v: Vec<i32>) -> Data {
        Data::I32(v)
    }
    fn unwrap(d: &Data) -> Option<Vec<i32>> {
        match d {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Host-side literal (dense array or tuple).
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { data: T::wrap(vec![v]), dims: Vec::new() }
    }

    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { data: T::wrap(v.to_vec()), dims: vec![v.len() as i64] }
    }

    fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(_) => 0,
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.element_count() {
            bail!(
                "reshape: {} elements into shape {:?} ({} elements)",
                self.element_count(),
                dims,
                want
            );
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        let ty = match &self.data {
            Data::F32(_) => ElementType::F32,
            Data::I32(_) => ElementType::S32,
            Data::Tuple(_) => bail!("array_shape on a tuple literal"),
        };
        Ok(ArrayShape { dims: self.dims.clone(), ty })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match T::unwrap(&self.data) {
            Some(v) => Ok(v),
            None => bail!("literal dtype mismatch (want {:?})", T::TY),
        }
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.data {
            Data::Tuple(parts) => Ok(parts.clone()),
            _ => bail!("to_tuple on a non-tuple literal"),
        }
    }
}

/// Shape of a dense (non-tuple) literal.
#[derive(Clone, Debug, PartialEq)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

const STUB_MSG: &str =
    "xla stub: PJRT execution unavailable in this offline build (vendor the real `xla` crate to run artifacts)";

/// Parsed HLO module (never constructible in the stub).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        bail!("{}", STUB_MSG)
    }
}

/// XLA computation wrapper.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// PJRT client handle.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient(()))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        bail!("{}", STUB_MSG)
    }
}

/// Compiled executable handle (never constructible in the stub).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        bail!("{}", STUB_MSG)
    }
}

/// Device buffer handle (never constructible in the stub).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        bail!("{}", STUB_MSG)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_construct_reshape_readback() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let m = l.reshape(&[2, 3]).unwrap();
        let shape = m.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 3]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(m.to_vec::<f32>().unwrap().len(), 6);
        assert!(m.to_vec::<i32>().is_err());
        assert!(l.reshape(&[7]).is_err());

        let s = Literal::scalar(5i32);
        assert_eq!(s.array_shape().unwrap().dims().len(), 0);
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![5]);
    }

    #[test]
    fn execution_paths_error_cleanly() {
        assert!(HloModuleProto::from_text_file("nope.hlo.txt").is_err());
        let client = PjRtClient::cpu().unwrap();
        let comp = XlaComputation(());
        assert!(client.compile(&comp).is_err());
    }
}
