//! Offline vendored SHA-256: the API-compatible subset of the `sha2` crate
//! this repo uses (`Sha256` driven through the `Digest` trait). Pure-Rust
//! FIPS 180-4 implementation; correctness is pinned by the known-answer
//! tests below and by `scalesfl::crypto`'s `sha256("abc")` vector.

// The message-schedule loops index fixed-size arrays; an iterator form
// obscures the FIPS reference notation.
#![allow(clippy::needless_range_loop)]

/// Streaming-hash trait (subset of `digest::Digest`).
pub trait Digest: Sized {
    fn new() -> Self;
    fn update(&mut self, data: impl AsRef<[u8]>);
    fn finalize(self) -> Output;
}

/// Finalized 32-byte digest.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Output([u8; 32]);

impl From<Output> for [u8; 32] {
    fn from(o: Output) -> [u8; 32] {
        o.0
    }
}

impl AsRef<[u8]> for Output {
    fn as_ref(&self) -> &[u8] {
        &self.0
    }
}

const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4, 0xab1c5ed5,
    0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174,
    0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967,
    0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab, 0x5be0cd19,
];

/// SHA-256 streaming state.
#[derive(Clone)]
pub struct Sha256 {
    h: [u32; 8],
    buf: [u8; 64],
    buf_len: usize,
    /// Total message length in bytes.
    len: u64,
}

impl Sha256 {
    /// One-shot convenience used by the vendored `hmac` crate.
    pub fn digest_of(data: &[u8]) -> [u8; 32] {
        let mut h = Self::new();
        h.update(data);
        h.finalize().into()
    }

    fn compress(&mut self, block: &[u8]) {
        debug_assert_eq!(block.len(), 64);
        let mut w = [0u32; 64];
        for t in 0..16 {
            w[t] = u32::from_be_bytes([
                block[t * 4],
                block[t * 4 + 1],
                block[t * 4 + 2],
                block[t * 4 + 3],
            ]);
        }
        for t in 16..64 {
            let s0 = w[t - 15].rotate_right(7) ^ w[t - 15].rotate_right(18) ^ (w[t - 15] >> 3);
            let s1 = w[t - 2].rotate_right(17) ^ w[t - 2].rotate_right(19) ^ (w[t - 2] >> 10);
            w[t] = w[t - 16]
                .wrapping_add(s0)
                .wrapping_add(w[t - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut hh] = self.h;
        for t in 0..64 {
            let big_s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = hh
                .wrapping_add(big_s1)
                .wrapping_add(ch)
                .wrapping_add(K[t])
                .wrapping_add(w[t]);
            let big_s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = big_s0.wrapping_add(maj);
            hh = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        self.h[0] = self.h[0].wrapping_add(a);
        self.h[1] = self.h[1].wrapping_add(b);
        self.h[2] = self.h[2].wrapping_add(c);
        self.h[3] = self.h[3].wrapping_add(d);
        self.h[4] = self.h[4].wrapping_add(e);
        self.h[5] = self.h[5].wrapping_add(f);
        self.h[6] = self.h[6].wrapping_add(g);
        self.h[7] = self.h[7].wrapping_add(hh);
    }
}

impl Digest for Sha256 {
    fn new() -> Self {
        Sha256 { h: H0, buf: [0u8; 64], buf_len: 0, len: 0 }
    }

    fn update(&mut self, data: impl AsRef<[u8]>) {
        let mut data = data.as_ref();
        self.len = self.len.wrapping_add(data.len() as u64);
        // Fill a partial buffer first.
        if self.buf_len > 0 {
            let take = (64 - self.buf_len).min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                self.compress(&block);
                self.buf_len = 0;
            }
        }
        while data.len() >= 64 {
            let (block, rest) = data.split_at(64);
            self.compress(block);
            data = rest;
        }
        if !data.is_empty() {
            self.buf[..data.len()].copy_from_slice(data);
            self.buf_len = data.len();
        }
    }

    fn finalize(mut self) -> Output {
        let bit_len = self.len.wrapping_mul(8);
        // Padding: 0x80, zeros, then the 64-bit big-endian bit length.
        self.update([0x80u8]);
        while self.buf_len != 56 {
            self.update([0u8]);
        }
        // The length bytes exactly complete the block.
        let mut block = [0u8; 64];
        block[..56].copy_from_slice(&self.buf[..56]);
        block[56..].copy_from_slice(&bit_len.to_be_bytes());
        self.compress(&block);
        let mut out = [0u8; 32];
        for (i, word) in self.h.iter().enumerate() {
            out[i * 4..(i + 1) * 4].copy_from_slice(&word.to_be_bytes());
        }
        Output(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hex(b: &[u8]) -> String {
        b.iter().map(|x| format!("{x:02x}")).collect()
    }

    #[test]
    fn known_answer_vectors() {
        // FIPS 180-4 / NIST vectors.
        assert_eq!(
            hex(&Sha256::digest_of(b"")),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            hex(&Sha256::digest_of(b"abc")),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            hex(&Sha256::digest_of(
                b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"
            )),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn streaming_matches_oneshot_across_boundaries() {
        let msg: Vec<u8> = (0..300u16).map(|i| (i % 251) as u8).collect();
        let oneshot = Sha256::digest_of(&msg);
        for split in [0usize, 1, 55, 56, 63, 64, 65, 128, 299] {
            let mut h = Sha256::new();
            h.update(&msg[..split]);
            h.update(&msg[split..]);
            let got: [u8; 32] = h.finalize().into();
            assert_eq!(got, oneshot, "split {split}");
        }
    }

    #[test]
    fn length_padding_edge_cases() {
        // 55/56/64-byte messages hit the padding block boundaries.
        for n in [55usize, 56, 57, 63, 64, 65, 119, 120] {
            let msg = vec![b'a'; n];
            let d = Sha256::digest_of(&msg);
            // Re-hash to ensure determinism (and that state is not reused).
            assert_eq!(d, Sha256::digest_of(&msg), "len {n}");
        }
        assert_eq!(
            hex(&Sha256::digest_of(&vec![b'a'; 1_000_000])),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }
}
