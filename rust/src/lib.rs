//! ScaleSFL — a sharding solution for blockchain-based federated learning.
//!
//! Reproduction of Madill et al., *ScaleSFL* (BSCI '22) as a three-layer
//! Rust + JAX + Pallas stack: this crate is Layer-3, the coordinator that owns
//! the permissioned-ledger substrate (execute–order–validate, Raft/PBFT
//! ordering, MVCC validation), the sharded federated-learning workflow
//! (shard chains + mainchain "catalyst" aggregation), the pluggable
//! model-acceptance defences, and the Caliper-style benchmark harness.
//!
//! **Ingress path** (`fabric::gateway` + `mempool`): clients drive the
//! pipeline through non-blocking submission handles. `Gateway::submit`
//! endorses, registers the tx with the channel's `CommitWaiter` demux (one
//! commit-event subscription per channel, however many transactions are in
//! flight), and passes admission control into the bounded per-shard
//! transaction pool — signature + endorsement-policy precheck, replay
//! dedup, per-client rate caps, priority lanes (catalyst/checkpoint >
//! model updates > queries) with TTL eviction. The commit outcome resolves
//! later through the returned `SubmitHandle`; `Gateway::submit_all` is the
//! open-loop batch driver that absorbs `Reject::PoolFull` backpressure by
//! draining its in-flight window, and other rejections surface as
//! `fabric::CommitOutcome::Rejected` / harness shed counters. The orderer
//! pulls size-and-byte-bounded batches from the pools fairly round-robin
//! across channels, so batch cutting, consensus, and block validation
//! overlap and thousands of transactions ride in flight without a thread
//! each. Pools are also linked by a cross-shard relay (`mempool::relay`):
//! a gateway bound to one shard's ingress can submit traffic homed
//! anywhere — misrouted model updates and shard→mainchain checkpoints hop
//! to their home pool over per-link `network::simnet` latencies, pumped
//! by the orderer driver so block cutting sees the arrival skew, with
//! home-pool dedup guaranteeing exactly-once commit.
//!
//! **Commit path** (`fabric::validator` + `fabric::peer`): block
//! validation is a two-stage pipeline — parallel endorsement-policy /
//! signature pre-validation (worker pool sized by
//! `OrdererConfig::validation_workers`, with a verdict cache shared
//! across peer replicas of the same block) followed by the serial MVCC
//! read-version check + apply under the state write lock. The mempool is
//! wired to a replica's `ledger::StateView`, so transactions whose
//! read-set is already stale shed at admission (`Reject::StaleReadSet`)
//! or at batch pull instead of costing consensus bandwidth.
//!
//! **Durability** (`ledger::store` + `ledger::snapshot`): each peer
//! channel can own a crash-safe ledger (`Peer::attach_store`, wired
//! network-wide through `OrdererConfig::ledger`). Commits append
//! CRC-framed blocks to an append-only log — fsync cost set by
//! `ledger::DurabilityMode` (`Off` / group commit / `Strict`) — and every
//! N blocks the world state is checkpointed to an atomically-replaced
//! snapshot stamped with a Merkle state root and the chain tip. Restart
//! recovery loads the latest valid snapshot, replays the log suffix
//! through the regular validation path, and truncates torn tails, so a
//! killed replica returns with an identical tip hash and state root (see
//! `ledger` module docs for the mode tradeoff table, and
//! `benches/durability.rs` for the throughput/recovery baselines).
//!
//! **Multi-process fabric** (`fabric::wire` + `network::transport` +
//! `network::node` + `network::client`): the same pipeline split across
//! real OS processes. `scalesfl node orderer` hosts an orderer-with-peers
//! stack behind a TCP or Unix-domain socket and `scalesfl node gateway`
//! fronts several of them, routing by channel; both speak length-prefixed
//! `fabric::wire` frames whose hardened decoder validates every length
//! against the remaining buffer before allocating (torn frames are
//! retryable `WireError::Truncated`, malformed ones close the
//! connection). `network::RemoteGateway` is the client library: `submit`
//! still returns a `SubmitHandle` immediately — commit events stream back
//! over the same connection into the per-channel `CommitWaiter` demux —
//! so remote submission keeps the non-blocking ingress API, and a child
//! process driven over the socket commits byte-identical blocks (height,
//! tip hash, state root) to an in-process run (`tests/multiprocess.rs`;
//! `benches/wire.rs` gates codec and loopback throughput).
//!
//! **Observability** (`telemetry`): one vocabulary for everything the
//! pipeline measures. Mempool, relay, validator, and orderer register
//! weak collectors into the process-wide metrics `telemetry::Registry`
//! (Prometheus-text / JSON exposition, `scalesfl_<subsystem>_<name>`
//! naming with `channel=` labels); every transaction is stamped through
//! the lock-free `telemetry::Tracer` at submit → admit → relay-hop →
//! batch-pull → prevalidate → apply → commit-event, feeding per-stage
//! latency histograms that the caliper `Report` and the `telemetry` CLI
//! subcommand expose; and a flight recorder freezes anomalously slow or
//! mid-pipeline-killed lifecycles with their full stage breakdown. The
//! instrumentation rides the hot paths, so its overhead is itself gated
//! by a benchmark (`benches/telemetry.rs`: admission throughput with
//! telemetry on vs off stays within 5%).
//!
//! Model compute (training, endorsement-time evaluation, FedAvg aggregation,
//! defence distance matrices) executes AOT-compiled HLO artifacts produced by
//! the Python build step (`make artifacts`) via the PJRT CPU client — Python
//! is never on the request path.
//!
//! See `DESIGN.md` for the system inventory and the per-figure experiment
//! index, and `EXPERIMENTS.md` for measured results.

// Seed code predates these pedantic-adjacent lints; keep `make check`
// (clippy -D warnings) focused on real defects.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::type_complexity)]

pub mod caliper;
pub mod chaincode;
pub mod consensus;
pub mod crypto;
pub mod defense;
pub mod fabric;
pub mod fl;
pub mod ledger;
pub mod mempool;
pub mod network;
pub mod runtime;
pub mod sharding;
pub mod sim;
pub mod storage;
pub mod telemetry;
pub mod util;
