//! ScaleSFL — a sharding solution for blockchain-based federated learning.
//!
//! Reproduction of Madill et al., *ScaleSFL* (BSCI '22) as a three-layer
//! Rust + JAX + Pallas stack: this crate is Layer-3, the coordinator that owns
//! the permissioned-ledger substrate (execute–order–validate, Raft/PBFT
//! ordering, MVCC validation), the sharded federated-learning workflow
//! (shard chains + mainchain "catalyst" aggregation), the pluggable
//! model-acceptance defences, and the Caliper-style benchmark harness.
//!
//! Model compute (training, endorsement-time evaluation, FedAvg aggregation,
//! defence distance matrices) executes AOT-compiled HLO artifacts produced by
//! the Python build step (`make artifacts`) via the PJRT CPU client — Python
//! is never on the request path.
//!
//! See `DESIGN.md` for the system inventory and the per-figure experiment
//! index, and `EXPERIMENTS.md` for measured results.

pub mod caliper;
pub mod chaincode;
pub mod consensus;
pub mod crypto;
pub mod defense;
pub mod fabric;
pub mod fl;
pub mod ledger;
pub mod network;
pub mod runtime;
pub mod sharding;
pub mod sim;
pub mod storage;
pub mod util;
