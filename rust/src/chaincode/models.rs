//! The shard-level "models" chaincode.
//!
//! `CreateModelUpdate(round, client, hash, uri, samples)` — endorsing peers:
//!  1. reject duplicates for (round, client),
//!  2. fetch the weights from the off-chain store and verify the hash
//!     (paper §3.4.6 integrity check),
//!  3. run the pluggable endorsement defence (RONI / norm-bound / none)
//!     against the peer's local test split,
//!  4. write `models/{round}/{client}` metadata on success.
//!
//! The write set contains only canonical metadata (identical across honest
//! peers) so endorsements agree byte-for-byte; verdicts that differ per peer
//! surface as missing endorsements, resolved by the majority policy — the
//! paper's "the model with more endorsements wins".

use std::sync::Arc;

use crate::defense::endorse::{EndorsementDefense, UpdateContext};
use crate::fabric::chaincode::{Chaincode, TxContext};
use crate::fl::datasets::SynthDataset;
use crate::ledger::codec::{Reader, Writer};
use crate::runtime::ops::{EvalResult, ModelOps};
use crate::storage::ModelStore;
use crate::crypto::Digest;

/// On-ledger model update metadata.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelMeta {
    pub round: u64,
    pub client: String,
    pub hash: String,
    pub uri: String,
    /// |D_k| — the FedAvg weight numerator (Eq. 6).
    pub samples: u64,
}

impl ModelMeta {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.u64(self.round).str(&self.client).str(&self.hash).str(&self.uri).u64(self.samples);
        w.finish()
    }

    pub fn decode(buf: &[u8]) -> Result<ModelMeta, String> {
        let mut r = Reader::new(buf);
        Ok(ModelMeta {
            round: r.u64()?,
            client: r.str()?,
            hash: r.str()?,
            uri: r.str()?,
            samples: r.u64()?,
        })
    }

    pub fn key(round: u64, client: &str) -> String {
        format!("models/{round:08}/{client}")
    }
}

/// Per-peer instance: the peer's local eval split personalises the defence.
pub struct ModelsChaincode {
    pub store: ModelStore,
    pub ops: ModelOps,
    pub defense: Arc<dyn EndorsementDefense>,
    /// This peer's held-out split for RONI-style checks.
    pub eval_data: SynthDataset,
}

impl ModelsChaincode {
    /// Locate the latest finalised global model pinned on this shard chain
    /// (written by the workflow when a round closes) for baseline checks.
    /// Returns the store's own `Arc` — every endorsement that needs the
    /// baseline bumps a refcount instead of copying the parameter vector.
    fn prev_global(&self, ctx: &mut TxContext<'_>, round: u64) -> Option<Arc<Vec<f32>>> {
        if round == 0 {
            return None;
        }
        let raw = ctx.get(&format!("global/{:08}", round - 1))?;
        let meta = ModelMeta::decode(&raw).ok()?;
        let digest = Digest::from_hex(&meta.hash)?;
        self.store.get_verified(&meta.uri, &digest).ok()
    }

    fn create_model_update(
        &self,
        ctx: &mut TxContext<'_>,
        args: &[String],
    ) -> Result<Vec<u8>, String> {
        if args.len() != 5 {
            return Err(format!("CreateModelUpdate expects 5 args, got {}", args.len()));
        }
        let round: u64 = args[0].parse().map_err(|_| "bad round".to_string())?;
        let client = args[1].clone();
        let hash = args[2].clone();
        let uri = args[3].clone();
        let samples: u64 = args[4].parse().map_err(|_| "bad samples".to_string())?;

        let key = ModelMeta::key(round, &client);
        if ctx.get(&key).is_some() {
            return Err(format!("duplicate update for {key}"));
        }
        let digest = Digest::from_hex(&hash).ok_or_else(|| "bad hash hex".to_string())?;
        // Step 6: fetch + integrity check.
        let params = self.store.get_verified(&uri, &digest)?;
        if params.len() != self.ops.p_pad() {
            return Err(format!("model has {} weights, expected {}", params.len(), self.ops.p_pad()));
        }
        // Steps 7-8: policy evaluation on this peer's local data.
        let prev_global = self.prev_global(ctx, round);
        let baseline: Option<EvalResult> = prev_global
            .as_ref()
            .and_then(|g| self.ops.evaluate(g, &self.eval_data.x, &self.eval_data.y).ok());
        let verdict_ctx = UpdateContext {
            params: &params,
            round,
            client: &client,
            ops: &self.ops,
            eval_x: &self.eval_data.x,
            eval_y: &self.eval_data.y,
            prev_global: prev_global.as_ref().map(|g| g.as_slice()),
            baseline,
        };
        self.defense.verdict(&verdict_ctx)?;

        let meta = ModelMeta { round, client, hash, uri, samples };
        ctx.put(&key, meta.encode());
        Ok(meta.encode())
    }
}

impl Chaincode for ModelsChaincode {
    fn name(&self) -> &str {
        "models"
    }

    fn invoke(
        &self,
        ctx: &mut TxContext<'_>,
        function: &str,
        args: &[String],
    ) -> Result<Vec<u8>, String> {
        match function {
            "CreateModelUpdate" => self.create_model_update(ctx, args),
            // Pin a finalised global model onto the shard chain so the next
            // round's endorsers have a baseline (workflow-only function).
            "PinGlobalModel" => {
                if args.len() != 4 {
                    return Err("PinGlobalModel expects 4 args".into());
                }
                let round: u64 = args[0].parse().map_err(|_| "bad round".to_string())?;
                let meta = ModelMeta {
                    round,
                    client: "global".into(),
                    hash: args[1].clone(),
                    uri: args[2].clone(),
                    samples: args[3].parse().map_err(|_| "bad samples".to_string())?,
                };
                let digest =
                    Digest::from_hex(&meta.hash).ok_or_else(|| "bad hash hex".to_string())?;
                self.store.get_verified(&meta.uri, &digest)?;
                ctx.put(&format!("global/{round:08}"), meta.encode());
                Ok(vec![])
            }
            other => Err(format!("models: unknown function {other}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::defense::endorse::{NoDefense, NormBound};
    use crate::fl::datasets;
    use crate::ledger::state::WorldState;
    use std::sync::RwLock;

    fn chaincode(defense: Arc<dyn EndorsementDefense>) -> Option<(ModelsChaincode, ModelStore)> {
        let ops = crate::runtime::shared_ops()?;
        let store = ModelStore::new();
        let eval_data = datasets::mnist_like(1, 1, 64, ops.input_dim(), 10);
        Some((ModelsChaincode { store: store.clone(), ops, defense, eval_data }, store))
    }

    fn args(round: u64, client: &str, hash: &str, uri: &str, samples: u64) -> Vec<String> {
        vec![round.to_string(), client.into(), hash.into(), uri.into(), samples.to_string()]
    }

    #[test]
    fn accepts_valid_update_and_writes_meta() {
        let Some((cc, store)) = chaincode(Arc::new(NoDefense)) else { return };
        let params = cc.ops.init_params(1).unwrap();
        let (digest, uri) = store.put(params);
        let state = RwLock::new(WorldState::new());
        let mut ctx = TxContext::new(&state);
        let out = cc
            .invoke(&mut ctx, "CreateModelUpdate", &args(1, "c0", &digest.hex(), &uri, 100))
            .unwrap();
        let meta = ModelMeta::decode(&out).unwrap();
        assert_eq!(meta.client, "c0");
        let rw = ctx.into_rw_set();
        assert_eq!(rw.writes.len(), 1);
        assert_eq!(rw.writes[0].0, ModelMeta::key(1, "c0"));
    }

    #[test]
    fn rejects_hash_mismatch_and_missing_blob() {
        let Some((cc, store)) = chaincode(Arc::new(NoDefense)) else { return };
        let params = cc.ops.init_params(1).unwrap();
        let (_d, uri) = store.put(params.clone());
        let wrong = crate::crypto::hash_f32(&[1.0]);
        let state = RwLock::new(WorldState::new());
        let mut ctx = TxContext::new(&state);
        assert!(cc
            .invoke(&mut ctx, "CreateModelUpdate", &args(1, "c0", &wrong.hex(), &uri, 1))
            .is_err());
        let ghost = format!("sim://{}", wrong.hex());
        assert!(cc
            .invoke(&mut ctx, "CreateModelUpdate", &args(1, "c0", &wrong.hex(), &ghost, 1))
            .is_err());
    }

    #[test]
    fn rejects_duplicate_for_same_round_client() {
        let Some((cc, store)) = chaincode(Arc::new(NoDefense)) else { return };
        let params = cc.ops.init_params(2).unwrap();
        let (digest, uri) = store.put(params);
        let state = RwLock::new(WorldState::new());
        let a = args(1, "c0", &digest.hex(), &uri, 10);
        // First submit commits.
        let mut ctx = TxContext::new(&state);
        cc.invoke(&mut ctx, "CreateModelUpdate", &a).unwrap();
        let rw = ctx.into_rw_set();
        state
            .write()
            .unwrap()
            .apply(&rw, crate::ledger::state::Version { block: 1, tx: 0 });
        // Second one is rejected at simulation time.
        let mut ctx2 = TxContext::new(&state);
        assert!(cc.invoke(&mut ctx2, "CreateModelUpdate", &a).is_err());
    }

    #[test]
    fn norm_bound_defense_blocks_boosted_update() {
        let Some((cc, store)) = chaincode(Arc::new(NormBound { max_norm: 1.0 })) else { return };
        let state = RwLock::new(WorldState::new());
        // Pin round-0 global so the delta check has a baseline.
        let global = cc.ops.init_params(7).unwrap();
        let (gd, guri) = store.put(global.clone());
        let mut ctx = TxContext::new(&state);
        cc.invoke(&mut ctx, "PinGlobalModel", &["0".into(), gd.hex(), guri, "0".into()])
            .unwrap();
        let rw = ctx.into_rw_set();
        state
            .write()
            .unwrap()
            .apply(&rw, crate::ledger::state::Version { block: 1, tx: 0 });
        // A far-away "model" violates the delta bound…
        let big: Vec<f32> = global.iter().map(|g| g + 1.0).collect();
        let (digest, uri) = store.put(big);
        let mut ctx = TxContext::new(&state);
        let err = cc
            .invoke(&mut ctx, "CreateModelUpdate", &args(1, "evil", &digest.hex(), &uri, 10))
            .unwrap_err();
        assert!(err.contains("norm"), "{err}");
        // …while a nearby one passes.
        let mut near = global.clone();
        near[0] += 0.5;
        let (nd, nuri) = store.put(near);
        let mut ctx = TxContext::new(&state);
        cc.invoke(&mut ctx, "CreateModelUpdate", &args(1, "ok", &nd.hex(), &nuri, 10))
            .unwrap();
    }

    #[test]
    fn pin_global_model_roundtrip() {
        let Some((cc, store)) = chaincode(Arc::new(NoDefense)) else { return };
        let params = cc.ops.init_params(3).unwrap();
        let (digest, uri) = store.put(params);
        let state = RwLock::new(WorldState::new());
        let mut ctx = TxContext::new(&state);
        cc.invoke(
            &mut ctx,
            "PinGlobalModel",
            &[0.to_string(), digest.hex(), uri, 800.to_string()],
        )
        .unwrap();
        let rw = ctx.into_rw_set();
        assert_eq!(rw.writes[0].0, "global/00000000");
    }
}
