//! The two ScaleSFL smart contracts (paper §4):
//!
//! - [`models`] — the shard-level "models" chaincode: clients submit model
//!   update metadata; endorsement fetches the weights from the off-chain
//!   store, verifies the hash, and applies the pluggable defence policy
//!   (the model evaluation that dominates transaction cost).
//! - [`catalyst`] — the mainchain contract: shard committees post
//!   shard-aggregated models; once every shard reported, the global FedAvg
//!   result is finalised and pinned for the next round.

pub mod catalyst;
pub mod models;

pub use catalyst::CatalystChaincode;
pub use models::{ModelMeta, ModelsChaincode};
