//! The mainchain "catalyst" chaincode (paper §4): coordinates shard-level
//! aggregates into the global model and manages task proposals.
//!
//! Functions:
//! - `ProposeTask(task_id, description, min_clients)` — §3.4.1 task proposal.
//! - `SubmitShardModel(round, shard, hash, uri, samples)` — a shard
//!   committee posts its aggregated model; endorsers verify blob + hash.
//! - `FinalizeGlobal(round, hash, uri, expected_shards)` — endorsers verify
//!   every shard reported and (deterministically) that the posted global
//!   equals the sample-weighted FedAvg of the shard models, then pin it.

use crate::crypto::Digest;
use crate::fabric::chaincode::{Chaincode, TxContext};
use crate::runtime::ops::ModelOps;
use crate::storage::ModelStore;

use super::models::ModelMeta;

/// Mainchain contract instance (one per peer; deterministic verification).
pub struct CatalystChaincode {
    pub store: ModelStore,
    pub ops: ModelOps,
    /// Verify the aggregate numerically during FinalizeGlobal endorsement
    /// (cost: one K-way aggregation per endorsement).
    pub verify_aggregate: bool,
}

impl CatalystChaincode {
    fn shard_key(round: u64, shard: &str) -> String {
        format!("shards/{round:08}/{shard}")
    }

    fn global_key(round: u64) -> String {
        format!("global/{round:08}")
    }

    fn submit_shard_model(
        &self,
        ctx: &mut TxContext<'_>,
        args: &[String],
    ) -> Result<Vec<u8>, String> {
        if args.len() != 5 {
            return Err("SubmitShardModel expects 5 args".into());
        }
        let round: u64 = args[0].parse().map_err(|_| "bad round".to_string())?;
        let shard = args[1].clone();
        let hash = args[2].clone();
        let uri = args[3].clone();
        let samples: u64 = args[4].parse().map_err(|_| "bad samples".to_string())?;
        let key = Self::shard_key(round, &shard);
        if ctx.get(&key).is_some() {
            return Err(format!("duplicate shard model {key}"));
        }
        let digest = Digest::from_hex(&hash).ok_or_else(|| "bad hash hex".to_string())?;
        let blob = self.store.get_verified(&uri, &digest)?;
        if blob.len() != self.ops.p_pad() {
            return Err("shard model has wrong width".into());
        }
        let meta = ModelMeta { round, client: shard, hash, uri, samples };
        ctx.put(&key, meta.encode());
        Ok(meta.encode())
    }

    fn finalize_global(
        &self,
        ctx: &mut TxContext<'_>,
        args: &[String],
    ) -> Result<Vec<u8>, String> {
        if args.len() != 4 {
            return Err("FinalizeGlobal expects 4 args".into());
        }
        let round: u64 = args[0].parse().map_err(|_| "bad round".to_string())?;
        let hash = args[1].clone();
        let uri = args[2].clone();
        let expected: usize = args[3].parse().map_err(|_| "bad shard count".to_string())?;
        let gkey = Self::global_key(round);
        if ctx.get(&gkey).is_some() {
            return Err(format!("round {round} already finalised"));
        }
        let shard_metas: Vec<ModelMeta> = ctx
            .scan(&format!("shards/{round:08}/"))
            .into_iter()
            .map(|(_, v)| ModelMeta::decode(&v))
            .collect::<Result<_, _>>()?;
        if shard_metas.len() != expected {
            return Err(format!(
                "round {round}: {} shard models present, expected {expected}",
                shard_metas.len()
            ));
        }
        let digest = Digest::from_hex(&hash).ok_or_else(|| "bad hash hex".to_string())?;
        let posted = self.store.get_verified(&uri, &digest)?;
        if self.verify_aggregate {
            // Recompute the sample-weighted FedAvg of shard models (Eq. 7)
            // and insist the posted global matches bit-for-bit.
            let blobs: Vec<std::sync::Arc<Vec<f32>>> = shard_metas
                .iter()
                .map(|m| {
                    let d = Digest::from_hex(&m.hash).ok_or("bad shard hash")?;
                    self.store.get_verified(&m.uri, &d)
                })
                .collect::<Result<_, String>>()?;
            let refs: Vec<&Vec<f32>> = blobs.iter().map(|b| b.as_ref()).collect();
            let weights: Vec<f64> = shard_metas.iter().map(|m| m.samples as f64).collect();
            let agg = self
                .ops
                .fedavg_agg(&refs, &weights)
                .map_err(|e| format!("aggregate verify failed: {e}"))?;
            let agg_hash = crate::crypto::hash_f32(&agg);
            if agg_hash != digest {
                // Bit-exactness can differ across FP orders; fall back to a
                // tolerance check before rejecting.
                let max_err = agg
                    .iter()
                    .zip(posted.iter())
                    .map(|(&a, &b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                if max_err > 1e-5 {
                    return Err(format!(
                        "posted global differs from recomputed FedAvg (max err {max_err})"
                    ));
                }
            }
        }
        let samples: u64 = shard_metas.iter().map(|m| m.samples).sum();
        let meta = ModelMeta { round, client: "global".into(), hash, uri, samples };
        ctx.put(&gkey, meta.encode());
        Ok(meta.encode())
    }
}

impl Chaincode for CatalystChaincode {
    fn name(&self) -> &str {
        "catalyst"
    }

    fn invoke(
        &self,
        ctx: &mut TxContext<'_>,
        function: &str,
        args: &[String],
    ) -> Result<Vec<u8>, String> {
        match function {
            "ProposeTask" => {
                if args.len() != 3 {
                    return Err("ProposeTask expects 3 args".into());
                }
                let key = format!("tasks/{}", args[0]);
                if ctx.get(&key).is_some() {
                    return Err(format!("task {} exists", args[0]));
                }
                let mut w = crate::ledger::codec::Writer::new();
                w.str(&args[1]).str(&args[2]);
                ctx.put(&key, w.finish());
                Ok(vec![])
            }
            "SubmitShardModel" => self.submit_shard_model(ctx, args),
            "FinalizeGlobal" => self.finalize_global(ctx, args),
            other => Err(format!("catalyst: unknown function {other}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ledger::state::{Version, WorldState};
    use std::sync::RwLock;

    fn cc() -> Option<(CatalystChaincode, ModelStore)> {
        let ops = crate::runtime::shared_ops()?;
        let store = ModelStore::new();
        Some((CatalystChaincode { store: store.clone(), ops, verify_aggregate: true }, store))
    }

    fn commit(state: &RwLock<WorldState>, ctx: TxContext<'_>, block: u64) {
        let rw = ctx.into_rw_set();
        state.write().unwrap().apply(&rw, Version { block, tx: 0 });
    }

    #[test]
    fn shard_submission_and_finalisation() {
        let Some((cc, store)) = cc() else { return };
        let state = RwLock::new(WorldState::new());
        // Two shards post models.
        let m0 = vec![1.0f32; cc.ops.p_pad()];
        let m1 = vec![3.0f32; cc.ops.p_pad()];
        for (i, (m, n)) in [(m0.clone(), 100u64), (m1.clone(), 300u64)].iter().enumerate() {
            let (d, uri) = store.put(m.clone());
            let mut ctx = TxContext::new(&state);
            cc.invoke(
                &mut ctx,
                "SubmitShardModel",
                &[
                    "1".into(),
                    format!("shard{i}"),
                    d.hex(),
                    uri,
                    n.to_string(),
                ],
            )
            .unwrap();
            commit(&state, ctx, i as u64 + 1);
        }
        // Weighted global: (100*1 + 300*3)/400 = 2.5
        let global = vec![2.5f32; cc.ops.p_pad()];
        let (gd, guri) = store.put(global);
        let mut ctx = TxContext::new(&state);
        cc.invoke(&mut ctx, "FinalizeGlobal", &["1".into(), gd.hex(), guri, "2".into()])
            .unwrap();
        commit(&state, ctx, 3);
        assert!(state.read().unwrap().get_value("global/00000001").is_some());
    }

    #[test]
    fn finalize_rejects_wrong_aggregate_and_missing_shards() {
        let Some((cc, store)) = cc() else { return };
        let state = RwLock::new(WorldState::new());
        let (d, uri) = store.put(vec![1.0f32; cc.ops.p_pad()]);
        let mut ctx = TxContext::new(&state);
        cc.invoke(
            &mut ctx,
            "SubmitShardModel",
            &["1".into(), "shard0".into(), d.hex(), uri, "100".into()],
        )
        .unwrap();
        commit(&state, ctx, 1);
        // Expecting 2 shards but only one posted.
        let (gd, guri) = store.put(vec![1.0f32; cc.ops.p_pad()]);
        let mut ctx = TxContext::new(&state);
        assert!(cc
            .invoke(
                &mut ctx,
                "FinalizeGlobal",
                &["1".into(), gd.hex(), guri.clone(), "2".into()]
            )
            .is_err());
        // Right count, wrong value.
        let (bad_d, bad_uri) = store.put(vec![9.0f32; cc.ops.p_pad()]);
        let mut ctx = TxContext::new(&state);
        let err = cc
            .invoke(
                &mut ctx,
                "FinalizeGlobal",
                &["1".into(), bad_d.hex(), bad_uri, "1".into()],
            )
            .unwrap_err();
        assert!(err.contains("differs"), "{err}");
    }

    #[test]
    fn task_proposals_deduplicate() {
        let Some((cc, _store)) = cc() else { return };
        let state = RwLock::new(WorldState::new());
        let mut ctx = TxContext::new(&state);
        cc.invoke(&mut ctx, "ProposeTask", &["t1".into(), "mnist".into(), "64".into()])
            .unwrap();
        commit(&state, ctx, 1);
        let mut ctx = TxContext::new(&state);
        assert!(cc
            .invoke(&mut ctx, "ProposeTask", &["t1".into(), "mnist".into(), "64".into()])
            .is_err());
    }
}
