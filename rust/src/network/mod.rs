//! Simulated network substrate.
//!
//! `simnet` is the message-level transport used to drive the sans-io
//! consensus nodes (and the fault-injection tests): per-link uniform latency,
//! probabilistic drops, and node isolation (partitions/crashes).

pub mod simnet;
