//! Network substrate: the simulated message fabric and the real-socket
//! transport for the multi-process split.
//!
//! Three layers live here:
//!
//! - [`simnet::SimNet`] is the message-level transport driving the sans-io
//!   consensus nodes and the fault-injection tests: scheduled delivery,
//!   probabilistic drops, and node isolation (partitions/crashes).
//! - [`simnet::LinkLatency`] is the per-link latency *oracle*: a
//!   deterministic map from directed `(src, dst)` link names to a stable
//!   mean plus bounded per-message jitter. It prices every hop of the
//!   cross-shard mempool relay (`crate::mempool::relay`) — misrouted
//!   transactions gossiping to their home shard, shard checkpoints
//!   relaying to the mainchain. The ordering service pumps relayed
//!   traffic each driver tick, so these latencies shape real batch-pull
//!   arrival order, not just simulation plots.
//! - [`transport`] carries `fabric::wire` frames between real OS
//!   processes over TCP or Unix-domain sockets: [`node`] hosts the
//!   `scalesfl node` orderer/gateway server roles, and
//!   [`client::RemoteGateway`] is the client library that rebuilds the
//!   in-process `SubmitHandle` submission API across a socket.

pub mod client;
pub mod node;
pub mod simnet;
pub mod transport;

pub use client::{ChannelStatus, RemoteGateway};
pub use node::{FabricNode, NodeConfig};
pub use simnet::{LinkLatency, SimNet};
pub use transport::{Endpoint, FramedConn, Listener};
