//! Simulated network substrate.
//!
//! Two layers live here:
//!
//! - [`simnet::SimNet`] is the message-level transport driving the sans-io
//!   consensus nodes and the fault-injection tests: scheduled delivery,
//!   probabilistic drops, and node isolation (partitions/crashes).
//! - [`simnet::LinkLatency`] is the per-link latency *oracle*: a
//!   deterministic map from directed `(src, dst)` link names to a stable
//!   mean plus bounded per-message jitter. It prices every hop of the
//!   cross-shard mempool relay (`crate::mempool::relay`) — misrouted
//!   transactions gossiping to their home shard, shard checkpoints
//!   relaying to the mainchain. The ordering service pumps relayed
//!   traffic each driver tick, so these latencies shape real batch-pull
//!   arrival order, not just simulation plots.

pub mod simnet;

pub use simnet::{LinkLatency, SimNet};
