//! Process roles for the multi-process fabric: a deterministic topology
//! builder plus the two `scalesfl node` server loops.
//!
//! [`FabricNode::build`] assembles one orderer-with-peers stack — CA,
//! enrolled endorsing peers joined to every configured channel, ordering
//! service, in-process [`Gateway`] — entirely from a [`NodeConfig`]. The
//! same builder backs three callers with byte-identical chains:
//!
//! - the `scalesfl node orderer` subcommand ([`serve`]), exposing the
//!   stack over a socket,
//! - the in-process reference run in the multi-process integration test,
//! - the loopback wire bench.
//!
//! Determinism is the point: credentials derive from the seeded PRNG in
//! enrollment order, blocks carry no timestamps, and with `batch_size: 1`
//! a sequential submission stream cuts one block per transaction — so a
//! remote client driving a child process over TCP must land the exact
//! same heights, tip hashes, and state roots as the same proposals
//! submitted through a local gateway.
//!
//! The server loop speaks `fabric::wire` frames over a
//! [`transport::Listener`]. Each connection gets a reader thread (this
//! function) and a writer thread draining an outbound queue, so commit
//! events pushed by waiter callbacks never interleave with responses
//! mid-frame. A malformed or protocol-violating frame closes the
//! connection (`WireError::Malformed` semantics); the process and its
//! other connections keep running, and nothing already committed is lost.
//!
//! [`serve_relay`] is the `scalesfl node gateway` role: it fronts several
//! orderer processes, routing each inbound request to the upstream that
//! owns its channel (connections are dialed lazily, per client, so
//! correlation ids never collide across clients) and pumping responses
//! and events back verbatim — frames transit without re-encoding.

use std::collections::HashMap;
use std::io;
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::Duration;

use crate::crypto::msp::{CertificateAuthority, MemberId};
use crate::crypto::Digest;
use crate::fabric::chaincode::{Chaincode, TxContext};
use crate::fabric::endorsement::EndorsementPolicy;
use crate::fabric::orderer::{OrdererConfig, OrderingService};
use crate::fabric::peer::Peer;
use crate::fabric::waiter::WaiterEvent;
use crate::fabric::wire::{encode_frame, Event, Frame, Request, Response};
use crate::fabric::Gateway;
use crate::util::prng::Prng;

use super::transport::{Endpoint, FramedConn, Listener};

/// Topology for one orderer-with-peers process. Two processes built from
/// equal configs and fed equal proposal streams produce identical chains.
#[derive(Clone, Debug, PartialEq)]
pub struct NodeConfig {
    /// Channels (shards) this node orders and its peers join.
    pub channels: Vec<String>,
    /// Endorsing peers, enrolled as `org{i}.peer` in index order.
    pub peers: usize,
    /// Seeds credential enrollment and the ordering service.
    pub seed: u64,
    /// Envelopes per block. The deterministic-comparison setup uses 1.
    pub batch_size: usize,
    /// Batch cut timeout.
    pub batch_timeout: Duration,
}

impl Default for NodeConfig {
    fn default() -> NodeConfig {
        NodeConfig {
            channels: vec!["ch".into()],
            peers: 2,
            seed: 7,
            batch_size: 1,
            batch_timeout: Duration::from_millis(10),
        }
    }
}

/// The reference chaincode every node installs: `Put key [value]`.
/// Deliberately total over hostile remote argument lists — a missing key
/// is an endorsement error, not a peer panic.
struct KvPut;

impl Chaincode for KvPut {
    fn name(&self) -> &str {
        "kv"
    }

    fn invoke(
        &self,
        ctx: &mut TxContext<'_>,
        _f: &str,
        args: &[String],
    ) -> Result<Vec<u8>, String> {
        let Some(key) = args.first() else {
            return Err("kv: missing key argument".into());
        };
        let value = args.get(1).map(|v| v.as_bytes().to_vec()).unwrap_or_else(|| b"v".to_vec());
        ctx.put(key, value);
        Ok(vec![])
    }
}

/// One assembled orderer-with-peers stack.
pub struct FabricNode {
    pub peers: Vec<Arc<Peer>>,
    pub orderer: Arc<OrderingService>,
    pub gateway: Arc<Gateway>,
}

impl FabricNode {
    /// Build the stack from `cfg`. Enrollment order, policy, and seeds are
    /// all functions of the config — the determinism contract above.
    pub fn build(cfg: &NodeConfig) -> FabricNode {
        let ca = CertificateAuthority::new();
        let mut rng = Prng::new(cfg.seed);
        let peers: Vec<Arc<Peer>> = (0..cfg.peers.max(1))
            .map(|i| {
                let cred = ca.enroll(MemberId::new(format!("org{i}.peer")), &mut rng);
                Peer::new(cred, ca.clone())
            })
            .collect();
        let members: Vec<MemberId> = peers.iter().map(|p| p.member.clone()).collect();
        for ch in &cfg.channels {
            for p in &peers {
                p.join_channel(ch, EndorsementPolicy::MajorityOf(members.clone()));
                p.install_chaincode(ch, Arc::new(KvPut)).expect("install chaincode");
            }
        }
        let ocfg = OrdererConfig {
            batch_size: cfg.batch_size.max(1),
            batch_timeout: cfg.batch_timeout,
            tick: Duration::from_millis(1),
            ..OrdererConfig::default()
        };
        let orderer = OrderingService::start(ocfg, peers.clone(), cfg.seed);
        let gateway = Arc::new(Gateway::new(peers.clone(), Arc::clone(&orderer)));
        FabricNode { peers, orderer, gateway }
    }

    /// (height, tip hash, state root) for `channel`, or `None` if no peer
    /// joined it.
    pub fn status(&self, channel: &str) -> Option<(u64, Digest, Digest)> {
        let ch = self.peers.first()?.channel(channel)?;
        let tip = ch.chain.lock().unwrap().tip_hash();
        Some((ch.height(), tip, ch.state_root()))
    }
}

/// Accept loop for the orderer role: one [`conn_loop`] thread per inbound
/// connection. Returns when the listener errors (socket closed).
pub fn serve(node: Arc<FabricNode>, listener: Listener) {
    while let Ok(conn) = listener.accept() {
        let node = Arc::clone(&node);
        thread::Builder::new()
            .name("node-conn".into())
            .spawn(move || conn_loop(node, conn))
            .expect("spawn node connection");
    }
}

/// Serve one client connection until it closes or violates the protocol.
fn conn_loop(node: Arc<FabricNode>, mut reader: FramedConn) {
    let Ok(writer) = reader.try_clone() else { return };
    // All outbound traffic — responses and waiter-callback events — funnels
    // through one writer thread, so frames never interleave.
    let (out_tx, out_rx) = mpsc::channel::<Frame>();
    thread::Builder::new()
        .name("node-conn-writer".into())
        .spawn(move || {
            let mut writer = writer;
            while let Ok(frame) = out_rx.recv() {
                if writer.send_frame(&frame).is_err() {
                    return;
                }
            }
        })
        .expect("spawn node connection writer");
    loop {
        match reader.recv_frame() {
            Ok(Some(Frame::Request(req))) => {
                if handle_request(&node, &out_tx, req).is_err() {
                    break; // client gone
                }
            }
            // Clients only send requests; a response/event here, a
            // malformed frame, or a torn read all close the connection.
            Ok(Some(_)) | Ok(None) | Err(_) => break,
        }
    }
    // Wakes the writer thread's pending sends; callbacks still registered
    // for in-flight transactions send into a closed socket harmlessly.
    reader.shutdown();
}

/// Dispatch one request; the reply (and any later events) go out through
/// `out`. `Err` means the outbound queue is gone.
fn handle_request(
    node: &FabricNode,
    out: &mpsc::Sender<Frame>,
    req: Request,
) -> Result<(), mpsc::SendError<Frame>> {
    match req {
        Request::Endorse { id, proposal } => {
            let resp = match node.gateway.endorse(&proposal) {
                Ok(envelope) => Response::Endorsed { id, envelope },
                Err(reason) => Response::Failed { id, reason },
            };
            out.send(Frame::Response(resp))
        }
        Request::Submit { id, envelope } => {
            let channel = envelope.proposal().channel.clone();
            let tx_id = envelope.tx_id();
            let waiter = match node.gateway.waiter(&channel) {
                Ok(w) => w,
                Err(reason) => return out.send(Frame::Response(Response::Failed { id, reason })),
            };
            // Register the event-forwarding callback before ordering, so
            // the commit cannot race past it; the callback runs on the
            // demux thread and only enqueues a frame.
            let events = out.clone();
            let cb_channel = channel.clone();
            let registered = waiter.register_callback(
                tx_id,
                Box::new(move |ev| {
                    let frame = match ev {
                        WaiterEvent::Committed(cev, _) => Frame::Event(Event::Committed {
                            channel: cev.channel.to_string(),
                            tx_id: cev.tx_id,
                            block: cev.block,
                            code: cev.code,
                        }),
                        WaiterEvent::Dropped(reject, _) => {
                            Frame::Event(Event::Dropped { channel: cb_channel, tx_id, reject })
                        }
                    };
                    let _ = events.send(frame);
                }),
            );
            if !registered {
                let reject = crate::mempool::Reject::Duplicate;
                return out.send(Frame::Response(Response::Rejected { id, reject }));
            }
            let resp = match node.orderer.submit(envelope) {
                Ok(()) => Response::Accepted { id, tx_id },
                Err(reject) => {
                    waiter.deregister(&tx_id);
                    Response::Rejected { id, reject }
                }
            };
            out.send(Frame::Response(resp))
        }
        Request::Status { id, channel } => {
            let resp = match node.status(&channel) {
                Some((height, tip, state_root)) => {
                    Response::Status { id, height, tip, state_root }
                }
                None => Response::Failed { id, reason: format!("unknown channel {channel:?}") },
            };
            out.send(Frame::Response(resp))
        }
    }
}

/// Accept loop for the gateway role: relay each client to the upstream
/// orderer processes owning the channels it touches.
pub fn serve_relay(upstreams: Arc<HashMap<String, Endpoint>>, listener: Listener) {
    while let Ok(conn) = listener.accept() {
        let upstreams = Arc::clone(&upstreams);
        thread::Builder::new()
            .name("gw-conn".into())
            .spawn(move || relay_loop(upstreams, conn))
            .expect("spawn gateway connection");
    }
}

/// Relay one client connection. Requests are routed by channel and
/// forwarded as the raw bytes that arrived (decoded only to validate and
/// extract the route); per-upstream pump threads copy responses and
/// events back into the client's writer queue.
fn relay_loop(upstreams: Arc<HashMap<String, Endpoint>>, mut client: FramedConn) {
    let Ok(writer) = client.try_clone() else { return };
    let (out_tx, out_rx) = mpsc::channel::<Vec<u8>>();
    thread::Builder::new()
        .name("gw-conn-writer".into())
        .spawn(move || {
            let mut writer = writer;
            while let Ok(buf) = out_rx.recv() {
                if writer.send(&buf).is_err() {
                    return;
                }
            }
        })
        .expect("spawn gateway connection writer");
    // Upstream write halves, dialed lazily per channel for this client.
    let mut ups: HashMap<String, FramedConn> = HashMap::new();
    loop {
        let buf = match client.recv() {
            Ok(Some(buf)) => buf,
            Ok(None) | Err(_) => break,
        };
        let (id, channel) = match crate::fabric::wire::decode_frame(&buf) {
            Ok(Frame::Request(Request::Endorse { id, proposal })) => (id, proposal.channel),
            Ok(Frame::Request(Request::Submit { id, envelope })) => {
                (id, envelope.proposal().channel.clone())
            }
            Ok(Frame::Request(Request::Status { id, channel })) => (id, channel),
            // Malformed, or not a request: close, matching the orderer role.
            _ => break,
        };
        if !ups.contains_key(&channel) {
            if let Some(up) = dial_upstream(&upstreams, &channel, &out_tx) {
                ups.insert(channel.clone(), up);
            }
        }
        let forwarded = match ups.get_mut(&channel) {
            Some(up) => up.send(&buf).is_ok(),
            None => false,
        };
        if !forwarded {
            ups.remove(&channel);
            let fail = Frame::Response(Response::Failed {
                id,
                reason: format!("no upstream for channel {channel:?}"),
            });
            if out_tx.send(encode_frame(&fail)).is_err() {
                break;
            }
        }
    }
    client.shutdown();
    for up in ups.values() {
        up.shutdown();
    }
}

/// Dial the upstream owning `channel` and start its client-bound pump.
fn dial_upstream(
    upstreams: &HashMap<String, Endpoint>,
    channel: &str,
    out_tx: &mpsc::Sender<Vec<u8>>,
) -> Option<FramedConn> {
    let ep = upstreams.get(channel)?;
    let up = FramedConn::connect_retry(ep, Duration::from_secs(5)).ok()?;
    let mut pump = up.try_clone().ok()?;
    let back = out_tx.clone();
    thread::Builder::new()
        .name("gw-upstream-pump".into())
        .spawn(move || {
            // Upstream frames (responses + events) transit verbatim.
            while let Ok(Some(buf)) = pump.recv() {
                if back.send(buf).is_err() {
                    return;
                }
            }
        })
        .expect("spawn gateway upstream pump");
    Some(up)
}

/// Bind, announce, and serve the orderer role until `listener` dies.
/// Returns the bound endpoint (port 0 resolved) before blocking — callers
/// print the `LISTENING` line themselves.
pub fn bind_and_serve(
    node: FabricNode,
    ep: &Endpoint,
) -> io::Result<(Endpoint, thread::JoinHandle<()>)> {
    let listener = Listener::bind(ep)?;
    let local = listener.local_endpoint()?;
    let node = Arc::new(node);
    let t = thread::Builder::new()
        .name("node-accept".into())
        .spawn(move || serve(node, listener))
        .expect("spawn node accept loop");
    Ok((local, t))
}

/// Bind, announce, and serve the gateway-relay role.
pub fn bind_and_serve_relay(
    upstreams: HashMap<String, Endpoint>,
    ep: &Endpoint,
) -> io::Result<(Endpoint, thread::JoinHandle<()>)> {
    let listener = Listener::bind(ep)?;
    let local = listener.local_endpoint()?;
    let upstreams = Arc::new(upstreams);
    let t = thread::Builder::new()
        .name("gw-accept".into())
        .spawn(move || serve_relay(upstreams, listener))
        .expect("spawn gateway accept loop");
    Ok((local, t))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::CommitOutcome;
    use crate::ledger::tx::Proposal;
    use crate::network::client::RemoteGateway;

    fn proposal(channel: &str, key: &str, nonce: u64) -> Proposal {
        Proposal {
            channel: channel.into(),
            chaincode: "kv".into(),
            function: "Put".into(),
            args: vec![key.into()],
            creator: MemberId::new("client"),
            nonce,
        }
    }

    fn loopback() -> Endpoint {
        Endpoint::parse("tcp:127.0.0.1:0").unwrap()
    }

    #[test]
    fn remote_submit_commits_and_matches_local_status() {
        let cfg = NodeConfig::default();
        let (ep, _t) = bind_and_serve(FabricNode::build(&cfg), &loopback()).unwrap();
        let reference = FabricNode::build(&cfg);
        let gw = RemoteGateway::connect(&ep).unwrap();
        for i in 0..4u64 {
            let p = proposal("ch", &format!("k{i}"), i);
            let out = gw.submit_and_wait(&p);
            assert!(out.is_valid(), "remote tx {i}: {out:?}");
            let out = reference.gateway.submit_and_wait(&p);
            assert!(out.is_valid(), "local tx {i}: {out:?}");
        }
        assert_eq!(gw.in_flight(), 0);
        let remote = gw.status("ch").unwrap();
        let (height, tip, root) = reference.status("ch").unwrap();
        assert_eq!(remote.height, height);
        assert_eq!(remote.tip, tip, "tip hash diverged between socket and in-process runs");
        assert_eq!(remote.state_root, root);
    }

    #[test]
    fn remote_endorse_submit_split_keeps_handle_semantics() {
        let (ep, _t) =
            bind_and_serve(FabricNode::build(&NodeConfig::default()), &loopback()).unwrap();
        let gw = RemoteGateway::connect(&ep).unwrap();
        let env = gw.endorse(&proposal("ch", "split", 1)).unwrap();
        assert!(!env.as_bytes().is_empty());
        let mut h = gw.submit_endorsed(env.clone());
        assert!(h.wait_timeout(Duration::from_secs(10)).is_valid());
        // Resubmitting the same envelope is a duplicate: depending on
        // where the pipeline catches it (admission dedup vs commit-time
        // DuplicateTxId) it surfaces as Rejected or an invalid commit —
        // never as a second valid commit.
        let out = gw.submit_endorsed(env).wait();
        assert!(!out.is_valid(), "{out:?}");
    }

    #[test]
    fn unknown_channel_and_bad_proposal_fail_cleanly() {
        let (ep, _t) =
            bind_and_serve(FabricNode::build(&NodeConfig::default()), &loopback()).unwrap();
        let gw = RemoteGateway::connect(&ep).unwrap();
        assert!(gw.status("nope").is_err());
        let out = gw.submit_and_wait(&proposal("nope", "k", 1));
        assert!(matches!(out, CommitOutcome::EndorsementFailed { .. }), "{out:?}");
        // A proposal with no args must not kill the peer or the server.
        let mut p = proposal("ch", "k", 2);
        p.args.clear();
        let out = gw.submit_and_wait(&p);
        assert!(matches!(out, CommitOutcome::EndorsementFailed { .. }), "{out:?}");
        // The connection survives all of it.
        assert!(gw.status("ch").is_ok());
    }

    /// Satellite: a connection killed mid-frame does not lose committed
    /// events for other connections, and a fresh connection resyncs.
    #[test]
    fn torn_client_does_not_disturb_other_connections() {
        let (ep, _t) =
            bind_and_serve(FabricNode::build(&NodeConfig::default()), &loopback()).unwrap();
        let gw = RemoteGateway::connect(&ep).unwrap();
        assert!(gw.submit_and_wait(&proposal("ch", "before", 1)).is_valid());
        {
            // A raw socket that dies inside a frame: the length prefix
            // promises 100 bytes, 10 arrive, then the connection drops.
            let Endpoint::Tcp(addr) = &ep else { panic!("loopback is tcp") };
            let mut raw = std::net::TcpStream::connect(addr.as_str()).unwrap();
            use std::io::Write as _;
            raw.write_all(&100u32.to_le_bytes()).unwrap();
            raw.write_all(&[1u8; 10]).unwrap();
            drop(raw);
        }
        {
            // A complete transport frame whose payload is a truncated
            // Submit request — WireError::Truncated inside the trust
            // boundary; the server closes the connection.
            let mut torn = FramedConn::connect(&ep).unwrap();
            torn.send(&[0x00, 0x01]).unwrap();
            assert_eq!(torn.recv().unwrap(), None, "server closes on torn request");
        }
        {
            // And one that sends a malformed frame; the server closes it.
            let mut bad = FramedConn::connect(&ep).unwrap();
            bad.send(&[0xEE, 0xEE, 0xEE]).unwrap();
            assert_eq!(bad.recv().unwrap(), None, "server closes on malformed frame");
        }
        // The original connection still commits and its chain advanced.
        assert!(gw.submit_and_wait(&proposal("ch", "after", 2)).is_valid());
        assert_eq!(gw.status("ch").unwrap().height, 2);
    }

    #[test]
    fn relay_routes_by_channel_and_reports_unroutable() {
        let shard = |name: &str, seed: u64| NodeConfig {
            channels: vec![name.into()],
            seed,
            ..NodeConfig::default()
        };
        let (ep0, _t0) = bind_and_serve(FabricNode::build(&shard("s0", 7)), &loopback()).unwrap();
        let (ep1, _t1) = bind_and_serve(FabricNode::build(&shard("s1", 8)), &loopback()).unwrap();
        let mut up = HashMap::new();
        up.insert("s0".to_string(), ep0);
        up.insert("s1".to_string(), ep1);
        let (gep, _tg) = bind_and_serve_relay(up, &loopback()).unwrap();
        let gw = RemoteGateway::connect(&gep).unwrap();
        assert!(gw.submit_and_wait(&proposal("s0", "a", 1)).is_valid());
        assert!(gw.submit_and_wait(&proposal("s1", "b", 1)).is_valid());
        assert_eq!(gw.status("s0").unwrap().height, 1);
        assert_eq!(gw.status("s1").unwrap().height, 1);
        let err = gw.status("s9").unwrap_err();
        assert!(err.contains("no upstream"), "{err}");
    }
}
