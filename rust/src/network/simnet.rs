//! In-process simulated network: latency, jitter, drops, partitions.
//!
//! Messages are scheduled onto a priority queue keyed by virtual delivery
//! time; `deliver_until(now)` drains in timestamp order. Deterministic given
//! the seed, which is what makes the consensus property tests reproducible.
//!
//! [`LinkLatency`] is the per-link latency *oracle*: every directed
//! `(src, dst)` pair gets a stable mean drawn by hashing the link name
//! under a seed, plus bounded per-message jitter. The cross-shard mempool
//! relay (`crate::mempool::relay`) prices every forwarding hop through
//! it, pumped by the ordering service's driver each tick.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::collections::HashSet;
use std::time::Duration;

use crate::consensus::NodeId;
use crate::util::prng::Prng;

/// Deterministic per-link latency oracle.
///
/// A directed link `(src, dst)` has a stable mean latency in
/// `[base, base + spread]`, fixed by hashing the link name under `seed`
/// (the topology: some links are simply longer than others). Each sampled
/// message adds jitter in `[0, jitter]` derived from a caller-supplied
/// salt, so repeated sends over one link vary but replay identically for
/// the same salt sequence. Self-links (`src == dst`) are free.
#[derive(Clone, Debug)]
pub struct LinkLatency {
    base_s: f64,
    spread_s: f64,
    jitter_s: f64,
    seed: u64,
}

impl LinkLatency {
    pub fn new(base: Duration, spread: Duration, jitter: Duration, seed: u64) -> LinkLatency {
        LinkLatency {
            base_s: base.as_secs_f64(),
            spread_s: spread.as_secs_f64(),
            jitter_s: jitter.as_secs_f64(),
            seed,
        }
    }

    /// An all-zero oracle: every hop is free (tests, latency-off runs).
    pub fn zero() -> LinkLatency {
        LinkLatency { base_s: 0.0, spread_s: 0.0, jitter_s: 0.0, seed: 0 }
    }

    /// FNV-1a over the seed and the link name.
    fn mix(&self, src: &str, dst: &str, salt: u64) -> u64 {
        let mut h = 0xcbf29ce484222325u64 ^ self.seed;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        };
        eat(src.as_bytes());
        eat(&[0xff]);
        eat(dst.as_bytes());
        eat(&salt.to_le_bytes());
        h
    }

    /// Map a hash to the unit interval.
    fn unit(h: u64) -> f64 {
        (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// The link's stable mean latency in seconds (no jitter).
    pub fn mean_s(&self, src: &str, dst: &str) -> f64 {
        if src == dst {
            return 0.0;
        }
        self.base_s + Self::unit(self.mix(src, dst, 0)) * self.spread_s
    }

    /// One message's latency in seconds: the link mean plus jitter hashed
    /// from `salt` (use a per-message sequence number).
    pub fn sample_s(&self, src: &str, dst: &str, salt: u64) -> f64 {
        if src == dst {
            return 0.0;
        }
        let jitter = Self::unit(self.mix(dst, src, salt ^ 0x9e3779b97f4a7c15));
        self.mean_s(src, dst) + jitter * self.jitter_s
    }

    /// Upper bound on any sampled latency (base + spread + jitter).
    pub fn max_s(&self) -> f64 {
        self.base_s + self.spread_s + self.jitter_s
    }
}

/// Orderable f64 wrapper for the scheduling heap.
#[derive(Clone, Copy, PartialEq, PartialOrd)]
struct Time(f64);

impl Eq for Time {}
#[allow(clippy::derive_ord_xor_partial_ord)]
impl Ord for Time {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).expect("NaN time")
    }
}

/// The simulated transport.
pub struct SimNet<M> {
    heap: BinaryHeap<Reverse<(Time, u64, NodeId, NodeId)>>,
    payloads: std::collections::HashMap<u64, M>,
    seq: u64,
    latency_min: f64,
    latency_max: f64,
    drop_prob: f64,
    isolated: HashSet<NodeId>,
    rng: Prng,
    pub sent: u64,
    pub dropped: u64,
}

impl<M> SimNet<M> {
    /// Uniform latency in [latency_min, latency_max], iid drop probability.
    pub fn new(latency_min: f64, latency_max: f64, drop_prob: f64, rng: Prng) -> Self {
        SimNet {
            heap: BinaryHeap::new(),
            payloads: std::collections::HashMap::new(),
            seq: 0,
            latency_min,
            latency_max,
            drop_prob,
            isolated: HashSet::new(),
            rng,
            sent: 0,
            dropped: 0,
        }
    }

    /// Schedule a message from `from` to `to` at virtual time `now`.
    pub fn send(&mut self, from: NodeId, to: NodeId, msg: M, now: f64) {
        self.sent += 1;
        if self.isolated.contains(&from) || self.isolated.contains(&to) {
            self.dropped += 1;
            return;
        }
        if self.drop_prob > 0.0 && self.rng.next_f64() < self.drop_prob {
            self.dropped += 1;
            return;
        }
        let latency =
            self.latency_min + self.rng.next_f64() * (self.latency_max - self.latency_min);
        let at = now + latency;
        self.seq += 1;
        self.payloads.insert(self.seq, msg);
        self.heap.push(Reverse((Time(at), self.seq, from, to)));
    }

    /// Pop all messages with delivery time <= now, in order.
    pub fn deliver_until(&mut self, now: f64) -> Vec<(NodeId, NodeId, M)> {
        let mut out = Vec::new();
        while let Some(Reverse((Time(t), seq, from, to))) = self.heap.peek().cloned() {
            if t > now {
                break;
            }
            self.heap.pop();
            // Late isolation drops in-flight traffic too.
            let msg = self.payloads.remove(&seq).expect("payload");
            if self.isolated.contains(&from) || self.isolated.contains(&to) {
                self.dropped += 1;
                continue;
            }
            out.push((from, to, msg));
        }
        out
    }

    /// Cut a node off from the network (crash/partition simulation).
    pub fn isolate(&mut self, node: NodeId) {
        self.isolated.insert(node);
    }

    /// Reconnect a previously isolated node.
    pub fn heal(&mut self, node: NodeId) {
        self.isolated.remove(&node);
    }

    pub fn in_flight(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delivers_in_time_order() {
        let mut net: SimNet<u32> = SimNet::new(0.001, 0.010, 0.0, Prng::new(1));
        for i in 0..50 {
            net.send(0, 1, i, 0.0);
        }
        let got = net.deliver_until(1.0);
        assert_eq!(got.len(), 50);
        // Monotone redelivery times are enforced by heap order; check count
        // and that nothing is left.
        assert_eq!(net.in_flight(), 0);
    }

    #[test]
    fn respects_now_cutoff() {
        let mut net: SimNet<u32> = SimNet::new(0.5, 0.5, 0.0, Prng::new(2));
        net.send(0, 1, 7, 0.0);
        assert!(net.deliver_until(0.4).is_empty());
        assert_eq!(net.deliver_until(0.6).len(), 1);
    }

    #[test]
    fn drops_at_configured_rate() {
        let mut net: SimNet<u32> = SimNet::new(0.0, 0.0, 0.3, Prng::new(3));
        for _ in 0..10_000 {
            net.send(0, 1, 0, 0.0);
        }
        let delivered = net.deliver_until(1.0).len() as f64;
        let rate = 1.0 - delivered / 10_000.0;
        assert!((rate - 0.3).abs() < 0.03, "drop rate {rate}");
    }

    #[test]
    fn link_oracle_is_stable_per_link_and_bounded() {
        let links = LinkLatency::new(
            Duration::from_millis(5),
            Duration::from_millis(10),
            Duration::from_millis(2),
            42,
        );
        // Per-link means are stable and within [base, base + spread].
        let m = links.mean_s("shard0", "mainchain");
        assert_eq!(m, links.mean_s("shard0", "mainchain"));
        assert!((0.005..=0.015).contains(&m), "mean {m}");
        // Directed links differ (with overwhelming probability for this
        // seed) and the topology depends on the seed.
        let back = links.mean_s("mainchain", "shard0");
        assert_ne!(m, back);
        let other = LinkLatency::new(
            Duration::from_millis(5),
            Duration::from_millis(10),
            Duration::from_millis(2),
            43,
        );
        assert_ne!(m, other.mean_s("shard0", "mainchain"));
        // Samples: mean + bounded jitter, reproducible per salt.
        for salt in 0..100 {
            let s = links.sample_s("shard0", "mainchain", salt);
            assert!(s >= m && s <= m + 0.002 + 1e-12, "sample {s} mean {m}");
            assert_eq!(s, links.sample_s("shard0", "mainchain", salt));
        }
        assert!(links.max_s() >= links.sample_s("a", "b", 7));
        // Self-links are free; the zero oracle prices everything at 0.
        assert_eq!(links.sample_s("shard1", "shard1", 3), 0.0);
        assert_eq!(LinkLatency::zero().sample_s("a", "b", 1), 0.0);
    }

    #[test]
    fn isolation_blocks_both_directions_and_in_flight() {
        let mut net: SimNet<u32> = SimNet::new(0.1, 0.1, 0.0, Prng::new(4));
        net.send(0, 1, 1, 0.0); // in flight when isolation happens
        net.isolate(1);
        net.send(0, 1, 2, 0.0);
        net.send(1, 0, 3, 0.0);
        assert!(net.deliver_until(1.0).is_empty());
        net.heal(1);
        net.send(0, 1, 4, 1.0);
        assert_eq!(net.deliver_until(2.0).len(), 1);
    }
}
