//! In-process simulated network: latency, jitter, drops, partitions.
//!
//! Messages are scheduled onto a priority queue keyed by virtual delivery
//! time; `deliver_until(now)` drains in timestamp order. Deterministic given
//! the seed, which is what makes the consensus property tests reproducible.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::collections::HashSet;

use crate::consensus::NodeId;
use crate::util::prng::Prng;

/// Orderable f64 wrapper for the scheduling heap.
#[derive(Clone, Copy, PartialEq, PartialOrd)]
struct Time(f64);

impl Eq for Time {}
#[allow(clippy::derive_ord_xor_partial_ord)]
impl Ord for Time {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).expect("NaN time")
    }
}

/// The simulated transport.
pub struct SimNet<M> {
    heap: BinaryHeap<Reverse<(Time, u64, NodeId, NodeId)>>,
    payloads: std::collections::HashMap<u64, M>,
    seq: u64,
    latency_min: f64,
    latency_max: f64,
    drop_prob: f64,
    isolated: HashSet<NodeId>,
    rng: Prng,
    pub sent: u64,
    pub dropped: u64,
}

impl<M> SimNet<M> {
    /// Uniform latency in [latency_min, latency_max], iid drop probability.
    pub fn new(latency_min: f64, latency_max: f64, drop_prob: f64, rng: Prng) -> Self {
        SimNet {
            heap: BinaryHeap::new(),
            payloads: std::collections::HashMap::new(),
            seq: 0,
            latency_min,
            latency_max,
            drop_prob,
            isolated: HashSet::new(),
            rng,
            sent: 0,
            dropped: 0,
        }
    }

    /// Schedule a message from `from` to `to` at virtual time `now`.
    pub fn send(&mut self, from: NodeId, to: NodeId, msg: M, now: f64) {
        self.sent += 1;
        if self.isolated.contains(&from) || self.isolated.contains(&to) {
            self.dropped += 1;
            return;
        }
        if self.drop_prob > 0.0 && self.rng.next_f64() < self.drop_prob {
            self.dropped += 1;
            return;
        }
        let latency =
            self.latency_min + self.rng.next_f64() * (self.latency_max - self.latency_min);
        let at = now + latency;
        self.seq += 1;
        self.payloads.insert(self.seq, msg);
        self.heap.push(Reverse((Time(at), self.seq, from, to)));
    }

    /// Pop all messages with delivery time <= now, in order.
    pub fn deliver_until(&mut self, now: f64) -> Vec<(NodeId, NodeId, M)> {
        let mut out = Vec::new();
        while let Some(Reverse((Time(t), seq, from, to))) = self.heap.peek().cloned() {
            if t > now {
                break;
            }
            self.heap.pop();
            // Late isolation drops in-flight traffic too.
            let msg = self.payloads.remove(&seq).expect("payload");
            if self.isolated.contains(&from) || self.isolated.contains(&to) {
                self.dropped += 1;
                continue;
            }
            out.push((from, to, msg));
        }
        out
    }

    /// Cut a node off from the network (crash/partition simulation).
    pub fn isolate(&mut self, node: NodeId) {
        self.isolated.insert(node);
    }

    /// Reconnect a previously isolated node.
    pub fn heal(&mut self, node: NodeId) {
        self.isolated.remove(&node);
    }

    pub fn in_flight(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delivers_in_time_order() {
        let mut net: SimNet<u32> = SimNet::new(0.001, 0.010, 0.0, Prng::new(1));
        for i in 0..50 {
            net.send(0, 1, i, 0.0);
        }
        let got = net.deliver_until(1.0);
        assert_eq!(got.len(), 50);
        // Monotone redelivery times are enforced by heap order; check count
        // and that nothing is left.
        assert_eq!(net.in_flight(), 0);
    }

    #[test]
    fn respects_now_cutoff() {
        let mut net: SimNet<u32> = SimNet::new(0.5, 0.5, 0.0, Prng::new(2));
        net.send(0, 1, 7, 0.0);
        assert!(net.deliver_until(0.4).is_empty());
        assert_eq!(net.deliver_until(0.6).len(), 1);
    }

    #[test]
    fn drops_at_configured_rate() {
        let mut net: SimNet<u32> = SimNet::new(0.0, 0.0, 0.3, Prng::new(3));
        for _ in 0..10_000 {
            net.send(0, 1, 0, 0.0);
        }
        let delivered = net.deliver_until(1.0).len() as f64;
        let rate = 1.0 - delivered / 10_000.0;
        assert!((rate - 0.3).abs() < 0.03, "drop rate {rate}");
    }

    #[test]
    fn isolation_blocks_both_directions_and_in_flight() {
        let mut net: SimNet<u32> = SimNet::new(0.1, 0.1, 0.0, Prng::new(4));
        net.send(0, 1, 1, 0.0); // in flight when isolation happens
        net.isolate(1);
        net.send(0, 1, 2, 0.0);
        net.send(1, 0, 3, 0.0);
        assert!(net.deliver_until(1.0).is_empty());
        net.heal(1);
        net.send(0, 1, 4, 1.0);
        assert_eq!(net.deliver_until(2.0).len(), 1);
    }
}
