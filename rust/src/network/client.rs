//! Remote gateway client: the in-process submission API, spoken over a
//! socket.
//!
//! [`RemoteGateway`] wraps one [`FramedConn`] to a `scalesfl node` process
//! (an orderer directly, or a gateway fronting several) and rebuilds the
//! PR 2 pipelined semantics on the client side of the wire: `submit`
//! returns a real `SubmitHandle` immediately, and the commit outcome
//! resolves later without the caller polling the server.
//!
//! The mechanics mirror the in-process demux exactly. A single reader
//! thread owns the receive half of the connection and routes every
//! inbound frame: `Response`s resolve the RPC waiting under their
//! correlation id, and `Event`s — the commit stream, uncorrelated —
//! resolve the per-channel [`CommitWaiter::external`] table through
//! [`CommitWaiter::complete`] / [`CommitWaiter::reject`], which is the
//! same table/slot machinery a local `Gateway` uses; the `SubmitHandle`s
//! handed out here are literally the same type with the same drop and
//! timeout behaviour. Waiters register *before* the `Submit` frame is
//! written, so a commit event can never outrun its waiter even though
//! events and responses share the socket.
//!
//! When the connection dies, every blocked RPC fails fast and pending
//! handles resolve as `TimedOut` when drained (their event source is
//! gone), rather than anything hanging.

use std::collections::HashMap;
use std::io;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::crypto::Digest;
use crate::fabric::peer::CommitEvent;
use crate::fabric::waiter::CommitWaiter;
use crate::fabric::wire::{encode_frame, Event, Frame, Request, RequestId, Response};
use crate::fabric::{CommitOutcome, SubmitHandle};
use crate::ledger::envelope::SharedEnvelope;
use crate::ledger::tx::Proposal;
use crate::mempool::Reject;

use super::transport::{Endpoint, FramedConn};

/// One channel's chain position, as answered by a `Status` request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ChannelStatus {
    pub height: u64,
    pub tip: Digest,
    pub state_root: Digest,
}

/// Shared between the API face and the reader thread.
struct Demux {
    /// RPCs awaiting their correlated response.
    responses: Mutex<HashMap<RequestId, mpsc::Sender<Response>>>,
    /// Per-channel external waiter tables resolving commit events.
    waiters: Mutex<HashMap<String, Arc<CommitWaiter>>>,
    /// Set once the reader thread exits; RPCs fail fast afterwards.
    dead: AtomicBool,
}

impl Demux {
    /// The channel's external waiter table, created on first use.
    fn waiter(&self, channel: &str) -> Arc<CommitWaiter> {
        let mut waiters = self.waiters.lock().unwrap();
        match waiters.get(channel) {
            Some(w) => Arc::clone(w),
            None => {
                let w = Arc::new(CommitWaiter::external());
                waiters.insert(channel.to_string(), Arc::clone(&w));
                w
            }
        }
    }

    /// Route one inbound frame. Anything other than a response or event is
    /// a protocol violation; the reader closes the connection.
    fn route(&self, frame: Frame) -> Result<(), ()> {
        match frame {
            Frame::Response(resp) => {
                let id = match &resp {
                    Response::Endorsed { id, .. }
                    | Response::Accepted { id, .. }
                    | Response::Rejected { id, .. }
                    | Response::Failed { id, .. }
                    | Response::Status { id, .. } => *id,
                };
                // An id nobody waits for (RPC timed out already) is dropped.
                let slot = self.responses.lock().unwrap().remove(&id);
                if let Some(tx) = slot {
                    let _ = tx.send(resp);
                }
                Ok(())
            }
            Frame::Event(Event::Committed { channel, tx_id, block, code }) => {
                self.waiter(&channel).complete(CommitEvent {
                    channel: channel.into(),
                    tx_id,
                    block,
                    code,
                });
                Ok(())
            }
            Frame::Event(Event::Dropped { channel, tx_id, reject }) => {
                self.waiter(&channel).reject(&tx_id, reject);
                Ok(())
            }
            Frame::Request(_) => Err(()),
        }
    }

    /// The connection is gone: fail every blocked RPC immediately (their
    /// senders drop, so `recv` disconnects). Registered commit waiters are
    /// left in place — their handles drain as `TimedOut`.
    fn poison(&self) {
        self.dead.store(true, Ordering::Relaxed);
        self.responses.lock().unwrap().clear();
    }
}

/// A client connection to a fabric node process, exposing the local
/// gateway's submission API across the socket.
pub struct RemoteGateway {
    writer: Mutex<FramedConn>,
    demux: Arc<Demux>,
    next_id: AtomicU64,
    /// Per-transaction commit timeout (the paper's 30 s), also the RPC
    /// response deadline.
    pub timeout: Duration,
}

impl RemoteGateway {
    /// Dial `ep` (retrying with bounded backoff while a freshly spawned
    /// node process is still binding) and start the demux reader.
    pub fn connect(ep: &Endpoint) -> io::Result<RemoteGateway> {
        let conn = FramedConn::connect_retry(ep, Duration::from_secs(5))?;
        let mut reader = conn.try_clone()?;
        let demux = Arc::new(Demux {
            responses: Mutex::new(HashMap::new()),
            waiters: Mutex::new(HashMap::new()),
            dead: AtomicBool::new(false),
        });
        let routed = Arc::clone(&demux);
        thread::Builder::new()
            .name("remote-gw-demux".into())
            .spawn(move || {
                loop {
                    match reader.recv_frame() {
                        Ok(Some(frame)) => {
                            if routed.route(frame).is_err() {
                                break;
                            }
                        }
                        Ok(None) | Err(_) => break,
                    }
                }
                reader.shutdown();
                routed.poison();
            })
            .expect("spawn remote gateway demux");
        Ok(RemoteGateway {
            writer: Mutex::new(conn),
            demux,
            next_id: AtomicU64::new(1),
            timeout: Duration::from_secs(30),
        })
    }

    /// Send one request and block for its correlated response.
    fn rpc(&self, build: impl FnOnce(RequestId) -> Request) -> Result<Response, String> {
        if self.demux.dead.load(Ordering::Relaxed) {
            return Err("connection lost".into());
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        self.demux.responses.lock().unwrap().insert(id, tx);
        let frame = Frame::Request(build(id));
        let sent = self.writer.lock().unwrap().send(&encode_frame(&frame));
        if let Err(e) = sent {
            self.demux.responses.lock().unwrap().remove(&id);
            return Err(format!("send failed: {e}"));
        }
        match rx.recv_timeout(self.timeout) {
            Ok(resp) => Ok(resp),
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Err("connection lost awaiting response".into())
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                self.demux.responses.lock().unwrap().remove(&id);
                Err("request timed out".into())
            }
        }
    }

    /// Endorse `proposal` on the server's peers; the returned envelope
    /// carries the exact canonical bytes the server produced, ready to
    /// [`submit_endorsed`](RemoteGateway::submit_endorsed) verbatim.
    pub fn endorse(&self, proposal: &Proposal) -> Result<SharedEnvelope, String> {
        match self.rpc(|id| Request::Endorse { id, proposal: proposal.clone() })? {
            Response::Endorsed { envelope, .. } => Ok(envelope),
            Response::Failed { reason, .. } => Err(reason),
            other => Err(format!("unexpected response: {other:?}")),
        }
    }

    /// Submit an endorsed envelope. The returned handle carries the
    /// admission verdict already; the commit outcome streams back as an
    /// event and resolves it, exactly like a local submission.
    pub fn submit_endorsed(&self, envelope: SharedEnvelope) -> SubmitHandle {
        let started = Instant::now();
        let tx_id = envelope.tx_id();
        let channel = envelope.proposal().channel.clone();
        let waiter = self.demux.waiter(&channel);
        // Register before the frame leaves: the commit event arrives on
        // the same socket after the server's Accepted, but ordering with
        // respect to *this thread* is only guaranteed by registering first.
        let Some(rx) = waiter.register(tx_id) else {
            let out = CommitOutcome::Rejected {
                reject: Reject::Duplicate,
                latency: started.elapsed(),
            };
            return SubmitHandle::resolved(tx_id, started, self.timeout, out);
        };
        let resolved =
            |out: CommitOutcome| SubmitHandle::resolved(tx_id, started, self.timeout, out);
        match self.rpc(|id| Request::Submit { id, envelope: envelope.clone() }) {
            Ok(Response::Accepted { .. }) => {
                SubmitHandle::pending(tx_id, started, self.timeout, rx, waiter)
            }
            Ok(Response::Rejected { reject, .. }) => {
                waiter.deregister(&tx_id);
                resolved(CommitOutcome::Rejected { reject, latency: started.elapsed() })
            }
            Ok(Response::Failed { reason, .. }) => {
                waiter.deregister(&tx_id);
                resolved(CommitOutcome::EndorsementFailed { reason, latency: started.elapsed() })
            }
            Ok(other) => {
                waiter.deregister(&tx_id);
                resolved(CommitOutcome::EndorsementFailed {
                    reason: format!("unexpected response: {other:?}"),
                    latency: started.elapsed(),
                })
            }
            Err(reason) => {
                waiter.deregister(&tx_id);
                resolved(CommitOutcome::EndorsementFailed { reason, latency: started.elapsed() })
            }
        }
    }

    /// Endorse + submit: the remote mirror of `Gateway::submit`.
    pub fn submit(&self, proposal: &Proposal) -> SubmitHandle {
        let started = Instant::now();
        match self.endorse(proposal) {
            Ok(envelope) => self.submit_endorsed(envelope),
            Err(reason) => SubmitHandle::resolved(
                proposal.tx_id(),
                started,
                self.timeout,
                CommitOutcome::EndorsementFailed { reason, latency: started.elapsed() },
            ),
        }
    }

    /// Closed-loop shim, as `Gateway::submit_and_wait`.
    pub fn submit_and_wait(&self, proposal: &Proposal) -> CommitOutcome {
        self.submit(proposal).wait()
    }

    /// Query a channel's chain position on the server.
    pub fn status(&self, channel: &str) -> Result<ChannelStatus, String> {
        match self.rpc(|id| Request::Status { id, channel: channel.to_string() })? {
            Response::Status { height, tip, state_root, .. } => {
                Ok(ChannelStatus { height, tip, state_root })
            }
            Response::Failed { reason, .. } => Err(reason),
            other => Err(format!("unexpected response: {other:?}")),
        }
    }

    /// Transactions currently awaiting their commit event (all channels).
    pub fn in_flight(&self) -> usize {
        self.demux.waiters.lock().unwrap().values().map(|w| w.pending()).sum()
    }
}

impl Drop for RemoteGateway {
    fn drop(&mut self) {
        // Shut the shared socket down so the demux reader wakes and exits;
        // it poisons the tables on the way out.
        self.writer.lock().unwrap().shutdown();
    }
}
