//! Socket transport for the multi-process fabric: length-prefixed frames
//! over TCP or Unix-domain sockets.
//!
//! The unit of exchange is one `fabric::wire` frame, carried as a `u32`
//! little-endian length prefix followed by exactly that many payload
//! bytes. The transport owns the framing only — payload grammar and
//! validation live in [`crate::fabric::wire`]. Both sides of the split
//! ([`crate::network::node`] servers and the
//! [`crate::network::client::RemoteGateway`]) speak through the same
//! [`FramedConn`], full-duplex: each half is driven by its own thread over
//! a [`FramedConn::try_clone`] of the connection, so responses and
//! asynchronous commit events share one socket without interleaving
//! partial writes (every frame is sent with a single `write_all`).
//!
//! Hostile-input posture matches the codec's: the length prefix is
//! validated against [`MAX_FRAME`] *before* any buffer is sized from it,
//! a connection that dies mid-frame surfaces as an explicit
//! `UnexpectedEof` error (torn — the stream cannot be resynchronized, the
//! connection is closed), and a clean close at a frame boundary is
//! `Ok(None)`, never an error.

use std::fmt;
use std::fs;
use std::io::{self, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::thread;
use std::time::{Duration, Instant};

use crate::fabric::wire::{decode_frame, encode_frame, Frame};

/// Hard cap on one frame's payload length. Generous against real traffic
/// (the largest frames carry one consensus batch of envelopes, well under
/// a MiB) while bounding what a hostile length prefix can make the
/// receiver allocate.
pub const MAX_FRAME: usize = 16 * 1024 * 1024;

/// A dialable/bindable address: `tcp:HOST:PORT` or `uds:/PATH`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Endpoint {
    /// TCP socket address, e.g. `127.0.0.1:7050` (port 0 binds ephemeral).
    Tcp(String),
    /// Unix-domain socket path.
    Uds(PathBuf),
}

impl Endpoint {
    /// Parse the textual form used by CLI flags and the `LISTENING` line.
    pub fn parse(s: &str) -> Result<Endpoint, String> {
        if let Some(addr) = s.strip_prefix("tcp:") {
            if addr.is_empty() {
                return Err("empty tcp address".into());
            }
            Ok(Endpoint::Tcp(addr.to_string()))
        } else if let Some(path) = s.strip_prefix("uds:") {
            if path.is_empty() {
                return Err("empty uds path".into());
            }
            Ok(Endpoint::Uds(PathBuf::from(path)))
        } else {
            Err(format!("bad endpoint {s:?}: expected tcp:HOST:PORT or uds:/PATH"))
        }
    }
}

impl fmt::Display for Endpoint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Endpoint::Tcp(addr) => write!(f, "tcp:{addr}"),
            Endpoint::Uds(path) => write!(f, "uds:{}", path.display()),
        }
    }
}

/// One bound listening socket. Accepting yields [`FramedConn`]s.
pub enum Listener {
    Tcp(TcpListener),
    /// Keeps the bound path so it can be unlinked on drop.
    Uds(UnixListener, PathBuf),
}

impl Listener {
    /// Bind `ep`. A stale UDS path from a crashed previous process is
    /// removed first (binding over a live one still fails with
    /// `AddrInUse` on the fresh path only if another process re-creates
    /// it, which is the caller's configuration error to resolve).
    pub fn bind(ep: &Endpoint) -> io::Result<Listener> {
        match ep {
            Endpoint::Tcp(addr) => Ok(Listener::Tcp(TcpListener::bind(addr.as_str())?)),
            Endpoint::Uds(path) => {
                let _ = fs::remove_file(path);
                Ok(Listener::Uds(UnixListener::bind(path)?, path.clone()))
            }
        }
    }

    /// The endpoint actually bound — resolves `tcp:...:0` to the ephemeral
    /// port the OS picked, which is what a parent process parses from the
    /// child's `LISTENING` line.
    pub fn local_endpoint(&self) -> io::Result<Endpoint> {
        match self {
            Listener::Tcp(l) => Ok(Endpoint::Tcp(l.local_addr()?.to_string())),
            Listener::Uds(_, path) => Ok(Endpoint::Uds(path.clone())),
        }
    }

    /// Block for the next inbound connection.
    pub fn accept(&self) -> io::Result<FramedConn> {
        match self {
            Listener::Tcp(l) => {
                let (s, _) = l.accept()?;
                let _ = s.set_nodelay(true);
                Ok(FramedConn { stream: Stream::Tcp(s) })
            }
            Listener::Uds(l, _) => {
                let (s, _) = l.accept()?;
                Ok(FramedConn { stream: Stream::Uds(s) })
            }
        }
    }
}

impl Drop for Listener {
    fn drop(&mut self) {
        if let Listener::Uds(_, path) = self {
            let _ = fs::remove_file(path);
        }
    }
}

/// The two stream flavors behind one Read/Write face.
enum Stream {
    Tcp(TcpStream),
    Uds(UnixStream),
}

impl Stream {
    fn try_clone(&self) -> io::Result<Stream> {
        match self {
            Stream::Tcp(s) => Ok(Stream::Tcp(s.try_clone()?)),
            Stream::Uds(s) => Ok(Stream::Uds(s.try_clone()?)),
        }
    }

    fn shutdown(&self) {
        match self {
            Stream::Tcp(s) => {
                let _ = s.shutdown(Shutdown::Both);
            }
            Stream::Uds(s) => {
                let _ = s.shutdown(Shutdown::Both);
            }
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            Stream::Uds(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            Stream::Uds(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            Stream::Uds(s) => s.flush(),
        }
    }
}

/// Fill `buf`, tolerating a clean EOF: returns how many bytes arrived
/// before the stream ended (== `buf.len()` on success).
fn read_full(r: &mut impl Read, buf: &mut [u8]) -> io::Result<usize> {
    let mut n = 0;
    while n < buf.len() {
        match r.read(&mut buf[n..]) {
            Ok(0) => return Ok(n),
            Ok(m) => n += m,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(n)
}

fn torn(what: &str) -> io::Error {
    io::Error::new(io::ErrorKind::UnexpectedEof, format!("connection closed inside a {what}"))
}

/// One framed, full-duplex connection. Writes are atomic per frame (one
/// buffered `write_all` of prefix + payload); reads validate the length
/// prefix before allocating and distinguish a clean close (`Ok(None)`)
/// from a torn frame (`Err`, kind `UnexpectedEof`).
pub struct FramedConn {
    stream: Stream,
}

impl FramedConn {
    /// Dial `ep` once.
    pub fn connect(ep: &Endpoint) -> io::Result<FramedConn> {
        match ep {
            Endpoint::Tcp(addr) => {
                let s = TcpStream::connect(addr.as_str())?;
                let _ = s.set_nodelay(true);
                Ok(FramedConn { stream: Stream::Tcp(s) })
            }
            Endpoint::Uds(path) => {
                Ok(FramedConn { stream: Stream::Uds(UnixStream::connect(path)?) })
            }
        }
    }

    /// Dial `ep` with bounded exponential backoff (10 ms doubling to a
    /// 250 ms cap) until `total` has elapsed — how a parent-spawned
    /// process is reached while it is still binding its listener. The
    /// last connect error is returned on timeout.
    pub fn connect_retry(ep: &Endpoint, total: Duration) -> io::Result<FramedConn> {
        let start = Instant::now();
        let mut backoff = Duration::from_millis(10);
        loop {
            match FramedConn::connect(ep) {
                Ok(c) => return Ok(c),
                Err(e) => {
                    if start.elapsed() + backoff >= total {
                        return Err(e);
                    }
                    thread::sleep(backoff);
                    backoff = (backoff * 2).min(Duration::from_millis(250));
                }
            }
        }
    }

    /// A second handle on the same socket, for driving the read and write
    /// halves from separate threads. Shutdown through either handle closes
    /// both directions.
    pub fn try_clone(&self) -> io::Result<FramedConn> {
        Ok(FramedConn { stream: self.stream.try_clone()? })
    }

    /// Close both directions, waking any thread blocked in [`recv`]
    /// (it observes EOF or a reset) on every clone of this connection.
    ///
    /// [`recv`]: FramedConn::recv
    pub fn shutdown(&self) {
        self.stream.shutdown();
    }

    /// Send one frame payload, length-prefixed, as a single write.
    pub fn send(&mut self, payload: &[u8]) -> io::Result<()> {
        if payload.len() > MAX_FRAME {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!("frame of {} bytes exceeds MAX_FRAME {MAX_FRAME}", payload.len()),
            ));
        }
        let mut buf = Vec::with_capacity(4 + payload.len());
        buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        buf.extend_from_slice(payload);
        self.stream.write_all(&buf)
    }

    /// Encode and send one protocol frame.
    pub fn send_frame(&mut self, f: &Frame) -> io::Result<()> {
        self.send(&encode_frame(f))
    }

    /// Receive one frame payload. `Ok(None)` is the peer closing cleanly
    /// at a frame boundary; a close inside the header or payload is a torn
    /// frame (`UnexpectedEof`), and a length prefix above [`MAX_FRAME`] is
    /// `InvalidData` — reported before any allocation is sized from it.
    pub fn recv(&mut self) -> io::Result<Option<Vec<u8>>> {
        let mut hdr = [0u8; 4];
        let got = read_full(&mut self.stream, &mut hdr)?;
        if got == 0 {
            return Ok(None);
        }
        if got < hdr.len() {
            return Err(torn("frame header"));
        }
        let len = u32::from_le_bytes(hdr) as usize;
        if len > MAX_FRAME {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("frame length {len} exceeds MAX_FRAME {MAX_FRAME}"),
            ));
        }
        let mut payload = vec![0u8; len];
        let got = read_full(&mut self.stream, &mut payload)?;
        if got < len {
            return Err(torn("frame payload"));
        }
        Ok(Some(payload))
    }

    /// Receive and decode one protocol frame. A payload the wire codec
    /// rejects — torn *inside* a complete transport frame is just as
    /// unrecoverable as structurally malformed — maps to `InvalidData`:
    /// the caller should close the connection.
    pub fn recv_frame(&mut self) -> io::Result<Option<Frame>> {
        match self.recv()? {
            None => Ok(None),
            Some(buf) => decode_frame(&buf)
                .map(Some)
                .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e.to_string())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::wire::{Request, Response};
    use crate::util::tempdir::TempDir;

    fn tcp_pair() -> (FramedConn, FramedConn) {
        let l = Listener::bind(&Endpoint::parse("tcp:127.0.0.1:0").unwrap()).unwrap();
        let ep = l.local_endpoint().unwrap();
        let t = thread::spawn(move || l.accept().unwrap());
        let client = FramedConn::connect(&ep).unwrap();
        (client, t.join().unwrap())
    }

    #[test]
    fn endpoint_parse_and_display() {
        let tcp = Endpoint::parse("tcp:127.0.0.1:7050").unwrap();
        assert_eq!(tcp, Endpoint::Tcp("127.0.0.1:7050".into()));
        assert_eq!(Endpoint::parse(&tcp.to_string()).unwrap(), tcp);
        let uds = Endpoint::parse("uds:/tmp/x.sock").unwrap();
        assert_eq!(uds, Endpoint::Uds(PathBuf::from("/tmp/x.sock")));
        assert_eq!(Endpoint::parse(&uds.to_string()).unwrap(), uds);
        assert!(Endpoint::parse("http:whatever").is_err());
        assert!(Endpoint::parse("tcp:").is_err());
        assert!(Endpoint::parse("uds:").is_err());
    }

    #[test]
    fn tcp_frames_roundtrip_full_duplex() {
        let (mut client, mut server) = tcp_pair();
        let req = Frame::Request(Request::Status { id: 1, channel: "ch".into() });
        let resp = Frame::Response(Response::Failed { id: 1, reason: "nope".into() });
        client.send_frame(&req).unwrap();
        assert_eq!(server.recv_frame().unwrap(), Some(req));
        server.send_frame(&resp).unwrap();
        // Several frames queued back to back stay delimited.
        server.send(b"").unwrap();
        server.send(&[7u8; 3]).unwrap();
        assert_eq!(client.recv_frame().unwrap(), Some(resp));
        assert_eq!(client.recv().unwrap(), Some(vec![]));
        assert_eq!(client.recv().unwrap(), Some(vec![7, 7, 7]));
        // Clean close at a frame boundary is None, not an error.
        drop(server);
        assert_eq!(client.recv().unwrap(), None);
    }

    #[test]
    fn uds_frames_roundtrip() {
        let dir = TempDir::new("uds");
        let ep = Endpoint::Uds(dir.join("node.sock"));
        let l = Listener::bind(&ep).unwrap();
        assert_eq!(l.local_endpoint().unwrap(), ep);
        let dial = ep.clone();
        let t = thread::spawn(move || {
            let mut c = FramedConn::connect_retry(&dial, Duration::from_secs(2)).unwrap();
            c.send(b"over uds").unwrap();
            c.recv().unwrap()
        });
        let mut server = l.accept().unwrap();
        assert_eq!(server.recv().unwrap(), Some(b"over uds".to_vec()));
        server.send(b"ack").unwrap();
        assert_eq!(t.join().unwrap(), Some(b"ack".to_vec()));
        // Dropping the listener unlinks the socket path.
        drop(l);
        assert!(!dir.join("node.sock").exists());
    }

    /// Satellite: a connection killed mid-frame surfaces as a torn-frame
    /// error — never a panic, never a silent truncation into `Ok`.
    #[test]
    fn killed_mid_frame_is_a_torn_error() {
        // Closed inside the payload: header promises 100 bytes, 10 arrive.
        let (mut client, mut server) = tcp_pair();
        client.stream.write_all(&100u32.to_le_bytes()).unwrap();
        client.stream.write_all(&[1u8; 10]).unwrap();
        drop(client);
        let err = server.recv().unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof, "{err}");

        // Closed inside the header itself.
        let (mut client, mut server) = tcp_pair();
        client.stream.write_all(&[5u8, 0]).unwrap();
        drop(client);
        let err = server.recv().unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof, "{err}");
    }

    #[test]
    fn oversized_length_prefix_rejected_before_allocation() {
        let (mut client, mut server) = tcp_pair();
        // Claims a 4 GiB - 1 frame; the receiver must refuse without
        // trying to allocate it.
        client.stream.write_all(&u32::MAX.to_le_bytes()).unwrap();
        let err = server.recv().unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData, "{err}");
        // And the sender refuses to produce one.
        let big = vec![0u8; MAX_FRAME + 1];
        assert!(client.send(&big).is_err());
    }

    #[test]
    fn recv_frame_maps_undecodable_payload_to_invalid_data() {
        let (mut client, mut server) = tcp_pair();
        client.send(&[0xEE, 0xEE, 0xEE]).unwrap();
        let err = server.recv_frame().unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData, "{err}");
    }

    #[test]
    fn connect_retry_reaches_a_late_listener() {
        let probe = Listener::bind(&Endpoint::parse("tcp:127.0.0.1:0").unwrap()).unwrap();
        let ep = probe.local_endpoint().unwrap();
        drop(probe); // port reserved a moment ago, nobody listening now
        let dial = ep.clone();
        let t = thread::spawn(move || {
            FramedConn::connect_retry(&dial, Duration::from_secs(5)).map(|_| ())
        });
        // Bind the listener after the dialer has (very likely) started
        // failing; backoff keeps retrying until it lands.
        thread::sleep(Duration::from_millis(50));
        let l = Listener::bind(&ep).unwrap();
        let accepted = l.accept();
        assert!(accepted.is_ok());
        assert!(t.join().unwrap().is_ok());
    }

    #[test]
    fn shutdown_wakes_a_blocked_reader() {
        let (client, mut server) = tcp_pair();
        let t = thread::spawn(move || server.recv());
        thread::sleep(Duration::from_millis(20));
        client.shutdown();
        // EOF (clean None) or a reset error — either way the reader wakes.
        let _ = t.join().unwrap();
    }
}
