//! Injectable clocks: wall-clock for deployments, virtual for tests.
//!
//! Components that model latency (the storage fetch hop, the mempool's TTL
//! and rate limiter) take an `Arc<dyn Clock>` instead of calling
//! `Instant::now()` / `thread::sleep` directly, so stress tests can advance
//! time instantly without stalling real threads.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A monotonic clock with an injectable sleep.
pub trait Clock: Send + Sync {
    /// Monotonic seconds since this clock's epoch.
    fn now(&self) -> f64;
    /// Wait for `d` to elapse on this clock.
    fn sleep(&self, d: Duration);
}

/// Wall-clock time; `sleep` blocks the calling thread.
pub struct SystemClock {
    start: Instant,
}

impl SystemClock {
    pub fn new() -> SystemClock {
        SystemClock { start: Instant::now() }
    }

    /// Convenience: a fresh system clock behind an `Arc`.
    pub fn shared() -> Arc<dyn Clock> {
        Arc::new(SystemClock::new())
    }
}

impl Default for SystemClock {
    fn default() -> Self {
        SystemClock::new()
    }
}

impl Clock for SystemClock {
    fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    fn sleep(&self, d: Duration) {
        std::thread::sleep(d);
    }
}

/// Deterministic test clock: `sleep` advances virtual time and returns
/// immediately, so simulated latencies never stall real threads.
#[derive(Default)]
pub struct VirtualClock {
    elapsed_ns: AtomicU64,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock::default()
    }

    /// Advance virtual time by `d`.
    pub fn advance(&self, d: Duration) {
        self.elapsed_ns.fetch_add(d.as_nanos() as u64, Ordering::SeqCst);
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> f64 {
        self.elapsed_ns.load(Ordering::SeqCst) as f64 / 1e9
    }

    fn sleep(&self, d: Duration) {
        self.advance(d);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn system_clock_is_monotonic() {
        let c = SystemClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
        let t0 = Instant::now();
        c.sleep(Duration::from_millis(5));
        assert!(t0.elapsed() >= Duration::from_millis(5));
    }

    #[test]
    fn virtual_clock_advances_without_blocking() {
        let c = VirtualClock::new();
        assert_eq!(c.now(), 0.0);
        let t0 = Instant::now();
        c.sleep(Duration::from_secs(3600));
        assert!((c.now() - 3600.0).abs() < 1e-9);
        // A one-hour virtual sleep must complete ~instantly in wall time.
        assert!(t0.elapsed() < Duration::from_secs(5));
        c.advance(Duration::from_millis(500));
        assert!((c.now() - 3600.5).abs() < 1e-9);
    }

    #[test]
    fn trait_object_usable_through_arc() {
        let c: Arc<dyn Clock> = Arc::new(VirtualClock::new());
        c.sleep(Duration::from_secs(1));
        assert!((c.now() - 1.0).abs() < 1e-9);
    }
}
