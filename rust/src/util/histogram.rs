//! Latency histogram with logarithmic buckets plus exact streaming summaries.
//!
//! Used by the Caliper-style harness for per-transaction latency
//! distributions (p50/p95/p99, mean, min/max) without retaining every sample.

/// Log-bucketed histogram over positive values (seconds).
#[derive(Clone, Debug)]
pub struct Histogram {
    /// Bucket i covers [base * gamma^i, base * gamma^(i+1)).
    counts: Vec<u64>,
    base: f64,
    gamma: f64,
    total: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        // 1 microsecond .. ~10 hours at 5% resolution.
        Histogram::new(1e-6, 1.05, 512)
    }
}

impl Histogram {
    pub fn new(base: f64, gamma: f64, nbuckets: usize) -> Self {
        Histogram {
            counts: vec![0; nbuckets],
            base,
            gamma,
            total: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    fn bucket(&self, v: f64) -> usize {
        if v <= self.base {
            return 0;
        }
        let i = ((v / self.base).ln() / self.gamma.ln()) as usize;
        i.min(self.counts.len() - 1)
    }

    pub fn record(&mut self, v: f64) {
        let b = self.bucket(v);
        self.counts[b] += 1;
        self.total += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Approximate quantile (bucket upper edge, clamped into the observed
    /// `[min, max]` range so degenerate distributions stay exact: a
    /// single-sample p50 is that sample, never a bucket boundary above
    /// it), q in [0, 1]. `None` when nothing has been recorded.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        if self.total == 0 {
            return None;
        }
        let target = (q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                let edge = self.base * self.gamma.powi(i as i32 + 1);
                return Some(edge.clamp(self.min, self.max));
            }
        }
        Some(self.max)
    }

    /// Bucket-wise aggregation; panics when the two histograms were built
    /// with different bucket layouts (base/gamma/bucket count), because
    /// merging those would silently misfile every count.
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.counts.len(), other.counts.len(), "merge: bucket counts differ");
        assert_eq!(self.base.to_bits(), other.base.to_bits(), "merge: bases differ");
        assert_eq!(self.gamma.to_bits(), other.gamma.to_bits(), "merge: gammas differ");
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram() {
        let h = Histogram::default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.5), None);
        assert_eq!(h.quantile(0.99), None);
    }

    #[test]
    fn single_sample_quantiles_are_the_sample() {
        let mut h = Histogram::default();
        h.record(0.123);
        for q in [0.0, 0.5, 0.95, 1.0] {
            assert_eq!(h.quantile(q), Some(0.123), "q={q}");
        }
    }

    #[test]
    fn quantiles_clamp_into_observed_range() {
        let mut h = Histogram::default();
        h.record(0.1);
        h.record(0.2);
        let p99 = h.quantile(0.99).unwrap();
        assert!((0.1..=0.2).contains(&p99), "p99 {p99}");
        assert_eq!(h.quantile(1.0), Some(0.2));
    }

    #[test]
    fn mean_and_extrema_exact() {
        let mut h = Histogram::default();
        for v in [0.1, 0.2, 0.3] {
            h.record(v);
        }
        assert!((h.mean() - 0.2).abs() < 1e-12);
        assert_eq!(h.min(), 0.1);
        assert_eq!(h.max(), 0.3);
    }

    #[test]
    fn quantiles_within_resolution() {
        let mut h = Histogram::default();
        let mut r = crate::util::prng::Prng::new(1);
        for _ in 0..50_000 {
            h.record(0.001 + 0.999 * r.next_f64()); // U(1ms, 1s)
        }
        let p50 = h.quantile(0.5).unwrap();
        assert!((p50 - 0.5).abs() < 0.06, "p50 {p50}");
        let p99 = h.quantile(0.99).unwrap();
        assert!((p99 - 0.99).abs() < 0.08, "p99 {p99}");
    }

    #[test]
    fn merge_combines() {
        let mut a = Histogram::default();
        let mut b = Histogram::default();
        a.record(0.1);
        b.record(0.3);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!((a.mean() - 0.2).abs() < 1e-12);
        assert_eq!(a.min(), 0.1);
        assert_eq!(a.max(), 0.3);
    }

    #[test]
    #[should_panic(expected = "bases differ")]
    fn merge_rejects_mismatched_layout() {
        let mut a = Histogram::new(1e-6, 1.05, 512);
        let b = Histogram::new(1e-3, 1.05, 512);
        a.merge(&b);
    }
}
