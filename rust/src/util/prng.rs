//! Deterministic PRNG (xoshiro256** seeded via splitmix64) plus the sampling
//! primitives the FL substrate needs: normals (Box–Muller), Gamma
//! (Marsaglia–Tsang) for Dirichlet partitioning, shuffles and choices.
//!
//! Every stochastic component in the repo (dataset synthesis, client
//! sampling, network jitter, attack injection) takes an explicit `Prng` so
//! experiments are reproducible from a single seed.

/// xoshiro256** generator.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
    /// Cached second normal from Box–Muller.
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Prng {
    /// Seed deterministically; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Prng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare: None,
        }
    }

    /// Derive an independent child stream (for per-client/per-shard PRNGs).
    pub fn fork(&mut self, tag: u64) -> Prng {
        Prng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        let (u1, u2) = (self.next_f64().max(1e-300), self.next_f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.spare = Some(r * s);
        r * c
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (shape > 0).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let u = self.next_f64().max(1e-300);
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.next_f64();
            if u < 1.0 - 0.0331 * x.powi(4) || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha * 1) sample of dimension `k` (non-IID partitioner).
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha).max(1e-12)).collect();
        let sum: f64 = g.iter().sum();
        for v in &mut g {
            *v /= sum;
        }
        g
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `n` distinct indices from [0, pool) (n <= pool).
    pub fn sample_indices(&mut self, pool: usize, n: usize) -> Vec<usize> {
        assert!(n <= pool);
        let mut idx: Vec<usize> = (0..pool).collect();
        self.shuffle(&mut idx);
        idx.truncate(n);
        idx
    }

    /// Exponential with the given mean (Poisson inter-arrival times).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * self.next_f64().max(1e-300).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Prng::new(7);
        let mut b = Prng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Prng::new(8);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Prng::new(1);
        let xs: Vec<f64> = (0..20_000).map(|_| r.next_f64()).collect();
        let m = crate::util::mean(&xs);
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
    }

    #[test]
    fn normal_moments() {
        let mut r = Prng::new(2);
        let xs: Vec<f64> = (0..40_000).map(|_| r.normal()).collect();
        let m = crate::util::mean(&xs);
        let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
        assert!(m.abs() < 0.03, "mean {m}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Prng::new(3);
        for &shape in &[0.3, 1.0, 4.5] {
            let xs: Vec<f64> = (0..20_000).map(|_| r.gamma(shape)).collect();
            let m = crate::util::mean(&xs);
            assert!((m - shape).abs() / shape < 0.08, "shape {shape} mean {m}");
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Prng::new(4);
        for _ in 0..50 {
            let d = r.dirichlet(0.5, 10);
            let s: f64 = d.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(d.iter().all(|&x| x > 0.0));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Prng::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Prng::new(6);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Prng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
