//! Hermetic scratch directories for disk-touching tests and benches.
//!
//! Every instance gets a process-unique path (pid + atomic counter), so
//! parallel test threads never share a directory, and the tree is removed
//! on drop — a failed assertion mid-test still cleans up, because the
//! unwind runs destructors. Hand-rolled because `tempfile` is not in the
//! offline vendor set.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::{env, fs, process};

static NEXT: AtomicU64 = AtomicU64::new(0);

/// A uniquely named directory under the system temp dir, deleted on drop.
#[derive(Debug)]
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Create `"$TMPDIR/scalesfl-<prefix>-<pid>-<n>"`. Panics if the
    /// directory cannot be created — a scratch dir that silently fails to
    /// exist would turn every downstream assertion into noise.
    pub fn new(prefix: &str) -> TempDir {
        let n = NEXT.fetch_add(1, Ordering::Relaxed);
        let path =
            env::temp_dir().join(format!("scalesfl-{prefix}-{}-{n}", process::id()));
        fs::create_dir_all(&path).expect("create temp dir");
        TempDir { path }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// A path inside the directory (not created).
    pub fn join(&self, rel: &str) -> PathBuf {
        self.path.join(rel)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        // Best-effort: a vanished tree (e.g. the test removed it) is fine.
        let _ = fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unique_created_and_removed_on_drop() {
        let a = TempDir::new("t");
        let b = TempDir::new("t");
        assert_ne!(a.path(), b.path());
        assert!(a.path().is_dir() && b.path().is_dir());
        let kept = a.path().to_path_buf();
        fs::write(a.join("f"), b"x").unwrap();
        drop(a);
        assert!(!kept.exists(), "dropped dir must be cleaned up");
        assert!(b.path().is_dir());
    }
}
