//! Shared utilities: deterministic PRNG, minimal JSON, thread pool,
//! latency histograms, and a small randomized property-testing helper.
//!
//! The offline build vendors only the `xla` dependency tree, so these are
//! hand-rolled rather than pulled from crates.io (no rand/serde/rayon).

pub mod check;
pub mod clock;
pub mod histogram;
pub mod json;
pub mod prng;
pub mod tempdir;
pub mod threadpool;

/// Round `x` up to the next multiple of `m`.
pub fn round_up(x: usize, m: usize) -> usize {
    x.div_ceil(m) * m
}

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn mean_basics() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
    }
}
