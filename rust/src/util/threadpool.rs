//! Fixed-size thread pool over std channels (no external deps).
//!
//! Peers use a pool for endorsement work; the caliper harness uses one for
//! workload workers. Jobs are `FnOnce() + Send` closures; `join` blocks until
//! the queue drains.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size worker pool.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<thread::JoinHandle<()>>,
    inflight: Arc<(AtomicUsize, Mutex<()>, Condvar)>,
}

impl ThreadPool {
    /// Spawn `n` worker threads (n >= 1).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let inflight = Arc::new((AtomicUsize::new(0), Mutex::new(()), Condvar::new()));
        let mut handles = Vec::with_capacity(n);
        for i in 0..n {
            let rx = Arc::clone(&rx);
            let inflight = Arc::clone(&inflight);
            handles.push(
                thread::Builder::new()
                    .name(format!("pool-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                let (count, lock, cv) = &*inflight;
                                if count.fetch_sub(1, Ordering::SeqCst) == 1 {
                                    let _g = lock.lock().unwrap();
                                    cv.notify_all();
                                }
                            }
                            Err(_) => return,
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { tx: Some(tx), handles, inflight }
    }

    /// Enqueue a job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.inflight.0.fetch_add(1, Ordering::SeqCst);
        self.tx.as_ref().unwrap().send(Box::new(job)).expect("pool closed");
    }

    /// Block until all enqueued jobs have completed.
    pub fn join(&self) {
        let (count, lock, cv) = &*self.inflight;
        let mut g = lock.lock().unwrap();
        while count.load(Ordering::SeqCst) != 0 {
            g = cv.wait(g).unwrap();
        }
    }

    /// Number of jobs submitted but not yet finished.
    pub fn inflight(&self) -> usize {
        self.inflight.0.load(Ordering::SeqCst)
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..1000 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 1000);
    }

    #[test]
    fn join_waits_for_slow_jobs() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..4 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                thread::sleep(std::time::Duration::from_millis(20));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 4);
        assert_eq!(pool.inflight(), 0);
    }

    #[test]
    fn drop_joins_threads() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool); // must not hang
    }
}
