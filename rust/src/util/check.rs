//! Tiny randomized property-testing helper (proptest is unavailable offline)
//! plus the seeded fault-scenario harness.
//!
//! `check(name, cases, |rng| ...)` runs a property closure against `cases`
//! independently seeded PRNGs and panics with the failing seed so a failure
//! reproduces deterministically:
//!
//! ```no_run
//! // (no_run: rustdoc test binaries don't inherit the xla rpath rustflags
//! // on this image, so the example is compile-checked only.)
//! use scalesfl::util::check::check;
//! check("sum-commutes", 64, |rng| {
//!     let (a, b) = (rng.next_f64(), rng.next_f64());
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! [`fault_scenario`] is the single-case variant for consensus fault
//! injection tests: the scenario runs from one seed (its default, or
//! `SCALESFL_TEST_SEED` to replay), and a failure panics with the exact
//! seed — "flaky in CI" becomes a one-command local repro:
//!
//! ```text
//! SCALESFL_TEST_SEED=12345 cargo test -q leader_crash
//! ```

use super::prng::Prng;

fn seed_from_env(var: &str) -> Option<u64> {
    std::env::var(var).ok().and_then(|s| s.parse().ok())
}

fn panic_message(e: &(dyn std::any::Any + Send)) -> String {
    e.downcast_ref::<String>()
        .cloned()
        .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_else(|| "<non-string panic>".into())
}

/// Run `prop` across `cases` seeded PRNGs; panics name the failing seed.
/// `SCALESFL_TEST_SEED` (preferred) or `SCALESFL_CHECK_SEED` overrides the
/// base seed to replay a reported failure.
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut Prng) + std::panic::RefUnwindSafe) {
    // Fixed base seed keeps CI deterministic.
    let base: u64 = seed_from_env("SCALESFL_TEST_SEED")
        .or_else(|| seed_from_env("SCALESFL_CHECK_SEED"))
        .unwrap_or(0x5CA1E5F1);
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(|| {
            let mut rng = Prng::new(seed);
            prop(&mut rng);
        });
        if let Err(e) = result {
            let msg = panic_message(&*e);
            panic!("property '{name}' failed on case {case} (SCALESFL_TEST_SEED={seed}): {msg}");
        }
    }
}

/// Run one seeded fault scenario. `f` receives the scenario seed —
/// `default_seed`, unless `SCALESFL_TEST_SEED` overrides it for replay —
/// and must derive *all* randomness (fault plans, link topologies) from
/// it. On failure the panic names the seed, so a CI log line is a local
/// repro command.
pub fn fault_scenario(name: &str, default_seed: u64, f: impl Fn(u64) + std::panic::RefUnwindSafe) {
    let seed = seed_from_env("SCALESFL_TEST_SEED").unwrap_or(default_seed);
    if let Err(e) = std::panic::catch_unwind(|| f(seed)) {
        let msg = panic_message(&*e);
        panic!("fault scenario '{name}' failed (replay: SCALESFL_TEST_SEED={seed}): {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("trivial", 32, |rng| {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_names_seed() {
        check("fails", 8, |rng| {
            assert!(rng.next_f64() < 0.0, "always fails");
        });
    }

    #[test]
    fn fault_scenario_passes_default_seed() {
        fault_scenario("uses-seed", 42, |seed| {
            // Env override only matters when the variable is set; the
            // harness must otherwise hand through the default.
            if std::env::var("SCALESFL_TEST_SEED").is_err() {
                assert_eq!(seed, 42);
            }
        });
    }

    #[test]
    #[should_panic(expected = "SCALESFL_TEST_SEED=")]
    fn fault_scenario_failure_names_replay_seed() {
        fault_scenario("always-fails", 7, |_seed| {
            panic!("scenario bug");
        });
    }
}
