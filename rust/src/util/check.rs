//! Tiny randomized property-testing helper (proptest is unavailable offline).
//!
//! `check(name, cases, |rng| ...)` runs a property closure against `cases`
//! independently seeded PRNGs and panics with the failing seed so a failure
//! reproduces deterministically:
//!
//! ```no_run
//! // (no_run: rustdoc test binaries don't inherit the xla rpath rustflags
//! // on this image, so the example is compile-checked only.)
//! use scalesfl::util::check::check;
//! check("sum-commutes", 64, |rng| {
//!     let (a, b) = (rng.next_f64(), rng.next_f64());
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::prng::Prng;

/// Run `prop` across `cases` seeded PRNGs; panics name the failing seed.
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut Prng) + std::panic::RefUnwindSafe) {
    // Fixed base seed keeps CI deterministic; override with SCALESFL_CHECK_SEED.
    let base: u64 = std::env::var("SCALESFL_CHECK_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5CA1E5F1);
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(|| {
            let mut rng = Prng::new(seed);
            prop(&mut rng);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed on case {case} (SCALESFL_CHECK_SEED={seed}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("trivial", 32, |rng| {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_names_seed() {
        check("fails", 8, |rng| {
            assert!(rng.next_f64() < 0.0, "always fails");
        });
    }
}
