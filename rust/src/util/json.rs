//! Minimal JSON value type with emitter and recursive-descent parser.
//!
//! Used for benchmark reports, config files, and chaincode payloads.
//! Supports the full JSON grammar except exotic number forms; keys keep
//! insertion order (Vec-backed object) so reports are stable.

use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object.
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Builder-style insert (object only).
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut kvs) = self {
            kvs.push((key.to_string(), val.into()));
        }
        self
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(kvs) => {
                write!(f, "{{")?;
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.i < self.b.len() && self.b[self.i] == c {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.ws();
        match self.b.get(self.i) {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => {
                self.i += 1;
                let mut arr = Vec::new();
                self.ws();
                if self.b.get(self.i) == Some(&b']') {
                    self.i += 1;
                    return Ok(Json::Arr(arr));
                }
                loop {
                    arr.push(self.value()?);
                    self.ws();
                    match self.b.get(self.i) {
                        Some(b',') => self.i += 1,
                        Some(b']') => {
                            self.i += 1;
                            return Ok(Json::Arr(arr));
                        }
                        _ => return Err(format!("bad array at byte {}", self.i)),
                    }
                }
            }
            Some(b'{') => {
                self.i += 1;
                let mut kvs = Vec::new();
                self.ws();
                if self.b.get(self.i) == Some(&b'}') {
                    self.i += 1;
                    return Ok(Json::Obj(kvs));
                }
                loop {
                    self.ws();
                    let k = self.string()?;
                    self.ws();
                    self.eat(b':')?;
                    kvs.push((k, self.value()?));
                    self.ws();
                    match self.b.get(self.i) {
                        Some(b',') => self.i += 1,
                        Some(b'}') => {
                            self.i += 1;
                            return Ok(Json::Obj(kvs));
                        }
                        _ => return Err(format!("bad object at byte {}", self.i)),
                    }
                }
            }
            Some(_) => self.number(),
            None => Err("unexpected end".into()),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.b.get(self.i) {
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.b.get(self.i) {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u".to_string())?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // UTF-8 passthrough: copy the full codepoint.
                    let rest = std::str::from_utf8(&self.b[self.i..]).map_err(|_| "bad utf8")?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
                None => return Err("unterminated string".into()),
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let v = Json::obj()
            .set("name", "fig4")
            .set("tps", 12.5)
            .set("ok", true)
            .set("series", Json::Arr(vec![1u64.into(), 2u64.into()]))
            .set("nested", Json::obj().set("x", Json::Null));
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn parse_whitespace_and_escapes() {
        let v = Json::parse(" { \"a\\n\" : [ 1 , -2.5e1 , \"\\u0041\" ] } ").unwrap();
        assert_eq!(v.get("a\n").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a\n").unwrap().as_arr().unwrap()[2].as_str(), Some("A"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nulll").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }
}
