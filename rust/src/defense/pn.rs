//! PN-sequence lazy-client detection (Ma et al. / BLADE-FL; paper §2.3, §5).
//!
//! Each client perturbs its published update with a pseudo-noise sequence
//! derived from a private seed, publishing the seed after the round closes.
//! A *lazy* client that copied someone else's published update carries the
//! victim's PN signature: correlating every update against every revealed
//! PN sequence exposes the copy.

use crate::util::prng::Prng;

/// Deterministic ±`amplitude` pseudo-noise sequence from a seed.
pub fn pn_sequence(seed: u64, len: usize, amplitude: f32) -> Vec<f32> {
    // Domain-separate PN streams from other PRNG uses of the same seed.
    let mut rng = Prng::new(seed ^ 0x504E_5345_5121_AA55);
    (0..len).map(|_| if rng.next_u64() & 1 == 0 { amplitude } else { -amplitude }).collect()
}

/// Add a PN sequence to an update (client-side, pre-publication).
pub fn apply_pn(update: &mut [f32], seed: u64, amplitude: f32) {
    let pn = pn_sequence(seed, update.len(), amplitude);
    for (u, p) in update.iter_mut().zip(pn) {
        *u += p;
    }
}

/// Normalised correlation between an update and a PN sequence in [-1, 1].
pub fn pn_correlation(update: &[f32], seed: u64, amplitude: f32) -> f64 {
    let pn = pn_sequence(seed, update.len(), amplitude);
    let dot: f64 = update.iter().zip(&pn).map(|(&u, &p)| u as f64 * p as f64).sum();
    let nu: f64 = update.iter().map(|&u| (u as f64).powi(2)).sum::<f64>().sqrt();
    let np: f64 = pn.iter().map(|&p| (p as f64).powi(2)).sum::<f64>().sqrt();
    if nu == 0.0 || np == 0.0 {
        return 0.0;
    }
    dot / (nu * np)
}

/// Given published updates and their revealed PN seeds, flag lazy clients:
/// update `i` correlating above `threshold` with client `j`'s PN (j != i)
/// means `i` copied `j`'s published update.
pub fn detect_lazy(
    updates: &[Vec<f32>],
    seeds: &[u64],
    amplitude: f32,
    threshold: f64,
) -> Vec<usize> {
    assert_eq!(updates.len(), seeds.len());
    let mut lazy = Vec::new();
    for (i, u) in updates.iter().enumerate() {
        for (j, &seed) in seeds.iter().enumerate() {
            if i != j && pn_correlation(u, seed, amplitude) > threshold {
                lazy.push(i);
                break;
            }
        }
    }
    lazy
}

#[cfg(test)]
mod tests {
    use super::*;

    fn update(seed: u64, n: usize) -> Vec<f32> {
        let mut rng = Prng::new(seed);
        (0..n).map(|_| rng.normal() as f32 * 0.01).collect()
    }

    const N: usize = 20_000;
    const AMP: f32 = 0.005;

    #[test]
    fn pn_sequence_deterministic_and_balanced() {
        let a = pn_sequence(7, N, AMP);
        assert_eq!(a, pn_sequence(7, N, AMP));
        let pos = a.iter().filter(|&&v| v > 0.0).count() as f64 / N as f64;
        assert!((pos - 0.5).abs() < 0.02, "positive fraction {pos}");
        assert_ne!(a, pn_sequence(8, N, AMP));
    }

    #[test]
    fn own_pn_correlates_others_do_not() {
        let mut u = update(1, N);
        apply_pn(&mut u, 42, AMP);
        assert!(pn_correlation(&u, 42, AMP) > 0.3, "{}", pn_correlation(&u, 42, AMP));
        assert!(pn_correlation(&u, 43, AMP).abs() < 0.05);
    }

    #[test]
    fn detects_lazy_copier() {
        // Clients 0, 1 honest; client 2 copies 0's published update and
        // stamps its own PN on top.
        let seeds = [100u64, 101, 102];
        let mut u0 = update(1, N);
        apply_pn(&mut u0, seeds[0], AMP);
        let mut u1 = update(2, N);
        apply_pn(&mut u1, seeds[1], AMP);
        let mut u2 = u0.clone();
        apply_pn(&mut u2, seeds[2], AMP);
        let lazy = detect_lazy(&[u0, u1, u2], &seeds, AMP, 0.2);
        assert_eq!(lazy, vec![2]);
    }

    #[test]
    fn honest_round_flags_nobody() {
        let seeds = [1u64, 2, 3, 4];
        let updates: Vec<Vec<f32>> = seeds
            .iter()
            .enumerate()
            .map(|(i, &s)| {
                let mut u = update(i as u64 + 10, N);
                apply_pn(&mut u, s, AMP);
                u
            })
            .collect();
        assert!(detect_lazy(&updates, &seeds, AMP, 0.2).is_empty());
    }
}
