//! Endorsement-time defences: a single peer's accept/reject verdict on one
//! model update, evaluated against the peer's private test split.

use crate::runtime::ops::{EvalResult, ModelOps};

/// What an endorsing peer knows when judging an update (paper §3.4.6).
pub struct UpdateContext<'a> {
    /// The fetched + hash-verified update weights.
    pub params: &'a [f32],
    pub round: u64,
    pub client: &'a str,
    /// The peer's runtime handle.
    pub ops: &'a ModelOps,
    /// Peer-local held-out test split (row-major x, labels y).
    pub eval_x: &'a [f32],
    pub eval_y: &'a [i32],
    /// Current global model's weights (previous round), if any.
    pub prev_global: Option<&'a [f32]>,
    /// Current global model's score on this peer's split, if computed.
    pub baseline: Option<EvalResult>,
}

/// An endorsement-time acceptance policy. `Err(reason)` rejects the update,
/// failing this peer's endorsement.
pub trait EndorsementDefense: Send + Sync {
    fn name(&self) -> &str;
    fn verdict(&self, ctx: &UpdateContext<'_>) -> Result<(), String>;
}

/// Accept everything (throughput benchmarking / trusted settings).
pub struct NoDefense;

impl EndorsementDefense for NoDefense {
    fn name(&self) -> &str {
        "none"
    }
    fn verdict(&self, _ctx: &UpdateContext<'_>) -> Result<(), String> {
        Ok(())
    }
}

/// RONI (Reject On Negative Influence, Barreno et al.): evaluate the update
/// on the peer's local split and reject if accuracy drops more than
/// `max_degradation` below the current global model's accuracy.
///
/// Per the paper this suits IID splits; non-IID shards should prefer the
/// aggregation-time FoolsGold pass.
pub struct Roni {
    pub max_degradation: f64,
}

impl EndorsementDefense for Roni {
    fn name(&self) -> &str {
        "roni"
    }

    fn verdict(&self, ctx: &UpdateContext<'_>) -> Result<(), String> {
        let params = ctx.params.to_vec();
        let result = ctx
            .ops
            .evaluate(&params, ctx.eval_x, ctx.eval_y)
            .map_err(|e| format!("roni eval failed: {e}"))?;
        if !result.loss.is_finite() {
            return Err("roni: non-finite loss".into());
        }
        if let Some(base) = ctx.baseline {
            if result.accuracy < base.accuracy - self.max_degradation {
                return Err(format!(
                    "roni: accuracy {:.4} below baseline {:.4} - {:.3}",
                    result.accuracy, base.accuracy, self.max_degradation
                ));
            }
        }
        Ok(())
    }
}

/// Norm-constraint defence (Kairouz et al. §5): reject updates whose delta
/// from the current global model exceeds `max_norm` (boosted/scaled attacks).
pub struct NormBound {
    pub max_norm: f64,
}

impl EndorsementDefense for NormBound {
    fn name(&self) -> &str {
        "norm-bound"
    }

    fn verdict(&self, ctx: &UpdateContext<'_>) -> Result<(), String> {
        // Without a pinned global there is no delta to judge; accept (the
        // workflow pins the initial model at round 0 so this only happens
        // in bootstrap/unit settings).
        let Some(g) = ctx.prev_global else {
            return Ok(());
        };
        let norm = delta_norm(ctx.params, g);
        if !norm.is_finite() {
            return Err("norm-bound: non-finite norm".into());
        }
        if norm > self.max_norm {
            return Err(format!("norm-bound: delta norm {norm:.3} > {:.3}", self.max_norm));
        }
        Ok(())
    }
}

/// Chain several defences; all must accept.
pub struct AllOf(pub Vec<Box<dyn EndorsementDefense>>);

impl EndorsementDefense for AllOf {
    fn name(&self) -> &str {
        "all-of"
    }

    fn verdict(&self, ctx: &UpdateContext<'_>) -> Result<(), String> {
        for d in &self.0 {
            d.verdict(ctx).map_err(|e| format!("{}: {e}", d.name()))?;
        }
        Ok(())
    }
}

#[allow(dead_code)]
fn l2(v: &[f32]) -> f64 {
    v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

fn delta_norm(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = x as f64 - y as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx_without_runtime<'a>(
        params: &'a [f32],
        prev: Option<&'a [f32]>,
        ops: &'a ModelOps,
    ) -> UpdateContext<'a> {
        UpdateContext {
            params,
            round: 1,
            client: "c0",
            ops,
            eval_x: &[],
            eval_y: &[],
            prev_global: prev,
            baseline: None,
        }
    }

    #[test]
    fn norm_bound_judges_delta() {
        let Some(ops) = crate::runtime::shared_ops() else { return };
        let g = vec![0.0f32; ops.p_pad()];
        let small: Vec<f32> = (0..ops.p_pad()).map(|i| if i == 0 { 0.5 } else { 0.0 }).collect();
        let big = vec![1.0f32; ops.p_pad()];
        let d = NormBound { max_norm: 10.0 };
        assert!(d.verdict(&ctx_without_runtime(&small, Some(&g), &ops)).is_ok());
        assert!(d.verdict(&ctx_without_runtime(&big, Some(&g), &ops)).is_err());
    }

    #[test]
    fn roni_rejects_degraded_model() {
        let Some(ops) = crate::runtime::shared_ops() else { return };
        use crate::fl::datasets;
        let data = datasets::mnist_like(42, 42, 256, ops.input_dim(), 10);
        // Train a decent model.
        let mut good = ops.init_params(1).unwrap();
        for _ in 0..40 {
            let (next, _) =
                ops.train_step(good, &data.x[..32 * ops.input_dim()], &data.y[..32], 0.05).unwrap();
            good = next;
        }
        let baseline = ops.evaluate(&good, &data.x, &data.y).unwrap();
        // A garbage model degrades accuracy.
        let garbage = ops.init_params(99).unwrap();
        let roni = Roni { max_degradation: 0.1 };
        let ctx = UpdateContext {
            params: &garbage,
            round: 1,
            client: "evil",
            ops: &ops,
            eval_x: &data.x,
            eval_y: &data.y,
            prev_global: Some(&good),
            baseline: Some(baseline),
        };
        assert!(baseline.accuracy > 0.5, "baseline acc {:.3}", baseline.accuracy);
        assert!(roni.verdict(&ctx).is_err());
        // The good model itself passes.
        let ctx_good = UpdateContext { params: &good, ..ctx };
        assert!(roni.verdict(&ctx_good).is_ok());
    }
}
