//! Aggregation-time defences over the round's full update set.

/// Multi-Krum (Blanchard et al., NeurIPS '17).
///
/// Given the pairwise squared-distance matrix of `n` updates and an assumed
/// byzantine count `f`, each update's Krum score is the sum of its distances
/// to its `n - f - 2` nearest neighbours; the `m = n - f` lowest-scoring
/// updates are selected for aggregation. Returns selected indices (sorted).
///
/// Tolerates up to ~33% adversaries; degrades if Sybils dominate the mean —
/// exactly the regime FoolsGold targets (compose both, paper §2.3).
pub fn multi_krum(dist: &[Vec<f64>], f: usize) -> Vec<usize> {
    let n = dist.len();
    if n == 0 {
        return Vec::new();
    }
    let m = n.saturating_sub(f).max(1);
    let neigh = n.saturating_sub(f + 2).max(1);
    let mut scores: Vec<(f64, usize)> = (0..n)
        .map(|i| {
            let mut ds: Vec<f64> = (0..n).filter(|&j| j != i).map(|j| dist[i][j]).collect();
            ds.sort_by(|a, b| a.partial_cmp(b).unwrap());
            (ds.iter().take(neigh).sum::<f64>(), i)
        })
        .collect();
    scores.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
    let mut selected: Vec<usize> = scores.into_iter().take(m).map(|(_, i)| i).collect();
    selected.sort_unstable();
    selected
}

/// FoolsGold (Fung et al., 2018), cosine-similarity variant.
///
/// Sybils pushing a shared objective submit highly similar updates; honest
/// non-IID clients do not. Each client's weight is down-scaled by its
/// maximum pairwise similarity (with the standard re-scaling and logit
/// sharpening). Returns per-update weights in [0, 1].
pub fn foolsgold_weights(cos: &[Vec<f64>]) -> Vec<f64> {
    let n = cos.len();
    if n == 0 {
        return Vec::new();
    }
    if n == 1 {
        return vec![1.0];
    }
    // max similarity to any other update
    let mut maxcs: Vec<f64> = (0..n)
        .map(|i| {
            (0..n)
                .filter(|&j| j != i)
                .map(|j| cos[i][j])
                .fold(f64::NEG_INFINITY, f64::max)
        })
        .collect();
    // pardoning: rescale j's similarity when i looks more sybil than j
    let snapshot = maxcs.clone();
    for i in 0..n {
        for j in 0..n {
            if i != j && snapshot[j] > snapshot[i] && snapshot[j] > 0.0 {
                maxcs[i] = maxcs[i].max(cos[i][j] * snapshot[i] / snapshot[j]);
            }
        }
    }
    let mut w: Vec<f64> = maxcs.iter().map(|&m| (1.0 - m).clamp(0.0, 1.0)).collect();
    // rescale to max 1
    let wmax = w.iter().cloned().fold(0.0f64, f64::max);
    if wmax > 0.0 {
        for v in &mut w {
            *v /= wmax;
        }
    }
    // logit sharpening
    for v in &mut w {
        let x = (*v).clamp(1e-6, 1.0 - 1e-6);
        *v = (0.5 * (x / (1.0 - x)).ln() + 0.5).clamp(0.0, 1.0);
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    /// Distances for n points where `outliers` are far from the cluster.
    fn dist_matrix(n: usize, outliers: &[usize], rng: &mut Prng) -> Vec<Vec<f64>> {
        let mut d = vec![vec![0.0; n]; n];
        for i in 0..n {
            for j in (i + 1)..n {
                let far = outliers.contains(&i) || outliers.contains(&j);
                let base = if far { 100.0 } else { 1.0 };
                let v = base + rng.next_f64() * 0.1;
                d[i][j] = v;
                d[j][i] = v;
            }
        }
        d
    }

    #[test]
    fn krum_excludes_outliers() {
        let mut rng = Prng::new(1);
        let d = dist_matrix(8, &[2, 5], &mut rng);
        let sel = multi_krum(&d, 2);
        assert_eq!(sel.len(), 6);
        assert!(!sel.contains(&2) && !sel.contains(&5), "selected {sel:?}");
    }

    #[test]
    fn krum_all_honest_keeps_n_minus_f() {
        let mut rng = Prng::new(2);
        let d = dist_matrix(8, &[], &mut rng);
        let sel = multi_krum(&d, 2);
        assert_eq!(sel.len(), 6);
    }

    #[test]
    fn krum_small_inputs() {
        assert!(multi_krum(&[], 0).is_empty());
        assert_eq!(multi_krum(&[vec![0.0]], 0), vec![0]);
        let d = vec![vec![0.0, 1.0], vec![1.0, 0.0]];
        assert_eq!(multi_krum(&d, 0).len(), 2);
    }

    /// Cosine matrix with a sybil cluster (identical directions).
    fn cos_matrix(n: usize, sybils: &[usize]) -> Vec<Vec<f64>> {
        let mut c = vec![vec![0.0; n]; n];
        for i in 0..n {
            c[i][i] = 1.0;
            for j in (i + 1)..n {
                let v = if sybils.contains(&i) && sybils.contains(&j) { 0.99 } else { 0.05 };
                c[i][j] = v;
                c[j][i] = v;
            }
        }
        c
    }

    #[test]
    fn foolsgold_downweights_sybils() {
        let c = cos_matrix(8, &[1, 4, 6]);
        let w = foolsgold_weights(&c);
        for s in [1usize, 4, 6] {
            assert!(w[s] < 0.2, "sybil {s} weight {}", w[s]);
        }
        for h in [0usize, 2, 3, 5, 7] {
            assert!(w[h] > 0.8, "honest {h} weight {}", w[h]);
        }
    }

    #[test]
    fn foolsgold_all_honest_keeps_weights() {
        let c = cos_matrix(6, &[]);
        let w = foolsgold_weights(&c);
        assert!(w.iter().all(|&v| v > 0.8), "{w:?}");
    }

    #[test]
    fn foolsgold_edge_sizes() {
        assert!(foolsgold_weights(&[]).is_empty());
        assert_eq!(foolsgold_weights(&[vec![1.0]]), vec![1.0]);
    }
}
