//! Pluggable model-acceptance defences (paper §2.3, §3.2).
//!
//! Two hook points, mirroring the paper's workflow:
//!
//! - **Endorsement-time** ([`EndorsementDefense`]): each endorsing peer
//!   votes on a single model update using its local data — RONI accuracy
//!   degradation, update-norm constraints. A rejection fails that peer's
//!   endorsement; the channel policy (majority) decides the transaction.
//! - **Aggregation-time** ([`aggregation`]): operates on the round's whole
//!   update set before FedAvg — Multi-Krum selection, FoolsGold similarity
//!   re-weighting, and PN-sequence lazy-client detection.

pub mod aggregation;
pub mod endorse;
pub mod pn;

pub use aggregation::{foolsgold_weights, multi_krum};
pub use endorse::{EndorsementDefense, NoDefense, NormBound, Roni, UpdateContext};
pub use pn::{apply_pn, detect_lazy, pn_correlation, pn_sequence};
