//! PJRT runtime: load the AOT artifacts and execute them from the L3 hot
//! path. Python never runs here — `make artifacts` lowered everything to
//! HLO text, which we parse, compile once per worker, and execute via the
//! `xla` crate's CPU PJRT client.
//!
//! `PjRtClient`/`PjRtLoadedExecutable` are not `Send`, so the runtime owns a
//! set of worker threads that each hold their own client + compiled
//! executables; callers submit jobs over a channel and block on a reply.
//! This mirrors the paper's "peer worker" processes (one gRPC worker per
//! peer) and lets every simulated peer evaluate models concurrently.

pub mod manifest;
pub mod ops;
pub mod service;
pub mod tensor;

pub use manifest::Manifest;
pub use ops::ModelOps;
pub use service::{Runtime, RuntimeConfig};
pub use tensor::Tensor;

/// Default artifacts directory relative to the repo root.
pub const DEFAULT_ARTIFACTS: &str = "artifacts";

/// Shared runtime for tests/benches: compiled once per process.
///
/// Returns `None` when `make artifacts` has not been run (tests that need
/// real PJRT skip themselves in that case).
pub fn shared() -> Option<std::sync::Arc<Runtime>> {
    use std::sync::OnceLock;
    static SHARED: OnceLock<Option<std::sync::Arc<Runtime>>> = OnceLock::new();
    SHARED
        .get_or_init(|| {
            let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(DEFAULT_ARTIFACTS);
            if !dir.join("manifest.txt").exists() {
                eprintln!("runtime::shared — artifacts not built, skipping");
                return None;
            }
            let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            Some(Runtime::load(&RuntimeConfig { artifacts_dir: dir, workers }).expect("load runtime"))
        })
        .clone()
}

/// Shared `ModelOps` over [`shared`].
pub fn shared_ops() -> Option<ModelOps> {
    shared().map(ModelOps::new)
}
