//! The runtime service: worker threads that each own a PJRT CPU client and
//! the full set of compiled executables, fed by a shared job queue.
//!
//! Job submission is blocking (the caller waits on a reply channel); the
//! per-worker client gives true pipeline parallelism when the host has
//! multiple cores, and a faithful "one single-threaded worker per peer"
//! model when capped at one (the paper's experimental configuration).

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::Instant;

use super::manifest::Manifest;
use super::tensor::Tensor;

/// Runtime configuration.
#[derive(Clone, Debug)]
pub struct RuntimeConfig {
    pub artifacts_dir: PathBuf,
    /// PJRT worker threads (each compiles its own copy of all executables).
    pub workers: usize,
}

impl Default for RuntimeConfig {
    fn default() -> Self {
        RuntimeConfig { artifacts_dir: PathBuf::from(super::DEFAULT_ARTIFACTS), workers: 1 }
    }
}

struct Job {
    exec: String,
    inputs: Vec<Tensor>,
    reply: mpsc::Sender<Result<Vec<Tensor>>>,
}

/// Handle to the runtime service. Cloneable; shared by all peers/clients.
pub struct Runtime {
    tx: Mutex<mpsc::Sender<Job>>,
    manifest: Manifest,
    handles: Vec<thread::JoinHandle<()>>,
    /// Total executions and total busy nanoseconds (for calibration).
    exec_count: Arc<AtomicU64>,
    busy_ns: Arc<AtomicU64>,
}

impl Runtime {
    /// Load the manifest and spin up workers; each worker parses + compiles
    /// every artifact once at startup.
    pub fn load(cfg: &RuntimeConfig) -> Result<Arc<Runtime>> {
        let manifest = Manifest::load(&cfg.artifacts_dir)?;
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let exec_count = Arc::new(AtomicU64::new(0));
        let busy_ns = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        for w in 0..cfg.workers.max(1) {
            let rx = Arc::clone(&rx);
            let dir = cfg.artifacts_dir.clone();
            let names = manifest.artifacts.clone();
            let ready = ready_tx.clone();
            let exec_count = Arc::clone(&exec_count);
            let busy_ns = Arc::clone(&busy_ns);
            handles.push(
                thread::Builder::new()
                    .name(format!("pjrt-{w}"))
                    .spawn(move || worker_main(rx, dir, names, ready, exec_count, busy_ns))
                    .expect("spawn pjrt worker"),
            );
        }
        drop(ready_tx);
        // Wait for every worker to finish compiling (or fail fast).
        for _ in 0..cfg.workers.max(1) {
            ready_rx.recv().context("pjrt worker died during startup")??;
        }
        Ok(Arc::new(Runtime { tx: Mutex::new(tx), manifest, handles, exec_count, busy_ns }))
    }

    /// Convenience: default config with `workers` threads.
    pub fn load_default(workers: usize) -> Result<Arc<Runtime>> {
        // Resolve artifacts relative to the crate root so tests/benches work
        // from any working directory.
        let mut dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        dir.push(super::DEFAULT_ARTIFACTS);
        Runtime::load(&RuntimeConfig { artifacts_dir: dir, workers })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute an artifact by name; blocks until the result is ready.
    pub fn run(&self, exec: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let (reply, wait) = mpsc::channel();
        {
            let tx = self.tx.lock().unwrap();
            tx.send(Job { exec: exec.to_string(), inputs, reply })
                .map_err(|_| anyhow!("runtime stopped"))?;
        }
        wait.recv().map_err(|_| anyhow!("runtime worker dropped job"))?
    }

    /// (executions, mean service seconds) since startup — used to calibrate
    /// the DES service-time model.
    pub fn stats(&self) -> (u64, f64) {
        let n = self.exec_count.load(Ordering::Relaxed);
        let ns = self.busy_ns.load(Ordering::Relaxed);
        (n, if n == 0 { 0.0 } else { ns as f64 / n as f64 / 1e9 })
    }
}

impl Drop for Runtime {
    fn drop(&mut self) {
        // Close the queue, then join workers.
        {
            let (dead_tx, _) = mpsc::channel();
            let mut guard = self.tx.lock().unwrap();
            *guard = dead_tx;
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_main(
    rx: Arc<Mutex<mpsc::Receiver<Job>>>,
    dir: PathBuf,
    names: Vec<String>,
    ready: mpsc::Sender<Result<()>>,
    exec_count: Arc<AtomicU64>,
    busy_ns: Arc<AtomicU64>,
) {
    let setup = || -> Result<(xla::PjRtClient, HashMap<String, xla::PjRtLoadedExecutable>)> {
        let client = xla::PjRtClient::cpu().context("PjRtClient::cpu")?;
        let mut execs = HashMap::new();
        for name in &names {
            let path = dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path utf8")?,
            )
            .with_context(|| format!("parsing {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).with_context(|| format!("compiling {name}"))?;
            execs.insert(name.clone(), exe);
        }
        Ok((client, execs))
    };
    let (_client, execs) = match setup() {
        Ok(v) => {
            let _ = ready.send(Ok(()));
            v
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    loop {
        let job = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        let Ok(job) = job else { return };
        let started = Instant::now();
        let result = run_one(&execs, &job.exec, &job.inputs);
        busy_ns.fetch_add(started.elapsed().as_nanos() as u64, Ordering::Relaxed);
        exec_count.fetch_add(1, Ordering::Relaxed);
        let _ = job.reply.send(result);
    }
}

fn run_one(
    execs: &HashMap<String, xla::PjRtLoadedExecutable>,
    name: &str,
    inputs: &[Tensor],
) -> Result<Vec<Tensor>> {
    let Some(exe) = execs.get(name) else {
        bail!("unknown executable '{name}'");
    };
    let mut literals = Vec::with_capacity(inputs.len());
    for t in inputs {
        literals.push(t.to_literal()?);
    }
    let out = exe.execute::<xla::Literal>(&literals)?;
    // AOT lowers with return_tuple=True: one device, one tuple literal.
    let lit = out
        .first()
        .and_then(|d| d.first())
        .context("empty execution result")?
        .to_literal_sync()?;
    let parts = lit.to_tuple()?;
    parts.iter().map(Tensor::from_literal).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Arc<Runtime>> {
        crate::runtime::shared()
    }

    #[test]
    fn init_params_returns_padded_vector() {
        let Some(rt) = runtime() else { return };
        let m = rt.manifest().clone();
        let out = rt.run("init_params", vec![Tensor::scalar_i32(0)]).unwrap();
        assert_eq!(out.len(), 1);
        let params = out[0].as_f32().unwrap();
        assert_eq!(params.len(), m.p_pad);
        // padding region is zero
        assert!(params[m.p..].iter().all(|&v| v == 0.0));
        // deterministic
        let again = rt.run("init_params", vec![Tensor::scalar_i32(0)]).unwrap();
        assert_eq!(out[0], again[0]);
    }

    #[test]
    fn unknown_executable_errors() {
        let Some(rt) = runtime() else { return };
        assert!(rt.run("nope", vec![]).is_err());
    }

    #[test]
    fn fedavg_agg_executes() {
        let Some(rt) = runtime() else { return };
        let m = rt.manifest().clone();
        let stack = vec![1.0f32; m.k * m.p_pad];
        let mut weights = vec![0.0f32; m.k];
        weights[0] = 1.0;
        let out = rt
            .run(
                "fedavg_agg",
                vec![Tensor::mat_f32(stack, m.k, m.p_pad), Tensor::vec_f32(weights)],
            )
            .unwrap();
        let agg = out[0].as_f32().unwrap();
        assert_eq!(agg.len(), m.p_pad);
        assert!(agg.iter().all(|&v| (v - 1.0).abs() < 1e-6));
    }
}
