//! Host tensors crossing the Rust <-> PJRT boundary.

use anyhow::{bail, Result};

/// A host tensor (f32 or i32) with shape.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl Tensor {
    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::F32(vec![v], vec![])
    }

    pub fn scalar_i32(v: i32) -> Tensor {
        Tensor::I32(vec![v], vec![])
    }

    pub fn vec_f32(v: Vec<f32>) -> Tensor {
        let n = v.len();
        Tensor::F32(v, vec![n])
    }

    pub fn vec_i32(v: Vec<i32>) -> Tensor {
        let n = v.len();
        Tensor::I32(v, vec![n])
    }

    pub fn mat_f32(v: Vec<f32>, rows: usize, cols: usize) -> Tensor {
        assert_eq!(v.len(), rows * cols);
        Tensor::F32(v, vec![rows, cols])
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32(_, s) | Tensor::I32(_, s) => s,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32(v, _) => v.len(),
            Tensor::I32(v, _) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32(v, _) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32(v, _) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            Tensor::F32(v, _) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    /// Scalar extraction (len-1 tensors of either dtype, widened to f64).
    pub fn scalar(&self) -> Result<f64> {
        match self {
            Tensor::F32(v, _) if v.len() == 1 => Ok(v[0] as f64),
            Tensor::I32(v, _) if v.len() == 1 => Ok(v[0] as f64),
            _ => bail!("tensor is not a scalar (len {})", self.len()),
        }
    }

    /// Build the xla literal for this tensor.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32(v, s) => {
                if s.is_empty() {
                    xla::Literal::scalar(v[0])
                } else {
                    xla::Literal::vec1(v).reshape(&dims)?
                }
            }
            Tensor::I32(v, s) => {
                if s.is_empty() {
                    xla::Literal::scalar(v[0])
                } else {
                    xla::Literal::vec1(v).reshape(&dims)?
                }
            }
        };
        Ok(lit)
    }

    /// Read a literal back into a host tensor.
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Tensor::F32(lit.to_vec::<f32>()?, dims)),
            xla::ElementType::S32 => Ok(Tensor::I32(lit.to_vec::<i32>()?, dims)),
            other => bail!("unsupported output element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_and_accessors() {
        let t = Tensor::mat_f32(vec![1.0, 2.0, 3.0, 4.0], 2, 2);
        assert_eq!(t.shape(), &[2, 2]);
        assert_eq!(t.len(), 4);
        assert!(t.as_f32().is_ok());
        assert!(t.as_i32().is_err());
        assert!(Tensor::scalar_f32(5.0).scalar().unwrap() == 5.0);
        assert!(Tensor::vec_i32(vec![1, 2]).scalar().is_err());
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::mat_f32(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_roundtrip_i32_scalar() {
        let t = Tensor::scalar_i32(42);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back.scalar().unwrap(), 42.0);
    }
}
