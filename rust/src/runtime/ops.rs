//! Typed model operations over the runtime: the vocabulary the FL workflow
//! and the endorsement policies speak (init / train / evaluate / aggregate /
//! distance matrices), hiding artifact names and tensor plumbing.

use anyhow::{bail, Result};
use std::sync::Arc;
use std::time::Instant;

use super::service::Runtime;
use super::tensor::Tensor;

/// A flat model parameter vector (length = manifest.p_pad).
pub type FlatParams = Vec<f32>;

/// Evaluation result over a dataset.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EvalResult {
    pub loss: f64,
    pub accuracy: f64,
    pub samples: usize,
}

/// High-level ops bound to a runtime handle.
#[derive(Clone)]
pub struct ModelOps {
    rt: Arc<Runtime>,
}

impl ModelOps {
    pub fn new(rt: Arc<Runtime>) -> Self {
        ModelOps { rt }
    }

    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.rt
    }

    pub fn p_pad(&self) -> usize {
        self.rt.manifest().p_pad
    }

    pub fn input_dim(&self) -> usize {
        self.rt.manifest().input_dim
    }

    pub fn k(&self) -> usize {
        self.rt.manifest().k
    }

    pub fn b_eval(&self) -> usize {
        self.rt.manifest().b_eval
    }

    /// Fresh parameters from a seed.
    pub fn init_params(&self, seed: i32) -> Result<FlatParams> {
        let out = self.rt.run("init_params", vec![Tensor::scalar_i32(seed)])?;
        out.into_iter().next().unwrap().into_f32()
    }

    /// One SGD minibatch step; `x` is row-major [b, input_dim].
    pub fn train_step(
        &self,
        params: FlatParams,
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<(FlatParams, f64)> {
        let b = y.len();
        if !self.rt.manifest().train_batch_sizes.contains(&b) {
            bail!(
                "no train_step artifact for batch {b} (have {:?})",
                self.rt.manifest().train_batch_sizes
            );
        }
        let out = self.rt.run(
            &format!("train_step_b{b}"),
            vec![
                Tensor::vec_f32(params),
                Tensor::mat_f32(x.to_vec(), b, self.input_dim()),
                Tensor::vec_i32(y.to_vec()),
                Tensor::scalar_f32(lr),
            ],
        )?;
        let mut it = out.into_iter();
        let new_params = it.next().unwrap().into_f32()?;
        let loss = it.next().unwrap().scalar()?;
        Ok((new_params, loss))
    }

    /// One DP-SGD minibatch step (batch 32): clip + Gaussian noise.
    #[allow(clippy::too_many_arguments)]
    pub fn dp_train_step(
        &self,
        params: FlatParams,
        x: &[f32],
        y: &[i32],
        lr: f32,
        seed: i32,
        clip: f32,
        noise_mult: f32,
    ) -> Result<(FlatParams, f64)> {
        let b = y.len();
        if b != 32 {
            bail!("dp_train_step lowered for batch 32, got {b}");
        }
        let out = self.rt.run(
            "dp_train_step_b32",
            vec![
                Tensor::vec_f32(params),
                Tensor::mat_f32(x.to_vec(), b, self.input_dim()),
                Tensor::vec_i32(y.to_vec()),
                Tensor::scalar_f32(lr),
                Tensor::scalar_i32(seed),
                Tensor::scalar_f32(clip),
                Tensor::scalar_f32(noise_mult),
            ],
        )?;
        let mut it = out.into_iter();
        let new_params = it.next().unwrap().into_f32()?;
        let loss = it.next().unwrap().scalar()?;
        Ok((new_params, loss))
    }

    /// Evaluate over (x, y), chunked into the lowered eval batch; partial
    /// tail batches are zero-padded and masked out of the counts.
    pub fn evaluate(&self, params: &FlatParams, x: &[f32], y: &[i32]) -> Result<EvalResult> {
        let (be, dim) = (self.b_eval(), self.input_dim());
        let n = y.len();
        if n == 0 {
            return Ok(EvalResult::default());
        }
        let mut loss_sum = 0.0;
        let mut correct = 0usize;
        // Perf note (§Perf iteration 3): a fused 2048-sample "eval_block"
        // executable was tried and measured *slower* than 8x256 dispatches
        // (35 ms vs 22 ms — the interpret-mode grid loop scales worse than
        // the dispatch overhead saved), so the per-batch path stays.
        let mut xb = vec![0.0f32; be * dim];
        let mut yb = vec![0i32; be];
        for start in (0..n).step_by(be) {
            let m = (n - start).min(be);
            xb[..m * dim].copy_from_slice(&x[start * dim..(start + m) * dim]);
            yb[..m].copy_from_slice(&y[start..start + m]);
            // Pad the tail with copies of the first row of the chunk so the
            // executable shape matches; padded rows are subtracted below.
            for pad in m..be {
                xb.copy_within(0..dim, pad * dim);
                yb[pad] = yb[0];
            }
            let out = self.rt.run(
                "eval_step",
                vec![
                    Tensor::vec_f32(params.clone()),
                    Tensor::mat_f32(xb.clone(), be, dim),
                    Tensor::vec_i32(yb.clone()),
                ],
            )?;
            let mut chunk_loss = out[0].scalar()?;
            let mut chunk_correct = out[1].scalar()? as i64;
            if m < be {
                // Measure the padded row once to subtract its contribution.
                let pad_out = self.rt.run(
                    "eval_step",
                    vec![
                        Tensor::vec_f32(params.clone()),
                        Tensor::mat_f32(
                            {
                                let mut one = vec![0.0f32; be * dim];
                                for r in 0..be {
                                    one[r * dim..(r + 1) * dim]
                                        .copy_from_slice(&xb[..dim]);
                                }
                                one
                            },
                            be,
                            dim,
                        ),
                        Tensor::vec_i32(vec![yb[0]; be]),
                    ],
                )?;
                let per_loss = pad_out[0].scalar()? / be as f64;
                let per_correct = pad_out[1].scalar()? / be as f64;
                chunk_loss -= per_loss * (be - m) as f64;
                chunk_correct -= (per_correct * (be - m) as f64).round() as i64;
            }
            loss_sum += chunk_loss;
            correct += chunk_correct.max(0) as usize;
        }
        Ok(EvalResult {
            loss: loss_sum / n as f64,
            accuracy: correct as f64 / n as f64,
            samples: n,
        })
    }

    /// FedAvg-aggregate up to K updates with the given weights (padded with
    /// zero-weight rows when fewer than K updates are present). Weights are
    /// normalised internally.
    pub fn fedavg_agg(&self, updates: &[&FlatParams], weights: &[f64]) -> Result<FlatParams> {
        let (k, p) = (self.k(), self.p_pad());
        if updates.is_empty() || updates.len() > k || updates.len() != weights.len() {
            bail!("fedavg_agg: got {} updates / {} weights (K={k})", updates.len(), weights.len());
        }
        let wsum: f64 = weights.iter().sum();
        if wsum <= 0.0 {
            bail!("fedavg_agg: non-positive weight sum");
        }
        let mut stack = vec![0.0f32; k * p];
        let mut w = vec![0.0f32; k];
        for (i, u) in updates.iter().enumerate() {
            if u.len() != p {
                bail!("update {i} has len {} != P_PAD {p}", u.len());
            }
            stack[i * p..(i + 1) * p].copy_from_slice(u);
            w[i] = (weights[i] / wsum) as f32;
        }
        let out = self
            .rt
            .run("fedavg_agg", vec![Tensor::mat_f32(stack, k, p), Tensor::vec_f32(w)])?;
        out.into_iter().next().unwrap().into_f32()
    }

    /// Pairwise squared-L2 distances between up to K updates (rows beyond
    /// the provided updates are zero vectors; callers use the top-left
    /// `n x n` submatrix).
    pub fn pairwise_dist(&self, updates: &[&FlatParams]) -> Result<Vec<Vec<f64>>> {
        self.kxk_matrix("pairwise_dist", updates)
    }

    /// Pairwise cosine similarities between up to K updates.
    pub fn cosine_sim(&self, updates: &[&FlatParams]) -> Result<Vec<Vec<f64>>> {
        self.kxk_matrix("cosine_sim", updates)
    }

    fn kxk_matrix(&self, exec: &str, updates: &[&FlatParams]) -> Result<Vec<Vec<f64>>> {
        let (k, p) = (self.k(), self.p_pad());
        let n = updates.len();
        if n == 0 || n > k {
            bail!("{exec}: got {n} updates (K={k})");
        }
        let mut stack = vec![0.0f32; k * p];
        for (i, u) in updates.iter().enumerate() {
            stack[i * p..(i + 1) * p].copy_from_slice(u);
        }
        let out = self.rt.run(exec, vec![Tensor::mat_f32(stack, k, p)])?;
        let m = out[0].as_f32()?;
        Ok((0..n)
            .map(|i| (0..n).map(|j| m[i * k + j] as f64).collect())
            .collect())
    }

    /// Clip updates to a max L2 norm; returns (clipped, norms).
    pub fn clip_updates(
        &self,
        updates: &[&FlatParams],
        max_norm: f32,
    ) -> Result<(Vec<FlatParams>, Vec<f64>)> {
        let (k, p) = (self.k(), self.p_pad());
        let n = updates.len();
        if n == 0 || n > k {
            bail!("clip_updates: got {n} updates (K={k})");
        }
        let mut stack = vec![0.0f32; k * p];
        for (i, u) in updates.iter().enumerate() {
            stack[i * p..(i + 1) * p].copy_from_slice(u);
        }
        let out = self.rt.run(
            "clip_updates",
            vec![Tensor::mat_f32(stack, k, p), Tensor::scalar_f32(max_norm)],
        )?;
        let clipped = out[0].as_f32()?;
        let norms = out[1].as_f32()?;
        Ok((
            (0..n).map(|i| clipped[i * p..(i + 1) * p].to_vec()).collect(),
            norms[..n].iter().map(|&v| v as f64).collect(),
        ))
    }

    /// Measure the mean wall-clock service time of one endorsement
    /// evaluation over `samples` samples and one aggregation — the inputs to
    /// the DES service-time model (DESIGN.md §3b).
    pub fn calibrate(&self, samples: usize, reps: usize) -> Result<Calibration> {
        let params = self.init_params(0)?;
        let dim = self.input_dim();
        let x = vec![0.1f32; samples.max(1) * dim];
        let y = vec![0i32; samples.max(1)];
        // Warm-up (first run pays buffer setup).
        self.evaluate(&params, &x, &y)?;
        let t0 = Instant::now();
        for _ in 0..reps.max(1) {
            self.evaluate(&params, &x, &y)?;
        }
        let eval_s = t0.elapsed().as_secs_f64() / reps.max(1) as f64;

        let refs: Vec<&FlatParams> = (0..self.k()).map(|_| &params).collect();
        let w = vec![1.0; self.k()];
        self.fedavg_agg(&refs, &w)?;
        let t1 = Instant::now();
        for _ in 0..reps.max(1) {
            self.fedavg_agg(&refs, &w)?;
        }
        let agg_s = t1.elapsed().as_secs_f64() / reps.max(1) as f64;
        Ok(Calibration { eval_s, agg_s, samples })
    }
}

/// Measured service times feeding the DES (seconds).
#[derive(Clone, Copy, Debug)]
pub struct Calibration {
    /// One endorsement evaluation over `samples` samples.
    pub eval_s: f64,
    /// One K-way FedAvg aggregation.
    pub agg_s: f64,
    pub samples: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn ops() -> Option<ModelOps> {
        crate::runtime::shared_ops()
    }

    fn toy_batch(ops: &ModelOps, rng: &mut Prng, b: usize) -> (Vec<f32>, Vec<i32>) {
        let dim = ops.input_dim();
        let x: Vec<f32> = (0..b * dim).map(|_| rng.normal() as f32 * 0.5).collect();
        let y: Vec<i32> = (0..b).map(|_| rng.below(10) as i32).collect();
        (x, y)
    }

    #[test]
    fn train_step_changes_params_and_is_finite() {
        let Some(ops) = ops() else { return };
        let mut rng = Prng::new(1);
        let params = ops.init_params(1).unwrap();
        let (x, y) = toy_batch(&ops, &mut rng, 32);
        let (new, loss) = ops.train_step(params.clone(), &x, &y, 1e-2).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert_ne!(params, new);
        assert!(ops.train_step(new, &x[..10 * ops.input_dim()], &y[..10], 1e-2).is_ok());
    }

    #[test]
    fn unsupported_batch_size_rejected() {
        let Some(ops) = ops() else { return };
        let params = ops.init_params(1).unwrap();
        let x = vec![0.0; 7 * ops.input_dim()];
        let y = vec![0; 7];
        assert!(ops.train_step(params, &x, &y, 1e-2).is_err());
    }

    #[test]
    fn evaluate_handles_partial_batches() {
        let Some(ops) = ops() else { return };
        let mut rng = Prng::new(2);
        let params = ops.init_params(2).unwrap();
        let (x, y) = toy_batch(&ops, &mut rng, 300); // 256 + 44 tail
        let r = ops.evaluate(&params, &x, &y).unwrap();
        assert_eq!(r.samples, 300);
        assert!(r.loss.is_finite() && r.loss > 0.0);
        assert!((0.0..=1.0).contains(&r.accuracy));
    }

    #[test]
    fn fedavg_agg_mean_of_two() {
        let Some(ops) = ops() else { return };
        let a = vec![1.0f32; ops.p_pad()];
        let b = vec![3.0f32; ops.p_pad()];
        let agg = ops.fedavg_agg(&[&a, &b], &[1.0, 1.0]).unwrap();
        assert!(agg.iter().all(|&v| (v - 2.0).abs() < 1e-5));
        // weight asymmetry
        let agg = ops.fedavg_agg(&[&a, &b], &[3.0, 1.0]).unwrap();
        assert!(agg.iter().all(|&v| (v - 1.5).abs() < 1e-5));
    }

    #[test]
    fn distance_and_cosine_matrices() {
        let Some(ops) = ops() else { return };
        let mut rng = Prng::new(3);
        let u1: Vec<f32> = (0..ops.p_pad()).map(|_| rng.normal() as f32).collect();
        let u2: Vec<f32> = u1.iter().map(|v| v * 2.0).collect(); // parallel
        let u3: Vec<f32> = (0..ops.p_pad()).map(|_| rng.normal() as f32).collect();
        let d = ops.pairwise_dist(&[&u1, &u2, &u3]).unwrap();
        assert_eq!(d.len(), 3);
        assert!(d[0][0].abs() < 1e-1);
        assert!(d[0][2] > 1.0);
        let c = ops.cosine_sim(&[&u1, &u2, &u3]).unwrap();
        assert!((c[0][1] - 1.0).abs() < 1e-3, "parallel vectors cos {}", c[0][1]);
        assert!(c[0][2].abs() < 0.05, "independent vectors cos {}", c[0][2]);
    }

    #[test]
    fn clip_updates_bounds_norms() {
        let Some(ops) = ops() else { return };
        let big = vec![1.0f32; ops.p_pad()];
        let (clipped, norms) = ops.clip_updates(&[&big], 5.0).unwrap();
        assert!(norms[0] > 5.0);
        let out_norm: f64 =
            clipped[0].iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt();
        assert!((out_norm - 5.0).abs() < 1e-2);
    }
}
