//! `artifacts/manifest.txt` parser: the static dimensions the Python AOT
//! step baked into the HLO executables (flat param width, committee size,
//! batch sizes). Rust-side shapes must match these exactly.

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// Parsed artifact manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Exact (unpadded) parameter count.
    pub p: usize,
    /// Lane-aligned flat vector width crossing the HLO boundary.
    pub p_pad: usize,
    /// Stacked updates per aggregation/defence executable.
    pub k: usize,
    /// Endorsement evaluation batch.
    pub b_eval: usize,
    /// Fused multi-batch evaluation width (perf path; 0 if absent).
    pub b_eval_block: usize,
    pub input_dim: usize,
    pub num_classes: usize,
    pub hidden: Vec<usize>,
    /// Train-step batch sizes with a lowered executable.
    pub train_batch_sizes: Vec<usize>,
    /// Artifact names present on disk.
    pub artifacts: Vec<String>,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let mut kv: HashMap<&str, &str> = HashMap::new();
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("bad manifest line: {line}"))?;
            kv.insert(k.trim(), v.trim());
        }
        let get = |k: &str| -> Result<&str> {
            kv.get(k).copied().with_context(|| format!("manifest missing key {k}"))
        };
        let usize_of = |k: &str| -> Result<usize> {
            get(k)?.parse::<usize>().with_context(|| format!("bad usize for {k}"))
        };
        let list_of = |k: &str| -> Result<Vec<usize>> {
            get(k)?
                .split(',')
                .map(|s| s.parse::<usize>().with_context(|| format!("bad list for {k}")))
                .collect()
        };
        let m = Manifest {
            p: usize_of("P")?,
            p_pad: usize_of("P_PAD")?,
            k: usize_of("K")?,
            b_eval: usize_of("B_EVAL")?,
            b_eval_block: kv.get("B_EVAL_BLOCK").and_then(|v| v.parse().ok()).unwrap_or(0),
            input_dim: usize_of("INPUT_DIM")?,
            num_classes: usize_of("NUM_CLASSES")?,
            hidden: list_of("HIDDEN")?,
            train_batch_sizes: list_of("TRAIN_BATCH_SIZES")?,
            artifacts: get("ARTIFACTS")?.split(',').map(|s| s.to_string()).collect(),
        };
        if m.p_pad < m.p {
            bail!("P_PAD {} < P {}", m.p_pad, m.p);
        }
        for name in &m.artifacts {
            let f = dir.join(format!("{name}.hlo.txt"));
            if !f.exists() {
                bail!("manifest lists {name} but {f:?} is missing");
            }
        }
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    fn write_manifest(dir: &Path, body: &str) {
        let mut f = std::fs::File::create(dir.join("manifest.txt")).unwrap();
        f.write_all(body.as_bytes()).unwrap();
    }

    #[test]
    fn parses_valid_manifest() {
        let dir = std::env::temp_dir().join(format!("scalesfl-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::File::create(dir.join("foo.hlo.txt")).unwrap();
        write_manifest(
            &dir,
            "P=235146\nP_PAD=235520\nK=8\nB_EVAL=256\nINPUT_DIM=784\nNUM_CLASSES=10\nHIDDEN=256,128\nTRAIN_BATCH_SIZES=10,20,32\nARTIFACTS=foo\n",
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.p, 235146);
        assert_eq!(m.hidden, vec![256, 128]);
        assert_eq!(m.artifacts, vec!["foo"]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_missing_artifact_file() {
        let dir = std::env::temp_dir().join(format!("scalesfl-manifest2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(
            &dir,
            "P=1\nP_PAD=1024\nK=8\nB_EVAL=4\nINPUT_DIM=4\nNUM_CLASSES=2\nHIDDEN=2\nTRAIN_BATCH_SIZES=2\nARTIFACTS=missing\n",
        );
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn real_artifacts_manifest_if_built() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.txt").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.p_pad % 1024 == 0);
            assert!(m.artifacts.iter().any(|a| a == "eval_step"));
        }
    }
}
