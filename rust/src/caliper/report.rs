//! Benchmark report: the metrics Caliper prints per workload round.
//!
//! Since the sharded mempool landed, overload no longer shows up as
//! unbounded queue growth: envelopes refused at admission (pool full /
//! rate capped) are counted in [`Report::shed`], separately from
//! [`Report::failed`] (endorsement rejections, invalidations, timeouts).
//! Surge rounds (Figs. 6-7) report nonzero shed while committed-tx latency
//! stays bounded. Per-reason reject counters live in
//! `mempool::StatsSnapshot` and export via its `to_json`.
//!
//! Since the staged validation pipeline landed, reports also carry the
//! commit-side MVCC columns: `mvcc_conflicts` (read-version invalidations
//! at commit) and `stale_dropped` (transactions shed by admission/pull-time
//! MVCC hinting before ordering).
//!
//! Since the telemetry layer landed, per-stage pipeline timing comes from
//! the lifecycle tracer instead of ad-hoc wall-time plumbing:
//! [`Report::stages`] holds one latency histogram per visited pipeline
//! stage (admit, relay-hop, batch-pull, prevalidate, apply, commit-event —
//! see `telemetry::Stage`) plus the end-to-end `commit_latency`, windowed
//! to the run by `Tracer::take_stage_snapshot`.
//!
//! Since the cross-shard relay landed, reports carry its columns too:
//! `forwarded` (transactions that entered at a non-home shard ingress and
//! hopped to their home pool) and `relay_lat_ms` (mean simnet link
//! latency paid per delivered hop).

use crate::util::histogram::Histogram;
use crate::util::json::Json;

/// Aggregated workload result.
#[derive(Clone, Debug)]
pub struct Report {
    pub name: String,
    /// Transactions submitted.
    pub sent: usize,
    /// Transactions committed valid within the timeout.
    pub succeeded: usize,
    /// Failures (endorsement rejections, invalidations, timeouts).
    pub failed: usize,
    /// Load shed by ingress admission control (mempool backpressure:
    /// `Reject::PoolFull` / `Reject::RateLimited`). Shed transactions never
    /// consumed pipeline capacity.
    pub shed: usize,
    /// Transactions invalidated by an MVCC read-version conflict at
    /// commit (a subset of `failed`).
    pub mvcc_conflicts: usize,
    /// Transactions shed by MVCC staleness hinting before ordering:
    /// admission rejects (`Reject::StaleReadSet`) plus pull-time drops.
    /// Each one is an `MvccConflict` that never cost consensus bandwidth.
    pub stale_dropped: usize,
    /// Transactions that entered at a non-home shard ingress and were
    /// forwarded to their home pool over the cross-shard relay.
    pub forwarded: usize,
    /// Mean relay link latency per delivered hop, in milliseconds (0 when
    /// nothing was forwarded or the backend has no relay).
    pub relay_lat_ms: f64,
    /// Per-stage pipeline latency histograms from the lifecycle tracer
    /// (stage name → latency from the previous visited stage, seconds),
    /// plus the end-to-end `commit_latency`. Empty for backends that don't
    /// trace (DES).
    pub stages: Vec<(String, Histogram)>,
    /// Actual aggregate send rate achieved (TPS).
    pub send_tps: f64,
    /// Observed throughput: successes / makespan (TPS).
    pub throughput: f64,
    /// Latency stats over *successful* transactions (seconds).
    pub latency: Histogram,
    /// Workload makespan in seconds (first send -> last completion).
    pub duration_s: f64,
    /// Deepest open-loop window the real backend reached: transactions in
    /// the submission pipeline at once, endorsement included (0 for DES
    /// reports; the demux-registered depth is `Gateway::in_flight_high_water`).
    pub in_flight_high_water: usize,
}

impl Report {
    pub fn new(name: &str) -> Report {
        Report {
            name: name.to_string(),
            sent: 0,
            succeeded: 0,
            failed: 0,
            shed: 0,
            mvcc_conflicts: 0,
            stale_dropped: 0,
            forwarded: 0,
            relay_lat_ms: 0.0,
            stages: Vec::new(),
            send_tps: 0.0,
            throughput: 0.0,
            latency: Histogram::default(),
            duration_s: 0.0,
            in_flight_high_water: 0,
        }
    }

    pub fn avg_latency(&self) -> f64 {
        self.latency.mean()
    }

    /// One table row, Caliper-style.
    pub fn row(&self) -> String {
        format!(
            "{:<28} sent={:<5} ok={:<5} fail={:<4} shed={:<4} mvcc={:<4} stale={:<4} fwd={:<4} relayLat={:>6.1}ms sendTPS={:>7.2} tput={:>7.2} avgLat={:>7.3}s p95={:>7.3}s inflight={:<4}",
            self.name,
            self.sent,
            self.succeeded,
            self.failed,
            self.shed,
            self.mvcc_conflicts,
            self.stale_dropped,
            self.forwarded,
            self.relay_lat_ms,
            self.send_tps,
            self.throughput,
            self.avg_latency(),
            self.latency.quantile(0.95).unwrap_or(0.0),
            self.in_flight_high_water,
        )
    }

    pub fn to_json(&self) -> Json {
        let mut stages = Json::obj();
        for (name, h) in &self.stages {
            stages = stages.set(
                name.as_str(),
                Json::obj()
                    .set("count", h.count())
                    .set("mean_s", h.mean())
                    .set("p50_s", h.quantile(0.5).unwrap_or(0.0))
                    .set("p95_s", h.quantile(0.95).unwrap_or(0.0)),
            );
        }
        Json::obj()
            .set("name", self.name.as_str())
            .set("sent", self.sent)
            .set("succeeded", self.succeeded)
            .set("failed", self.failed)
            .set("shed", self.shed)
            .set("mvcc_conflicts", self.mvcc_conflicts)
            .set("stale_dropped", self.stale_dropped)
            .set("forwarded", self.forwarded)
            .set("relay_lat_ms", self.relay_lat_ms)
            .set("stages", stages)
            .set("send_tps", self.send_tps)
            .set("throughput", self.throughput)
            .set("avg_latency_s", self.avg_latency())
            .set("p95_latency_s", self.latency.quantile(0.95).unwrap_or(0.0))
            .set("max_latency_s", self.latency.max())
            .set("duration_s", self.duration_s)
            .set("in_flight_high_water", self.in_flight_high_water)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_row_and_json() {
        let mut r = Report::new("fig4/s2");
        r.sent = 100;
        r.succeeded = 90;
        r.failed = 5;
        r.shed = 5;
        r.mvcc_conflicts = 2;
        r.stale_dropped = 3;
        r.forwarded = 7;
        r.relay_lat_ms = 12.5;
        let mut h = Histogram::default();
        h.record(0.002);
        r.stages = vec![("apply".to_string(), h)];
        r.send_tps = 10.0;
        r.throughput = 9.0;
        r.latency.record(0.5);
        r.duration_s = 10.0;
        r.in_flight_high_water = 32;
        assert!(r.row().contains("fig4/s2"));
        assert!(r.row().contains("shed=5"));
        assert!(r.row().contains("mvcc=2"));
        assert!(r.row().contains("stale=3"));
        assert!(r.row().contains("fwd=7"));
        assert!(r.row().contains("inflight=32"));
        let j = r.to_json();
        assert_eq!(j.get("succeeded").unwrap().as_f64(), Some(90.0));
        assert_eq!(j.get("shed").unwrap().as_f64(), Some(5.0));
        assert_eq!(j.get("mvcc_conflicts").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("stale_dropped").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("forwarded").unwrap().as_f64(), Some(7.0));
        assert_eq!(j.get("relay_lat_ms").unwrap().as_f64(), Some(12.5));
        let apply = j.get("stages").unwrap().get("apply").unwrap();
        assert_eq!(apply.get("count").unwrap().as_f64(), Some(1.0));
        assert_eq!(apply.get("p95_s").unwrap().as_f64(), Some(0.002));
        assert_eq!(j.get("avg_latency_s").unwrap().as_f64(), Some(0.5));
        assert_eq!(j.get("in_flight_high_water").unwrap().as_f64(), Some(32.0));
    }
}
