//! Hyperledger-Caliper-style benchmark harness (paper §4.1).
//!
//! Workloads are defined by (#transactions, target send TPS, #workers,
//! timeout); the harness reports sent/observed TPS, latency distribution,
//! and failure counts — the exact quantities Figs. 4-8 plot. Since the
//! sharded mempool landed, reports also carry a `shed` column: load refused
//! by ingress admission control (`Reject::PoolFull` / `Reject::RateLimited`),
//! reported separately from failures so surge figures show explicit
//! backpressure instead of unbounded queue growth. Per-reason counters come
//! from `mempool::StatsSnapshot`.
//!
//! Two execution backends:
//! - [`real`]: wall-clock workers driving the actual fabric pipeline with
//!   real PJRT endorsement evaluations (bounded by host cores — this image
//!   has one).
//! - [`des`]: a discrete-event simulation of the same pipeline whose service
//!   times are *calibrated from real PJRT runs* (DESIGN.md §3b), used to
//!   regenerate the paper's multi-core figures on a 1-core host.

pub mod des;
pub mod figures;
pub mod real;
pub mod report;

pub use des::{run_des, DesConfig, DesWorkload};
pub use report::Report;

/// Workload shape shared by both backends.
#[derive(Clone, Copy, Debug)]
pub struct Workload {
    /// Total transactions to send.
    pub txs: usize,
    /// Target aggregate send rate (TPS).
    pub send_tps: f64,
    /// Caliper worker processes generating load.
    pub workers: usize,
    /// Transaction timeout in seconds (paper: 30).
    pub timeout_s: f64,
}

impl Default for Workload {
    fn default() -> Self {
        Workload { txs: 200, send_tps: 10.0, workers: 2, timeout_s: 30.0 }
    }
}
