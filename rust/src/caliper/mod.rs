//! Hyperledger-Caliper-style benchmark harness (paper §4.1).
//!
//! Workloads are defined by (#transactions, target send TPS, #workers,
//! timeout); the harness reports sent/observed TPS, latency distribution,
//! and failure counts — the exact quantities Figs. 4-8 plot. Since the
//! sharded mempool landed, reports also carry a `shed` column: load refused
//! by ingress admission control (`Reject::PoolFull` / `Reject::RateLimited`),
//! reported separately from failures so surge figures show explicit
//! backpressure instead of unbounded queue growth. Per-reason counters come
//! from `mempool::StatsSnapshot`; the commit-side `mvcc_conflicts` /
//! `stale_dropped` columns and per-stage validation timings come from
//! `fabric::ValidationSnapshot`; the cross-shard columns (`forwarded`,
//! `relay_lat_ms`) come from the mempool registry and relay snapshots
//! (see `report`).
//!
//! Two execution backends:
//! - [`real`]: a rate-targeted **open-loop** driver over the pipelined
//!   submission API (`Gateway::submit` handles): workers pace submissions
//!   at the target TPS and commits resolve asynchronously through the
//!   per-channel demux, so in-flight depth — reported as
//!   [`Report::in_flight_high_water`] — is bounded by
//!   [`Workload::max_in_flight`], not by worker count. Endorsements still
//!   run real PJRT evaluations (bounded by host cores — this image has
//!   one).
//! - [`des`]: a discrete-event simulation of the same pipeline whose service
//!   times are *calibrated from real PJRT runs* (DESIGN.md §3b), used to
//!   regenerate the paper's multi-core figures on a 1-core host.

pub mod des;
pub mod figures;
pub mod real;
pub mod report;

pub use des::{run_des, DesConfig, DesWorkload};
pub use report::Report;

/// Workload shape shared by both backends.
#[derive(Clone, Copy, Debug)]
pub struct Workload {
    /// Total transactions to send.
    pub txs: usize,
    /// Target aggregate send rate (TPS).
    pub send_tps: f64,
    /// Caliper worker processes generating load.
    pub workers: usize,
    /// Transaction timeout in seconds (paper: 30).
    pub timeout_s: f64,
    /// Open-loop depth cap for the [`real`] backend: max transactions in
    /// the submission pipeline at once — from the moment a worker starts
    /// endorsing until the commit outcome resolves — before submitters
    /// pause (the DES models concurrency through `workers` instead and
    /// ignores this).
    pub max_in_flight: usize,
}

impl Default for Workload {
    fn default() -> Self {
        Workload { txs: 200, send_tps: 10.0, workers: 2, timeout_s: 30.0, max_in_flight: 256 }
    }
}
