//! Wall-clock Caliper backend: worker threads drive the real fabric
//! pipeline (real PJRT endorsement evaluations) at a target send rate.
//!
//! On this 1-core image the endorsement evaluations serialize, so absolute
//! numbers undershoot the paper's 8-core testbed; the DES backend
//! regenerates the figures (DESIGN.md §3b). This path exists to validate
//! the DES against reality at small scale (see `benches/micro.rs`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::fabric::gateway::{CommitOutcome, Gateway};
use crate::ledger::tx::Proposal;
use crate::util::histogram::Histogram;

use super::report::Report;
use super::Workload;

/// Run a workload against real gateways. `make_proposal(i)` builds the i-th
/// transaction; `gateways[i % gateways.len()]` submits it (shard
/// round-robin, as the paper's Caliper config distributes load).
pub fn run_real(
    name: &str,
    wl: &Workload,
    gateways: &[Arc<Gateway>],
    make_proposal: impl Fn(usize) -> Proposal + Send + Sync,
) -> Report {
    let started = Instant::now();
    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<(bool, bool, f64)>> = Mutex::new(Vec::with_capacity(wl.txs));
    let make_proposal = &make_proposal;
    thread::scope(|s| {
        for _ in 0..wl.workers.max(1) {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::SeqCst);
                if i >= wl.txs {
                    return;
                }
                // Fixed-rate pacing: tx i is due at i / send_tps.
                let due = started + Duration::from_secs_f64(i as f64 / wl.send_tps.max(1e-9));
                if let Some(wait) = due.checked_duration_since(Instant::now()) {
                    thread::sleep(wait);
                }
                let gw = &gateways[i % gateways.len()];
                let sent_at = Instant::now();
                let outcome = gw.submit_and_wait(&make_proposal(i));
                let latency = sent_at.elapsed().as_secs_f64();
                let ok = matches!(outcome, CommitOutcome::Committed { code, .. }
                    if code == crate::ledger::block::ValidationCode::Valid);
                // Admission-control backpressure is shed load, not failure.
                results.lock().unwrap().push((ok, outcome.is_rejected(), latency));
            });
        }
    });
    let duration = started.elapsed().as_secs_f64().max(1e-9);
    let results = results.into_inner().unwrap();
    let mut report = Report::new(name);
    report.sent = wl.txs;
    let mut hist = Histogram::default();
    for (ok, shed, lat) in &results {
        if *ok && *lat <= wl.timeout_s {
            report.succeeded += 1;
            hist.record(*lat);
        } else if *shed {
            report.shed += 1;
        } else {
            report.failed += 1;
        }
    }
    report.send_tps = wl.txs as f64 / duration;
    report.duration_s = duration;
    report.throughput = report.succeeded as f64 / duration;
    report.latency = hist;
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::msp::{CertificateAuthority, MemberId};
    use crate::fabric::chaincode::{Chaincode, TxContext};
    use crate::fabric::endorsement::EndorsementPolicy;
    use crate::fabric::orderer::{OrdererConfig, OrderingService};
    use crate::fabric::peer::Peer;
    use crate::util::prng::Prng;

    struct FastPut;
    impl Chaincode for FastPut {
        fn name(&self) -> &str {
            "kv"
        }
        fn invoke(
            &self,
            ctx: &mut TxContext<'_>,
            _f: &str,
            args: &[String],
        ) -> Result<Vec<u8>, String> {
            ctx.put(&args[0], b"v".to_vec());
            Ok(vec![])
        }
    }

    #[test]
    fn real_harness_end_to_end() {
        let ca = CertificateAuthority::new();
        let mut rng = Prng::new(3);
        let peers: Vec<Arc<Peer>> = (0..2)
            .map(|i| {
                let cred = ca.enroll(MemberId::new(format!("org{i}.peer")), &mut rng);
                Peer::new(cred, ca.clone())
            })
            .collect();
        let members: Vec<MemberId> = peers.iter().map(|p| p.member.clone()).collect();
        for p in &peers {
            p.join_channel("ch", EndorsementPolicy::MajorityOf(members.clone()));
            p.install_chaincode("ch", Arc::new(FastPut)).unwrap();
        }
        let orderer = OrderingService::start(
            OrdererConfig { batch_timeout: Duration::from_millis(5), ..Default::default() },
            peers.clone(),
            1,
        );
        let gw = Arc::new(Gateway::new(peers.clone(), orderer));
        let wl = Workload { txs: 40, send_tps: 500.0, workers: 4, timeout_s: 10.0 };
        let report = run_real("smoke", &wl, &[gw], |i| Proposal {
            channel: "ch".into(),
            chaincode: "kv".into(),
            function: "Put".into(),
            args: vec![format!("k{i}")],
            creator: MemberId::new("client"),
            nonce: i as u64,
        });
        assert_eq!(report.succeeded, 40, "{}", report.row());
        assert_eq!(report.failed, 0);
        assert!(report.throughput > 5.0);
    }
}
