//! Wall-clock Caliper backend: a rate-targeted **open-loop** driver over
//! the pipelined submission API.
//!
//! Workers pace `Gateway::submit` calls at the target send rate and hand
//! the returned `SubmitHandle`s to a collector that resolves commit
//! outcomes as they land — submitters never block on a commit, so the
//! pipeline holds up to [`Workload::max_in_flight`] transactions at once
//! (the observed depth is reported as `Report::in_flight_high_water`).
//! This is how the paper's Caliper setup saturates each shard; the old
//! closed-loop driver capped concurrency at the worker count and never
//! exercised the mempool/orderer pipeline.
//!
//! On this 1-core image the endorsement evaluations serialize, so absolute
//! numbers undershoot the paper's 8-core testbed; the DES backend
//! regenerates the figures (DESIGN.md §3b). This path exists to validate
//! the DES against reality at small scale (see `benches/micro.rs`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

use crate::fabric::gateway::{CommitOutcome, Gateway, SubmitHandle};
use crate::ledger::block::ValidationCode;
use crate::ledger::tx::Proposal;
use crate::telemetry;
use crate::util::histogram::Histogram;

use super::report::Report;
use super::Workload;

/// How long submitters nap when the in-flight window is full.
const BACKOFF: Duration = Duration::from_micros(200);

/// Run a workload against real gateways. `make_proposal(i)` builds the i-th
/// transaction; `gateways[i % gateways.len()]` submits it (shard
/// round-robin, as the paper's Caliper config distributes load).
pub fn run_real(
    name: &str,
    wl: &Workload,
    gateways: &[Arc<Gateway>],
    make_proposal: impl Fn(usize) -> Proposal + Send + Sync,
) -> Report {
    // Deltas for the validation-pipeline columns come from the first
    // gateway's orderer (drivers share one ordering service).
    let stats_base = gateways.first().map(|g| g.orderer.mempool().snapshot()).unwrap_or_default();
    // Window the tracer's per-stage histograms to this run: drain whatever
    // earlier workloads accumulated, collect what this one produced at the
    // end. Lifecycle counters stay monotone for the metrics registry.
    //
    // The tracer is process-global, so this windowing is best-effort:
    // concurrent run_real calls (e.g. parallel `cargo test` harnesses)
    // drain each other's samples, and a timed-out tx from a *previous* run
    // whose commit event lands late is attributed to this window. The
    // drivers in sim/ and main.rs run workloads sequentially against one
    // pipeline, where the window is exact; `Report.stages` is stage-level
    // attribution for them, not an isolation boundary. (The per-orderer
    // mempool/relay deltas below are unaffected — they diff per-instance
    // snapshots.)
    let _ = telemetry::global().tracer().take_stage_snapshot();
    let relay_base = gateways
        .first()
        .and_then(|g| g.orderer.relay().map(|r| r.snapshot()))
        .unwrap_or_default();
    let started = Instant::now();
    let next = AtomicUsize::new(0);
    let in_flight = AtomicUsize::new(0);
    let in_flight_high = AtomicUsize::new(0);
    let max_in_flight = wl.max_in_flight.max(1);
    let timeout = Duration::from_secs_f64(wl.timeout_s.max(0.0));
    let (handle_tx, handle_rx) = mpsc::channel::<SubmitHandle>();

    let outcomes = thread::scope(|s| {
        let (next, in_flight, in_flight_high) = (&next, &in_flight, &in_flight_high);
        let make_proposal = &make_proposal;
        // Collector: sweeps the window with non-blocking polls and resolves
        // handles in *commit* order, so one slow head-of-line tx (batch
        // timeout, leadership churn) cannot pin the in-flight gauge and
        // stall every submitter while the pipeline is actually empty.
        let collector = s.spawn(move || {
            let mut out: Vec<CommitOutcome> = Vec::with_capacity(wl.txs);
            let mut pending: Vec<SubmitHandle> = Vec::new();
            let mut open = true;
            while open || !pending.is_empty() {
                if open {
                    match handle_rx.recv_timeout(Duration::from_millis(1)) {
                        Ok(h) => pending.push(h),
                        Err(mpsc::RecvTimeoutError::Timeout) => {}
                        Err(mpsc::RecvTimeoutError::Disconnected) => open = false,
                    }
                } else {
                    // Workers are done; pace the remaining sweeps.
                    thread::sleep(Duration::from_millis(1));
                }
                let mut i = 0;
                while i < pending.len() {
                    let h = &mut pending[i];
                    let resolved = match h.try_wait() {
                        Some(outcome) => Some(outcome),
                        None if h.elapsed() >= timeout => Some(CommitOutcome::TimedOut),
                        None => None,
                    };
                    if let Some(outcome) = resolved {
                        out.push(outcome);
                        in_flight.fetch_sub(1, Ordering::SeqCst);
                        pending.swap_remove(i);
                    } else {
                        i += 1;
                    }
                }
            }
            out
        });
        for _ in 0..wl.workers.max(1) {
            let handle_tx = handle_tx.clone();
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::SeqCst);
                if i >= wl.txs {
                    return;
                }
                // Fixed-rate pacing: tx i is due at i / send_tps.
                let due = started + Duration::from_secs_f64(i as f64 / wl.send_tps.max(1e-9));
                if let Some(wait) = due.checked_duration_since(Instant::now()) {
                    thread::sleep(wait);
                }
                // Open-loop depth cap: claim a slot by CAS so concurrent
                // workers cannot collectively overshoot the window.
                let mut depth = in_flight.load(Ordering::SeqCst);
                loop {
                    if depth >= max_in_flight {
                        thread::sleep(BACKOFF);
                        depth = in_flight.load(Ordering::SeqCst);
                        continue;
                    }
                    match in_flight.compare_exchange_weak(
                        depth,
                        depth + 1,
                        Ordering::SeqCst,
                        Ordering::SeqCst,
                    ) {
                        Ok(_) => break,
                        Err(cur) => depth = cur,
                    }
                }
                in_flight_high.fetch_max(depth + 1, Ordering::SeqCst);
                let gw = &gateways[i % gateways.len()];
                let h = gw.submit(&make_proposal(i));
                if handle_tx.send(h).is_err() {
                    return;
                }
            });
        }
        // Workers hold clones; once they all finish the collector drains.
        drop(handle_tx);
        collector.join().expect("collector panicked")
    });

    let duration = started.elapsed().as_secs_f64().max(1e-9);
    let mut report = Report::new(name);
    report.sent = wl.txs;
    let mut hist = Histogram::default();
    for outcome in &outcomes {
        let lat = match outcome {
            CommitOutcome::Committed { latency, .. } => latency.as_secs_f64(),
            _ => f64::INFINITY,
        };
        if outcome.is_valid() && lat <= wl.timeout_s {
            report.succeeded += 1;
            hist.record(lat);
        } else if outcome.is_rejected() {
            // Admission-control backpressure is shed load, not failure.
            report.shed += 1;
        } else {
            report.failed += 1;
        }
        if matches!(
            outcome,
            CommitOutcome::Committed { code: ValidationCode::MvccConflict, .. }
        ) {
            report.mvcc_conflicts += 1;
        }
    }
    report.send_tps = wl.txs as f64 / duration;
    report.duration_s = duration;
    report.throughput = report.succeeded as f64 / duration;
    report.latency = hist;
    report.in_flight_high_water = in_flight_high.load(Ordering::SeqCst);
    if let Some(gw) = gateways.first() {
        let stats = gw.orderer.mempool().snapshot();
        report.stale_dropped = (stats.stale_shed() - stats_base.stale_shed()) as usize;
        report.forwarded = (stats.forwarded - stats_base.forwarded) as usize;
        if let Some(relay) = gw.orderer.relay() {
            // Delta from the run's start, like every other column: a
            // reused ordering service must not leak earlier workloads'
            // hop latencies into this report.
            let snap = relay.snapshot();
            let hops = snap.delivered - relay_base.delivered;
            if hops > 0 {
                let us = snap.hop_latency_us - relay_base.hop_latency_us;
                report.relay_lat_ms = us as f64 / 1e3 / hops as f64;
            }
        }
    }
    let snap = telemetry::global().tracer().take_stage_snapshot();
    report.stages = snap
        .stages
        .iter()
        .filter(|(_, h)| h.count() > 0)
        .map(|(st, h)| (st.name().to_string(), h.clone()))
        .collect();
    if snap.commit_latency.count() > 0 {
        report.stages.push(("commit_latency".to_string(), snap.commit_latency.clone()));
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::msp::{CertificateAuthority, MemberId};
    use crate::fabric::chaincode::{Chaincode, TxContext};
    use crate::fabric::endorsement::EndorsementPolicy;
    use crate::fabric::orderer::{OrdererConfig, OrderingService};
    use crate::fabric::peer::Peer;
    use crate::util::prng::Prng;

    struct FastPut;
    impl Chaincode for FastPut {
        fn name(&self) -> &str {
            "kv"
        }
        fn invoke(
            &self,
            ctx: &mut TxContext<'_>,
            _f: &str,
            args: &[String],
        ) -> Result<Vec<u8>, String> {
            ctx.put(&args[0], b"v".to_vec());
            Ok(vec![])
        }
    }

    #[test]
    fn real_harness_end_to_end() {
        let ca = CertificateAuthority::new();
        let mut rng = Prng::new(3);
        let peers: Vec<Arc<Peer>> = (0..2)
            .map(|i| {
                let cred = ca.enroll(MemberId::new(format!("org{i}.peer")), &mut rng);
                Peer::new(cred, ca.clone())
            })
            .collect();
        let members: Vec<MemberId> = peers.iter().map(|p| p.member.clone()).collect();
        for p in &peers {
            p.join_channel("ch", EndorsementPolicy::MajorityOf(members.clone()));
            p.install_chaincode("ch", Arc::new(FastPut)).unwrap();
        }
        let orderer = OrderingService::start(
            OrdererConfig { batch_timeout: Duration::from_millis(5), ..Default::default() },
            peers.clone(),
            1,
        );
        let gw = Arc::new(Gateway::new(peers.clone(), orderer));
        let wl =
            Workload { txs: 40, send_tps: 500.0, workers: 4, timeout_s: 10.0, max_in_flight: 16 };
        let report = run_real("smoke", &wl, &[gw], |i| Proposal {
            channel: "ch".into(),
            chaincode: "kv".into(),
            function: "Put".into(),
            args: vec![format!("k{i}")],
            creator: MemberId::new("client"),
            nonce: i as u64,
        });
        assert_eq!(report.succeeded, 40, "{}", report.row());
        assert_eq!(report.failed, 0);
        assert!(report.throughput > 5.0);
    }
}
