//! Regeneration of every figure and table in the paper's evaluation
//! (DESIGN.md §4 experiment index). Shared by `benches/` and the CLI's
//! `figures` subcommand.
//!
//! Figures 4-8 run the calibrated DES (service times measured from live
//! PJRT executions at startup); Figure 9 / Table 2 run real federated
//! training through the full blockchain pipeline.
//!
//! `quick=true` shrinks workloads for CI; set `SCALESFL_FULL=1` (or
//! quick=false) for paper-scale runs.

use anyhow::Result;

use crate::fl::client::TrainConfig;
use crate::runtime::ops::{Calibration, ModelOps};
use crate::sim::{
    fedavg_baseline, FedAvgConfig, Partition, ScaleSfl, SimConfig,
};

use super::des::{global_capacity, run_des, shard_capacity, DesConfig};
use super::report::Report;
use super::Workload;

/// Calibrated environment shared by the DES figures.
pub struct FigureEnv {
    pub ops: ModelOps,
    pub cal: Calibration,
    pub base: DesConfig,
    pub quick: bool,
}

/// Is a full (paper-scale) run requested?
pub fn full_requested() -> bool {
    std::env::var("SCALESFL_FULL").map(|v| v == "1").unwrap_or(false)
}

/// Build the calibrated environment (None when artifacts are missing).
///
/// The paper evaluates each update against the full MNIST test split
/// (10 000 samples); quick mode calibrates on 2 000 and scales.
pub fn env(quick: bool) -> Option<FigureEnv> {
    let ops = crate::runtime::shared_ops()?;
    let samples = if quick { 2_000 } else { 10_000 };
    let reps = if quick { 2 } else { 5 };
    let cal = ops.calibrate(samples, reps).ok()?;
    // Scale quick calibration up to the paper's 10k-sample endorsement cost.
    let eval_s = if quick { cal.eval_s * (10_000.0 / samples as f64) } else { cal.eval_s };
    let base = DesConfig {
        shards: 1,
        endorsers_per_shard: 8, // paper: 8 peers, P = P_E
        quorum: 5,              // majority of 8
        eval_s,
        eval_jitter: 0.08,
        net_hop_s: 0.002,
        order_s: 0.015,
        batch_size: 10,
        batch_timeout_s: 0.5,
        validate_s: 0.0005,
        worker_overhead_s: 0.01,
        ..Default::default()
    };
    Some(FigureEnv { ops, cal, base, quick })
}

/// Fig. 4 — #shards vs system throughput at saturation (200 txs, 2 workers,
/// sent TPS just above each configuration's capacity).
pub fn fig4(env: &FigureEnv) -> Vec<(usize, Report)> {
    let txs = if env.quick { 120 } else { 200 };
    (1..=8)
        .map(|shards| {
            let cfg = DesConfig { shards, ..env.base };
            let cap = global_capacity(&cfg);
            let wl = Workload { txs, send_tps: cap * 1.15, workers: 2, ..Default::default() };
            let mut r = run_des(&cfg, &wl, 4_000 + shards as u64);
            r.name = format!("fig4/shards={shards}");
            (shards, r)
        })
        .collect()
}

/// Fig. 5 — sent TPS vs observed TPS + avg latency, per shard count
/// (200 txs, 2 workers, sent TPS stepped by 3 from 3).
pub fn fig5(env: &FigureEnv) -> Vec<(usize, f64, Report)> {
    let txs = if env.quick { 100 } else { 200 };
    let shard_counts: &[usize] = if env.quick { &[1, 4] } else { &[1, 2, 4, 8] };
    let mut rows = Vec::new();
    for &shards in shard_counts {
        let cfg = DesConfig { shards, ..env.base };
        let cap = global_capacity(&cfg);
        // Paper steps sent TPS in increments of 3 TPS; our capacity differs,
        // so step in fractions of capacity covering the same knee shape.
        let steps = if env.quick { 4 } else { 8 };
        for i in 1..=steps {
            let tps = cap * (0.3 + 0.25 * i as f64);
            let wl = Workload { txs, send_tps: tps, workers: 2, ..Default::default() };
            let mut r = run_des(&cfg, &wl, 5_000 + shards as u64 * 100 + i as u64);
            r.name = format!("fig5/shards={shards}/sent={tps:.2}");
            rows.push((shards, tps, r));
        }
    }
    rows
}

/// Figs. 6+7 — surge: tx count vs latency, failures, shed load, and
/// throughput at a sent TPS just above max (2 workers, 30 s timeout).
///
/// The sharded mempool bounds each shard's ingress at ~80% of what the
/// 30 s timeout can absorb, so overload is reported as *shed* transactions
/// (explicit backpressure) while committed-tx latency stays bounded —
/// instead of the seed's unbounded queue growth and timeout collapse.
pub fn fig6_7(env: &FigureEnv) -> Vec<(usize, Report)> {
    let mut cfg = DesConfig { shards: 2, ..env.base };
    cfg.pool_capacity = (0.8 * 30.0 * shard_capacity(&cfg)).ceil() as usize;
    let cap = global_capacity(&cfg);
    let counts: &[usize] =
        if env.quick { &[50, 200, 600, 1400] } else { &[50, 100, 200, 400, 800, 1600, 3200] };
    counts
        .iter()
        .map(|&txs| {
            let wl = Workload { txs, send_tps: cap * 1.3, workers: 2, ..Default::default() };
            let mut r = run_des(&cfg, &wl, 6_000 + txs as u64);
            r.name = format!("fig6_7/txs={txs}");
            (txs, r)
        })
        .collect()
}

/// Fig. 8 — #caliper workers vs throughput + latency (200 txs, sent TPS at
/// the max observed in Fig. 5).
pub fn fig8(env: &FigureEnv) -> Vec<(usize, usize, Report)> {
    let txs = if env.quick { 100 } else { 200 };
    let shard_counts: &[usize] = if env.quick { &[2] } else { &[1, 2, 4, 8] };
    let workers: &[usize] =
        if env.quick { &[1, 4, 8] } else { &[1, 2, 3, 4, 5, 6, 7, 8, 9, 10] };
    let mut rows = Vec::new();
    for &shards in shard_counts {
        let cfg = DesConfig { shards, ..env.base };
        let cap = global_capacity(&cfg);
        for &w in workers {
            let wl = Workload { txs, send_tps: cap, workers: w, ..Default::default() };
            let mut r = run_des(&cfg, &wl, 8_000 + shards as u64 * 100 + w as u64);
            r.name = format!("fig8/shards={shards}/workers={w}");
            rows.push((shards, w, r));
        }
    }
    rows
}

/// §3.2 ablation — endorsement computations per round: flat C x P_E vs
/// sharded C x P_E / S^2 per shard (C x P_E / S globally).
pub fn ablation_eval_count(clients: usize, endorsers: usize, shards: usize) -> (u64, u64, u64) {
    let flat = (clients * endorsers) as u64;
    let per_shard = ((clients / shards) * (endorsers / shards)) as u64;
    let global = per_shard as u64 * shards as u64;
    (flat, per_shard, global)
}

/// One Fig. 9 / Table 2 cell: ScaleSFL + FedAvg curves for a (B, E) pair.
pub struct ModelPerfCell {
    pub batch: usize,
    pub epochs: usize,
    /// (round, train_loss, test_accuracy) per global epoch.
    pub scalesfl: Vec<(u64, f64, f64)>,
    pub fedavg: Vec<(u64, f64, f64)>,
}

impl ModelPerfCell {
    pub fn best_scalesfl(&self) -> f64 {
        self.scalesfl.iter().map(|r| r.2).fold(0.0, f64::max)
    }

    pub fn best_fedavg(&self) -> f64 {
        self.fedavg.iter().map(|r| r.2).fold(0.0, f64::max)
    }
}

/// Fig. 9 + Table 2 — training loss / test accuracy of ScaleSFL (S shards x
/// K clients each) vs flat FedAvg (S*K clients), non-IID split,
/// eta = 1e-2 (paper), over the B x E grid.
pub fn fig9_table2(ops: &ModelOps, quick: bool) -> Result<Vec<ModelPerfCell>> {
    // Paper: 8 shards x 8 clients, B in {10, 20}, E in {1, 5, 15}, 15 global
    // epochs. Quick mode shrinks everything but keeps the comparison shape.
    let (shards, clients_per_shard, rounds) = if quick { (2, 4, 3) } else { (8, 8, 15) };
    let grid: Vec<(usize, usize)> = if quick {
        vec![(10, 1), (10, 5)]
    } else {
        vec![(10, 1), (10, 5), (10, 15), (20, 1), (20, 5), (20, 15)]
    };
    let samples_per_client = if quick { 60 } else { 100 };
    let test_samples = if quick { 256 } else { 1024 };

    let mut cells = Vec::new();
    for (batch, epochs) in grid {
        let train = TrainConfig { batch, epochs, lr: 1e-2, dp: None };
        let sim_cfg = SimConfig {
            shards,
            peers_per_shard: 2,
            clients_per_shard,
            train,
            partition: Partition::Dirichlet { alpha: 0.5 },
            samples_per_client,
            eval_samples: 32,
            test_samples,
            verify_aggregate: false, // honest-clients comparison (paper §4.3)
            seed: 42,
            ..Default::default()
        };
        let mut net = ScaleSfl::build(sim_cfg, ops.clone())?;
        let mut scalesfl = Vec::new();
        for _ in 0..rounds {
            let rep = net.run_round()?;
            scalesfl.push((rep.round, rep.mean_train_loss, rep.global_eval.accuracy));
        }
        let fed_cfg = FedAvgConfig {
            clients: shards * clients_per_shard,
            train,
            partition: Partition::Dirichlet { alpha: 0.5 },
            samples_per_client,
            test_samples,
            seed: 42,
        };
        let fedavg = fedavg_baseline(&fed_cfg, ops, rounds as u64)?
            .into_iter()
            .map(|r| (r.round, r.mean_train_loss, r.global_eval.accuracy))
            .collect();
        cells.push(ModelPerfCell { batch, epochs, scalesfl, fedavg });
    }
    Ok(cells)
}

/// Print Table 2 from the computed cells.
pub fn print_table2(cells: &[ModelPerfCell]) {
    println!("\nTable 2: best accuracy by minibatch size (B) and local epochs (E)");
    println!("{:<4} {:<4} {:>18} {:>20}", "B", "E", "FedAvg (Accuracy)", "ScaleSFL (Accuracy)");
    for c in cells {
        println!(
            "{:<4} {:<4} {:>18.4} {:>20.4}",
            c.batch,
            c.epochs,
            c.best_fedavg(),
            c.best_scalesfl()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablation_matches_paper_formula() {
        // Paper's example: C = 64 clients, P_E = 8 endorsers, S = 8 shards:
        // per shard C*P_E/S^2 = 8, global C*P_E/S = 64 (vs flat 512).
        let (flat, per_shard, global) = ablation_eval_count(64, 8, 8);
        assert_eq!(flat, 512);
        assert_eq!(per_shard, 8);
        assert_eq!(global, 64);
    }

    #[test]
    fn fig4_scales_linearly() {
        let Some(env) = env(true) else { return };
        let rows = fig4(&env);
        assert_eq!(rows.len(), 8);
        let t1 = rows[0].1.throughput;
        let t8 = rows[7].1.throughput;
        assert!(t8 > 5.0 * t1, "1 shard {t1:.2} TPS, 8 shards {t8:.2} TPS");
    }
}
