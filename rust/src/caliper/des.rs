//! Discrete-event simulation of the ScaleSFL transaction pipeline.
//!
//! Faithfully models the stages the real fabric path executes, with service
//! times calibrated from real PJRT runs (`ModelOps::calibrate`):
//!
//!   caliper worker (serial, per-tx overhead)
//!     -> [cross-shard relay hop for the `cross_shard_frac` of traffic
//!        arriving at a non-home ingress — one simnet link latency]
//!     -> shard endorsers (each a single-threaded FIFO server evaluating the
//!        model — the paper's per-peer worker thread; a tx is endorsed when
//!        the quorum-th endorsement lands)
//!     -> orderer batching (block cut at size or timeout) + consensus latency
//!     -> validation/commit (per tx)
//!
//! Every stage is FIFO, so the schedule is computed exactly in arrival
//! order without a global event heap. Transactions exceeding the timeout
//! count as failures but still consume the resources they occupied —
//! reproducing the paper's surge behaviour (Figs. 6-7).

use crate::util::histogram::Histogram;
use crate::util::prng::Prng;

use super::report::Report;
use super::Workload;

/// Pipeline timing model (seconds). Defaults are placeholders; benches
/// overwrite from live calibration.
#[derive(Clone, Copy, Debug)]
pub struct DesConfig {
    pub shards: usize,
    /// Endorsing peers per shard (each evaluates every shard tx).
    pub endorsers_per_shard: usize,
    /// Endorsements required (majority of endorsers by default).
    pub quorum: usize,
    /// Mean endorsement evaluation service time (calibrated).
    pub eval_s: f64,
    /// Lognormal sigma for service-time jitter.
    pub eval_jitter: f64,
    /// One-way network latency client<->peer / peer<->orderer.
    pub net_hop_s: f64,
    /// Consensus + delivery latency per block.
    pub order_s: f64,
    /// Orderer block cut parameters.
    pub batch_size: usize,
    pub batch_timeout_s: f64,
    /// Per-transaction validation/commit cost at a peer (at one
    /// validation worker).
    pub validate_s: f64,
    /// Worker threads in the peer's parallel pre-validation stage
    /// (mirrors `OrdererConfig::validation_workers`). Signature/policy
    /// verification — modelled as [`VALIDATE_PARALLEL_FRACTION`] of
    /// `validate_s` — scales with workers; the serial MVCC+apply
    /// remainder does not (Amdahl).
    pub validation_workers: usize,
    /// Caliper worker per-submission overhead (drives Fig 8).
    pub worker_overhead_s: f64,
    /// CPU stolen from peers per extra workload worker (the paper runs
    /// Caliper on the same machine as the peers, so more workers slow the
    /// endorsement servers — Fig 8's downward throughput trend).
    pub worker_cpu_contention: f64,
    /// Bounded per-shard ingress pool (the sharded mempool's lane
    /// capacity): a transaction arriving while `pool_capacity` admitted
    /// transactions are still in flight is *shed* — rejected instantly,
    /// consuming no endorser time — and counted in `Report::shed`.
    /// `0` models the legacy unbounded ingress queue.
    pub pool_capacity: usize,
    /// Fraction of transactions that arrive at a *non-home* shard ingress
    /// (misrouted clients, failed-over gateways, shard→mainchain
    /// checkpoints) and pay one cross-shard relay hop before joining
    /// their home shard's pipeline. `0` models the idealized direct
    /// router the pre-relay system assumed.
    pub cross_shard_frac: f64,
    /// Mean one-hop relay link latency in seconds (the `network::simnet`
    /// `LinkLatency` mean; jittered lognormally per message).
    pub relay_hop_s: f64,
}

impl Default for DesConfig {
    fn default() -> Self {
        DesConfig {
            shards: 1,
            endorsers_per_shard: 2,
            quorum: 2,
            eval_s: 0.25,
            eval_jitter: 0.08,
            net_hop_s: 0.002,
            order_s: 0.015,
            batch_size: 10,
            batch_timeout_s: 0.5,
            validate_s: 0.0005,
            validation_workers: 1,
            worker_overhead_s: 0.01,
            worker_cpu_contention: 0.02,
            pool_capacity: 0,
            cross_shard_frac: 0.0,
            relay_hop_s: 0.012,
        }
    }
}

/// Share of `DesConfig::validate_s` that the parallel pre-validation stage
/// (signature + policy crypto) accounts for; the rest is the serial
/// MVCC-check + apply stage. Matches the measured split on
/// signature-heavy blocks (`benches/validation.rs`).
pub const VALIDATE_PARALLEL_FRACTION: f64 = 0.9;

/// Effective per-tx validation cost at the configured worker count.
pub fn effective_validate_s(cfg: &DesConfig) -> f64 {
    let w = cfg.validation_workers.max(1) as f64;
    cfg.validate_s
        * ((1.0 - VALIDATE_PARALLEL_FRACTION) + VALIDATE_PARALLEL_FRACTION / w)
}

/// Workload wrapper (re-exported alias for clarity in benches).
pub type DesWorkload = Workload;

/// Internal per-tx record.
struct Tx {
    submit: f64,
    endorsed: f64,
    shard: usize,
}

/// Run the DES; returns the Caliper-style report.
pub fn run_des(cfg: &DesConfig, wl: &Workload, seed: u64) -> Report {
    assert!(cfg.quorum <= cfg.endorsers_per_shard);
    let mut rng = Prng::new(seed);
    let mut report = Report::new("des");
    report.sent = wl.txs;
    // Load generators share the testbed with the peers (paper Table 1):
    // every worker beyond the first slows the endorsement servers.
    let contention = 1.0 + cfg.worker_cpu_contention * (wl.workers.saturating_sub(1)) as f64;
    let eval_s = cfg.eval_s * contention;

    // Stage 1: workers serialize submissions.
    let mut worker_free = vec![0.0f64; wl.workers.max(1)];
    // Stage 2: each endorser is a FIFO single server.
    let mut endorser_free = vec![vec![0.0f64; cfg.endorsers_per_shard]; cfg.shards];
    // Bounded ingress pool: per-shard endorsement completion times of
    // admitted transactions still in flight at a given arrival (FIFO, so
    // completions are nondecreasing and a deque front-pop suffices).
    let mut inflight: Vec<std::collections::VecDeque<f64>> =
        vec![std::collections::VecDeque::new(); cfg.shards];

    let mut txs: Vec<Tx> = Vec::with_capacity(wl.txs);
    let mut relay_lat_sum = 0.0f64;
    for i in 0..wl.txs {
        let sched = i as f64 / wl.send_tps.max(1e-9);
        let w = i % worker_free.len();
        let submit = sched.max(worker_free[w]) + cfg.worker_overhead_s;
        worker_free[w] = submit;
        let shard = i % cfg.shards;
        let mut arrive = submit + cfg.net_hop_s;

        // Cross-shard arrivals pay one relay hop before reaching their
        // home pool (the rng draws are gated on the knob so legacy runs
        // replay the exact pre-relay schedules).
        if cfg.cross_shard_frac > 0.0 && rng.next_f64() < cfg.cross_shard_frac {
            let hop = cfg.relay_hop_s * (0.25 * rng.normal()).exp();
            arrive += hop;
            relay_lat_sum += hop;
            report.forwarded += 1;
        }

        // Admission control: shed instantly when the shard pool is full
        // (the client got backpressure; no endorser time is consumed).
        if cfg.pool_capacity > 0 {
            let q = &mut inflight[shard];
            while q.front().is_some_and(|&done| done <= arrive) {
                q.pop_front();
            }
            if q.len() >= cfg.pool_capacity {
                report.shed += 1;
                continue;
            }
        }

        // Every endorser evaluates; the quorum-th completion endorses.
        let mut dones: Vec<f64> = endorser_free[shard]
            .iter_mut()
            .map(|free| {
                let start = arrive.max(*free);
                // Lognormal service time around the calibrated mean.
                let z = rng.normal();
                let service = eval_s * (cfg.eval_jitter * z).exp();
                let done = start + service;
                *free = done;
                done
            })
            .collect();
        dones.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let endorsed = dones[cfg.quorum - 1] + cfg.net_hop_s;
        if cfg.pool_capacity > 0 {
            inflight[shard].push_back(endorsed);
        }
        txs.push(Tx { submit: sched, endorsed, shard });
    }

    // Stage 3: per-shard batching -> consensus -> commit (per-tx
    // validation cost scaled by the parallel pre-validation workers).
    let validate_s = effective_validate_s(cfg);
    let mut completion = vec![0.0f64; txs.len()];
    for s in 0..cfg.shards {
        let mut idx: Vec<usize> = (0..txs.len()).filter(|&i| txs[i].shard == s).collect();
        idx.sort_by(|&a, &b| txs[a].endorsed.partial_cmp(&txs[b].endorsed).unwrap());
        let mut pos = 0usize;
        let mut orderer_free = 0.0f64;
        while pos < idx.len() {
            let first_arrival = txs[idx[pos]].endorsed;
            // The block closes when batch_size txs have arrived or the
            // timeout after the first arrival elapses — whichever first.
            let size_cut = if pos + cfg.batch_size <= idx.len() {
                Some(txs[idx[pos + cfg.batch_size - 1]].endorsed)
            } else {
                None
            };
            let timeout_cut = first_arrival + cfg.batch_timeout_s;
            let (cut_time, count) = match size_cut {
                Some(t) if t <= timeout_cut => (t, cfg.batch_size),
                _ => {
                    // All txs that arrived by the timeout join the block.
                    let mut n = 0;
                    while pos + n < idx.len() && txs[idx[pos + n]].endorsed <= timeout_cut {
                        n += 1;
                    }
                    (timeout_cut, n.max(1))
                }
            };
            let start = cut_time.max(orderer_free) + cfg.net_hop_s;
            let committed = start + cfg.order_s;
            orderer_free = committed;
            for (j, &i) in idx[pos..pos + count].iter().enumerate() {
                completion[i] = committed + validate_s * (j + 1) as f64 + cfg.net_hop_s;
            }
            pos += count;
        }
    }

    // Metrics: latency from scheduled submission (Caliper semantics).
    let mut last_completion = 0.0f64;
    let mut first_send = f64::INFINITY;
    let mut hist = Histogram::default();
    for (i, tx) in txs.iter().enumerate() {
        first_send = first_send.min(tx.submit);
        let latency = completion[i] - tx.submit;
        if latency <= wl.timeout_s {
            report.succeeded += 1;
            hist.record(latency);
            last_completion = last_completion.max(completion[i]);
        } else {
            report.failed += 1;
            // Failed txs are reported at the timeout bound (the client gave
            // up then), matching the paper's ~16 s average under surge.
            last_completion = last_completion.max(tx.submit + wl.timeout_s);
        }
    }
    let send_duration = txs.last().map(|t| t.submit).unwrap_or(0.0) - first_send;
    report.send_tps =
        if send_duration > 0.0 { wl.txs as f64 / send_duration } else { wl.send_tps };
    report.duration_s = (last_completion - first_send).max(1e-9);
    report.throughput = report.succeeded as f64 / report.duration_s;
    report.latency = hist;
    if report.forwarded > 0 {
        report.relay_lat_ms = relay_lat_sum / report.forwarded as f64 * 1e3;
    }
    report
}

/// Theoretical per-shard capacity of the modelled pipeline (TPS): each
/// endorser evaluates every shard transaction, so one endorser's queue is
/// the bottleneck.
pub fn shard_capacity(cfg: &DesConfig) -> f64 {
    1.0 / cfg.eval_s
}

/// Global capacity: shards process independently (the paper's linear claim).
pub fn global_capacity(cfg: &DesConfig) -> f64 {
    cfg.shards as f64 * shard_capacity(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(shards: usize) -> DesConfig {
        DesConfig { shards, endorsers_per_shard: 2, quorum: 2, eval_s: 0.2, ..Default::default() }
    }

    fn wl(txs: usize, tps: f64) -> Workload {
        Workload { txs, send_tps: tps, workers: 2, ..Default::default() }
    }

    #[test]
    fn under_load_everything_succeeds_fast() {
        let r = run_des(&cfg(2), &wl(100, 2.0), 1);
        assert_eq!(r.failed, 0);
        assert!(r.avg_latency() < 2.0, "avg {}", r.avg_latency());
    }

    #[test]
    fn throughput_scales_linearly_with_shards() {
        // Saturate: send well above capacity and compare observed tput.
        let mut tputs = Vec::new();
        for s in [1usize, 2, 4, 8] {
            let c = cfg(s);
            let r = run_des(&c, &wl(400, global_capacity(&c) * 1.5), 2);
            tputs.push(r.throughput);
        }
        // Each doubling of shards should give ~2x throughput (within 25%).
        for w in tputs.windows(2) {
            let ratio = w[1] / w[0];
            assert!((1.5..=2.5).contains(&ratio), "ratios {tputs:?}");
        }
    }

    #[test]
    fn saturation_knee_raises_latency() {
        let c = cfg(1);
        let cap = global_capacity(&c);
        let below = run_des(&c, &wl(150, cap * 0.6), 3);
        let above = run_des(&c, &wl(150, cap * 2.0), 3);
        assert!(above.avg_latency() > 3.0 * below.avg_latency().max(1e-3),
            "below {} above {}", below.avg_latency(), above.avg_latency());
        assert!(above.throughput <= cap * 1.15);
    }

    #[test]
    fn surge_causes_timeouts_and_throughput_collapse() {
        let c = cfg(1);
        let cap = global_capacity(&c);
        // Far more txs than 30 s of capacity can absorb.
        let r = run_des(&c, &wl(600, cap * 4.0), 4);
        assert!(r.failed > 0, "expected timeouts");
        let modest = run_des(&c, &wl(100, cap * 0.8), 4);
        assert!(modest.failed == 0);
        assert!(r.throughput < modest.throughput * 1.2);
    }

    #[test]
    fn more_workers_add_overhead_not_capacity() {
        let c = cfg(4);
        let cap = global_capacity(&c);
        let few = run_des(&c, &Workload { workers: 1, ..wl(300, cap) }, 5);
        let many = run_des(&c, &Workload { workers: 10, ..wl(300, cap) }, 5);
        // Generation parallelism doesn't raise server-side capacity.
        assert!(many.throughput <= few.throughput * 1.2);
    }

    #[test]
    fn bounded_pool_sheds_instead_of_queueing_unboundedly() {
        let c = cfg(1);
        let cap = global_capacity(&c);
        // Pool sized to ~4 s of service at the knee.
        let bounded = DesConfig { pool_capacity: (4.0 * cap).ceil() as usize, ..c };
        let wl2x = wl(400, cap * 2.0);
        let with_pool = run_des(&bounded, &wl2x, 11);
        let without_pool = run_des(&c, &wl2x, 11);
        // Backpressure: nonzero shed, and everything else accounted for.
        assert!(with_pool.shed > 0, "expected shed load at 2x knee");
        assert_eq!(
            with_pool.succeeded + with_pool.failed + with_pool.shed,
            with_pool.sent
        );
        assert_eq!(without_pool.shed, 0, "unbounded ingress never sheds");
        // Admitted-tx latency stays bounded by roughly the pool's service
        // backlog, far below the unbounded queue's worst case.
        assert!(
            with_pool.latency.max() < 3.0 * (4.0 + c.eval_s),
            "bounded pool latency {:.2}s",
            with_pool.latency.max()
        );
        assert!(
            without_pool.latency.max() > with_pool.latency.max(),
            "unbounded {:.2}s vs bounded {:.2}s",
            without_pool.latency.max(),
            with_pool.latency.max()
        );
        // Throughput still tracks capacity.
        assert!(with_pool.throughput > 0.5 * cap);
    }

    #[test]
    fn validation_workers_shrink_the_commit_tail() {
        // Make per-tx validation the dominant cost so the worker knob is
        // visible in end-to-end latency.
        let base = DesConfig { validate_s: 0.05, batch_size: 20, ..cfg(1) };
        assert!(effective_validate_s(&base) > effective_validate_s(&DesConfig {
            validation_workers: 4,
            ..base
        }));
        // Amdahl: the serial fraction survives at any worker count.
        let wide = DesConfig { validation_workers: 1_000, ..base };
        assert!(effective_validate_s(&wide) > base.validate_s * 0.09);
        let serial = run_des(&base, &wl(100, 4.0), 7);
        let parallel =
            run_des(&DesConfig { validation_workers: 4, ..base }, &wl(100, 4.0), 7);
        assert!(
            parallel.avg_latency() < serial.avg_latency(),
            "serial {:.3}s parallel {:.3}s",
            serial.avg_latency(),
            parallel.avg_latency()
        );
    }

    #[test]
    fn relay_hops_add_latency_and_are_counted() {
        let base = cfg(2);
        let direct = run_des(&base, &wl(200, 4.0), 13);
        let relayed_cfg =
            DesConfig { cross_shard_frac: 1.0, relay_hop_s: 0.5, ..base };
        let relayed = run_des(&relayed_cfg, &wl(200, 4.0), 13);
        assert_eq!(direct.forwarded, 0);
        assert_eq!(direct.relay_lat_ms, 0.0);
        assert_eq!(relayed.forwarded, 200);
        assert!(relayed.relay_lat_ms > 300.0, "{}", relayed.relay_lat_ms);
        assert!(
            relayed.avg_latency() > direct.avg_latency() + 0.3,
            "direct {:.3}s relayed {:.3}s",
            direct.avg_latency(),
            relayed.avg_latency()
        );
        // Relayed runs replay exactly under a fixed seed too.
        let again = run_des(&relayed_cfg, &wl(200, 4.0), 13);
        assert_eq!(again.forwarded, relayed.forwarded);
        assert!((again.relay_lat_ms - relayed.relay_lat_ms).abs() < 1e-12);
        assert!((again.throughput - relayed.throughput).abs() < 1e-12);
    }

    #[test]
    fn partial_cross_shard_traffic_is_counted_proportionally() {
        let c = DesConfig { cross_shard_frac: 0.25, ..cfg(2) };
        let r = run_des(&c, &wl(400, 4.0), 17);
        // ~25% forwarded (binomial; generous bounds).
        assert!((50..=150).contains(&r.forwarded), "forwarded {}", r.forwarded);
        assert!(r.relay_lat_ms > 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let c = cfg(2);
        let a = run_des(&c, &wl(100, 5.0), 9);
        let b = run_des(&c, &wl(100, 5.0), 9);
        assert_eq!(a.succeeded, b.succeeded);
        assert!((a.throughput - b.throughput).abs() < 1e-12);
    }
}
