//! Sharding: client-to-shard assignment strategies and per-round committee
//! (endorsing peer) election — the paper's §3 contribution surface.
//!
//! Assignment is pluggable (random / region-based / organisation-based,
//! §5 "Hierarchical Sharding"); committees are re-elected per round either
//! randomly (the paper's implementation simplification) or by score from the
//! previous round (Li et al.'s committee consensus).

use std::collections::HashMap;

use crate::util::prng::Prng;

/// Identifies a shard (channel `shard{N}`).
pub type ShardId = usize;

/// A participant eligible for shard assignment.
#[derive(Clone, Debug)]
pub struct Participant {
    pub id: usize,
    /// Region label for region-based placement (e.g. latency domain).
    pub region: usize,
    /// Organisation for consortium grouping.
    pub org: usize,
}

/// Client-to-shard assignment strategies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Assignment {
    /// Uniform random (the paper's default; resists single-shard takeover).
    Random,
    /// Group by region to cut intra-shard latency (§5).
    ByRegion,
    /// Group by organisation (cross-silo / consortium settings, §5).
    ByOrg,
}

/// Assign participants to `shards` shards.
pub fn assign(
    participants: &[Participant],
    shards: usize,
    strategy: Assignment,
    rng: &mut Prng,
) -> HashMap<ShardId, Vec<usize>> {
    assert!(shards > 0);
    let mut out: HashMap<ShardId, Vec<usize>> = (0..shards).map(|s| (s, Vec::new())).collect();
    match strategy {
        Assignment::Random => {
            let mut ids: Vec<usize> = participants.iter().map(|p| p.id).collect();
            rng.shuffle(&mut ids);
            for (i, id) in ids.into_iter().enumerate() {
                out.get_mut(&(i % shards)).unwrap().push(id);
            }
        }
        Assignment::ByRegion => {
            for p in participants {
                out.get_mut(&(p.region % shards)).unwrap().push(p.id);
            }
        }
        Assignment::ByOrg => {
            for p in participants {
                out.get_mut(&(p.org % shards)).unwrap().push(p.id);
            }
        }
    }
    out
}

/// Committee election policy for a shard round.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Election {
    /// Uniform random committee (paper's implementation).
    Random,
    /// Highest-scoring peers from the previous round (committee consensus).
    ByScore,
}

/// Elect `committee_size` endorsing peers from the shard's peer list.
///
/// `scores` maps peer id -> previous-round score (higher = better); peers
/// without a score default to 0 (ByScore) and ties break deterministically
/// by id so every honest node elects the same committee.
pub fn elect_committee(
    peers: &[usize],
    committee_size: usize,
    policy: Election,
    scores: &HashMap<usize, f64>,
    rng: &mut Prng,
) -> Vec<usize> {
    let n = committee_size.min(peers.len());
    match policy {
        Election::Random => {
            let idx = rng.sample_indices(peers.len(), n);
            let mut c: Vec<usize> = idx.into_iter().map(|i| peers[i]).collect();
            c.sort_unstable();
            c
        }
        Election::ByScore => {
            let mut ranked: Vec<usize> = peers.to_vec();
            ranked.sort_by(|a, b| {
                let (sa, sb) = (scores.get(a).unwrap_or(&0.0), scores.get(b).unwrap_or(&0.0));
                sb.partial_cmp(sa).unwrap().then(a.cmp(b))
            });
            let mut c: Vec<usize> = ranked.into_iter().take(n).collect();
            c.sort_unstable();
            c
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;

    fn participants(n: usize) -> Vec<Participant> {
        (0..n).map(|id| Participant { id, region: id % 3, org: id % 4 }).collect()
    }

    #[test]
    fn random_assignment_is_balanced_partition() {
        let mut rng = Prng::new(1);
        let ps = participants(64);
        let m = assign(&ps, 8, Assignment::Random, &mut rng);
        let mut all: Vec<usize> = m.values().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..64).collect::<Vec<_>>());
        for v in m.values() {
            assert_eq!(v.len(), 8);
        }
    }

    #[test]
    fn region_assignment_groups_regions() {
        let mut rng = Prng::new(2);
        let ps = participants(30);
        let m = assign(&ps, 3, Assignment::ByRegion, &mut rng);
        for (shard, members) in &m {
            for id in members {
                assert_eq!(ps[*id].region % 3, *shard);
            }
        }
    }

    #[test]
    fn org_assignment_groups_orgs() {
        let mut rng = Prng::new(3);
        let ps = participants(40);
        let m = assign(&ps, 4, Assignment::ByOrg, &mut rng);
        for (shard, members) in &m {
            for id in members {
                assert_eq!(ps[*id].org % 4, *shard, "org purity violated");
            }
        }
        // Every participant landed somewhere.
        assert_eq!(m.values().map(|v| v.len()).sum::<usize>(), 40);
    }

    #[test]
    fn assignment_is_deterministic_given_seed() {
        let ps = participants(32);
        for strat in [Assignment::Random, Assignment::ByRegion, Assignment::ByOrg] {
            let a = assign(&ps, 4, strat, &mut Prng::new(11));
            let b = assign(&ps, 4, strat, &mut Prng::new(11));
            assert_eq!(a, b, "{strat:?} not reproducible under a fixed seed");
        }
        // Random assignment actually depends on the seed (not degenerate).
        let a = assign(&ps, 4, Assignment::Random, &mut Prng::new(11));
        let c = assign(&ps, 4, Assignment::Random, &mut Prng::new(12));
        assert_ne!(a, c, "random assignment ignored the seed");
    }

    #[test]
    fn committee_random_is_deterministic_given_seed() {
        let peers: Vec<usize> = (0..16).collect();
        let scores = HashMap::new();
        let a = elect_committee(&peers, 4, Election::Random, &scores, &mut Prng::new(7));
        let b = elect_committee(&peers, 4, Election::Random, &scores, &mut Prng::new(7));
        assert_eq!(a, b);
        assert_eq!(a.len(), 4);
    }

    #[test]
    fn committee_by_score_picks_top() {
        let peers: Vec<usize> = (0..6).collect();
        let scores: HashMap<usize, f64> =
            [(0, 0.1), (1, 0.9), (2, 0.5), (3, 0.9), (4, 0.0), (5, 0.2)].into();
        let c = elect_committee(&peers, 3, Election::ByScore, &scores, &mut Prng::new(1));
        assert_eq!(c, vec![1, 2, 3]);
    }

    #[test]
    fn committee_size_capped_at_peer_count() {
        let peers = vec![3, 5];
        let c =
            elect_committee(&peers, 10, Election::Random, &HashMap::new(), &mut Prng::new(1));
        assert_eq!(c, vec![3, 5]);
    }

    #[test]
    fn committee_by_score_is_seed_independent() {
        // Score-based election must not consult the PRNG: every honest node
        // elects the same committee whatever its local seed.
        let peers: Vec<usize> = (0..10).collect();
        let scores: HashMap<usize, f64> =
            [(2, 0.7), (5, 0.9), (7, 0.7), (9, 0.1)].into();
        let a = elect_committee(&peers, 3, Election::ByScore, &scores, &mut Prng::new(1));
        let b = elect_committee(&peers, 3, Election::ByScore, &scores, &mut Prng::new(999));
        assert_eq!(a, b);
        // Ties (peers 2 and 7 at 0.7) break deterministically by id.
        assert_eq!(a, vec![2, 5, 7]);
    }

    #[test]
    fn per_round_election_sequence_reproduces_under_fixed_seed() {
        // A multi-round election schedule (fresh committee per round off one
        // seeded PRNG) must reproduce exactly — the property the sim relies
        // on for reproducible experiments.
        let peers: Vec<usize> = (0..12).collect();
        let scores = HashMap::new();
        let rounds = |seed: u64| -> Vec<Vec<usize>> {
            let mut rng = Prng::new(seed);
            (0..5)
                .map(|_| elect_committee(&peers, 4, Election::Random, &scores, &mut rng))
                .collect()
        };
        assert_eq!(rounds(42), rounds(42));
        assert_ne!(rounds(42), rounds(43));
        // Committees rotate across rounds (not stuck on one draw).
        let seq = rounds(42);
        assert!(seq.windows(2).any(|w| w[0] != w[1]), "committee never rotated: {seq:?}");
    }

    #[test]
    fn property_region_and_org_purity() {
        check("assign-purity", 24, |rng| {
            let n = rng.range(1, 80);
            let s = rng.range(1, 7);
            let ps = participants(n);
            let by_region = assign(&ps, s, Assignment::ByRegion, rng);
            for (shard, members) in &by_region {
                for id in members {
                    assert_eq!(ps[*id].region % s, *shard);
                }
            }
            let by_org = assign(&ps, s, Assignment::ByOrg, rng);
            for (shard, members) in &by_org {
                for id in members {
                    assert_eq!(ps[*id].org % s, *shard);
                }
            }
        });
    }

    #[test]
    fn property_assignment_is_always_partition() {
        check("assign-partition", 24, |rng| {
            let n = rng.range(1, 100);
            let s = rng.range(1, 9);
            let ps = participants(n);
            let strat = match rng.below(3) {
                0 => Assignment::Random,
                1 => Assignment::ByRegion,
                _ => Assignment::ByOrg,
            };
            let m = assign(&ps, s, strat, rng);
            let mut all: Vec<usize> = m.values().flatten().copied().collect();
            all.sort_unstable();
            assert_eq!(all, (0..n).collect::<Vec<_>>());
        });
    }
}
