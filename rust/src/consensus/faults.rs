//! Deterministic fault injection for consensus clusters.
//!
//! A [`FaultPlan`] is *data*: a seed plus a time-ordered schedule of
//! [`Fault`]s. The schedule is applied by the consensus
//! [`Transport`](super::transport::Transport) as virtual (or driver) time
//! passes — crash/restart a replica, partition the cluster, drop or delay
//! a fraction of messages per link, or mark a replica Byzantine so the
//! transport's protocol-specific mutator equivocates its broadcasts.
//! Because the plan is plain data (`Clone + Debug`), it travels inside
//! `OrdererConfig` and bench configs, and a failing scenario replays from
//! its seed alone (`SCALESFL_TEST_SEED`, see [`crate::util::check`]).
//!
//! All probabilistic choices (message drops) come from a `Prng` forked
//! from the plan seed, so two runs of the same plan over the same message
//! sequence make identical drop decisions.

use std::collections::{HashMap, HashSet};

use super::NodeId;
use crate::util::prng::Prng;

/// One injectable fault. Times live in the surrounding [`FaultPlan`].
#[derive(Clone, Debug, PartialEq)]
pub enum Fault {
    /// Take a replica down: it stops ticking and every message to or from
    /// it (including in-flight) is dropped.
    Crash(NodeId),
    /// Crash whichever replica is the leader/primary when the event
    /// fires (falls back to node 0 if no leader is known) — the
    /// "leader crash mid-surge" scenario without hardcoding an id.
    CrashLeader,
    /// Bring a crashed replica back with its in-memory state (models a
    /// restart from durable consensus state).
    Restart(NodeId),
    /// Split the cluster: traffic flows only between nodes that share a
    /// group; a node in no group is isolated from everyone.
    Partition(Vec<Vec<NodeId>>),
    /// Remove the active partition.
    Heal,
    /// Drop this fraction of all messages, iid per message.
    Drop { frac: f64 },
    /// Drop this fraction of messages on one directed link.
    LinkDrop { src: NodeId, dst: NodeId, frac: f64 },
    /// Multiply every sampled link latency by `factor` (1.0 = nominal).
    Delay { factor: f64 },
    /// Mark a replica Byzantine: the transport's mutator (e.g.
    /// [`pbft::equivocate`](super::pbft::equivocate)) rewrites its
    /// outbound broadcasts per destination.
    Equivocate(NodeId),
    /// Clear a replica's Byzantine flag.
    Honest(NodeId),
}

/// A seeded, time-ordered schedule of [`Fault`]s (see the module doc).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    seed: u64,
    events: Vec<(f64, Fault)>,
}

impl FaultPlan {
    /// An empty plan whose drop decisions derive from `seed`.
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, events: Vec::new() }
    }

    /// Schedule `fault` at time `at` (seconds on the driving clock).
    pub fn at(mut self, at: f64, fault: Fault) -> FaultPlan {
        self.events.push((at, fault));
        self
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Does any scheduled event mark a replica Byzantine? (The orderer
    /// uses this to decide whether to install the protocol's
    /// equivocation mutator.)
    pub fn has_equivocation(&self) -> bool {
        self.events.iter().any(|(_, f)| matches!(f, Fault::Equivocate(_)))
    }

    fn sorted_events(&self) -> Vec<(f64, Fault)> {
        let mut ev = self.events.clone();
        ev.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("NaN fault time"));
        ev
    }
}

/// Runtime state of an applied [`FaultPlan`] — owned by the transport.
pub(crate) struct FaultState {
    events: Vec<(f64, Fault)>,
    next: usize,
    rng: Prng,
    crashed: HashSet<NodeId>,
    partition: Option<Vec<HashSet<NodeId>>>,
    drop_frac: f64,
    link_drop: HashMap<(NodeId, NodeId), f64>,
    delay_factor: f64,
    equivocating: HashSet<NodeId>,
}

impl FaultState {
    pub fn new(plan: &FaultPlan) -> FaultState {
        FaultState {
            events: plan.sorted_events(),
            next: 0,
            rng: Prng::new(plan.seed ^ 0xFA117),
            crashed: HashSet::new(),
            partition: None,
            drop_frac: 0.0,
            link_drop: HashMap::new(),
            delay_factor: 1.0,
            equivocating: HashSet::new(),
        }
    }

    /// Apply every event due at `now`; `leader` resolves
    /// [`Fault::CrashLeader`]. Returns the applied faults (resolved).
    pub fn advance(&mut self, now: f64, leader: Option<NodeId>) -> Vec<Fault> {
        let mut applied = Vec::new();
        while self.next < self.events.len() && self.events[self.next].0 <= now {
            let fault = match self.events[self.next].1.clone() {
                Fault::CrashLeader => Fault::Crash(leader.unwrap_or(0)),
                f => f,
            };
            self.next += 1;
            match &fault {
                Fault::Crash(n) => {
                    self.crashed.insert(*n);
                }
                Fault::Restart(n) => {
                    self.crashed.remove(n);
                }
                Fault::Partition(groups) => {
                    self.partition =
                        Some(groups.iter().map(|g| g.iter().copied().collect()).collect());
                }
                Fault::Heal => self.partition = None,
                Fault::Drop { frac } => self.drop_frac = *frac,
                Fault::LinkDrop { src, dst, frac } => {
                    self.link_drop.insert((*src, *dst), *frac);
                }
                Fault::Delay { factor } => self.delay_factor = *factor,
                Fault::Equivocate(n) => {
                    self.equivocating.insert(*n);
                }
                Fault::Honest(n) => {
                    self.equivocating.remove(n);
                }
                Fault::CrashLeader => unreachable!("resolved above"),
            }
            applied.push(fault);
        }
        applied
    }

    pub fn is_crashed(&self, node: NodeId) -> bool {
        self.crashed.contains(&node)
    }

    /// Is the directed link currently usable (both ends up, same side of
    /// any partition)?
    pub fn link_up(&self, src: NodeId, dst: NodeId) -> bool {
        if self.crashed.contains(&src) || self.crashed.contains(&dst) {
            return false;
        }
        match &self.partition {
            None => true,
            Some(groups) => groups.iter().any(|g| g.contains(&src) && g.contains(&dst)),
        }
    }

    /// Deterministically decide whether to drop one message on the link.
    pub fn should_drop(&mut self, src: NodeId, dst: NodeId) -> bool {
        let frac = self
            .link_drop
            .get(&(src, dst))
            .copied()
            .unwrap_or(0.0)
            .max(self.drop_frac);
        frac > 0.0 && self.rng.next_f64() < frac
    }

    pub fn delay_factor(&self) -> f64 {
        self.delay_factor
    }

    pub fn is_equivocating(&self, node: NodeId) -> bool {
        self.equivocating.contains(&node)
    }

    pub fn rng_mut(&mut self) -> &mut Prng {
        &mut self.rng
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_apply_in_time_order_and_resolve_leader() {
        let plan = FaultPlan::new(1)
            .at(2.0, Fault::Restart(3))
            .at(1.0, Fault::CrashLeader)
            .at(1.5, Fault::Crash(3));
        let mut st = FaultState::new(&plan);
        assert!(st.advance(0.5, Some(2)).is_empty());
        // CrashLeader resolves against the leader at fire time.
        assert_eq!(st.advance(1.0, Some(2)), vec![Fault::Crash(2)]);
        assert!(st.is_crashed(2));
        assert!(!st.link_up(0, 2) && !st.link_up(2, 0));
        // Later events apply together once due; restart clears the crash.
        assert_eq!(st.advance(3.0, None), vec![Fault::Crash(3), Fault::Restart(3)]);
        assert!(!st.is_crashed(3));
        assert!(st.link_up(0, 3));
    }

    #[test]
    fn partition_blocks_cross_group_links_only() {
        let plan = FaultPlan::new(2).at(0.0, Fault::Partition(vec![vec![0, 1], vec![2, 3]]));
        let mut st = FaultState::new(&plan);
        st.advance(0.0, None);
        assert!(st.link_up(0, 1) && st.link_up(2, 3));
        assert!(!st.link_up(0, 2) && !st.link_up(3, 1));
        // Node 4 is in no group: isolated from everyone.
        assert!(!st.link_up(4, 0) && !st.link_up(1, 4));
        st.advance(1.0, None);
        let healed = FaultPlan::new(2)
            .at(0.0, Fault::Partition(vec![vec![0, 1], vec![2, 3]]))
            .at(1.0, Fault::Heal);
        let mut st = FaultState::new(&healed);
        st.advance(1.0, None);
        assert!(st.link_up(0, 2));
    }

    #[test]
    fn drop_decisions_replay_identically_for_one_seed() {
        let plan = FaultPlan::new(7)
            .at(0.0, Fault::Drop { frac: 0.3 })
            .at(0.0, Fault::LinkDrop { src: 0, dst: 1, frac: 0.9 });
        let decide = || {
            let mut st = FaultState::new(&plan);
            st.advance(0.0, None);
            (0..200).map(|i| st.should_drop(i % 3, 1)).collect::<Vec<bool>>()
        };
        let a = decide();
        assert_eq!(a, decide(), "same plan seed must make identical drop choices");
        // The per-link override dominates the global fraction.
        let dropped_on_link = a.iter().step_by(3).filter(|&&d| d).count();
        assert!(dropped_on_link > 50, "0.9 link drop should fire often: {dropped_on_link}/67");
        assert_ne!(a, {
            let mut st = FaultState::new(&FaultPlan { seed: 8, ..plan.clone() });
            st.advance(0.0, None);
            (0..200).map(|i| st.should_drop(i % 3, 1)).collect::<Vec<bool>>()
        });
    }

    #[test]
    fn equivocation_and_delay_flags_toggle() {
        let plan = FaultPlan::new(3)
            .at(0.0, Fault::Equivocate(2))
            .at(0.0, Fault::Delay { factor: 4.0 })
            .at(5.0, Fault::Honest(2))
            .at(5.0, Fault::Delay { factor: 1.0 });
        assert!(plan.has_equivocation());
        let mut st = FaultState::new(&plan);
        st.advance(0.0, None);
        assert!(st.is_equivocating(2));
        assert_eq!(st.delay_factor(), 4.0);
        st.advance(5.0, None);
        assert!(!st.is_equivocating(2));
        assert_eq!(st.delay_factor(), 1.0);
    }
}
