//! A consensus cluster: N sans-io replicas joined by a simnet
//! [`Transport`], with fault injection and telemetry.
//!
//! [`Cluster`] owns what the orderer driver used to improvise inline:
//! ticking every replica, routing its outbound messages through the
//! latency-priced transport, merging the replicas' committed streams into
//! one exactly-once sequence, and keeping the books (elections/view
//! changes, leader identity, per-channel commit latency, message-flow
//! accounting). It is deliberately driver-agnostic: the orderer drives it
//! with wall-clock time, tests and benches with virtual time.
//!
//! Delivery semantics: [`Cluster::take_committed`] returns each sequence
//! number exactly once, taken from whichever replica executes it first —
//! so a crashed replica 0 no longer stalls delivery (the old driver only
//! ever read `nodes[0]`). When two replicas report the same sequence with
//! different payloads, the cluster counts a *divergence* instead of
//! panicking; every fault-scenario test asserts that counter is zero.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};

use super::faults::{Fault, FaultPlan};
use super::transport::{Mutator, Transport, TransportConfig, TransportStats};
use super::{ConsensusNode, NodeId, NotLeader};
use crate::crypto::{sha256, Digest};
use crate::telemetry::{Registry, Sample};
use crate::util::histogram::Histogram;

/// Upper bound on same-instant delivery rounds per tick (a zero-latency
/// transport can cascade handle→send→handle chains; a real PBFT commit is
/// 3 hops). Anything still queued after this stays queued — the next tick
/// delivers it. Nothing is ever discarded here.
const MAX_DELIVERY_ROUNDS: usize = 8;

/// Live counters for the `scalesfl_consensus_*` collectors. Shared
/// (`Arc`) between the driver-owned [`Cluster`] and the process-wide
/// telemetry [`Registry`], which captures it weakly.
#[derive(Default)]
pub struct ConsensusTelemetry {
    /// Raft elections started / PBFT views entered (monotone).
    epoch_changes: AtomicU64,
    /// Current Raft term / PBFT view (max over replicas).
    epoch: AtomicU64,
    /// Observed changes of leader identity.
    leader_changes: AtomicU64,
    /// Current leader id, -1 when unknown.
    current_leader: AtomicI64,
    /// Payloads delivered through `take_committed`.
    commits: AtomicU64,
    /// Same-sequence payload disagreements between replicas.
    divergence: AtomicU64,
    sent: AtomicU64,
    delivered: AtomicU64,
    fault_dropped: AtomicU64,
    in_flight: AtomicU64,
    lost: AtomicU64,
    /// Commit latency (propose → first replica execution) per channel.
    commit_latency: Mutex<HashMap<String, Histogram>>,
}

impl ConsensusTelemetry {
    /// Register the `scalesfl_consensus_*` collector. `protocol` labels
    /// every sample; it also picks the epoch-change metric name
    /// (`elections` for raft, `view_changes` for pbft) so dashboards get
    /// the protocol's own vocabulary.
    pub fn register(self: &Arc<Self>, registry: &Registry, protocol: &'static str) {
        let weak: Weak<ConsensusTelemetry> = Arc::downgrade(self);
        registry.register(move || {
            let t = weak.upgrade()?;
            let labels = vec![("protocol".to_string(), protocol.to_string())];
            let epoch_metric = if protocol == "raft" {
                "scalesfl_consensus_elections_total"
            } else {
                "scalesfl_consensus_view_changes_total"
            };
            let c = |a: &AtomicU64| a.load(Ordering::Relaxed) as f64;
            let mut out = vec![
                Sample::counter(epoch_metric, labels.clone(), c(&t.epoch_changes)),
                Sample::gauge("scalesfl_consensus_epoch", labels.clone(), c(&t.epoch)),
                Sample::counter(
                    "scalesfl_consensus_leader_changes_total",
                    labels.clone(),
                    c(&t.leader_changes),
                ),
                Sample::gauge(
                    "scalesfl_consensus_current_leader",
                    labels.clone(),
                    t.current_leader.load(Ordering::Relaxed) as f64,
                ),
                Sample::counter("scalesfl_consensus_commits_total", labels.clone(), c(&t.commits)),
                Sample::counter(
                    "scalesfl_consensus_divergence_total",
                    labels.clone(),
                    c(&t.divergence),
                ),
                Sample::gauge(
                    "scalesfl_consensus_driver_lost_messages",
                    labels.clone(),
                    c(&t.lost),
                ),
            ];
            for (event, v) in [
                ("sent", &t.sent),
                ("delivered", &t.delivered),
                ("fault_dropped", &t.fault_dropped),
                ("in_flight", &t.in_flight),
            ] {
                let mut l = labels.clone();
                l.push(("event".to_string(), event.to_string()));
                out.push(Sample::counter("scalesfl_consensus_messages_total", l, c(v)));
            }
            for (channel, h) in t.commit_latency.lock().unwrap().iter() {
                let mut l = labels.clone();
                l.push(("channel".to_string(), channel.clone()));
                out.push(Sample::summary("scalesfl_consensus_commit_seconds", l, h));
            }
            Some(out)
        });
    }

}

/// Point-in-time cluster bookkeeping (tests and benches read this).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ClusterStats {
    pub epoch: u64,
    pub epoch_changes: u64,
    pub leader_changes: u64,
    pub leader: Option<NodeId>,
    pub commits: u64,
    pub divergence: u64,
    pub transport: TransportStats,
}

impl ClusterStats {
    /// Messages the driver can't account for — the satellite invariant.
    /// Stays 0 in every scenario: queued ≠ lost, and fault kills are
    /// counted separately.
    pub fn driver_lost(&self) -> u64 {
        self.transport.lost()
    }
}

/// See the module doc.
pub struct Cluster<C: ConsensusNode> {
    nodes: Vec<C>,
    transport: Transport<C::Msg>,
    telemetry: Arc<ConsensusTelemetry>,

    /// Digest of every sequence any replica has executed (agreement check).
    committed_digests: BTreeMap<u64, Digest>,
    /// Executed but not yet handed to the driver, keyed by sequence.
    pending_delivery: BTreeMap<u64, Vec<u8>>,
    delivered_upto: u64,
    /// Propose time + channel label per payload digest (commit latency).
    proposed_at: HashMap<Digest, (String, f64)>,

    last_leader: Option<NodeId>,
    epoch_changes: u64,
    leader_changes: u64,
    commits: u64,
    divergence: u64,
}

impl<C: ConsensusNode> Cluster<C> {
    pub fn new(nodes: Vec<C>, net: &TransportConfig, plan: &FaultPlan) -> Cluster<C> {
        assert!(!nodes.is_empty());
        Cluster {
            nodes,
            transport: Transport::new(net, plan),
            telemetry: Arc::new(ConsensusTelemetry::default()),
            committed_digests: BTreeMap::new(),
            pending_delivery: BTreeMap::new(),
            delivered_upto: 0,
            proposed_at: HashMap::new(),
            last_leader: None,
            epoch_changes: 0,
            leader_changes: 0,
            commits: 0,
            divergence: 0,
        }
    }

    /// Install the Byzantine message rewriter (see [`Transport::set_mutator`]).
    pub fn set_mutator(&mut self, m: Mutator<C::Msg>) {
        self.transport.set_mutator(m);
    }

    pub fn telemetry(&self) -> Arc<ConsensusTelemetry> {
        Arc::clone(&self.telemetry)
    }

    /// Current leader/primary: the lowest alive replica claiming the role.
    pub fn leader(&self) -> Option<NodeId> {
        self.nodes
            .iter()
            .position(|n| n.is_leader() && !self.transport.is_crashed(n.node_id()))
    }

    /// Max Raft term / PBFT view across replicas. The driver watches this
    /// to re-propose outstanding payloads after leadership moves.
    pub fn epoch(&self) -> u64 {
        self.nodes.iter().map(|n| n.epoch()).max().unwrap_or(0)
    }

    /// One tick: apply due fault events, tick alive replicas, pump the
    /// transport. Undelivered messages stay queued across ticks.
    pub fn tick(&mut self, now: f64) {
        let leader = self.leader();
        for fault in self.transport.advance_faults(now, leader) {
            if let Fault::Restart(n) = fault {
                self.nodes[n].restarted(now);
            }
        }
        for i in 0..self.nodes.len() {
            if self.transport.is_crashed(i) {
                continue;
            }
            let out = self.nodes[i].tick(now);
            for (to, m) in out {
                self.transport.send(i, to, m, now);
            }
            let out = self.nodes[i].take_outbound();
            for (to, m) in out {
                self.transport.send(i, to, m, now);
            }
        }
        for _ in 0..MAX_DELIVERY_ROUNDS {
            let due = self.transport.deliver_due(now);
            if due.is_empty() {
                break;
            }
            for (from, to, msg) in due {
                let out = self.nodes[to].handle(from, msg, now);
                for (dest, m) in out {
                    self.transport.send(to, dest, m, now);
                }
            }
        }
        self.observe(now);
    }

    /// Submit a payload to the current leader. `channel` labels the
    /// commit-latency histogram. Re-proposals of an already-tracked
    /// payload keep the original propose time, so measured latency spans
    /// the fault, not just the retry.
    ///
    /// Every other alive replica also gets
    /// [`ConsensusNode::note_request`] — the client-broadcast model: PBFT
    /// backups start a liveness timer for the request, so a primary that
    /// dies before its pre-prepares deliver still gets voted out.
    pub fn propose(&mut self, channel: &str, data: Vec<u8>, now: f64) -> Result<(), NotLeader> {
        let Some(l) = self.leader() else {
            return Err(NotLeader { hint: None });
        };
        let digest = sha256(&data);
        for i in 0..self.nodes.len() {
            if i != l && !self.transport.is_crashed(i) {
                self.nodes[i].note_request(&data, now);
            }
        }
        self.nodes[l].propose(data, now)?;
        self.proposed_at
            .entry(digest)
            .or_insert_with(|| (channel.to_string(), now));
        let out = self.nodes[l].take_outbound();
        for (to, m) in out {
            self.transport.send(l, to, m, now);
        }
        Ok(())
    }

    /// Client broadcast without a proposal: every alive replica learns the
    /// request exists (fault scenarios where the leader is already dead —
    /// the replicas must converge on a new one and order it themselves).
    pub fn broadcast_request(&mut self, channel: &str, data: Vec<u8>, now: f64) {
        let digest = sha256(&data);
        for i in 0..self.nodes.len() {
            if !self.transport.is_crashed(i) {
                self.nodes[i].note_request(&data, now);
            }
        }
        self.proposed_at
            .entry(digest)
            .or_insert_with(|| (channel.to_string(), now));
    }

    /// Drain newly committed payloads, each sequence exactly once and in
    /// order, from whichever replica executed it first. Cross-replica
    /// disagreement on a sequence increments `divergence`.
    pub fn take_committed(&mut self, now: f64) -> Vec<Vec<u8>> {
        for node in self.nodes.iter_mut() {
            for c in node.take_committed() {
                let digest = sha256(&c.data);
                match self.committed_digests.get(&c.seq) {
                    Some(prev) => {
                        if *prev != digest {
                            self.divergence += 1;
                        }
                    }
                    None => {
                        self.committed_digests.insert(c.seq, digest);
                        self.pending_delivery.insert(c.seq, c.data);
                    }
                }
            }
        }
        let mut out = Vec::new();
        while let Some(data) = self.pending_delivery.remove(&(self.delivered_upto + 1)) {
            self.delivered_upto += 1;
            self.commits += 1;
            if let Some((channel, t0)) = self.proposed_at.remove(&sha256(&data)) {
                self.telemetry
                    .commit_latency
                    .lock()
                    .unwrap()
                    .entry(channel)
                    .or_default()
                    .record(now - t0);
            }
            out.push(data);
        }
        if !out.is_empty() {
            self.observe(now);
        }
        out
    }

    pub fn stats(&self) -> ClusterStats {
        ClusterStats {
            epoch: self.epoch(),
            epoch_changes: self.epoch_changes,
            leader_changes: self.leader_changes,
            leader: self.leader(),
            commits: self.commits,
            divergence: self.divergence,
            transport: self.transport.stats(),
        }
    }

    /// p95 commit latency for one channel, if anything committed.
    pub fn commit_latency_p95(&self, channel: &str) -> Option<f64> {
        self.telemetry.commit_latency.lock().unwrap().get(channel)?.quantile(0.95)
    }

    /// Refresh the shared telemetry atomics from live state.
    fn observe(&mut self, _now: f64) {
        self.epoch_changes = self.nodes.iter().map(|n| n.epoch_changes()).sum();
        let leader = self.leader();
        if leader.is_some() && leader != self.last_leader {
            self.leader_changes += 1;
        }
        if leader.is_some() {
            self.last_leader = leader;
        }
        let t = &self.telemetry;
        t.epoch_changes.store(self.epoch_changes, Ordering::Relaxed);
        t.epoch.store(self.epoch(), Ordering::Relaxed);
        t.leader_changes.store(self.leader_changes, Ordering::Relaxed);
        t.current_leader
            .store(leader.map(|l| l as i64).unwrap_or(-1), Ordering::Relaxed);
        t.commits.store(self.commits, Ordering::Relaxed);
        t.divergence.store(self.divergence, Ordering::Relaxed);
        let s = self.transport.stats();
        t.sent.store(s.sent, Ordering::Relaxed);
        t.delivered.store(s.delivered, Ordering::Relaxed);
        t.fault_dropped.store(s.fault_dropped, Ordering::Relaxed);
        t.in_flight.store(s.in_flight, Ordering::Relaxed);
        t.lost.store(s.lost(), Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consensus::pbft::{self, Pbft, PbftConfig};
    use crate::consensus::raft::{Raft, RaftConfig};
    use crate::util::check::{check, fault_scenario};
    use crate::util::prng::Prng;

    fn raft_cluster(n: usize, seed: u64, plan: FaultPlan) -> Cluster<Raft> {
        let mut rng = Prng::new(seed);
        let nodes = (0..n)
            .map(|i| Raft::new(i, n, RaftConfig::default(), rng.fork(i as u64)))
            .collect();
        Cluster::new(nodes, &TransportConfig::lan(seed), &plan)
    }

    fn pbft_cluster(n: usize, view: u64, seed: u64, plan: FaultPlan) -> Cluster<Pbft> {
        let nodes = (0..n)
            .map(|i| Pbft::new(i, n, PbftConfig::default()).with_view(view))
            .collect();
        Cluster::new(nodes, &TransportConfig::lan(seed), &plan)
    }

    /// Tick in 10 ms virtual steps, draining commits into `out`.
    fn drive<C: ConsensusNode>(c: &mut Cluster<C>, from: f64, until: f64, out: &mut Vec<Vec<u8>>) {
        let mut now = from;
        while now < until {
            now += 0.01;
            c.tick(now);
            out.append(&mut c.take_committed(now));
        }
    }

    #[test]
    fn raft_commits_in_order_over_latency_links() {
        let mut c = raft_cluster(3, 11, FaultPlan::default());
        let mut out = Vec::new();
        drive(&mut c, 0.0, 2.0, &mut out);
        assert!(c.leader().is_some(), "no leader after 2s");
        for i in 0..5u8 {
            c.propose("ch", vec![i], 2.0).unwrap();
        }
        drive(&mut c, 2.0, 4.0, &mut out);
        assert_eq!(out, (0..5u8).map(|i| vec![i]).collect::<Vec<_>>());
        let s = c.stats();
        assert_eq!(s.divergence, 0);
        assert_eq!(s.driver_lost(), 0, "transport accounting must close: {s:?}");
        assert!(s.transport.sent > 0 && s.transport.delivered > 0);
        let p95 = c.commit_latency_p95("ch").expect("latency recorded");
        assert!(p95 > 0.0 && p95 < 1.0, "p95 {p95}");
    }

    #[test]
    fn partition_stalls_minority_and_heals() {
        // Majority side {2,3,4} keeps committing; after heal the minority
        // catches up to the same sequence.
        let plan = FaultPlan::new(5)
            .at(2.0, Fault::Partition(vec![vec![0, 1], vec![2, 3, 4]]))
            .at(6.0, Fault::Heal);
        let mut c = raft_cluster(5, 5, plan);
        let mut out = Vec::new();
        drive(&mut c, 0.0, 2.5, &mut out);
        // Partition landed at 2.0; wait for a leader inside the majority.
        drive(&mut c, 2.5, 5.0, &mut out);
        let l = c.leader().expect("majority leader");
        assert!(l >= 2, "leader {l} must sit in the majority group");
        c.propose("ch", b"during".to_vec(), 5.0).unwrap();
        drive(&mut c, 5.0, 6.0, &mut out);
        assert!(out.contains(&b"during".to_vec()), "majority side commits");
        drive(&mut c, 6.0, 8.0, &mut out);
        let s = c.stats();
        assert_eq!(s.divergence, 0);
        assert_eq!(s.driver_lost(), 0);
    }

    /// Satellite: Raft re-elects within a bounded number of ticks for
    /// every seeded latency assignment, byte-identical across reruns.
    #[test]
    fn property_raft_reelects_bounded_for_every_latency_assignment() {
        check("raft-reelection-bounded", 6, |rng| {
            let seed = rng.next_u64();
            let run = |seed: u64| {
                let plan = FaultPlan::new(seed).at(3.0, Fault::CrashLeader);
                let mut c = raft_cluster(5, seed, plan);
                let mut out = Vec::new();
                drive(&mut c, 0.0, 3.0, &mut out);
                let old = c.leader().expect("initial leader");
                // The crash fires at 3.0; 300 ticks (3 s) bounds recovery —
                // an election timeout is at most 0.3 s.
                drive(&mut c, 3.0, 6.0, &mut out);
                let new = c.leader().expect("re-elected within 300 ticks");
                assert_ne!(new, old, "crashed leader cannot lead");
                c.propose("ch", vec![seed as u8], 6.0).unwrap();
                drive(&mut c, 6.0, 8.0, &mut out);
                assert!(out.contains(&vec![seed as u8]), "post-recovery liveness");
                let s = c.stats();
                assert_eq!(s.divergence, 0);
                assert_eq!(s.driver_lost(), 0);
                (new, c.epoch(), s.epoch_changes, out)
            };
            assert_eq!(run(seed), run(seed), "rerun with one seed must be identical");
        });
    }

    /// Satellite: PBFT elects a new primary for every choice of crashed
    /// leader at f=1 (4 nodes), byte-identical across reruns.
    #[test]
    fn property_pbft_new_primary_for_every_crashed_leader() {
        fault_scenario("pbft-new-primary", 0xB1FF, |seed| {
            for v in 0..4u64 {
                let primary = (v % 4) as usize;
                let run = |seed: u64| {
                    let plan = FaultPlan::new(seed ^ v).at(0.05, Fault::Crash(primary));
                    let mut c = pbft_cluster(4, v, seed ^ v, plan);
                    // Clients broadcast the request; the primary dies before
                    // ordering it. Backups must vote in a new primary that
                    // orders it for them.
                    c.broadcast_request("ch", b"req".to_vec(), 0.0);
                    let mut out = Vec::new();
                    drive(&mut c, 0.0, 8.0, &mut out);
                    let new = c.leader().expect("new primary elected");
                    assert_ne!(new, primary, "crashed primary {primary} re-elected");
                    assert_eq!(out, vec![b"req".to_vec()], "request ordered once");
                    let s = c.stats();
                    assert!(s.epoch > v, "view must advance past {v}");
                    assert_eq!(s.divergence, 0);
                    assert_eq!(s.driver_lost(), 0);
                    (new, s.epoch, s.epoch_changes)
                };
                assert_eq!(run(seed), run(seed), "primary {primary}: rerun differs");
            }
        });
    }

    #[test]
    fn equivocating_primary_is_voted_out_and_request_survives() {
        fault_scenario("pbft-equivocation", 0xEB01, |seed| {
            let plan = FaultPlan::new(seed).at(0.0, Fault::Equivocate(0));
            let mut c = pbft_cluster(4, 0, seed, plan);
            c.set_mutator(Box::new(pbft::equivocate));
            let mut out = Vec::new();
            drive(&mut c, 0.0, 0.05, &mut out); // apply the fault event
            c.propose("ch", b"honest-batch".to_vec(), 0.05).unwrap();
            drive(&mut c, 0.05, 6.0, &mut out);
            // The forged pre-prepares can never assemble a prepare quorum,
            // so the slot stalls into a view change; the new (honest)
            // primary re-proposes everything pending — the real batch
            // commits, and the per-destination forgeries surface as extra
            // garbage payloads (the orderer counts those as bad batches).
            assert!(c.epoch() >= 1, "equivocation must force a view change");
            assert!(out.contains(&b"honest-batch".to_vec()), "request survives");
            let garbage = out.iter().filter(|p| p.as_slice() != b"honest-batch").count();
            assert!(garbage >= 1, "forged variants should surface, not vanish");
            let s = c.stats();
            assert_eq!(s.divergence, 0, "safety: replicas agree per sequence");
            assert_eq!(s.driver_lost(), 0);
        });
    }

    #[test]
    fn restart_rejoins_and_catches_up() {
        let plan = FaultPlan::new(9).at(2.5, Fault::Crash(0)).at(4.0, Fault::Restart(0));
        let mut c = raft_cluster(3, 9, plan);
        let mut out = Vec::new();
        drive(&mut c, 0.0, 2.0, &mut out);
        c.propose("ch", b"a".to_vec(), 2.0).unwrap();
        drive(&mut c, 2.0, 4.0, &mut out); // node 0 crashes at 2.5
        drive(&mut c, 4.0, 7.0, &mut out); // restarts at 4.0, must catch up
        assert!(c.leader().is_some(), "no leader after restart window");
        let _ = c.propose("ch", b"b".to_vec(), 7.0);
        drive(&mut c, 7.0, 9.0, &mut out);
        assert!(out.contains(&b"a".to_vec()) && out.contains(&b"b".to_vec()));
        let s = c.stats();
        assert_eq!(s.divergence, 0);
        assert_eq!(s.driver_lost(), 0);
    }

    #[test]
    fn telemetry_collector_exports_consensus_family() {
        let reg = Registry::new();
        let mut c = raft_cluster(3, 21, FaultPlan::default());
        c.telemetry().register(&reg, "raft");
        let mut out = Vec::new();
        drive(&mut c, 0.0, 2.0, &mut out);
        c.propose("ch", b"x".to_vec(), 2.0).unwrap();
        drive(&mut c, 2.0, 3.0, &mut out);
        let text = reg.render_prometheus();
        for name in [
            "scalesfl_consensus_elections_total",
            "scalesfl_consensus_epoch",
            "scalesfl_consensus_current_leader",
            "scalesfl_consensus_commits_total",
            "scalesfl_consensus_messages_total",
            "scalesfl_consensus_driver_lost_messages",
            "scalesfl_consensus_commit_seconds",
        ] {
            assert!(text.contains(name), "missing {name} in:\n{text}");
        }
        assert!(text.contains("protocol=\"raft\""), "{text}");
        assert!(text.contains("channel=\"ch\""), "{text}");
        // The cluster is owned by this test; dropping it prunes the
        // collector on the next render.
        drop(c);
        assert!(!reg.render_prometheus().contains("scalesfl_consensus"));
    }
}
