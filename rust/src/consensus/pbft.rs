//! PBFT (Castro & Liskov, OSDI '99) as a sans-io state machine.
//!
//! The paper (§3.2) proposes PBFT for shards whose threat model includes
//! byzantine peers, with Raft for smaller/trusted shards; the orderer
//! accepts either through the `ConsensusNode` trait.
//!
//! Implemented: the normal-case three-phase protocol (pre-prepare / prepare
//! / commit) with n = 3f+1 and quorums of 2f+1, in-order execution, and a
//! timeout-triggered view change that rotates the primary and re-proposes
//! unexecuted requests. Checkpointing/garbage collection are out of scope
//! (logs are bounded by the benchmark horizon).

use std::collections::{BTreeMap, HashMap, HashSet};

use super::{Committed, ConsensusNode, NodeId, NotLeader};
use crate::crypto::{sha256, Digest};
use crate::util::prng::Prng;

/// Where the replica stands in the protocol (the sawtooth-pbft node-state
/// shape): `Normal` three-phase operation vs. voting a primary out.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PbftMode {
    Normal,
    ViewChanging,
}

/// Phase of the *next-to-execute* sequence in the current view — the
/// observable answer to "what is this replica waiting on right now".
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PbftPhase {
    /// No in-progress slot at the execution frontier.
    Idle,
    /// Pre-prepare accepted; collecting prepare votes.
    Preparing,
    /// Prepared; collecting commit votes.
    Committing,
}

/// PBFT wire messages.
#[derive(Clone, Debug)]
pub enum Msg {
    PrePrepare { view: u64, seq: u64, digest: Digest, data: Vec<u8> },
    Prepare { view: u64, seq: u64, digest: Digest },
    Commit { view: u64, seq: u64, digest: Digest },
    /// Simplified view change: vote to move to `new_view`, carrying the
    /// voter's executed-sequence high-water mark and pending requests.
    ViewChange { new_view: u64, last_exec: u64, pending: Vec<Vec<u8>> },
    NewView { new_view: u64 },
}

/// Per-(view, seq) voting state.
#[derive(Default)]
struct SlotState {
    digest: Option<Digest>,
    data: Option<Vec<u8>>,
    prepares: HashSet<NodeId>,
    commits: HashSet<NodeId>,
    prepared: bool,
    committed: bool,
}

/// Timing configuration (seconds).
#[derive(Clone, Copy, Debug)]
pub struct PbftConfig {
    /// Progress timeout before a replica votes to change view.
    pub view_timeout: f64,
}

impl Default for PbftConfig {
    fn default() -> Self {
        PbftConfig { view_timeout: 1.0 }
    }
}

/// One PBFT replica.
pub struct Pbft {
    id: NodeId,
    n: usize,
    f: usize,
    cfg: PbftConfig,

    view: u64,
    mode: PbftMode,
    /// Views this replica has entered (monotone; telemetry).
    view_changes: u64,
    next_seq: u64,
    slots: BTreeMap<(u64, u64), SlotState>,
    /// Executed (delivered) in seq order.
    executed: Vec<Committed>,
    exec_upto: u64,
    drained: usize,

    /// Requests this node has accepted for ordering but not yet executed
    /// (carried into view changes).
    pending: Vec<Vec<u8>>,
    view_votes: HashMap<u64, HashSet<NodeId>>,
    progress_deadline: f64,
    /// Messages produced inside `propose` (drained via `take_outbound`).
    outbound_buffer: Vec<(NodeId, Msg)>,
}

impl Pbft {
    pub fn new(id: NodeId, n: usize, cfg: PbftConfig) -> Self {
        assert!(n >= 1, "need at least one replica");
        let f = (n - 1) / 3;
        Pbft {
            id,
            n,
            f,
            cfg,
            view: 0,
            mode: PbftMode::Normal,
            view_changes: 0,
            next_seq: 0,
            slots: BTreeMap::new(),
            executed: Vec::new(),
            exec_upto: 0,
            drained: 0,
            pending: Vec::new(),
            view_votes: HashMap::new(),
            progress_deadline: cfg.view_timeout,
            outbound_buffer: Vec::new(),
        }
    }

    /// Start at `view` instead of 0 — rotates the initial primary to
    /// `view % n` (fault-sweep tests crash every possible primary).
    pub fn with_view(mut self, view: u64) -> Self {
        self.view = view;
        self
    }

    pub fn view(&self) -> u64 {
        self.view
    }

    pub fn mode(&self) -> PbftMode {
        self.mode
    }

    /// Views entered by this replica.
    pub fn view_changes(&self) -> u64 {
        self.view_changes
    }

    /// Phase of the execution frontier (seq `exec_upto + 1`) in the
    /// current view; see [`PbftPhase`].
    pub fn phase(&self) -> PbftPhase {
        match self.slots.get(&(self.view, self.exec_upto + 1)) {
            None => PbftPhase::Idle,
            Some(s) if s.committed => PbftPhase::Idle,
            Some(s) if s.prepared => PbftPhase::Committing,
            Some(s) if s.digest.is_some() => PbftPhase::Preparing,
            Some(_) => PbftPhase::Idle,
        }
    }

    fn primary(&self) -> NodeId {
        (self.view as usize) % self.n
    }

    /// 2f+1 matching votes (including one's own).
    fn quorum(&self) -> usize {
        2 * self.f + 1
    }

    fn others(&self) -> impl Iterator<Item = NodeId> + '_ {
        (0..self.n).filter(move |p| *p != self.id)
    }

    fn broadcast(&self, msg: Msg) -> Vec<(NodeId, Msg)> {
        self.others().map(|p| (p, msg.clone())).collect()
    }

    fn slot(&mut self, view: u64, seq: u64) -> &mut SlotState {
        self.slots.entry((view, seq)).or_default()
    }

    /// Execute committed slots strictly in sequence order.
    fn try_execute(&mut self) {
        loop {
            let seq = self.exec_upto + 1;
            let Some(slot) = self.slots.get(&(self.view, seq)) else { break };
            if !slot.committed {
                break;
            }
            let data = slot.data.clone().expect("committed slot has data");
            self.pending.retain(|p| p != &data);
            self.executed.push(Committed { seq, data });
            self.exec_upto = seq;
        }
    }

    /// Record a prepare vote; fires the commit phase at quorum.
    fn on_prepared(&mut self, view: u64, seq: u64, digest: Digest) -> Vec<(NodeId, Msg)> {
        let q = self.quorum();
        let my_id = self.id;
        let slot = self.slot(view, seq);
        // Own pre-prepare acceptance counts as the primary's prepare.
        if slot.digest == Some(digest) && !slot.prepared && slot.prepares.len() + 1 >= q {
            slot.prepared = true;
            slot.commits.insert(my_id);
            let mut out = self.broadcast(Msg::Commit { view, seq, digest });
            out.extend(self.on_committed(view, seq));
            return out;
        }
        Vec::new()
    }

    /// Record commit votes; executes at quorum.
    fn on_committed(&mut self, view: u64, seq: u64) -> Vec<(NodeId, Msg)> {
        let q = self.quorum();
        let slot = self.slot(view, seq);
        if slot.prepared && !slot.committed && slot.commits.len() >= q {
            slot.committed = true;
            self.try_execute();
        }
        Vec::new()
    }

    fn start_view_change(&mut self, now: f64) -> Vec<(NodeId, Msg)> {
        let new_view = self.view + 1;
        self.mode = PbftMode::ViewChanging;
        self.progress_deadline = now + self.cfg.view_timeout;
        let msg = Msg::ViewChange {
            new_view,
            last_exec: self.exec_upto,
            pending: self.pending.clone(),
        };
        let mut out = self.broadcast(msg);
        out.extend(self.record_view_vote(new_view, self.id, now, Vec::new()));
        out
    }

    fn record_view_vote(
        &mut self,
        new_view: u64,
        from: NodeId,
        now: f64,
        carried: Vec<Vec<u8>>,
    ) -> Vec<(NodeId, Msg)> {
        if new_view <= self.view {
            return Vec::new();
        }
        for p in carried {
            if !self.pending.contains(&p) {
                self.pending.push(p);
            }
        }
        let votes = self.view_votes.entry(new_view).or_default();
        votes.insert(from);
        if votes.len() >= self.quorum() {
            self.enter_view(new_view, now);
            if self.primary() == self.id {
                let mut out = self.broadcast(Msg::NewView { new_view });
                // Re-propose everything pending under the new view.
                let pending = std::mem::take(&mut self.pending);
                for data in pending {
                    out.extend(self.propose_internal(data, now));
                }
                return out;
            }
        }
        Vec::new()
    }

    fn enter_view(&mut self, view: u64, now: f64) {
        self.view = view;
        self.mode = PbftMode::Normal;
        self.view_changes += 1;
        self.next_seq = self.exec_upto;
        self.view_votes.retain(|v, _| *v > view);
        self.progress_deadline = now + self.cfg.view_timeout;
    }

    fn propose_internal(&mut self, data: Vec<u8>, _now: f64) -> Vec<(NodeId, Msg)> {
        self.next_seq = self.next_seq.max(self.exec_upto) + 1;
        let seq = self.next_seq;
        let digest = sha256(&data);
        let view = self.view;
        if !self.pending.contains(&data) {
            self.pending.push(data.clone());
        }
        {
            let slot = self.slot(view, seq);
            slot.digest = Some(digest);
            slot.data = Some(data.clone());
        }
        if self.n == 1 {
            let slot = self.slot(view, seq);
            slot.prepared = true;
            slot.committed = true;
            self.try_execute();
            return Vec::new();
        }
        self.broadcast(Msg::PrePrepare { view, seq, digest, data })
    }
}

impl ConsensusNode for Pbft {
    type Msg = Msg;

    fn tick(&mut self, now: f64) -> Vec<(NodeId, Msg)> {
        // View change only fires when there is unexecuted work stalling.
        if now >= self.progress_deadline {
            self.progress_deadline = now + self.cfg.view_timeout;
            if !self.pending.is_empty() && self.n > 1 {
                return self.start_view_change(now);
            }
        }
        Vec::new()
    }

    fn handle(&mut self, from: NodeId, msg: Msg, now: f64) -> Vec<(NodeId, Msg)> {
        match msg {
            Msg::PrePrepare { view, seq, digest, data } => {
                if view != self.view || from != self.primary() {
                    return Vec::new();
                }
                if sha256(&data) != digest {
                    return Vec::new(); // byzantine primary: bad digest
                }
                self.progress_deadline = now + self.cfg.view_timeout;
                let my_id = self.id;
                {
                    let slot = self.slot(view, seq);
                    if slot.digest.is_some() && slot.digest != Some(digest) {
                        return Vec::new(); // conflicting pre-prepare
                    }
                    slot.digest = Some(digest);
                    slot.data = Some(data.clone());
                    slot.prepares.insert(my_id);
                }
                if !self.pending.contains(&data) {
                    self.pending.push(data);
                }
                let mut out = self.broadcast(Msg::Prepare { view, seq, digest });
                out.extend(self.on_prepared(view, seq, digest));
                out
            }
            Msg::Prepare { view, seq, digest } => {
                if view != self.view {
                    return Vec::new();
                }
                {
                    let slot = self.slot(view, seq);
                    if slot.digest.is_some() && slot.digest != Some(digest) {
                        return Vec::new();
                    }
                    slot.prepares.insert(from);
                }
                self.on_prepared(view, seq, digest)
            }
            Msg::Commit { view, seq, digest } => {
                if view != self.view {
                    return Vec::new();
                }
                {
                    let slot = self.slot(view, seq);
                    if slot.digest.is_some() && slot.digest != Some(digest) {
                        return Vec::new();
                    }
                    slot.commits.insert(from);
                }
                self.on_committed(view, seq)
            }
            Msg::ViewChange { new_view, last_exec: _, pending } => {
                self.record_view_vote(new_view, from, now, pending)
            }
            Msg::NewView { new_view } => {
                if new_view > self.view {
                    self.enter_view(new_view, now);
                }
                Vec::new()
            }
        }
    }

    fn propose(&mut self, data: Vec<u8>, now: f64) -> Result<(), NotLeader> {
        if self.primary() != self.id {
            return Err(NotLeader { hint: Some(self.primary()) });
        }
        // Sans-io contract: propose() cannot emit; the orderer drains
        // outbound via `take_outbound` below.
        let msgs = self.propose_internal(data, now);
        self.outbound_buffer.extend(msgs);
        Ok(())
    }

    fn take_committed(&mut self) -> Vec<Committed> {
        let out = self.executed[self.drained..].to_vec();
        self.drained = self.executed.len();
        out
    }

    /// Messages produced by `propose` (drained by the driver after each call).
    fn take_outbound(&mut self) -> Vec<(NodeId, Msg)> {
        std::mem::take(&mut self.outbound_buffer)
    }

    fn is_leader(&self) -> bool {
        self.primary() == self.id
    }

    fn node_id(&self) -> NodeId {
        self.id
    }

    fn epoch(&self) -> u64 {
        self.view
    }

    fn epoch_changes(&self) -> u64 {
        self.view_changes
    }

    /// PBFT's client timer: a backup that learns a request exists starts
    /// expecting execution; if the primary never orders it, the pending
    /// entry makes the progress timeout vote for a view change — this is
    /// what gives liveness when the primary dies *before* its
    /// pre-prepares deliver (no backup would otherwise hold any evidence
    /// the request was ever made).
    fn note_request(&mut self, data: &[u8], _now: f64) {
        if !self.pending.iter().any(|p| p == data) {
            self.pending.push(data.to_vec());
        }
    }

    /// Back up with protocol state retained; the progress timer restarts
    /// from `now` so a stale deadline can't fire instantly on revival.
    fn restarted(&mut self, now: f64) {
        self.mode = PbftMode::Normal;
        self.progress_deadline = now + self.cfg.view_timeout;
    }
}

/// Byzantine primary equivocation (a [`Mutator`](super::transport::Mutator)
/// for [`Transport::set_mutator`](super::transport::Transport::set_mutator)):
/// each destination receives a *different* pre-prepare for the same slot —
/// payload perturbed per destination, digest recomputed so it passes the
/// replica's digest check. Honest replicas then hold conflicting digests
/// for one `(view, seq)`, no variant can gather a 2f+1 prepare quorum, and
/// the stalled slot forces a view change; any perturbed payload that later
/// commits is garbage the orderer counts as a `bad_batch` (the wire codec
/// rejects trailing bytes). Non-pre-prepare messages pass untouched.
pub fn equivocate(src: NodeId, dst: NodeId, msg: &mut Msg, rng: &mut Prng) {
    if let Msg::PrePrepare { digest, data, .. } = msg {
        data.extend_from_slice(&[0xEB, src as u8, dst as u8, rng.next_u64() as u8]);
        *digest = sha256(data);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::simnet::SimNet;
    use crate::util::prng::Prng;

    fn cluster(n: usize, seed: u64) -> (Vec<Pbft>, SimNet<Msg>) {
        let nodes = (0..n).map(|i| Pbft::new(i, n, PbftConfig::default())).collect();
        let net = SimNet::new(0.001, 0.005, 0.0, Prng::new(seed));
        (nodes, net)
    }

    fn run(nodes: &mut Vec<Pbft>, net: &mut SimNet<Msg>, from: f64, until: f64) {
        let tick = 0.01;
        let mut now = from;
        while now < until {
            now += tick;
            for i in 0..nodes.len() {
                for (to, m) in nodes[i].tick(now) {
                    net.send(i, to, m, now);
                }
                for (to, m) in nodes[i].take_outbound() {
                    net.send(i, to, m, now);
                }
            }
            for (f, t, m) in net.deliver_until(now) {
                for (to, out) in nodes[t].handle(f, m, now) {
                    net.send(t, to, out, now);
                }
            }
        }
    }

    #[test]
    fn single_replica_executes_immediately() {
        let (mut nodes, mut net) = cluster(1, 1);
        nodes[0].propose(b"a".to_vec(), 0.0).unwrap();
        run(&mut nodes, &mut net, 0.0, 0.1);
        assert_eq!(nodes[0].take_committed().len(), 1);
    }

    #[test]
    fn four_replicas_commit_in_order() {
        let (mut nodes, mut net) = cluster(4, 2);
        for i in 0..5u8 {
            nodes[0].propose(vec![i], 0.0).unwrap();
        }
        run(&mut nodes, &mut net, 0.0, 2.0);
        for (id, n) in nodes.iter_mut().enumerate() {
            let data: Vec<Vec<u8>> = n.take_committed().into_iter().map(|c| c.data).collect();
            assert_eq!(data, (0..5u8).map(|i| vec![i]).collect::<Vec<_>>(), "replica {id}");
        }
    }

    #[test]
    fn non_primary_rejects_proposals() {
        let (mut nodes, _net) = cluster(4, 3);
        assert_eq!(nodes[1].propose(b"x".to_vec(), 0.0), Err(NotLeader { hint: Some(0) }));
    }

    #[test]
    fn view_change_recovers_from_dead_primary() {
        let (mut nodes, mut net) = cluster(4, 4);
        // Replica 1 learns of a request but primary 0 is isolated: the
        // request reaches replicas only as pending (simulate by injecting a
        // pre-prepare then isolating before prepares land).
        net.isolate(0);
        // Clients resubmit to a backup: model by marking pending directly.
        for n in nodes.iter_mut().skip(1) {
            n.pending.push(b"req".to_vec());
        }
        run(&mut nodes, &mut net, 0.0, 5.0);
        // New view installed, request executed on the healthy replicas.
        for (id, n) in nodes.iter_mut().enumerate().skip(1) {
            assert!(n.view() >= 1, "replica {id} still in view 0");
            let data: Vec<Vec<u8>> = n.take_committed().into_iter().map(|c| c.data).collect();
            assert_eq!(data, vec![b"req".to_vec()], "replica {id}");
        }
    }

    #[test]
    fn byzantine_digest_rejected() {
        let mut replica = Pbft::new(1, 4, PbftConfig::default());
        let out = replica.handle(
            0,
            Msg::PrePrepare {
                view: 0,
                seq: 1,
                digest: sha256(b"other"),
                data: b"data".to_vec(),
            },
            0.0,
        );
        assert!(out.is_empty());
        assert!(replica.take_committed().is_empty());
    }
}
