//! Simnet-routed message transport for one consensus cluster.
//!
//! The sans-io state machines in [`raft`](super::raft) / [`pbft`](super::pbft)
//! emit `(dst, msg)` pairs; previously the orderer driver handed those to the
//! destination in the same instant ("8 instant rounds and drop the rest").
//! [`Transport`] replaces that: every message is priced through the
//! [`LinkLatency`](crate::network::simnet::LinkLatency) oracle — stable
//! per-directed-link means plus per-message jitter, so elections, heartbeats
//! and PBFT phases see realistic delay *and reordering* — and queued on a
//! delivery heap. Messages not yet due simply stay queued for the next tick;
//! the transport never discards traffic on its own. The only ways a message
//! dies are fault-plan actions (crash, partition, probabilistic drop), and
//! those are counted in [`TransportStats::fault_dropped`], so
//! [`TransportStats::lost`] is an invariant the driver asserts at zero.
//!
//! A [`FaultPlan`] (see [`super::faults`]) is applied here as time passes:
//! crashed nodes send/receive nothing (including in-flight traffic), a
//! partition blocks cross-group links, `Drop`/`LinkDrop` kill a seeded
//! fraction of messages, `Delay` scales every sampled latency, and
//! `Equivocate` routes a Byzantine node's outbound messages through a
//! protocol-specific [`Mutator`] (e.g. [`pbft::equivocate`](super::pbft::equivocate))
//! that can rewrite each copy per destination.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::time::Duration;

use super::faults::{Fault, FaultPlan, FaultState};
use super::NodeId;
use crate::network::simnet::LinkLatency;
use crate::util::prng::Prng;

/// Latency profile for intra-cluster replica links.
#[derive(Clone, Debug)]
pub struct TransportConfig {
    /// Minimum one-way link latency.
    pub base: Duration,
    /// Stable per-link spread on top of `base` (hashed per directed link).
    pub spread: Duration,
    /// Per-message jitter bound.
    pub jitter: Duration,
    /// Seed for the link topology and jitter.
    pub seed: u64,
}

impl TransportConfig {
    /// Free links: every message delivers on the next tick (tests).
    pub fn zero() -> TransportConfig {
        TransportConfig {
            base: Duration::ZERO,
            spread: Duration::ZERO,
            jitter: Duration::ZERO,
            seed: 0,
        }
    }

    /// Same-rack orderers: ~0.5–2.5 ms per hop. The orderer default.
    pub fn lan(seed: u64) -> TransportConfig {
        TransportConfig {
            base: Duration::from_micros(500),
            spread: Duration::from_millis(2),
            jitter: Duration::from_micros(500),
            seed,
        }
    }

    /// Geo-distributed orderers: ~10–35 ms per hop (benches).
    pub fn wan(seed: u64) -> TransportConfig {
        TransportConfig {
            base: Duration::from_millis(10),
            spread: Duration::from_millis(20),
            jitter: Duration::from_millis(5),
            seed,
        }
    }

    fn oracle(&self) -> LinkLatency {
        LinkLatency::new(self.base, self.spread, self.jitter, self.seed)
    }
}

impl Default for TransportConfig {
    fn default() -> TransportConfig {
        TransportConfig::lan(0x5CA1E5F1)
    }
}

/// Message-flow counters; see [`TransportStats::lost`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TransportStats {
    /// Messages handed to [`Transport::send`].
    pub sent: u64,
    /// Messages delivered to their destination.
    pub delivered: u64,
    /// Messages killed by the fault plan (crash/partition/drop), at send
    /// time or in flight.
    pub fault_dropped: u64,
    /// Messages currently queued on the delivery heap.
    pub in_flight: u64,
}

impl TransportStats {
    /// Messages unaccounted for. The transport's contract is that this is
    /// **always zero**: undelivered traffic stays queued, and every
    /// fault-plan kill is counted. The orderer driver asserts it.
    pub fn lost(&self) -> u64 {
        self.sent - self.delivered - self.fault_dropped - self.in_flight
    }
}

/// Per-destination message rewrite hook for Byzantine senders
/// (installed via [`Transport::set_mutator`]).
pub type Mutator<M> = Box<dyn FnMut(NodeId, NodeId, &mut M, &mut Prng) + Send>;

/// Orderable f64 wrapper for the delivery heap.
#[derive(Clone, Copy, PartialEq)]
struct Time(f64);

impl Eq for Time {}

impl PartialOrd for Time {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Time {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).expect("NaN time")
    }
}

/// The cluster message fabric (see the module doc).
pub struct Transport<M> {
    links: LinkLatency,
    heap: BinaryHeap<Reverse<(Time, u64, NodeId, NodeId)>>,
    payloads: HashMap<u64, M>,
    seq: u64,
    faults: FaultState,
    mutator: Option<Mutator<M>>,
    sent: u64,
    delivered: u64,
    fault_dropped: u64,
}

impl<M> Transport<M> {
    pub fn new(config: &TransportConfig, plan: &FaultPlan) -> Transport<M> {
        Transport {
            links: config.oracle(),
            heap: BinaryHeap::new(),
            payloads: HashMap::new(),
            seq: 0,
            faults: FaultState::new(plan),
            mutator: None,
            sent: 0,
            delivered: 0,
            fault_dropped: 0,
        }
    }

    /// Install the protocol-specific equivocation hook; it runs on every
    /// message sent while the source is marked [`Fault::Equivocate`].
    pub fn set_mutator(&mut self, m: Mutator<M>) {
        self.mutator = Some(m);
    }

    /// Apply fault-plan events due at `now`; `leader` resolves
    /// [`Fault::CrashLeader`]. Returns the faults applied this call so the
    /// cluster can react (e.g. notify a restarted node).
    pub fn advance_faults(&mut self, now: f64, leader: Option<NodeId>) -> Vec<Fault> {
        self.faults.advance(now, leader)
    }

    pub fn is_crashed(&self, node: NodeId) -> bool {
        self.faults.is_crashed(node)
    }

    pub fn is_equivocating(&self, node: NodeId) -> bool {
        self.faults.is_equivocating(node)
    }

    fn link_name(node: NodeId) -> String {
        format!("node{node}")
    }

    /// Queue one message; it will be deliverable after the sampled link
    /// latency. Fault-plan kills (down link, seeded drop) are counted in
    /// `fault_dropped` — never silent.
    pub fn send(&mut self, from: NodeId, to: NodeId, mut msg: M, now: f64) {
        self.sent += 1;
        if !self.faults.link_up(from, to) || self.faults.should_drop(from, to) {
            self.fault_dropped += 1;
            return;
        }
        if self.faults.is_equivocating(from) {
            if let Some(mutate) = self.mutator.as_mut() {
                mutate(from, to, &mut msg, self.faults.rng_mut());
            }
        }
        self.seq += 1;
        let latency = self.links.sample_s(&Self::link_name(from), &Self::link_name(to), self.seq)
            * self.faults.delay_factor();
        self.payloads.insert(self.seq, msg);
        self.heap.push(Reverse((Time(now + latency), self.seq, from, to)));
    }

    /// Pop every message whose delivery time has arrived, in timestamp
    /// order. Messages still in the future stay queued — the next tick
    /// picks them up. A link that went down while a message was in flight
    /// kills it (counted).
    pub fn deliver_due(&mut self, now: f64) -> Vec<(NodeId, NodeId, M)> {
        let mut out = Vec::new();
        while let Some(&Reverse((Time(t), seq, from, to))) = self.heap.peek() {
            if t > now {
                break;
            }
            self.heap.pop();
            let msg = self.payloads.remove(&seq).expect("payload");
            if !self.faults.link_up(from, to) {
                self.fault_dropped += 1;
                continue;
            }
            self.delivered += 1;
            out.push((from, to, msg));
        }
        out
    }

    /// Earliest queued delivery time, if any (virtual-time drivers use it
    /// to jump the clock instead of polling).
    pub fn next_due(&self) -> Option<f64> {
        self.heap.peek().map(|Reverse((Time(t), ..))| *t)
    }

    pub fn stats(&self) -> TransportStats {
        TransportStats {
            sent: self.sent,
            delivered: self.delivered,
            fault_dropped: self.fault_dropped,
            in_flight: self.heap.len() as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lan() -> TransportConfig {
        TransportConfig::lan(7)
    }

    #[test]
    fn undelivered_messages_stay_queued_not_dropped() {
        let mut t: Transport<u32> = Transport::new(&lan(), &FaultPlan::default());
        for i in 0..100 {
            t.send(0, 1, i, 0.0);
        }
        // Far too early: nothing due yet, but nothing lost either.
        assert!(t.deliver_due(0.0001).is_empty());
        let s = t.stats();
        assert_eq!(s.in_flight, 100);
        assert_eq!(s.lost(), 0);
        // Eventually everything arrives; accounting closes.
        let got = t.deliver_due(1.0);
        assert_eq!(got.len(), 100);
        let s = t.stats();
        assert_eq!((s.delivered, s.in_flight, s.lost()), (100, 0, 0));
    }

    #[test]
    fn delivery_respects_per_link_latency_and_orders_by_time() {
        let mut t: Transport<u32> = Transport::new(&lan(), &FaultPlan::default());
        t.send(0, 1, 1, 0.0);
        t.send(2, 3, 2, 0.0);
        t.send(1, 0, 3, 0.0);
        assert!(t.next_due().unwrap() >= 0.0005, "base latency applies");
        let got = t.deliver_due(1.0);
        assert_eq!(got.len(), 3);
        // Distinct links have distinct stable means, so arrival order is a
        // function of the topology, not send order. Just check it's sorted
        // by redelivery: heap pops in time order by construction; verify
        // the messages all arrived intact.
        let mut payloads: Vec<u32> = got.iter().map(|&(_, _, m)| m).collect();
        payloads.sort_unstable();
        assert_eq!(payloads, vec![1, 2, 3]);
    }

    #[test]
    fn crash_kills_in_flight_and_future_traffic_counted() {
        let plan = FaultPlan::new(1).at(0.5, Fault::Crash(1));
        let mut t: Transport<u32> = Transport::new(&lan(), &plan);
        t.send(0, 1, 1, 0.0); // arrives before the crash
        assert_eq!(t.deliver_due(0.4).len(), 1);
        t.send(0, 1, 2, 0.4); // in flight when the crash lands
        t.advance_faults(0.5, None);
        t.send(0, 1, 3, 0.6); // sent to a dead node
        t.send(1, 0, 4, 0.6); // sent from a dead node
        assert!(t.deliver_due(2.0).is_empty());
        let s = t.stats();
        assert_eq!(s.fault_dropped, 3);
        assert_eq!(s.lost(), 0, "every undelivered message is accounted");
    }

    #[test]
    fn delay_factor_scales_latency() {
        let plan = FaultPlan::new(2).at(0.0, Fault::Delay { factor: 10.0 });
        let mut nominal: Transport<u32> = Transport::new(&lan(), &FaultPlan::default());
        let mut slowed: Transport<u32> = Transport::new(&lan(), &plan);
        slowed.advance_faults(0.0, None);
        nominal.send(0, 1, 1, 0.0);
        slowed.send(0, 1, 1, 0.0);
        let t0 = nominal.next_due().unwrap();
        let t1 = slowed.next_due().unwrap();
        assert!((t1 - t0 * 10.0).abs() < 1e-12, "{t1} vs 10x{t0}");
    }

    #[test]
    fn mutator_runs_only_for_equivocating_sender() {
        let plan = FaultPlan::new(3).at(0.0, Fault::Equivocate(0));
        let mut t: Transport<Vec<u8>> = Transport::new(&lan(), &plan);
        t.set_mutator(Box::new(|_, dst, msg, _| msg.push(dst as u8)));
        t.advance_faults(0.0, None);
        t.send(0, 1, vec![9], 0.0);
        t.send(0, 2, vec![9], 0.0);
        t.send(1, 2, vec![9], 0.0); // honest sender: untouched
        let mut got = t.deliver_due(1.0);
        got.sort_by_key(|&(from, to, _)| (from, to));
        assert_eq!(got[0].2, vec![9, 1]);
        assert_eq!(got[1].2, vec![9, 2]);
        assert_eq!(got[2].2, vec![9]);
    }

    #[test]
    fn zero_config_delivers_immediately() {
        let mut t: Transport<u32> = Transport::new(&TransportConfig::zero(), &FaultPlan::default());
        t.send(0, 1, 5, 1.0);
        assert_eq!(t.deliver_due(1.0).len(), 1);
    }
}
