//! Consensus substrates for the ordering service.
//!
//! The paper runs a Raft orderer for its Fabric test network and calls out
//! PBFT as the shard-level alternative for byzantine settings (§3.2); both
//! are implemented here as *sans-io state machines*: they consume
//! `(time, message)` inputs and emit outbound messages, so the same code is
//! driven deterministically by the test/DES harness and in real time by the
//! ordering service threads.

pub mod pbft;
pub mod raft;

/// Node identifier inside a consensus group.
pub type NodeId = usize;

/// A consensus-agnostic committed entry: (sequence, payload).
#[derive(Clone, Debug, PartialEq)]
pub struct Committed {
    pub seq: u64,
    pub data: Vec<u8>,
}

/// Common driver-facing surface so the orderer can swap Raft <-> PBFT
/// (the paper's "pluggable consensus" principle).
pub trait ConsensusNode {
    type Msg: Clone + std::fmt::Debug;

    /// Advance timers; returns outbound (dest, msg) pairs.
    fn tick(&mut self, now: f64) -> Vec<(NodeId, Self::Msg)>;
    /// Handle an inbound message; returns outbound (dest, msg) pairs.
    fn handle(&mut self, from: NodeId, msg: Self::Msg, now: f64) -> Vec<(NodeId, Self::Msg)>;
    /// Submit a payload for ordering (leader/primary only; Err otherwise).
    fn propose(&mut self, data: Vec<u8>, now: f64) -> Result<(), NotLeader>;
    /// Drain entries that became committed since the last call.
    fn take_committed(&mut self) -> Vec<Committed>;
    /// Drain messages produced inside `propose` (protocols whose proposal
    /// broadcasts immediately, e.g. PBFT pre-prepare; Raft ships entries on
    /// the next heartbeat and returns nothing here).
    fn take_outbound(&mut self) -> Vec<(NodeId, Self::Msg)> {
        Vec::new()
    }
    /// Is this node currently the leader/primary?
    fn is_leader(&self) -> bool;
    fn node_id(&self) -> NodeId;
}

/// Proposal rejected: this node is not the current leader.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NotLeader {
    /// Best-known current leader, if any.
    pub hint: Option<NodeId>,
}
