//! Consensus substrates for the ordering service: sans-io replicas, a
//! simnet-routed transport, and deterministic fault injection.
//!
//! The paper runs a Raft orderer for its Fabric test network and calls out
//! PBFT as the shard-level alternative for byzantine settings (§3.2). Both
//! live here as *sans-io state machines* ([`raft`], [`pbft`]): they consume
//! `(time, message)` inputs and emit `(dst, msg)` outputs, never touching a
//! socket or a clock. That interface is what makes the rest of this module
//! possible — the same state machines are driven in real time by the
//! orderer and in virtual time by tests and benches, deterministically.
//!
//! # Lifecycle
//!
//! A [`cluster::Cluster`] wires N replicas to a [`transport::Transport`]:
//!
//! 1. **Tick.** The driver calls [`cluster::Cluster::tick`] with the
//!    current time. Due fault-plan events are applied first (crashes,
//!    partitions, restarts — a restarted replica gets
//!    [`ConsensusNode::restarted`]); then every alive replica's
//!    [`ConsensusNode::tick`] timers fire and their outbound messages are
//!    queued on the transport.
//! 2. **Transit.** Each `(src, dst, msg)` is priced by the
//!    [`LinkLatency`](crate::network::simnet::LinkLatency) oracle — stable
//!    per-directed-link means plus per-message jitter — so elections,
//!    heartbeats, and PBFT phases see realistic delay *and reordering*.
//!    Messages not yet due stay queued across ticks; the transport never
//!    drops traffic on its own (the old driver's "8 instant rounds, then
//!    discard" bug is structurally gone, and
//!    [`transport::TransportStats::lost`] asserts it stays gone).
//! 3. **Fault injection.** A [`faults::FaultPlan`] — plain, `Clone`able
//!    data scheduled on the same clock — can crash/restart replicas,
//!    partition the cluster, drop or delay message fractions per link,
//!    and mark a replica Byzantine so the transport rewrites its
//!    broadcasts per destination ([`pbft::equivocate`] forges
//!    per-destination pre-prepares). Every probabilistic choice derives
//!    from the plan's seed: a failing scenario replays from
//!    `SCALESFL_TEST_SEED` alone.
//! 4. **Commit.** [`cluster::Cluster::take_committed`] merges the
//!    replicas' executed streams into one exactly-once sequence (from
//!    whichever replica executes first, so a crashed replica can't stall
//!    delivery) and checks cross-replica agreement per sequence.
//!
//! Observability rides along: the cluster exports the
//! `scalesfl_consensus_*` family (elections/view changes, current
//! leader/epoch, per-channel commit-latency summaries, message-flow
//! accounting) documented in [`crate::telemetry`].

pub mod cluster;
pub mod faults;
pub mod pbft;
pub mod raft;
pub mod transport;

pub use cluster::{Cluster, ClusterStats, ConsensusTelemetry};
pub use faults::{Fault, FaultPlan};
pub use transport::{Transport, TransportConfig, TransportStats};

/// Node identifier inside a consensus group.
pub type NodeId = usize;

/// A consensus-agnostic committed entry: (sequence, payload).
#[derive(Clone, Debug, PartialEq)]
pub struct Committed {
    pub seq: u64,
    pub data: Vec<u8>,
}

/// Common driver-facing surface so the orderer can swap Raft <-> PBFT
/// (the paper's "pluggable consensus" principle).
pub trait ConsensusNode {
    type Msg: Clone + std::fmt::Debug;

    /// Advance timers; returns outbound (dest, msg) pairs.
    fn tick(&mut self, now: f64) -> Vec<(NodeId, Self::Msg)>;
    /// Handle an inbound message; returns outbound (dest, msg) pairs.
    fn handle(&mut self, from: NodeId, msg: Self::Msg, now: f64) -> Vec<(NodeId, Self::Msg)>;
    /// Submit a payload for ordering (leader/primary only; Err otherwise).
    fn propose(&mut self, data: Vec<u8>, now: f64) -> Result<(), NotLeader>;
    /// Drain entries that became committed since the last call.
    fn take_committed(&mut self) -> Vec<Committed>;
    /// Drain messages produced inside `propose` (protocols whose proposal
    /// broadcasts immediately, e.g. PBFT pre-prepare; Raft ships entries on
    /// the next heartbeat and returns nothing here).
    fn take_outbound(&mut self) -> Vec<(NodeId, Self::Msg)> {
        Vec::new()
    }
    /// Is this node currently the leader/primary?
    fn is_leader(&self) -> bool;
    fn node_id(&self) -> NodeId;

    /// Current election epoch: Raft term / PBFT view. Monotone; the
    /// orderer driver re-proposes outstanding payloads when it moves.
    fn epoch(&self) -> u64 {
        0
    }
    /// Elections started (Raft) / views entered (PBFT) on this replica —
    /// monotone, feeds `scalesfl_consensus_{elections,view_changes}_total`.
    fn epoch_changes(&self) -> u64 {
        0
    }
    /// Client-style request notification on a *non-leader* replica: the
    /// replica learns the request exists so it can force a view change if
    /// the leader never orders it (PBFT's client timer). Protocols whose
    /// followers play no part in request liveness ignore it (Raft — the
    /// driver's epoch-change re-proposal covers leader loss there).
    fn note_request(&mut self, _data: &[u8], _now: f64) {}
    /// The fault plan restarted this replica after a crash: state is
    /// retained (modelling recovery from durable consensus state) but any
    /// leadership claim must be re-earned and timers re-anchored to `now`.
    fn restarted(&mut self, _now: f64) {}
}

/// Proposal rejected: this node is not the current leader.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NotLeader {
    /// Best-known current leader, if any.
    pub hint: Option<NodeId>,
}
