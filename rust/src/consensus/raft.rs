//! Raft (Ongaro & Ousterhout, USENIX ATC '14) as a sans-io state machine:
//! leader election, log replication, and commitment — the ordering service
//! the paper's Fabric test network runs.
//!
//! The node never touches a socket or a clock: `tick(now)` fires timers and
//! `handle(from, msg, now)` processes inputs, both returning outbound
//! messages. Election timeouts are randomized from the node's own `Prng`.

use std::collections::{HashMap, HashSet};

use super::{Committed, ConsensusNode, NodeId, NotLeader};
use crate::util::prng::Prng;

pub type Term = u64;

/// A replicated log entry.
#[derive(Clone, Debug, PartialEq)]
pub struct LogEntry {
    pub term: Term,
    pub data: Vec<u8>,
}

/// Raft wire messages.
#[derive(Clone, Debug)]
pub enum Msg {
    RequestVote { term: Term, last_log_index: u64, last_log_term: Term },
    Vote { term: Term, granted: bool },
    Append { term: Term, prev_index: u64, prev_term: Term, entries: Vec<LogEntry>, leader_commit: u64 },
    AppendResp { term: Term, success: bool, match_index: u64 },
}

/// Explicit mode tracking (observable for fault scenarios and telemetry).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Follower,
    Candidate,
    Leader,
}

/// Timing configuration (seconds).
#[derive(Clone, Copy, Debug)]
pub struct RaftConfig {
    pub election_timeout_min: f64,
    pub election_timeout_max: f64,
    pub heartbeat_interval: f64,
    /// Max entries shipped per AppendEntries.
    pub max_batch: usize,
}

impl Default for RaftConfig {
    fn default() -> Self {
        RaftConfig {
            election_timeout_min: 0.15,
            election_timeout_max: 0.30,
            heartbeat_interval: 0.05,
            max_batch: 64,
        }
    }
}

/// One Raft participant.
pub struct Raft {
    id: NodeId,
    n: usize,
    cfg: RaftConfig,
    rng: Prng,

    term: Term,
    voted_for: Option<NodeId>,
    /// log[i] has index i+1 (1-based Raft indices; index 0 = empty sentinel).
    log: Vec<LogEntry>,
    commit: u64,
    delivered: u64,

    role: Role,
    leader_hint: Option<NodeId>,
    /// Elections this replica has started (monotone; telemetry).
    elections: u64,
    votes: HashSet<NodeId>,
    next_index: HashMap<NodeId, u64>,
    match_index: HashMap<NodeId, u64>,

    election_deadline: f64,
    heartbeat_due: f64,
}

impl Raft {
    pub fn new(id: NodeId, n: usize, cfg: RaftConfig, mut rng: Prng) -> Self {
        assert!(n >= 1 && id < n);
        let first_deadline = cfg.election_timeout_min
            + rng.next_f64() * (cfg.election_timeout_max - cfg.election_timeout_min);
        Raft {
            id,
            n,
            cfg,
            rng,
            term: 0,
            voted_for: None,
            log: Vec::new(),
            commit: 0,
            delivered: 0,
            role: Role::Follower,
            leader_hint: None,
            elections: 0,
            votes: HashSet::new(),
            next_index: HashMap::new(),
            match_index: HashMap::new(),
            election_deadline: first_deadline,
            heartbeat_due: 0.0,
        }
    }

    pub fn term(&self) -> Term {
        self.term
    }

    pub fn log_len(&self) -> u64 {
        self.log.len() as u64
    }

    pub fn commit_index(&self) -> u64 {
        self.commit
    }

    pub fn role(&self) -> Role {
        self.role
    }

    /// Best-known current leader (self when leading).
    pub fn leader_hint(&self) -> Option<NodeId> {
        self.leader_hint
    }

    /// Elections this replica has started.
    pub fn elections(&self) -> u64 {
        self.elections
    }

    fn peers(&self) -> impl Iterator<Item = NodeId> + '_ {
        (0..self.n).filter(move |p| *p != self.id)
    }

    fn majority(&self) -> usize {
        self.n / 2 + 1
    }

    fn last_log_term(&self) -> Term {
        self.log.last().map(|e| e.term).unwrap_or(0)
    }

    fn reset_election_deadline(&mut self, now: f64) {
        let span = self.cfg.election_timeout_max - self.cfg.election_timeout_min;
        self.election_deadline = now + self.cfg.election_timeout_min + self.rng.next_f64() * span;
    }

    fn become_follower(&mut self, term: Term, now: f64) {
        self.term = term;
        self.role = Role::Follower;
        self.voted_for = None;
        self.votes.clear();
        self.reset_election_deadline(now);
    }

    fn start_election(&mut self, now: f64) -> Vec<(NodeId, Msg)> {
        self.term += 1;
        self.elections += 1;
        self.role = Role::Candidate;
        self.voted_for = Some(self.id);
        self.votes = HashSet::from([self.id]);
        self.leader_hint = None;
        self.reset_election_deadline(now);
        if self.n == 1 {
            return self.become_leader(now);
        }
        let msg = Msg::RequestVote {
            term: self.term,
            last_log_index: self.log_len(),
            last_log_term: self.last_log_term(),
        };
        self.peers().map(|p| (p, msg.clone())).collect()
    }

    fn become_leader(&mut self, now: f64) -> Vec<(NodeId, Msg)> {
        self.role = Role::Leader;
        self.leader_hint = Some(self.id);
        let next = self.log_len() + 1;
        self.next_index = self.peers().map(|p| (p, next)).collect();
        self.match_index = self.peers().map(|p| (p, 0)).collect();
        self.heartbeat_due = now; // fire immediately
        self.broadcast_append(now)
    }

    fn append_for(&self, peer: NodeId) -> Msg {
        let next = *self.next_index.get(&peer).unwrap_or(&1);
        let prev_index = next - 1;
        let prev_term = if prev_index == 0 { 0 } else { self.log[prev_index as usize - 1].term };
        let from = prev_index as usize;
        let to = (from + self.cfg.max_batch).min(self.log.len());
        Msg::Append {
            term: self.term,
            prev_index,
            prev_term,
            entries: self.log[from..to].to_vec(),
            leader_commit: self.commit,
        }
    }

    fn broadcast_append(&mut self, now: f64) -> Vec<(NodeId, Msg)> {
        self.heartbeat_due = now + self.cfg.heartbeat_interval;
        let peers: Vec<NodeId> = self.peers().collect();
        peers.into_iter().map(|p| (p, self.append_for(p))).collect()
    }

    /// Advance commit to the highest index replicated on a majority within
    /// the current term (Raft §5.4.2: only current-term entries commit by
    /// counting).
    fn advance_commit(&mut self) {
        if self.role != Role::Leader {
            return;
        }
        for n in ((self.commit + 1)..=self.log_len()).rev() {
            if self.log[n as usize - 1].term != self.term {
                continue;
            }
            let replicas =
                1 + self.match_index.values().filter(|&&m| m >= n).count();
            if replicas >= self.majority() {
                self.commit = n;
                break;
            }
        }
        if self.n == 1 {
            self.commit = self.log_len();
        }
    }

    /// Candidate log at least as up-to-date as ours? (Raft §5.4.1)
    fn log_up_to_date(&self, last_index: u64, last_term: Term) -> bool {
        let (our_term, our_index) = (self.last_log_term(), self.log_len());
        last_term > our_term || (last_term == our_term && last_index >= our_index)
    }
}

impl ConsensusNode for Raft {
    type Msg = Msg;

    fn tick(&mut self, now: f64) -> Vec<(NodeId, Msg)> {
        match self.role {
            Role::Leader => {
                if now >= self.heartbeat_due {
                    self.broadcast_append(now)
                } else {
                    Vec::new()
                }
            }
            _ => {
                if now >= self.election_deadline {
                    self.start_election(now)
                } else {
                    Vec::new()
                }
            }
        }
    }

    fn handle(&mut self, from: NodeId, msg: Msg, now: f64) -> Vec<(NodeId, Msg)> {
        match msg {
            Msg::RequestVote { term, last_log_index, last_log_term } => {
                if term > self.term {
                    self.become_follower(term, now);
                }
                let grant = term == self.term
                    && self.role == Role::Follower
                    && self.voted_for.is_none_or(|v| v == from)
                    && self.log_up_to_date(last_log_index, last_log_term);
                if grant {
                    self.voted_for = Some(from);
                    self.reset_election_deadline(now);
                }
                vec![(from, Msg::Vote { term: self.term, granted: grant })]
            }
            Msg::Vote { term, granted } => {
                if term > self.term {
                    self.become_follower(term, now);
                    return Vec::new();
                }
                if self.role == Role::Candidate && term == self.term && granted {
                    self.votes.insert(from);
                    if self.votes.len() >= self.majority() {
                        return self.become_leader(now);
                    }
                }
                Vec::new()
            }
            Msg::Append { term, prev_index, prev_term, entries, leader_commit } => {
                if term > self.term || (term == self.term && self.role != Role::Follower) {
                    self.become_follower(term, now);
                }
                if term < self.term {
                    return vec![(
                        from,
                        Msg::AppendResp { term: self.term, success: false, match_index: 0 },
                    )];
                }
                self.leader_hint = Some(from);
                self.reset_election_deadline(now);
                // Consistency check on the entry preceding the batch.
                let prev_ok = prev_index == 0
                    || (prev_index <= self.log_len()
                        && self.log[prev_index as usize - 1].term == prev_term);
                if !prev_ok {
                    return vec![(
                        from,
                        Msg::AppendResp {
                            term: self.term,
                            success: false,
                            match_index: self.log_len().min(prev_index.saturating_sub(1)),
                        },
                    )];
                }
                // Append, truncating any conflicting suffix.
                let mut idx = prev_index as usize;
                for e in entries {
                    if idx < self.log.len() {
                        if self.log[idx].term != e.term {
                            self.log.truncate(idx);
                            self.log.push(e);
                        }
                    } else {
                        self.log.push(e);
                    }
                    idx += 1;
                }
                let match_index = idx as u64;
                if leader_commit > self.commit {
                    self.commit = leader_commit.min(match_index);
                }
                vec![(from, Msg::AppendResp { term: self.term, success: true, match_index })]
            }
            Msg::AppendResp { term, success, match_index } => {
                if term > self.term {
                    self.become_follower(term, now);
                    return Vec::new();
                }
                if self.role != Role::Leader || term != self.term {
                    return Vec::new();
                }
                if success {
                    let m = self.match_index.entry(from).or_insert(0);
                    *m = (*m).max(match_index);
                    self.next_index.insert(from, match_index + 1);
                    self.advance_commit();
                    // Ship more immediately if the follower is behind.
                    if match_index < self.log_len() {
                        return vec![(from, self.append_for(from))];
                    }
                } else {
                    // Back off next_index; the hint jumps us near the match.
                    let next = self.next_index.entry(from).or_insert(1);
                    *next = (match_index + 1).min((*next).saturating_sub(1)).max(1);
                    return vec![(from, self.append_for(from))];
                }
                Vec::new()
            }
        }
    }

    fn propose(&mut self, data: Vec<u8>, _now: f64) -> Result<(), NotLeader> {
        if self.role != Role::Leader {
            return Err(NotLeader { hint: self.leader_hint });
        }
        self.log.push(LogEntry { term: self.term, data });
        if self.n == 1 {
            self.commit = self.log_len();
        }
        Ok(())
    }

    fn take_committed(&mut self) -> Vec<Committed> {
        let mut out = Vec::new();
        while self.delivered < self.commit {
            self.delivered += 1;
            out.push(Committed {
                seq: self.delivered,
                data: self.log[self.delivered as usize - 1].data.clone(),
            });
        }
        out
    }

    fn is_leader(&self) -> bool {
        self.role == Role::Leader
    }

    fn node_id(&self) -> NodeId {
        self.id
    }

    fn epoch(&self) -> u64 {
        self.term
    }

    fn epoch_changes(&self) -> u64 {
        self.elections
    }

    /// Back up with durable state (term, vote, log) retained: leadership
    /// is dropped and must be re-earned, election timer re-anchored.
    /// `voted_for` is deliberately kept — forgetting a vote cast in the
    /// current term could elect two leaders for one term.
    fn restarted(&mut self, now: f64) {
        self.role = Role::Follower;
        self.votes.clear();
        self.leader_hint = None;
        self.reset_election_deadline(now);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::simnet::SimNet;
    use crate::util::check::check;

    /// Drive a cluster until `pred` or deadline; returns final virtual time.
    fn run_cluster(
        nodes: &mut Vec<Raft>,
        net: &mut SimNet<Msg>,
        until: f64,
        mut on_step: impl FnMut(&mut Vec<Raft>, f64),
    ) {
        let tick = 0.01;
        let mut now = 0.0;
        while now < until {
            now += tick;
            for i in 0..nodes.len() {
                for (to, m) in nodes[i].tick(now) {
                    net.send(i, to, m, now);
                }
            }
            for (from, to, msg) in net.deliver_until(now) {
                for (dest, m) in nodes[to].handle(from, msg, now) {
                    net.send(to, dest, m, now);
                }
            }
            on_step(nodes, now);
        }
    }

    fn cluster(n: usize, seed: u64) -> (Vec<Raft>, SimNet<Msg>) {
        let mut rng = Prng::new(seed);
        let nodes = (0..n)
            .map(|i| Raft::new(i, n, RaftConfig::default(), rng.fork(i as u64)))
            .collect();
        let net = SimNet::new(0.001, 0.005, 0.0, rng.fork(999));
        (nodes, net)
    }

    fn leader_of(nodes: &[Raft]) -> Option<usize> {
        nodes.iter().position(|n| n.is_leader())
    }

    #[test]
    fn single_node_self_commits() {
        let (mut nodes, mut net) = cluster(1, 1);
        run_cluster(&mut nodes, &mut net, 1.0, |_, _| {});
        assert!(nodes[0].is_leader());
        nodes[0].propose(b"x".to_vec(), 1.0).unwrap();
        let c = nodes[0].take_committed();
        assert_eq!(c.len(), 1);
        assert_eq!(c[0].data, b"x");
    }

    #[test]
    fn elects_exactly_one_leader() {
        let (mut nodes, mut net) = cluster(5, 2);
        run_cluster(&mut nodes, &mut net, 2.0, |_, _| {});
        let leaders: Vec<usize> = (0..5).filter(|&i| nodes[i].is_leader()).collect();
        assert_eq!(leaders.len(), 1, "leaders: {leaders:?}");
    }

    #[test]
    fn replicates_and_commits_on_all() {
        let (mut nodes, mut net) = cluster(3, 3);
        run_cluster(&mut nodes, &mut net, 1.5, |_, _| {});
        let l = leader_of(&nodes).expect("leader");
        for i in 0..10u8 {
            nodes[l].propose(vec![i], 1.5).unwrap();
        }
        run_cluster(&mut nodes, &mut net, 3.0, |_, _| {});
        for (i, n) in nodes.iter_mut().enumerate() {
            let data: Vec<Vec<u8>> = n.take_committed().into_iter().map(|c| c.data).collect();
            assert_eq!(data, (0..10u8).map(|i| vec![i]).collect::<Vec<_>>(), "node {i}");
        }
    }

    #[test]
    fn follower_rejects_propose() {
        let (mut nodes, mut net) = cluster(3, 4);
        run_cluster(&mut nodes, &mut net, 1.5, |_, _| {});
        let l = leader_of(&nodes).unwrap();
        let f = (0..3).find(|&i| i != l).unwrap();
        assert!(nodes[f].propose(b"x".to_vec(), 1.5).is_err());
    }

    #[test]
    fn survives_leader_crash() {
        let (mut nodes, mut net) = cluster(5, 5);
        run_cluster(&mut nodes, &mut net, 2.0, |_, _| {});
        let l0 = leader_of(&nodes).unwrap();
        nodes[l0].propose(b"pre".to_vec(), 2.0).unwrap();
        run_cluster(&mut nodes, &mut net, 3.0, |_, _| {});
        // Crash the leader (partition it away).
        net.isolate(l0);
        run_cluster(&mut nodes, &mut net, 6.0, |_, _| {});
        let l1 = (0..5).find(|&i| i != l0 && nodes[i].is_leader()).expect("new leader");
        nodes[l1].propose(b"post".to_vec(), 6.0).unwrap();
        run_cluster(&mut nodes, &mut net, 8.0, |_, _| {});
        // All reachable nodes committed both entries in order.
        for i in (0..5).filter(|&i| i != l0) {
            let data: Vec<Vec<u8>> =
                nodes[i].take_committed().into_iter().map(|c| c.data).collect();
            assert_eq!(data, vec![b"pre".to_vec(), b"post".to_vec()], "node {i}");
        }
    }

    #[test]
    fn property_committed_prefixes_agree() {
        check("raft-agreement", 6, |rng| {
            let seed = rng.next_u64();
            let (mut nodes, mut net) = cluster(3, seed);
            let mut proposed = 0u8;
            run_cluster(&mut nodes, &mut net, 6.0, |nodes, now| {
                if proposed < 20 {
                    if let Some(l) = nodes.iter().position(|n| n.is_leader()) {
                        if nodes[l].propose(vec![proposed], now).is_ok() {
                            proposed += 1;
                        }
                    }
                }
            });
            let logs: Vec<Vec<Committed>> =
                nodes.iter_mut().map(|n| n.take_committed()).collect();
            // Agreement: any two committed sequences are prefix-compatible.
            for a in &logs {
                for b in &logs {
                    let common = a.len().min(b.len());
                    assert_eq!(&a[..common], &b[..common]);
                }
            }
            // Liveness under a clean network: everything proposed commits.
            assert!(logs.iter().any(|l| l.len() == proposed as usize));
        });
    }
}
