//! Unified observability for the sharded pipeline: a metrics registry,
//! per-transaction lifecycle tracing, and a flight recorder.
//!
//! Before this module, every subsystem grew its own snapshot struct
//! (`mempool::StatsSnapshot`, `fabric::ValidationSnapshot`, relay
//! snapshots, ad-hoc `caliper::Report` columns) with no shared naming and
//! no way to answer "why was this one transaction slow?". Telemetry is
//! the one vocabulary they all report in:
//!
//! * [`Registry`] — pull-model metrics. Subsystems register weak
//!   collectors; [`Registry::render_prometheus`] / [`Registry::render_json`]
//!   expose everything on demand (the `telemetry` subcommand, end-of-run
//!   dumps from the caliper drivers).
//! * [`Tracer`] — a lock-free span recorder stamping each transaction at
//!   every pipeline stage on an injectable [`Clock`], aggregated into
//!   per-stage latency histograms.
//! * [`FlightRecorder`] — retains the last N completed lifecycles and
//!   freezes anomalous ones (commit latency beyond a multiple of the
//!   rolling p95, or any mid-pipeline abort) with their full stage
//!   breakdown.
//!
//! # Metric naming convention
//!
//! Every metric is `scalesfl_<subsystem>_<name>`, where `<subsystem>` is
//! the module that owns the number (`mempool`, `relay`, `validator`,
//! `orderer`, `consensus`, `trace`, `flight`). Counters end in `_total`;
//! gauges and summaries end in a unit (`_seconds`, `_bytes`) or a bare
//! noun for dimensionless levels (`_depth`). Per-shard series carry a
//! `channel="<shard>"` label; alternatives within one number use a
//! discriminating label (`reason=`, `stage=`) rather than new names.
//! Example: `scalesfl_mempool_admitted_total{channel="shard0"}`.
//!
//! The `scalesfl_consensus_*` family is exported by
//! [`crate::consensus::ConsensusTelemetry`] (one collector per replica
//! cluster, registered by the orderer driver) and carries a
//! `protocol="raft"|"pbft"` label throughout:
//!
//! | metric | kind | meaning |
//! |--------|------|---------|
//! | `scalesfl_consensus_elections_total` / `_view_changes_total` | counter | epoch changes, named per protocol |
//! | `scalesfl_consensus_epoch` | gauge | current term / view (max over replicas) |
//! | `scalesfl_consensus_leader_changes_total` | counter | distinct leader handovers observed |
//! | `scalesfl_consensus_current_leader` | gauge | leader node id, `-1` while none is reachable |
//! | `scalesfl_consensus_commits_total` | counter | payloads committed through the cluster |
//! | `scalesfl_consensus_divergence_total` | counter | same-slot digest mismatches across replicas (must stay 0) |
//! | `scalesfl_consensus_messages_total{event=}` | counter | transport accounting: `sent`, `delivered`, `fault_dropped`, `in_flight` |
//! | `scalesfl_consensus_driver_lost_messages` | gauge | sent − delivered − fault_dropped − in_flight (must stay 0) |
//! | `scalesfl_consensus_commit_seconds{channel=}` | summary | propose→commit latency per channel, across faults |
//!
//! # Span stages
//!
//! A transaction lifecycle is stamped at up to seven stages, in pipeline
//! order (see [`Stage`]):
//!
//! | stage          | stamped by | meaning |
//! |----------------|------------|---------|
//! | `submit`       | `Gateway::submit` | registered with the commit demux, handed to the orderer |
//! | `admit`        | `ShardMempool` | passed admission control (home lane or ingress forward) |
//! | `relay_hop`    | `Relay` | a cross-shard hop delivered (first hop's time; hops counted) |
//! | `batch_pull`   | orderer driver | pulled into a proposed batch |
//! | `prevalidate`  | `BlockValidator` | endorsement/signature checks done (crypto replica only) |
//! | `apply`        | `Peer` | MVCC check + state apply decided the validation code |
//! | `commit_event` | `CommitWaiter` | commit event reached the gateway demux |
//!
//! Stamps are first-write-wins, so replicas and re-deliveries never move
//! a stage forward and completed traces are monotone. Lifecycles end via
//! `complete_commit` (commit event), `abort` (relay drop, stale drop,
//! shutdown — frozen by the flight recorder with a reason), or `discard`
//! (admission rejects: fully accounted by mempool counters already).
//!
//! Instrumentation is process-wide through [`Telemetry::global`] and
//! gated by one relaxed atomic load ([`Telemetry::enabled`]); the
//! telemetry bench (`benches/telemetry.rs`) holds the enabled-vs-disabled
//! admission overhead within 5%.

pub mod flight;
pub mod registry;
pub mod trace;

pub use flight::{FlightConfig, FlightRecorder};
pub use registry::{Registry, Sample, Value};
pub use trace::{Stage, StageSnapshot, TraceOutcome, Tracer, TxTrace, STAGES, STAGE_COUNT};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, OnceLock};

use crate::ledger::tx::TxId;
use crate::util::clock::{Clock, SystemClock};

/// The telemetry facade: one registry + one tracer (with its flight
/// recorder) + an on/off gate. Subsystems use the process-wide instance
/// from [`Telemetry::global`]; tests build private ones on a
/// `VirtualClock`.
pub struct Telemetry {
    enabled: AtomicBool,
    registry: Registry,
    tracer: Tracer,
}

impl Telemetry {
    pub fn new() -> Telemetry {
        Telemetry::with_parts(SystemClock::shared(), FlightConfig::default())
    }

    pub fn with_parts(clock: Arc<dyn Clock>, flight: FlightConfig) -> Telemetry {
        let tracer = Tracer::with_parts(clock, flight);
        let registry = Registry::new();
        tracer.register_collector(&registry);
        Telemetry { enabled: AtomicBool::new(true), registry, tracer }
    }

    /// The process-wide instance every pipeline component stamps into.
    pub fn global() -> &'static Telemetry {
        static GLOBAL: OnceLock<Telemetry> = OnceLock::new();
        GLOBAL.get_or_init(Telemetry::new)
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Disable/enable all lifecycle stamping (collectors still render).
    /// The benches flip this to measure instrumentation overhead.
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    pub fn flight(&self) -> &FlightRecorder {
        self.tracer.flight()
    }

    // Enabled-gated shims over the tracer — the instrumentation points
    // call these so a disabled telemetry layer costs one relaxed load.

    #[inline]
    pub fn stamp(&self, id: &TxId, stage: Stage) {
        if self.enabled() {
            self.tracer.stamp(id, stage);
        }
    }

    #[inline]
    pub fn stamp_hop(&self, id: &TxId) {
        if self.enabled() {
            self.tracer.stamp_hop(id);
        }
    }

    #[inline]
    pub fn complete_commit(&self, id: &TxId) {
        if self.enabled() {
            self.tracer.complete_commit(id);
        }
    }

    #[inline]
    pub fn abort(&self, id: &TxId, reason: &'static str) {
        if self.enabled() {
            self.tracer.abort(id, reason);
        }
    }

    #[inline]
    pub fn discard(&self, id: &TxId) {
        if self.enabled() {
            self.tracer.discard(id);
        }
    }
}

impl Default for Telemetry {
    fn default() -> Self {
        Telemetry::new()
    }
}

/// Shorthand for [`Telemetry::global`].
#[inline]
pub fn global() -> &'static Telemetry {
    Telemetry::global()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::msp::{CertificateAuthority, MemberId};
    use crate::fabric::chaincode::{Chaincode, TxContext};
    use crate::fabric::endorsement::EndorsementPolicy;
    use crate::fabric::orderer::{OrderingService, OrdererConfig};
    use crate::fabric::peer::Peer;
    use crate::fabric::Gateway;
    use crate::ledger::tx::Proposal;
    use crate::util::prng::Prng;
    use std::time::Duration;

    struct Put;
    impl Chaincode for Put {
        fn name(&self) -> &str {
            "kv"
        }
        fn invoke(
            &self,
            ctx: &mut TxContext<'_>,
            _f: &str,
            args: &[String],
        ) -> Result<Vec<u8>, String> {
            ctx.put(&args[0], b"v".to_vec());
            Ok(vec![])
        }
    }

    fn prop(key: &str, nonce: u64) -> Proposal {
        Proposal {
            channel: "ch".into(),
            chaincode: "kv".into(),
            function: "Put".into(),
            args: vec![key.into()],
            creator: MemberId::new("client"),
            nonce,
        }
    }

    /// The acceptance-criteria render test: a run through the real
    /// pipeline (ingress shard + relay hop + ordering + validation +
    /// commit demux) leaves labelled metrics from the mempool, the
    /// validator, and the relay in the process-wide registry.
    #[test]
    fn pipeline_metrics_expose_through_global_registry() {
        let ca = CertificateAuthority::new();
        let mut rng = Prng::new(61);
        let peers: Vec<Arc<Peer>> = (0..2)
            .map(|i| {
                let cred = ca.enroll(MemberId::new(format!("org{i}.peer")), &mut rng);
                Peer::new(cred, ca.clone())
            })
            .collect();
        let members: Vec<MemberId> = peers.iter().map(|p| p.member.clone()).collect();
        for p in &peers {
            p.join_channel("ch", EndorsementPolicy::MajorityOf(members.clone()));
            p.install_chaincode("ch", Arc::new(Put)).unwrap();
        }
        let cfg = OrdererConfig {
            batch_timeout: Duration::from_millis(10),
            tick: Duration::from_millis(1),
            relay: Some(crate::mempool::RelayConfig {
                base_latency: Duration::from_millis(2),
                latency_spread: Duration::from_millis(2),
                jitter: Duration::from_millis(1),
                seed: 61,
            }),
            ..OrdererConfig::default()
        };
        let orderer = OrderingService::start(cfg, peers.clone(), 61);
        let mut gw = Gateway::new(peers, orderer);
        // Submit through a foreign ingress so the relay carries every tx.
        gw.ingress = Some("edge".into());
        for i in 1..=6u64 {
            let out = gw.submit(&prop(&format!("k{i}"), i)).wait();
            assert!(out.is_valid(), "tx {i}: {out:?}");
        }

        let text = global().registry().render_prometheus();
        // Mempool: home-lane admissions on "ch", forwards out of "edge".
        assert!(text.contains("scalesfl_mempool_admitted_total{channel=\"ch\"}"), "{text}");
        assert!(text.contains("scalesfl_mempool_forwarded_total{channel=\"edge\"}"), "{text}");
        // Validator and relay totals.
        assert!(text.contains("scalesfl_validator_txs_total"), "{text}");
        assert!(text.contains("scalesfl_relay_delivered_total"), "{text}");
        // Orderer progress and the tracer's own series.
        assert!(text.contains("scalesfl_orderer_blocks_cut_total"), "{text}");
        assert!(text.contains("scalesfl_trace_stage_seconds"), "{text}");
        assert!(text.contains("scalesfl_trace_completed_total"), "{text}");

        // Every committed tx completed a lifecycle through the demux.
        assert!(global().tracer().stage_snapshot().completed >= 6);

        // JSON exposition mirrors the same samples.
        let j = global().registry().render_json();
        let metrics = j.get("metrics").unwrap().as_arr().unwrap();
        assert!(!metrics.is_empty());
        assert!(metrics.iter().any(|m| {
            m.get("name").map(|n| n.as_str() == Some("scalesfl_relay_delivered_total"))
                == Some(true)
        }));
    }
}
