//! Per-transaction lifecycle tracing: a lock-free, atomic-slot table that
//! stamps every transaction at each pipeline stage it passes.
//!
//! Design constraints, in order:
//!
//! 1. **The hot path must be wait-free-ish and allocation-free.** A stamp
//!    is a handful of relaxed/acquire atomic operations on a fixed slot
//!    table — no locks, no heap. The admission path stamps every admitted
//!    transaction, and the telemetry bench gates its cost at ≤ 5% of
//!    admitted-tx throughput.
//! 2. **Deterministic under `VirtualClock`.** All stamps read one
//!    injectable [`Clock`], so virtual-clock tests replay stage timings
//!    exactly.
//! 3. **Best-effort beats blocking.** Under pathological load (more live
//!    lifecycles than slots) the table steals a slot inside the probe
//!    window (`evicted` counter) or, failing the steal race, drops the
//!    stamp (`dropped` counter). Tracing never stalls the pipeline.
//!
//! Slot protocol: a slot's `key` is 0 when free, the first 8 bytes of the
//! transaction id when owned, and `u64::MAX` (tombstone) while a completer
//! extracts it. The first stamp for an unknown id claims a free slot by
//! CAS; stage timestamps are written first-write-wins (peer replicas and
//! relay re-deliveries must not move a stamp forward), encoded as
//! `1 + nanoseconds` so a `VirtualClock` stamp at t=0 is distinguishable
//! from "unset". Completion/abort tombstones the slot, reads the stamps
//! out, and frees it. A stamp racing an extraction can leak into the
//! slot's next occupant — accepted and documented: this is a tracing
//! facility, not an accounting one (the accounting counters live in
//! `mempool::stats` / `fabric::validator`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::ledger::tx::TxId;
use crate::util::clock::Clock;
use crate::util::histogram::Histogram;
use crate::util::json::Json;

use super::flight::{FlightConfig, FlightRecorder};
use super::registry::{Registry, Sample};

/// Pipeline stages a transaction is stamped at, in pipeline order: a
/// monotone lifecycle visits a subset of these with non-decreasing
/// timestamps. `RelayHop` sits between ingress admission and batch pull
/// because a cross-shard transaction is admitted (for forwarding) at its
/// ingress pool before any hop is scheduled.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Stage {
    /// Gateway registered the tx with the commit demux and handed it to
    /// the orderer (`Gateway::submit`).
    Submit = 0,
    /// Admission control accepted the envelope — into a lane slot, or for
    /// cross-shard forwarding at an ingress pool.
    Admit = 1,
    /// A cross-shard relay hop delivered the envelope toward its home
    /// pool (`TxTrace::hops` counts them; the stamp keeps the first).
    RelayHop = 2,
    /// The orderer driver pulled the envelope into a proposed batch.
    BatchPull = 3,
    /// Endorsement-policy / signature pre-validation finished for the
    /// envelope (stamped by the replica that did the crypto, not by
    /// cache-hit replicas).
    Prevalidate = 4,
    /// MVCC check + state apply decided the validation code (first
    /// replica wins the stamp).
    Apply = 5,
    /// The commit event reached a gateway's `CommitWaiter` demux — the
    /// gateway-observed end of the lifecycle, separable from the
    /// peer-observed `Apply` time.
    CommitEvent = 6,
}

/// Number of distinct stages.
pub const STAGE_COUNT: usize = 7;

/// Every stage, in pipeline order.
pub const STAGES: [Stage; STAGE_COUNT] = [
    Stage::Submit,
    Stage::Admit,
    Stage::RelayHop,
    Stage::BatchPull,
    Stage::Prevalidate,
    Stage::Apply,
    Stage::CommitEvent,
];

impl Stage {
    pub fn index(self) -> usize {
        self as usize
    }

    /// Metric-label spelling (`scalesfl_trace_stage_seconds{stage=...}`).
    pub fn name(self) -> &'static str {
        match self {
            Stage::Submit => "submit",
            Stage::Admit => "admit",
            Stage::RelayHop => "relay_hop",
            Stage::BatchPull => "batch_pull",
            Stage::Prevalidate => "prevalidate",
            Stage::Apply => "apply",
            Stage::CommitEvent => "commit_event",
        }
    }
}

/// How a recorded lifecycle ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceOutcome {
    /// Commit event observed.
    Completed,
    /// Died mid-pipeline; the reason is a short static tag
    /// (`"relay_drop"`, `"stale_drop"`, `"reject"`, ...).
    Aborted(&'static str),
}

/// A finished (completed or aborted) transaction lifecycle.
#[derive(Clone, Debug)]
pub struct TxTrace {
    pub tx_id: TxId,
    /// Cross-shard relay hops the envelope took (0 for direct routing).
    pub hops: u64,
    /// Per-stage timestamps in clock seconds (`None` = stage not visited).
    pub stamps: [Option<f64>; STAGE_COUNT],
    pub outcome: TraceOutcome,
}

impl TxTrace {
    /// The visited stages with their timestamps, in pipeline order.
    pub fn stages(&self) -> Vec<(Stage, f64)> {
        STAGES.iter().filter_map(|&st| self.stamps[st.index()].map(|t| (st, t))).collect()
    }

    pub fn begin(&self) -> Option<f64> {
        self.stages().first().map(|&(_, t)| t)
    }

    pub fn end(&self) -> Option<f64> {
        self.stages().last().map(|&(_, t)| t)
    }

    /// First stamp to last stamp (for completed traces: submission-side
    /// entry to gateway-observed commit).
    pub fn latency(&self) -> Option<f64> {
        match (self.begin(), self.end()) {
            (Some(b), Some(e)) => Some(e - b),
            _ => None,
        }
    }

    /// Timestamps non-decreasing in pipeline order?
    pub fn is_monotone(&self) -> bool {
        self.stages().windows(2).all(|w| w[0].1 <= w[1].1)
    }

    /// Stage breakdown dump (the flight recorder's exposition format).
    pub fn to_json(&self) -> Json {
        let begin = self.begin().unwrap_or(0.0);
        let stages: Vec<Json> = self
            .stages()
            .iter()
            .map(|&(st, t)| {
                Json::obj().set("stage", st.name()).set("t_s", t).set("offset_s", t - begin)
            })
            .collect();
        let outcome = match self.outcome {
            TraceOutcome::Completed => "completed".to_string(),
            TraceOutcome::Aborted(reason) => format!("aborted:{reason}"),
        };
        Json::obj()
            .set("tx_id", self.tx_id.hex())
            .set("outcome", outcome)
            .set("hops", self.hops)
            .set("latency_s", self.latency().unwrap_or(0.0))
            .set("stages", stages)
    }
}

/// Linear-probe distance before the table steals a slot.
const PROBE_WINDOW: usize = 16;

/// Default slot count (~8k live lifecycles; 72 B per slot).
const DEFAULT_SLOTS: usize = 8192;

/// Slot `key` value while a completer owns the slot for extraction.
const TOMBSTONE: u64 = u64::MAX;

struct Slot {
    /// 0 = free, `TOMBSTONE` = mid-extraction, else the tx key.
    key: AtomicU64,
    hops: AtomicU64,
    /// 0 = unset, else `1 + nanoseconds` on the tracer's clock.
    stamps: [AtomicU64; STAGE_COUNT],
}

impl Slot {
    fn new() -> Slot {
        Slot {
            key: AtomicU64::new(0),
            hops: AtomicU64::new(0),
            stamps: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    fn clear_payload(&self) {
        self.hops.store(0, Ordering::Relaxed);
        for s in &self.stamps {
            s.store(0, Ordering::Relaxed);
        }
    }
}

/// Slot key: the first 8 bytes of the (uniform, SHA-256) transaction id.
/// 0 is reserved for "free", so the measure-zero all-zero prefix maps to 1.
fn key_of(id: &TxId) -> u64 {
    let k = u64::from_le_bytes(id.0[..8].try_into().expect("8-byte prefix"));
    if k == 0 {
        1
    } else {
        k
    }
}

struct StageHists {
    /// `stages[i]` holds the latency from the *previous visited stage* to
    /// stage `i` (the first visited stage is the epoch and records
    /// nothing), fed at lifecycle completion.
    stages: [Histogram; STAGE_COUNT],
    /// First stamp → commit event, per completed lifecycle.
    commit_latency: Histogram,
}

impl StageHists {
    fn new() -> StageHists {
        StageHists {
            stages: std::array::from_fn(|_| Histogram::default()),
            commit_latency: Histogram::default(),
        }
    }
}

struct Shared {
    slots: Vec<Slot>,
    clock: Arc<dyn Clock>,
    hists: Mutex<StageHists>,
    flight: FlightRecorder,
    completed: AtomicU64,
    aborted: AtomicU64,
    evicted: AtomicU64,
    dropped: AtomicU64,
}

/// Point-in-time copy of the tracer's aggregates.
#[derive(Clone, Debug)]
pub struct StageSnapshot {
    /// Per-stage arrival latencies (from the previous visited stage), all
    /// stages in pipeline order.
    pub stages: Vec<(Stage, Histogram)>,
    pub commit_latency: Histogram,
    /// Monotone lifecycle counters (never reset by `take_stage_snapshot`).
    pub completed: u64,
    pub aborted: u64,
    pub evicted: u64,
    pub dropped: u64,
}

impl StageSnapshot {
    pub fn stage(&self, st: Stage) -> &Histogram {
        &self.stages[st.index()].1
    }

    pub fn to_json(&self) -> Json {
        let mut stages = Json::obj();
        for (st, h) in &self.stages {
            stages = stages.set(
                st.name(),
                Json::obj()
                    .set("count", h.count())
                    .set("mean_s", h.mean())
                    .set("p95_s", h.quantile(0.95).unwrap_or(0.0))
                    .set("max_s", h.max()),
            );
        }
        Json::obj()
            .set("completed", self.completed)
            .set("aborted", self.aborted)
            .set("evicted", self.evicted)
            .set("dropped", self.dropped)
            .set("commit_latency_p95_s", self.commit_latency.quantile(0.95).unwrap_or(0.0))
            .set("stages", stages)
    }
}

/// The lock-free span recorder. Cheap to clone-share via its inner `Arc`;
/// the process-wide instance lives in [`super::Telemetry::global`].
pub struct Tracer {
    shared: Arc<Shared>,
}

impl Tracer {
    pub fn new(clock: Arc<dyn Clock>) -> Tracer {
        Tracer::with_capacity(clock, DEFAULT_SLOTS, FlightConfig::default())
    }

    pub fn with_parts(clock: Arc<dyn Clock>, flight: FlightConfig) -> Tracer {
        Tracer::with_capacity(clock, DEFAULT_SLOTS, flight)
    }

    pub fn with_capacity(clock: Arc<dyn Clock>, slots: usize, flight: FlightConfig) -> Tracer {
        let n = slots.max(PROBE_WINDOW);
        Tracer {
            shared: Arc::new(Shared {
                slots: (0..n).map(|_| Slot::new()).collect(),
                clock,
                hists: Mutex::new(StageHists::new()),
                flight: FlightRecorder::new(flight),
                completed: AtomicU64::new(0),
                aborted: AtomicU64::new(0),
                evicted: AtomicU64::new(0),
                dropped: AtomicU64::new(0),
            }),
        }
    }

    pub fn flight(&self) -> &FlightRecorder {
        &self.shared.flight
    }

    fn encode_now(&self) -> u64 {
        1 + (self.shared.clock.now() * 1e9) as u64
    }

    /// Stamp `stage` for `id` now. The first stamp for an unknown id
    /// begins its lifecycle (claims a slot); per-stage, the first write
    /// wins.
    pub fn stamp(&self, id: &TxId, stage: Stage) {
        let t = self.encode_now();
        self.stamp_at(id, stage, t, false);
    }

    /// Stamp a relay hop: first-hop timestamp plus a hop count.
    pub fn stamp_hop(&self, id: &TxId) {
        let t = self.encode_now();
        self.stamp_at(id, Stage::RelayHop, t, true);
    }

    fn stamp_at(&self, id: &TxId, stage: Stage, t: u64, hop: bool) {
        let s = &self.shared;
        let key = key_of(id);
        let n = s.slots.len();
        let start = (key as usize) % n;
        for i in 0..PROBE_WINDOW {
            let slot = &s.slots[(start + i) % n];
            let cur = slot.key.load(Ordering::Acquire);
            if cur == key {
                write_stamp(slot, stage, t, hop);
                return;
            }
            if cur == 0 {
                match slot.key.compare_exchange(0, key, Ordering::AcqRel, Ordering::Acquire) {
                    Ok(_) => {
                        write_stamp(slot, stage, t, hop);
                        return;
                    }
                    Err(won) if won == key => {
                        write_stamp(slot, stage, t, hop);
                        return;
                    }
                    // Lost the free slot to a different tx; keep probing.
                    Err(_) => continue,
                }
            }
        }
        // Probe window exhausted: steal the window's first slot
        // (best-effort eviction of whatever lifecycle holds it — under
        // synthetic open-loop load that is almost always an abandoned
        // trace that would never complete anyway).
        let slot = &s.slots[start];
        let cur = slot.key.load(Ordering::Acquire);
        if cur == key {
            write_stamp(slot, stage, t, hop);
        } else if cur != 0
            && cur != TOMBSTONE
            && slot.key.compare_exchange(cur, key, Ordering::AcqRel, Ordering::Acquire).is_ok()
        {
            s.evicted.fetch_add(1, Ordering::Relaxed);
            slot.clear_payload();
            write_stamp(slot, stage, t, hop);
        } else {
            s.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn find(&self, key: u64) -> Option<&Slot> {
        let s = &self.shared;
        let n = s.slots.len();
        let start = (key as usize) % n;
        (0..PROBE_WINDOW)
            .map(|i| &s.slots[(start + i) % n])
            .find(|slot| slot.key.load(Ordering::Acquire) == key)
    }

    /// Tombstone the slot, read the lifecycle out, and free it. `None`
    /// when another completer won the race (or the slot was evicted).
    fn extract(slot: &Slot, key: u64, id: TxId, outcome: TraceOutcome) -> Option<TxTrace> {
        slot.key.compare_exchange(key, TOMBSTONE, Ordering::AcqRel, Ordering::Acquire).ok()?;
        let mut stamps = [None; STAGE_COUNT];
        for (i, s) in slot.stamps.iter().enumerate() {
            let v = s.load(Ordering::Acquire);
            if v != 0 {
                stamps[i] = Some((v - 1) as f64 / 1e9);
            }
        }
        let hops = slot.hops.load(Ordering::Relaxed);
        slot.clear_payload();
        slot.key.store(0, Ordering::Release);
        Some(TxTrace { tx_id: id, hops, stamps, outcome })
    }

    /// Stamp the commit event and finish the lifecycle: feed the stage
    /// histograms and hand the trace to the flight recorder. Unlike
    /// [`Tracer::stamp`] this never claims a slot — a commit event for an
    /// untracked tx (second demux on the channel, tracing enabled
    /// mid-flight) is a silent no-op, not a garbage lifecycle.
    pub fn complete_commit(&self, id: &TxId) -> Option<TxTrace> {
        let key = key_of(id);
        let slot = self.find(key)?;
        let t = self.encode_now();
        let _ = slot.stamps[Stage::CommitEvent.index()].compare_exchange(
            0,
            t,
            Ordering::AcqRel,
            Ordering::Acquire,
        );
        let trace = Tracer::extract(slot, key, *id, TraceOutcome::Completed)?;
        self.shared.completed.fetch_add(1, Ordering::Relaxed);
        self.record_completed(&trace);
        Some(trace)
    }

    fn record_completed(&self, trace: &TxTrace) {
        let mut h = self.shared.hists.lock().unwrap();
        let mut prev: Option<f64> = None;
        for (stage, t) in trace.stages() {
            if let Some(p) = prev {
                h.stages[stage.index()].record((t - p).max(0.0));
            }
            prev = Some(t);
        }
        if let Some(lat) = trace.latency() {
            h.commit_latency.record(lat);
        }
        drop(h);
        self.shared.flight.on_complete(trace.clone());
    }

    /// Kill a lifecycle mid-pipeline (relay drop, stale drop, shutdown
    /// flush): the partial trace is frozen into the flight recorder with
    /// `reason`. No-op for untracked ids.
    pub fn abort(&self, id: &TxId, reason: &'static str) -> Option<TxTrace> {
        let key = key_of(id);
        let slot = self.find(key)?;
        let trace = Tracer::extract(slot, key, *id, TraceOutcome::Aborted(reason))?;
        self.shared.aborted.fetch_add(1, Ordering::Relaxed);
        self.shared.flight.on_abort(trace.clone());
        Some(trace)
    }

    /// Free a lifecycle without recording it anywhere. For outcomes that
    /// are already fully accounted elsewhere and carry no latency signal
    /// (admission rejects resolved at submit time).
    pub fn discard(&self, id: &TxId) {
        let key = key_of(id);
        if let Some(slot) = self.find(key) {
            let _ = Tracer::extract(slot, key, *id, TraceOutcome::Completed);
        }
    }

    /// Wipe every live slot (benchmarks/tests that reuse the process-wide
    /// tracer across measurement reps). Aggregates are untouched.
    pub fn reset(&self) {
        for slot in &self.shared.slots {
            let cur = slot.key.load(Ordering::Acquire);
            if cur != 0
                && cur != TOMBSTONE
                && slot
                    .key
                    .compare_exchange(cur, TOMBSTONE, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
            {
                slot.clear_payload();
                slot.key.store(0, Ordering::Release);
            }
        }
    }

    /// Live (claimed, not yet completed) lifecycles — a table scan; for
    /// tests and exposition, not hot paths.
    pub fn live(&self) -> usize {
        self.shared
            .slots
            .iter()
            .filter(|s| {
                let k = s.key.load(Ordering::Relaxed);
                k != 0 && k != TOMBSTONE
            })
            .count()
    }

    /// Copy the aggregates.
    pub fn stage_snapshot(&self) -> StageSnapshot {
        let h = self.shared.hists.lock().unwrap();
        self.snapshot_from(&h)
    }

    /// Copy the aggregates and reset the *histograms* for the next
    /// measurement window (caliper rounds report per-round stage
    /// latencies, not process totals). The lifecycle counters stay
    /// monotone — they are exposed as Prometheus counters.
    pub fn take_stage_snapshot(&self) -> StageSnapshot {
        let mut h = self.shared.hists.lock().unwrap();
        let snap = self.snapshot_from(&h);
        *h = StageHists::new();
        snap
    }

    fn snapshot_from(&self, h: &StageHists) -> StageSnapshot {
        StageSnapshot {
            stages: STAGES.iter().map(|&st| (st, h.stages[st.index()].clone())).collect(),
            commit_latency: h.commit_latency.clone(),
            completed: self.shared.completed.load(Ordering::Relaxed),
            aborted: self.shared.aborted.load(Ordering::Relaxed),
            evicted: self.shared.evicted.load(Ordering::Relaxed),
            dropped: self.shared.dropped.load(Ordering::Relaxed),
        }
    }

    /// Register this tracer's metrics (lifecycle counters, per-stage
    /// latency summaries, flight-recorder gauges) with `registry`. Weakly:
    /// a dropped tracer's collector prunes itself at the next render.
    pub(crate) fn register_collector(&self, registry: &Registry) {
        let w = Arc::downgrade(&self.shared);
        registry.register(move || {
            let s = w.upgrade()?;
            let mut out = vec![
                Sample::counter(
                    "scalesfl_trace_completed_total",
                    Vec::new(),
                    s.completed.load(Ordering::Relaxed) as f64,
                ),
                Sample::counter(
                    "scalesfl_trace_aborted_total",
                    Vec::new(),
                    s.aborted.load(Ordering::Relaxed) as f64,
                ),
                Sample::counter(
                    "scalesfl_trace_evicted_total",
                    Vec::new(),
                    s.evicted.load(Ordering::Relaxed) as f64,
                ),
                Sample::counter(
                    "scalesfl_trace_dropped_total",
                    Vec::new(),
                    s.dropped.load(Ordering::Relaxed) as f64,
                ),
            ];
            {
                let h = s.hists.lock().unwrap();
                for st in STAGES {
                    out.push(Sample::summary(
                        "scalesfl_trace_stage_seconds",
                        vec![("stage".to_string(), st.name().to_string())],
                        &h.stages[st.index()],
                    ));
                }
                out.push(Sample::summary(
                    "scalesfl_trace_commit_latency_seconds",
                    Vec::new(),
                    &h.commit_latency,
                ));
            }
            out.push(Sample::gauge(
                "scalesfl_flight_retained",
                Vec::new(),
                s.flight.retained() as f64,
            ));
            out.push(Sample::gauge(
                "scalesfl_flight_anomalies",
                Vec::new(),
                s.flight.anomaly_count() as f64,
            ));
            out.push(Sample::gauge(
                "scalesfl_flight_rolling_p95_seconds",
                Vec::new(),
                s.flight.rolling_p95().unwrap_or(0.0),
            ));
            Some(out)
        });
    }
}

fn write_stamp(slot: &Slot, stage: Stage, t: u64, hop: bool) {
    // First write wins: replicas / re-deliveries must not move a stage
    // stamp forward, so the stage list stays monotone at completion.
    let _ = slot.stamps[stage.index()].compare_exchange(0, t, Ordering::AcqRel, Ordering::Acquire);
    if hop {
        slot.hops.fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::Digest;
    use crate::util::clock::VirtualClock;
    use std::time::Duration;

    fn txid(n: u64) -> TxId {
        let mut b = [0u8; 32];
        b[..8].copy_from_slice(&n.to_le_bytes());
        Digest(b)
    }

    fn virtual_tracer() -> (Arc<VirtualClock>, Tracer) {
        let clock = Arc::new(VirtualClock::new());
        let tracer = Tracer::with_parts(
            Arc::clone(&clock) as Arc<dyn Clock>,
            FlightConfig { retain: 2048, ..FlightConfig::default() },
        );
        (clock, tracer)
    }

    #[test]
    fn lifecycle_records_all_stages_in_order() {
        let (clock, tracer) = virtual_tracer();
        let id = txid(7);
        tracer.stamp(&id, Stage::Submit);
        clock.advance(Duration::from_millis(1));
        tracer.stamp(&id, Stage::Admit);
        clock.advance(Duration::from_millis(2));
        tracer.stamp_hop(&id);
        clock.advance(Duration::from_millis(3));
        tracer.stamp(&id, Stage::BatchPull);
        clock.advance(Duration::from_millis(4));
        tracer.stamp(&id, Stage::Prevalidate);
        clock.advance(Duration::from_millis(5));
        tracer.stamp(&id, Stage::Apply);
        clock.advance(Duration::from_millis(6));
        let trace = tracer.complete_commit(&id).expect("completed");
        assert_eq!(trace.outcome, TraceOutcome::Completed);
        assert_eq!(trace.hops, 1);
        let stages: Vec<Stage> = trace.stages().iter().map(|&(s, _)| s).collect();
        assert_eq!(stages, STAGES.to_vec());
        assert!(trace.is_monotone(), "{trace:?}");
        assert!((trace.latency().unwrap() - 0.021).abs() < 1e-9);
        // Slot freed: a second completion finds nothing.
        assert!(tracer.complete_commit(&id).is_none());
        assert_eq!(tracer.live(), 0);
        let snap = tracer.stage_snapshot();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.stage(Stage::Admit).count(), 1);
        // Stage latencies are f64 subtractions (e.g. 0.021 - 0.015), so
        // compare with a tolerance like the commit-latency check above —
        // quantile() clamps to the observed max, rounding error included.
        assert!((snap.stage(Stage::Admit).quantile(0.5).unwrap() - 0.001).abs() < 1e-12);
        assert!((snap.stage(Stage::CommitEvent).quantile(0.5).unwrap() - 0.006).abs() < 1e-12);
        assert_eq!(snap.commit_latency.count(), 1);
    }

    #[test]
    fn first_stamp_wins_per_stage() {
        let (clock, tracer) = virtual_tracer();
        let id = txid(9);
        tracer.stamp(&id, Stage::Apply);
        clock.advance(Duration::from_secs(1));
        tracer.stamp(&id, Stage::Apply); // replica re-stamp: ignored
        let trace = tracer.complete_commit(&id).unwrap();
        assert_eq!(trace.stamps[Stage::Apply.index()], Some(0.0));
    }

    #[test]
    fn untracked_completion_and_abort_are_noops() {
        let (_clock, tracer) = virtual_tracer();
        assert!(tracer.complete_commit(&txid(1)).is_none());
        assert!(tracer.abort(&txid(2), "reject").is_none());
        assert_eq!(tracer.live(), 0);
        let snap = tracer.stage_snapshot();
        assert_eq!((snap.completed, snap.aborted), (0, 0));
    }

    #[test]
    fn discard_frees_without_recording() {
        let (_clock, tracer) = virtual_tracer();
        let id = txid(3);
        tracer.stamp(&id, Stage::Submit);
        assert_eq!(tracer.live(), 1);
        tracer.discard(&id);
        assert_eq!(tracer.live(), 0);
        let snap = tracer.stage_snapshot();
        assert_eq!((snap.completed, snap.aborted), (0, 0));
        assert!(tracer.flight().completed().is_empty());
    }

    #[test]
    fn full_window_steals_a_slot() {
        let clock = Arc::new(VirtualClock::new());
        // Capacity == probe window: any 17th live lifecycle must steal.
        let tracer =
            Tracer::with_capacity(Arc::clone(&clock) as Arc<dyn Clock>, 16, FlightConfig::default());
        for n in 1..=16u64 {
            tracer.stamp(&txid(n), Stage::Submit);
        }
        assert_eq!(tracer.live(), 16);
        tracer.stamp(&txid(1000), Stage::Submit);
        let snap = tracer.stage_snapshot();
        assert_eq!(snap.evicted, 1);
        assert_eq!(tracer.live(), 16, "stolen, not grown");
        assert!(tracer.complete_commit(&txid(1000)).is_some(), "newcomer is tracked");
    }

    #[test]
    fn reset_clears_live_lifecycles() {
        let (_clock, tracer) = virtual_tracer();
        for n in 1..=10u64 {
            tracer.stamp(&txid(n), Stage::Admit);
        }
        assert_eq!(tracer.live(), 10);
        tracer.reset();
        assert_eq!(tracer.live(), 0);
        assert!(tracer.complete_commit(&txid(5)).is_none());
    }

    /// The satellite coverage requirement: ≥ 4 threads hammering the slot
    /// table under `VirtualClock` — no lifecycle lost or duplicated, and
    /// every recorded trace has monotone stage timestamps.
    #[test]
    fn concurrent_lifecycles_none_lost_or_duplicated() {
        let (clock, tracer) = virtual_tracer();
        let threads = 4usize;
        let per = 200usize;
        std::thread::scope(|s| {
            for t in 0..threads {
                let tracer = &tracer;
                let clock = &clock;
                s.spawn(move || {
                    for i in 0..per {
                        let id = txid(1 + (t * per + i) as u64);
                        for st in
                            [Stage::Submit, Stage::Admit, Stage::BatchPull, Stage::Prevalidate, Stage::Apply]
                        {
                            tracer.stamp(&id, st);
                            clock.advance(Duration::from_micros(7));
                        }
                        let trace = tracer.complete_commit(&id).expect("lifecycle completed");
                        assert_eq!(trace.tx_id, id);
                    }
                });
            }
        });
        let snap = tracer.stage_snapshot();
        assert_eq!(snap.completed, (threads * per) as u64, "every lifecycle completed once");
        assert_eq!(snap.dropped, 0);
        assert_eq!(snap.evicted, 0);
        assert_eq!(tracer.live(), 0, "no slot leaked");
        let done = tracer.flight().completed();
        assert_eq!(done.len(), threads * per);
        let mut seen = std::collections::HashSet::new();
        for tr in &done {
            assert!(seen.insert(tr.tx_id), "duplicated lifecycle {}", tr.tx_id.hex());
            assert!(tr.is_monotone(), "non-monotone stamps: {tr:?}");
            assert_eq!(tr.stages().len(), 6, "all stamped stages present: {tr:?}");
            assert_eq!(tr.outcome, TraceOutcome::Completed);
        }
    }
}
