//! Process-wide metrics registry with pull-model collectors.
//!
//! Subsystems do not push samples: they `register` a closure that, when a
//! render is requested, reads the subsystem's live atomics and returns the
//! current [`Sample`]s. Closures capture `Weak` references to their
//! subsystem and return `None` once it is gone, at which point the
//! registry prunes them — so short-lived test networks and benches can
//! register into the process-wide registry without leaking collectors.
//!
//! Metric names follow the convention documented in [`crate::telemetry`]:
//! `scalesfl_<subsystem>_<name>` with `_total` for counters and a unit
//! suffix (`_seconds`, `_bytes`) for gauges/summaries; per-shard series
//! carry a `channel` label.

use std::sync::Mutex;

use crate::util::histogram::Histogram;
use crate::util::json::Json;

/// A metric value at collection time.
#[derive(Clone, Debug)]
pub enum Value {
    /// Monotone total.
    Counter(f64),
    /// Point-in-time level.
    Gauge(f64),
    /// Distribution digest (from a [`Histogram`]).
    Summary { count: u64, sum: f64, p50: f64, p95: f64, p99: f64, max: f64 },
}

/// One labelled metric sample.
#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: Value,
}

impl Sample {
    pub fn counter(name: impl Into<String>, labels: Vec<(String, String)>, v: f64) -> Sample {
        Sample { name: name.into(), labels, value: Value::Counter(v) }
    }

    pub fn gauge(name: impl Into<String>, labels: Vec<(String, String)>, v: f64) -> Sample {
        Sample { name: name.into(), labels, value: Value::Gauge(v) }
    }

    pub fn summary(name: impl Into<String>, labels: Vec<(String, String)>, h: &Histogram) -> Sample {
        Sample {
            name: name.into(),
            labels,
            value: Value::Summary {
                count: h.count(),
                // Histogram keeps mean = sum/count exactly.
                sum: h.mean() * h.count() as f64,
                p50: h.quantile(0.5).unwrap_or(0.0),
                p95: h.quantile(0.95).unwrap_or(0.0),
                p99: h.quantile(0.99).unwrap_or(0.0),
                max: h.max(),
            },
        }
    }

    /// Convenience for the ubiquitous single `channel` label.
    pub fn channel_label(channel: &str) -> Vec<(String, String)> {
        vec![("channel".to_string(), channel.to_string())]
    }
}

type Collector = Box<dyn Fn() -> Option<Vec<Sample>> + Send + Sync>;

/// See the module doc. Cheap to create; the process-wide instance lives in
/// [`crate::telemetry::Telemetry::global`].
#[derive(Default)]
pub struct Registry {
    collectors: Mutex<Vec<Collector>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Register a collector. Return `None` (typically via a failed
    /// `Weak::upgrade`) to be pruned.
    pub fn register<F>(&self, f: F)
    where
        F: Fn() -> Option<Vec<Sample>> + Send + Sync + 'static,
    {
        self.collectors.lock().unwrap().push(Box::new(f));
    }

    /// Registered (not yet pruned) collectors.
    pub fn collector_count(&self) -> usize {
        self.collectors.lock().unwrap().len()
    }

    /// Run every collector, prune the dead, and return all samples sorted
    /// by (name, labels) for stable rendering.
    fn gather(&self) -> Vec<Sample> {
        let mut collectors = self.collectors.lock().unwrap();
        let mut out = Vec::new();
        collectors.retain(|c| match c() {
            Some(mut samples) => {
                out.append(&mut samples);
                true
            }
            None => false,
        });
        drop(collectors);
        out.sort_by(|a, b| (a.name.as_str(), &a.labels).cmp(&(b.name.as_str(), &b.labels)));
        out
    }

    /// Prometheus text exposition (one `# TYPE` line per metric name;
    /// summaries expand into `quantile`-labelled series plus `_sum` and
    /// `_count`).
    pub fn render_prometheus(&self) -> String {
        let samples = self.gather();
        let mut out = String::new();
        let mut last: Option<&str> = None;
        for s in &samples {
            if last != Some(s.name.as_str()) {
                let ty = match s.value {
                    Value::Counter(_) => "counter",
                    Value::Gauge(_) => "gauge",
                    Value::Summary { .. } => "summary",
                };
                out.push_str("# TYPE ");
                out.push_str(&s.name);
                out.push(' ');
                out.push_str(ty);
                out.push('\n');
                last = Some(s.name.as_str());
            }
            match &s.value {
                Value::Counter(v) | Value::Gauge(v) => {
                    out.push_str(&format!("{}{} {}\n", s.name, fmt_labels(&s.labels, None), v));
                }
                Value::Summary { count, sum, p50, p95, p99, max } => {
                    for (q, v) in
                        [("0.5", p50), ("0.95", p95), ("0.99", p99), ("1", max)]
                    {
                        out.push_str(&format!(
                            "{}{} {}\n",
                            s.name,
                            fmt_labels(&s.labels, Some(("quantile", q))),
                            v
                        ));
                    }
                    out.push_str(&format!("{}_sum{} {}\n", s.name, fmt_labels(&s.labels, None), sum));
                    out.push_str(&format!(
                        "{}_count{} {}\n",
                        s.name,
                        fmt_labels(&s.labels, None),
                        count
                    ));
                }
            }
        }
        out
    }

    /// JSON exposition: `{"metrics": [{name, type, labels, ...}, ...]}`.
    pub fn render_json(&self) -> Json {
        let metrics: Vec<Json> = self
            .gather()
            .iter()
            .map(|s| {
                let mut labels = Json::obj();
                for (k, v) in &s.labels {
                    labels = labels.set(k.as_str(), v.as_str());
                }
                let base = Json::obj().set("name", s.name.as_str()).set("labels", labels);
                match &s.value {
                    Value::Counter(v) => base.set("type", "counter").set("value", *v),
                    Value::Gauge(v) => base.set("type", "gauge").set("value", *v),
                    Value::Summary { count, sum, p50, p95, p99, max } => base
                        .set("type", "summary")
                        .set("count", *count)
                        .set("sum", *sum)
                        .set("p50", *p50)
                        .set("p95", *p95)
                        .set("p99", *p99)
                        .set("max", *max),
                }
            })
            .collect();
        Json::obj().set("metrics", metrics)
    }
}

fn fmt_labels(labels: &[(String, String)], extra: Option<(&str, &str)>) -> String {
    if labels.is_empty() && extra.is_none() {
        return String::new();
    }
    let mut parts: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{v}\"")).collect();
    if let Some((k, v)) = extra {
        parts.push(format!("{k}=\"{v}\""));
    }
    format!("{{{}}}", parts.join(","))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;

    #[test]
    fn collectors_prune_when_source_drops() {
        let reg = Registry::new();
        let src = Arc::new(AtomicU64::new(3));
        let weak = Arc::downgrade(&src);
        reg.register(move || {
            let s = weak.upgrade()?;
            Some(vec![Sample::counter(
                "scalesfl_test_total",
                Vec::new(),
                s.load(Ordering::Relaxed) as f64,
            )])
        });
        assert_eq!(reg.collector_count(), 1);
        let text = reg.render_prometheus();
        assert!(text.contains("# TYPE scalesfl_test_total counter"), "{text}");
        assert!(text.contains("scalesfl_test_total 3"), "{text}");
        drop(src);
        assert!(!reg.render_prometheus().contains("scalesfl_test_total"));
        assert_eq!(reg.collector_count(), 0, "dead collector pruned");
    }

    #[test]
    fn labels_and_summaries_render() {
        let reg = Registry::new();
        reg.register(|| {
            let mut h = Histogram::default();
            h.record(0.25);
            Some(vec![
                Sample::gauge("scalesfl_test_depth", Sample::channel_label("shard0"), 7.0),
                Sample::summary("scalesfl_test_latency_seconds", Vec::new(), &h),
            ])
        });
        let text = reg.render_prometheus();
        assert!(text.contains("scalesfl_test_depth{channel=\"shard0\"} 7"), "{text}");
        assert!(text.contains("# TYPE scalesfl_test_latency_seconds summary"), "{text}");
        assert!(text.contains("scalesfl_test_latency_seconds{quantile=\"0.5\"} 0.25"), "{text}");
        assert!(text.contains("scalesfl_test_latency_seconds{quantile=\"1\"} 0.25"), "{text}");
        assert!(text.contains("scalesfl_test_latency_seconds_count 1"), "{text}");
        assert!(text.contains("scalesfl_test_latency_seconds_sum 0.25"), "{text}");
    }

    #[test]
    fn json_exposition_mirrors_samples() {
        let reg = Registry::new();
        reg.register(|| {
            Some(vec![
                Sample::counter("scalesfl_b_total", Vec::new(), 2.0),
                Sample::gauge("scalesfl_a_level", Sample::channel_label("ch"), 1.5),
            ])
        });
        let j = reg.render_json();
        let metrics = j.get("metrics").unwrap().as_arr().unwrap();
        assert_eq!(metrics.len(), 2);
        // Sorted by name: a_level first.
        assert_eq!(metrics[0].get("name").unwrap().as_str(), Some("scalesfl_a_level"));
        assert_eq!(metrics[0].get("labels").unwrap().get("channel").unwrap().as_str(), Some("ch"));
        assert_eq!(metrics[1].get("value").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn summary_sample_handles_empty_histogram() {
        let h = Histogram::default();
        let s = Sample::summary("scalesfl_empty_seconds", Vec::new(), &h);
        match s.value {
            Value::Summary { count, sum, p50, .. } => {
                assert_eq!(count, 0);
                assert_eq!(sum, 0.0);
                assert_eq!(p50, 0.0);
            }
            _ => unreachable!(),
        }
    }
}
