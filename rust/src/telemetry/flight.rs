//! Flight recorder: retains the last N completed transaction lifecycles
//! and freezes anomalous ones for post-mortem.
//!
//! Two triggers freeze a trace into the anomaly log:
//!
//! * **Latency anomaly** — a completed lifecycle whose commit latency
//!   exceeds `anomaly_multiple ×` the rolling p95 of prior completions
//!   (judged *before* the sample joins the rolling histogram, and only
//!   once `min_samples` completions have seeded it, so startup noise
//!   cannot self-trigger).
//! * **Abort** — any lifecycle killed mid-pipeline (relay drop, stale
//!   drop, shutdown flush) is always frozen with its reason.
//!
//! The recorder is fed exclusively by [`super::trace::Tracer`] at
//! lifecycle completion — never on the stamp hot path — so a `Mutex` is
//! fine here: contention is bounded by the commit rate, not the submit
//! rate.

use std::collections::VecDeque;
use std::sync::Mutex;

use crate::util::histogram::Histogram;
use crate::util::json::Json;

use super::trace::TxTrace;

/// Flight-recorder tuning knobs.
#[derive(Clone, Debug)]
pub struct FlightConfig {
    /// Completed lifecycles kept in the ring (oldest evicted first).
    pub retain: usize,
    /// Frozen anomaly dumps kept. Freezing stops at the cap, but the
    /// monotone [`FlightRecorder::anomaly_count`] (exported as the
    /// `scalesfl_flight_anomalies` metric) keeps counting past it, so
    /// anomalies beyond the cap are tallied even though their traces are
    /// not retained.
    pub max_anomalies: usize,
    /// A completion is anomalous when its latency exceeds this multiple
    /// of the rolling p95.
    pub anomaly_multiple: f64,
    /// Completions required in the rolling histogram before the latency
    /// trigger arms.
    pub min_samples: u64,
}

impl Default for FlightConfig {
    fn default() -> Self {
        FlightConfig { retain: 256, max_anomalies: 64, anomaly_multiple: 3.0, min_samples: 32 }
    }
}

struct Inner {
    completed: VecDeque<TxTrace>,
    /// Rolling commit-latency distribution — never reset, so the anomaly
    /// threshold reflects the whole run, not the last caliper window.
    rolling: Histogram,
    anomalies: Vec<TxTrace>,
    /// Monotone count of every anomaly seen, including those past the
    /// `max_anomalies` freeze cap whose traces were not retained.
    total_anomalies: u64,
}

/// See the module doc.
pub struct FlightRecorder {
    cfg: FlightConfig,
    inner: Mutex<Inner>,
}

impl FlightRecorder {
    pub fn new(cfg: FlightConfig) -> FlightRecorder {
        FlightRecorder {
            inner: Mutex::new(Inner {
                completed: VecDeque::with_capacity(cfg.retain.min(1024)),
                rolling: Histogram::default(),
                anomalies: Vec::new(),
                total_anomalies: 0,
            }),
            cfg,
        }
    }

    /// Record a completed lifecycle; returns whether it tripped the
    /// latency-anomaly trigger.
    pub(crate) fn on_complete(&self, trace: TxTrace) -> bool {
        let mut g = self.inner.lock().unwrap();
        let mut anomalous = false;
        if let Some(lat) = trace.latency() {
            if g.rolling.count() >= self.cfg.min_samples {
                if let Some(p95) = g.rolling.quantile(0.95) {
                    anomalous = lat > self.cfg.anomaly_multiple * p95;
                }
            }
            g.rolling.record(lat);
        }
        if anomalous {
            g.total_anomalies += 1;
            if g.anomalies.len() < self.cfg.max_anomalies {
                g.anomalies.push(trace.clone());
            }
        }
        g.completed.push_back(trace);
        while g.completed.len() > self.cfg.retain {
            g.completed.pop_front();
        }
        anomalous
    }

    /// Freeze an aborted lifecycle (always anomalous).
    pub(crate) fn on_abort(&self, trace: TxTrace) {
        let mut g = self.inner.lock().unwrap();
        g.total_anomalies += 1;
        if g.anomalies.len() < self.cfg.max_anomalies {
            g.anomalies.push(trace);
        }
    }

    /// The retained completed lifecycles, oldest first.
    pub fn completed(&self) -> Vec<TxTrace> {
        self.inner.lock().unwrap().completed.iter().cloned().collect()
    }

    /// The frozen anomalous lifecycles, in freeze order.
    pub fn anomalies(&self) -> Vec<TxTrace> {
        self.inner.lock().unwrap().anomalies.clone()
    }

    pub fn retained(&self) -> usize {
        self.inner.lock().unwrap().completed.len()
    }

    /// Monotone anomaly tally: unlike [`FlightRecorder::anomalies`], this
    /// keeps incrementing after the `max_anomalies` freeze cap is hit.
    pub fn anomaly_count(&self) -> u64 {
        self.inner.lock().unwrap().total_anomalies
    }

    /// How many anomalous traces are actually frozen (≤ `max_anomalies`).
    pub fn frozen_count(&self) -> usize {
        self.inner.lock().unwrap().anomalies.len()
    }

    /// Rolling p95 commit latency the anomaly trigger compares against.
    pub fn rolling_p95(&self) -> Option<f64> {
        self.inner.lock().unwrap().rolling.quantile(0.95)
    }

    /// Full dump: ring stats plus the per-trace stage breakdown of every
    /// frozen anomaly.
    pub fn to_json(&self) -> Json {
        let g = self.inner.lock().unwrap();
        let anomalies: Vec<Json> = g.anomalies.iter().map(|t| t.to_json()).collect();
        Json::obj()
            .set("retained", g.completed.len())
            .set("anomalies_total", g.total_anomalies)
            .set("rolling_count", g.rolling.count())
            .set("rolling_p95_s", g.rolling.quantile(0.95).unwrap_or(0.0))
            .set("anomaly_multiple", self.cfg.anomaly_multiple)
            .set("anomalies", anomalies)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::Digest;
    use crate::ledger::tx::TxId;
    use crate::telemetry::trace::{Stage, Tracer, TraceOutcome, STAGES};
    use crate::util::clock::{Clock, VirtualClock};
    use std::sync::Arc;
    use std::time::Duration;

    fn txid(n: u64) -> TxId {
        let mut b = [0u8; 32];
        b[..8].copy_from_slice(&n.to_le_bytes());
        Digest(b)
    }

    fn setup() -> (Arc<VirtualClock>, Tracer) {
        let clock = Arc::new(VirtualClock::new());
        let tracer = Tracer::with_parts(
            Arc::clone(&clock) as Arc<dyn Clock>,
            FlightConfig { min_samples: 8, ..FlightConfig::default() },
        );
        (clock, tracer)
    }

    /// Drive one full lifecycle with `step` between stages; returns the
    /// completed trace.
    fn run_lifecycle(clock: &VirtualClock, tracer: &Tracer, id: &TxId, step: Duration) -> TxTrace {
        tracer.stamp(id, Stage::Submit);
        clock.advance(step);
        tracer.stamp(id, Stage::Admit);
        clock.advance(step);
        tracer.stamp_hop(id);
        clock.advance(step);
        tracer.stamp(id, Stage::BatchPull);
        clock.advance(step);
        tracer.stamp(id, Stage::Prevalidate);
        clock.advance(step);
        tracer.stamp(id, Stage::Apply);
        clock.advance(step);
        tracer.complete_commit(id).expect("lifecycle completed")
    }

    /// The acceptance-criteria test: a deterministic (virtual-clock) run
    /// where one slow transaction trips the anomaly trigger, and the
    /// frozen dump contains every pipeline stage in order.
    #[test]
    fn anomalous_commit_latency_freezes_full_stage_breakdown() {
        let (clock, tracer) = setup();
        for n in 1..=16u64 {
            run_lifecycle(&clock, &tracer, &txid(n), Duration::from_millis(1));
        }
        assert_eq!(tracer.flight().anomaly_count(), 0, "baseline traffic is clean");
        let p95 = tracer.flight().rolling_p95().expect("rolling p95 seeded");
        assert!(p95 < 0.010, "baseline p95 {p95}");

        // 100× the baseline per-stage time: latency 0.6s >> 3 × p95.
        run_lifecycle(&clock, &tracer, &txid(999), Duration::from_millis(100));
        let frozen = tracer.flight().anomalies();
        assert_eq!(frozen.len(), 1);
        let tr = &frozen[0];
        assert_eq!(tr.tx_id, txid(999));
        assert_eq!(tr.outcome, TraceOutcome::Completed);
        assert_eq!(tr.hops, 1);
        assert!(tr.is_monotone(), "{tr:?}");
        let stages: Vec<Stage> = tr.stages().iter().map(|&(s, _)| s).collect();
        assert_eq!(stages, STAGES.to_vec(), "dump contains all pipeline stages in order");
        assert!((tr.latency().unwrap() - 0.6).abs() < 1e-9);

        // The JSON dump names every stage.
        let dump = tr.to_json().to_string();
        for st in STAGES {
            assert!(dump.contains(st.name()), "dump missing {}: {dump}", st.name());
        }
        let full = tracer.flight().to_json().to_string();
        assert!(full.contains(&txid(999).hex()));
    }

    #[test]
    fn trigger_stays_disarmed_until_min_samples() {
        let (clock, tracer) = setup();
        // Alternate fast/slow before the 8-sample arm point: nothing
        // freezes, because the rolling p95 is not trusted yet.
        for n in 1..=7u64 {
            let step = if n % 2 == 0 { 1 } else { 40 };
            run_lifecycle(&clock, &tracer, &txid(n), Duration::from_millis(step));
        }
        assert_eq!(tracer.flight().anomaly_count(), 0);
    }

    #[test]
    fn aborts_always_freeze_with_reason() {
        let (clock, tracer) = setup();
        let id = txid(42);
        tracer.stamp(&id, Stage::Submit);
        clock.advance(Duration::from_millis(2));
        tracer.stamp(&id, Stage::Admit);
        let tr = tracer.abort(&id, "relay_drop").expect("tracked");
        assert_eq!(tr.outcome, TraceOutcome::Aborted("relay_drop"));
        let frozen = tracer.flight().anomalies();
        assert_eq!(frozen.len(), 1);
        assert!(frozen[0].to_json().to_string().contains("aborted:relay_drop"));
        // The slot is freed — a late commit event is a no-op.
        assert!(tracer.complete_commit(&id).is_none());
    }

    #[test]
    fn anomaly_tally_keeps_counting_past_freeze_cap() {
        let clock = Arc::new(VirtualClock::new());
        let tracer = Tracer::with_parts(
            Arc::clone(&clock) as Arc<dyn Clock>,
            FlightConfig { max_anomalies: 2, ..FlightConfig::default() },
        );
        for n in 1..=5u64 {
            let id = txid(n);
            tracer.stamp(&id, Stage::Submit);
            clock.advance(Duration::from_millis(1));
            tracer.abort(&id, "relay_drop").expect("tracked");
        }
        // Only the first two traces freeze, but the tally is monotone.
        assert_eq!(tracer.flight().frozen_count(), 2);
        assert_eq!(tracer.flight().anomalies().len(), 2);
        assert_eq!(tracer.flight().anomaly_count(), 5);
    }

    #[test]
    fn ring_retains_last_n() {
        let clock = Arc::new(VirtualClock::new());
        let tracer = Tracer::with_parts(
            Arc::clone(&clock) as Arc<dyn Clock>,
            FlightConfig { retain: 4, ..FlightConfig::default() },
        );
        for n in 1..=10u64 {
            run_lifecycle(&clock, &tracer, &txid(n), Duration::from_millis(1));
        }
        let kept = tracer.flight().completed();
        assert_eq!(kept.len(), 4);
        let ids: Vec<TxId> = kept.iter().map(|t| t.tx_id).collect();
        assert_eq!(ids, vec![txid(7), txid(8), txid(9), txid(10)], "oldest evicted first");
    }
}
