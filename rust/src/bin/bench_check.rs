//! CI bench-regression gate.
//!
//! Compares every `BENCH_*.json` in a baseline directory against the same
//! file in a candidate directory (the fresh `--smoke` outputs under
//! `target/smoke/`). Fails (exit 1) when:
//!
//! - a baseline file has no candidate, a candidate has no committed
//!   baseline (a new bench must be gated from its first commit), or
//!   either side fails to parse;
//! - the JSON **schema drifts**: a key path present on one side is
//!   missing on the other, or a value changed type (arrays are checked
//!   element-wise against the baseline's first element);
//! - a **headline metric regresses** beyond the tolerance (default 20%):
//!   each bench embeds a `headline` array of
//!   `{metric, value, higher_is_better}` entries, so the gate needs no
//!   per-bench knowledge here.
//!
//! Usage: `bench_check <baseline_dir> <candidate_dir> [--tolerance 0.2]`
//! (ci.sh runs it as `bench_check bench-baselines target/smoke`; refresh
//! the committed baselines with `make bench-baseline`).

use std::path::Path;
use std::process::ExitCode;

use scalesfl::util::json::Json;

/// Recursively compare key sets and value types; every mismatch is one
/// human-readable line pushed into `out`.
fn schema_diff(base: &Json, cand: &Json, path: &str, out: &mut Vec<String>) {
    match (base, cand) {
        (Json::Obj(b), Json::Obj(c)) => {
            for (k, bv) in b {
                match c.iter().find(|(ck, _)| ck == k) {
                    Some((_, cv)) => schema_diff(bv, cv, &format!("{path}.{k}"), out),
                    None => out.push(format!("schema drift: {path}.{k} missing from candidate")),
                }
            }
            for (k, _) in c {
                if !b.iter().any(|(bk, _)| bk == k) {
                    out.push(format!("schema drift: {path}.{k} is new in candidate"));
                }
            }
        }
        (Json::Arr(b), Json::Arr(c)) => {
            if let Some(proto) = b.first() {
                for (i, cv) in c.iter().enumerate() {
                    schema_diff(proto, cv, &format!("{path}[{i}]"), out);
                }
                if c.is_empty() {
                    out.push(format!("schema drift: {path} emptied in candidate"));
                }
            }
        }
        (Json::Num(_), Json::Num(_))
        | (Json::Str(_), Json::Str(_))
        | (Json::Bool(_), Json::Bool(_))
        | (Json::Null, Json::Null) => {}
        _ => out.push(format!("schema drift: {path} changed type")),
    }
}

struct Headline {
    metric: String,
    value: f64,
    higher_is_better: bool,
}

fn headlines(doc: &Json, side: &str, out: &mut Vec<String>) -> Vec<Headline> {
    let Some(arr) = doc.get("headline").and_then(|h| h.as_arr()) else {
        out.push(format!("{side}: no `headline` array — nothing to gate on"));
        return Vec::new();
    };
    let mut parsed = Vec::new();
    for (i, item) in arr.iter().enumerate() {
        let metric = item.get("metric").and_then(|m| m.as_str());
        let value = item.get("value").and_then(|v| v.as_f64());
        match (metric, value) {
            (Some(m), Some(v)) => parsed.push(Headline {
                metric: m.to_string(),
                value: v,
                higher_is_better: item
                    .get("higher_is_better")
                    .and_then(|b| b.as_bool())
                    .unwrap_or(false),
            }),
            _ => out.push(format!("{side}: headline[{i}] is malformed")),
        }
    }
    parsed
}

/// Direction-aware regression check for one metric. Returns a failure
/// line, or a PASS/near-zero note in `notes`.
fn check_metric(
    file: &str,
    base: &Headline,
    cand_value: f64,
    tolerance: f64,
    notes: &mut Vec<String>,
) -> Option<String> {
    if base.value.abs() < 1e-12 {
        notes.push(format!(
            "  ~ {file}:{} baseline is 0 — skipped ratio check (candidate {cand_value:.4})",
            base.metric
        ));
        return None;
    }
    let (regressed, bound) = if base.higher_is_better {
        (cand_value < base.value * (1.0 - tolerance), base.value * (1.0 - tolerance))
    } else {
        (cand_value > base.value * (1.0 + tolerance), base.value * (1.0 + tolerance))
    };
    if regressed {
        Some(format!(
            "{file}: {} regressed — baseline {:.4}, candidate {cand_value:.4}, allowed {} {bound:.4}",
            base.metric,
            base.value,
            if base.higher_is_better { ">=" } else { "<=" },
        ))
    } else {
        notes.push(format!(
            "  ✓ {file}:{} {:.4} -> {cand_value:.4} (bound {} {bound:.4})",
            base.metric,
            base.value,
            if base.higher_is_better { ">=" } else { "<=" },
        ));
        None
    }
}

/// Compare one baseline/candidate document pair; returns failure lines.
fn check_pair(
    file: &str,
    base: &Json,
    cand: &Json,
    tolerance: f64,
    notes: &mut Vec<String>,
) -> Vec<String> {
    let mut failures = Vec::new();
    schema_diff(base, cand, file, &mut failures);
    let base_heads = headlines(base, &format!("{file} (baseline)"), &mut failures);
    let cand_heads = headlines(cand, &format!("{file} (candidate)"), &mut failures);
    for bh in &base_heads {
        match cand_heads.iter().find(|ch| ch.metric == bh.metric) {
            Some(ch) => {
                if let Some(fail) = check_metric(file, bh, ch.value, tolerance, notes) {
                    failures.push(fail);
                }
            }
            None => failures.push(format!(
                "{file}: headline metric `{}` missing from candidate",
                bh.metric
            )),
        }
    }
    failures
}

fn load(path: &Path) -> Result<Json, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("{}: unreadable ({e})", path.display()))?;
    Json::parse(text.trim()).map_err(|e| format!("{}: bad JSON ({e})", path.display()))
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut dirs: Vec<&String> = Vec::new();
    let mut tolerance = 0.20f64;
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--tolerance" {
            match args.get(i + 1).and_then(|t| t.parse::<f64>().ok()) {
                Some(t) => tolerance = t,
                None => {
                    eprintln!("--tolerance needs a numeric argument");
                    return ExitCode::FAILURE;
                }
            }
            i += 2;
        } else {
            dirs.push(&args[i]);
            i += 1;
        }
    }
    let [baseline_dir, candidate_dir] = dirs.as_slice() else {
        eprintln!("usage: bench_check <baseline_dir> <candidate_dir> [--tolerance 0.2]");
        return ExitCode::FAILURE;
    };
    let (baseline_dir, candidate_dir) = (baseline_dir.as_str(), candidate_dir.as_str());

    let mut names: Vec<String> = match std::fs::read_dir(baseline_dir) {
        Ok(entries) => entries
            .filter_map(|e| e.ok())
            .filter_map(|e| e.file_name().into_string().ok())
            .filter(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
            .collect(),
        Err(e) => {
            eprintln!("cannot read baseline dir {baseline_dir}: {e}");
            return ExitCode::FAILURE;
        }
    };
    names.sort();
    if names.is_empty() {
        eprintln!("no BENCH_*.json baselines in {baseline_dir} — run `make bench-baseline`");
        return ExitCode::FAILURE;
    }

    let mut failures: Vec<String> = Vec::new();
    let mut notes: Vec<String> = Vec::new();
    let mut metrics = 0usize;
    for name in &names {
        let base_path = Path::new(baseline_dir).join(name);
        let cand_path = Path::new(candidate_dir).join(name);
        let base = match load(&base_path) {
            Ok(j) => j,
            Err(e) => {
                failures.push(e);
                continue;
            }
        };
        if !cand_path.exists() {
            failures.push(format!(
                "{name}: no candidate in {candidate_dir} — did its smoke bench run?"
            ));
            continue;
        }
        let cand = match load(&cand_path) {
            Ok(j) => j,
            Err(e) => {
                failures.push(e);
                continue;
            }
        };
        metrics += base.get("headline").and_then(|h| h.as_arr()).map_or(0, |a| a.len());
        failures.extend(check_pair(name, &base, &cand, tolerance, &mut notes));
    }

    // The reverse direction: a smoke bench whose output has no committed
    // baseline would otherwise be silently exempt from the gate forever.
    if let Ok(entries) = std::fs::read_dir(candidate_dir) {
        let mut ungated: Vec<String> = entries
            .filter_map(|e| e.ok())
            .filter_map(|e| e.file_name().into_string().ok())
            .filter(|n| {
                n.starts_with("BENCH_") && n.ends_with(".json") && !names.contains(n)
            })
            .collect();
        ungated.sort();
        for n in ungated {
            failures.push(format!(
                "{n}: no committed baseline in {baseline_dir} — run `make bench-baseline` \
                 and commit it so the new bench is gated"
            ));
        }
    }

    for n in &notes {
        println!("{n}");
    }
    if failures.is_empty() {
        println!(
            "bench_check OK: {} files, {metrics} headline metrics within {:.0}% of baseline",
            names.len(),
            tolerance * 100.0
        );
        ExitCode::SUCCESS
    } else {
        eprintln!("bench_check FAILED ({} problem(s)):", failures.len());
        for f in &failures {
            eprintln!("  ✗ {f}");
        }
        eprintln!(
            "(intentional? regenerate baselines with `make bench-baseline` and commit them)"
        );
        ExitCode::FAILURE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(headline: &str) -> Json {
        Json::parse(&format!(
            "{{\"bench\":\"x\",\"stats\":{{\"a\":1,\"b\":true}},\"headline\":{headline}}}"
        ))
        .unwrap()
    }

    #[test]
    fn identical_documents_pass() {
        let j = doc("[{\"metric\":\"tps\",\"value\":100,\"higher_is_better\":true}]");
        let mut notes = Vec::new();
        let failures = check_pair("BENCH_x.json", &j, &j, 0.2, &mut notes);
        assert!(failures.is_empty(), "{failures:?}");
        assert_eq!(notes.len(), 1);
    }

    #[test]
    fn regression_is_direction_aware() {
        let base = doc(
            "[{\"metric\":\"tps\",\"value\":100,\"higher_is_better\":true},\
              {\"metric\":\"lat_ms\",\"value\":50,\"higher_is_better\":false}]",
        );
        // tps down 30% -> fail; lat up 10% -> fine.
        let cand = doc(
            "[{\"metric\":\"tps\",\"value\":70,\"higher_is_better\":true},\
              {\"metric\":\"lat_ms\",\"value\":55,\"higher_is_better\":false}]",
        );
        let failures = check_pair("f", &base, &cand, 0.2, &mut Vec::new());
        assert_eq!(failures.len(), 1, "{failures:?}");
        assert!(failures[0].contains("tps regressed"), "{}", failures[0]);
        // Improvements never fail, in either direction.
        let better = doc(
            "[{\"metric\":\"tps\",\"value\":500,\"higher_is_better\":true},\
              {\"metric\":\"lat_ms\",\"value\":5,\"higher_is_better\":false}]",
        );
        assert!(check_pair("f", &base, &better, 0.2, &mut Vec::new()).is_empty());
        // Just inside the 20% band passes.
        let inside = doc(
            "[{\"metric\":\"tps\",\"value\":81,\"higher_is_better\":true},\
              {\"metric\":\"lat_ms\",\"value\":59,\"higher_is_better\":false}]",
        );
        assert!(check_pair("f", &base, &inside, 0.2, &mut Vec::new()).is_empty());
    }

    #[test]
    fn schema_drift_is_flagged_both_ways() {
        let base = Json::parse("{\"a\":1,\"b\":{\"c\":2},\"headline\":[]}").unwrap();
        let missing = Json::parse("{\"a\":1,\"headline\":[]}").unwrap();
        let mut out = Vec::new();
        schema_diff(&base, &missing, "f", &mut out);
        assert_eq!(out.len(), 1);
        assert!(out[0].contains("f.b missing"), "{}", out[0]);
        let extra = Json::parse("{\"a\":1,\"b\":{\"c\":2},\"d\":9,\"headline\":[]}").unwrap();
        let mut out = Vec::new();
        schema_diff(&base, &extra, "f", &mut out);
        assert_eq!(out.len(), 1);
        assert!(out[0].contains("f.d is new"), "{}", out[0]);
        let retyped = Json::parse("{\"a\":\"one\",\"b\":{\"c\":2},\"headline\":[]}").unwrap();
        let mut out = Vec::new();
        schema_diff(&base, &retyped, "f", &mut out);
        assert_eq!(out.len(), 1);
        assert!(out[0].contains("f.a changed type"), "{}", out[0]);
    }

    #[test]
    fn array_elements_checked_against_first_baseline_element() {
        let base = Json::parse("{\"runs\":[{\"d\":1,\"t\":2.5}]}").unwrap();
        let ok = Json::parse("{\"runs\":[{\"d\":8,\"t\":0.1},{\"d\":64,\"t\":9}]}").unwrap();
        let mut out = Vec::new();
        schema_diff(&base, &ok, "f", &mut out);
        assert!(out.is_empty(), "{out:?}");
        let bad = Json::parse("{\"runs\":[{\"d\":8}]}").unwrap();
        let mut out = Vec::new();
        schema_diff(&base, &bad, "f", &mut out);
        assert_eq!(out.len(), 1);
        assert!(out[0].contains("f.runs[0].t missing"), "{}", out[0]);
    }

    #[test]
    fn missing_headline_metric_fails() {
        let base = doc("[{\"metric\":\"tps\",\"value\":100,\"higher_is_better\":true}]");
        let cand = doc("[{\"metric\":\"other\",\"value\":1,\"higher_is_better\":true}]");
        let failures = check_pair("f", &base, &cand, 0.2, &mut Vec::new());
        assert!(
            failures.iter().any(|f| f.contains("`tps` missing from candidate")),
            "{failures:?}"
        );
    }

    #[test]
    fn zero_baseline_skips_ratio_check() {
        let base = doc("[{\"metric\":\"drops\",\"value\":0,\"higher_is_better\":false}]");
        let cand = doc("[{\"metric\":\"drops\",\"value\":3,\"higher_is_better\":false}]");
        let mut notes = Vec::new();
        let failures = check_pair("f", &base, &cand, 0.2, &mut notes);
        assert!(failures.is_empty(), "{failures:?}");
        assert!(notes.iter().any(|n| n.contains("skipped ratio check")));
    }
}
