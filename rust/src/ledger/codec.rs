//! Length-prefixed binary codec for ledger payloads (model metadata,
//! chaincode values). Hand-rolled because serde's facade crate is not in the
//! offline vendor set; the format is versionless and internal to this repo.
//!
//! Decoding is hardened against hostile input: every read is bounds-checked
//! against the buffer ([`WireError::Truncated`]), and count prefixes must be
//! backed by enough remaining bytes ([`Reader::count`]) before any
//! allocation is sized from them — a frame that lies about its lengths
//! errors without over-allocating.

use std::fmt;

/// Typed decode error for the wire codec and everything layered on it
/// (envelopes, batches, blocks, protocol frames).
///
/// The split matters to transport code: [`WireError::Truncated`] means the
/// input ended before the value it promises — a torn frame, retryable once
/// more bytes arrive — while [`WireError::Malformed`] means the bytes are
/// structurally invalid and no amount of further input can fix them, so the
/// connection should be closed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// The buffer ended mid-value: `want` more bytes were needed at offset
    /// `at`. Retryable at the transport layer (wait for the rest of the
    /// frame).
    Truncated { at: usize, want: usize },
    /// Structurally invalid bytes (bad tag, bad UTF-8, a length or count
    /// prefix that lies). Not retryable — close the connection.
    Malformed(String),
}

impl WireError {
    pub(crate) fn malformed(why: impl Into<String>) -> WireError {
        WireError::Malformed(why.into())
    }

    /// True for torn-frame errors a transport may retry by reading more
    /// bytes; false for malformed frames that warrant closing the
    /// connection.
    pub fn is_truncated(&self) -> bool {
        matches!(self, WireError::Truncated { .. })
    }
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Truncated { at, want } => {
                write!(f, "truncated at byte {at} (want {want})")
            }
            WireError::Malformed(why) => write!(f, "malformed frame: {why}"),
        }
    }
}

impl std::error::Error for WireError {}

/// Legacy boundary: pipeline layers that still report `String` errors can
/// take a `WireError` through `?`.
impl From<WireError> for String {
    fn from(e: WireError) -> String {
        e.to_string()
    }
}

/// Append-only binary writer.
#[derive(Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn u8(&mut self, v: u8) -> &mut Self {
        self.buf.push(v);
        self
    }

    pub fn u32(&mut self, v: u32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn f64(&mut self, v: f64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn bytes(&mut self, v: &[u8]) -> &mut Self {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
        self
    }

    pub fn str(&mut self, v: &str) -> &mut Self {
        self.bytes(v.as_bytes())
    }

    /// Append pre-encoded bytes verbatim (no length prefix). This is what
    /// makes shared-buffer serialization a memcpy: a payload already in
    /// canonical form (e.g. a [`crate::ledger::envelope::SharedEnvelope`]
    /// buffer) is spliced in without re-encoding.
    pub fn raw(&mut self, v: &[u8]) -> &mut Self {
        self.buf.extend_from_slice(v);
        self
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Cursor-based reader; all methods error (not panic) on truncation.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if n > self.buf.len() - self.pos {
            return Err(WireError::Truncated { at: self.pos, want: n });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn bytes(&mut self) -> Result<&'a [u8], WireError> {
        let n = self.u32()? as usize;
        self.take(n)
    }

    pub fn str(&mut self) -> Result<String, WireError> {
        let b = self.bytes()?;
        match std::str::from_utf8(b) {
            Ok(s) => Ok(s.to_owned()),
            Err(_) => Err(WireError::malformed("invalid utf-8 in string")),
        }
    }

    /// Read a u32 element count and validate it against the bytes actually
    /// remaining: each promised element occupies at least `min_size` bytes
    /// on the wire, so a lying (or hostile) count fails here before any
    /// `Vec::with_capacity` sized from it can allocate.
    pub fn count(&mut self, min_size: usize) -> Result<usize, WireError> {
        let n = self.u32()? as usize;
        let left = self.buf.len() - self.pos;
        if n.saturating_mul(min_size.max(1)) > left {
            return Err(WireError::Malformed(format!(
                "count {n} of >={min_size}-byte elements exceeds {left} remaining bytes"
            )));
        }
        Ok(n)
    }

    pub fn done(&self) -> bool {
        self.pos == self.buf.len()
    }

    /// Current cursor offset into the underlying buffer. Lets callers
    /// record section boundaries (e.g. to hash or splice a sub-slice of
    /// the encoding without copying it out).
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// The whole underlying buffer (cursor-independent). Paired with
    /// [`Reader::pos`] to carve out the exact byte span of a decoded
    /// value.
    pub fn underlying(&self) -> &'a [u8] {
        self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;

    #[test]
    fn roundtrip_all_types() {
        let mut w = Writer::new();
        w.u8(7).u32(1234).u64(u64::MAX).f64(1.25).str("hello").bytes(&[1, 2, 3]);
        let buf = w.finish();
        let mut r = Reader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 1234);
        assert_eq!(r.u64().unwrap(), u64::MAX);
        assert_eq!(r.f64().unwrap(), 1.25);
        assert_eq!(r.str().unwrap(), "hello");
        assert_eq!(r.bytes().unwrap(), &[1, 2, 3]);
        assert!(r.done());
    }

    #[test]
    fn truncation_errors() {
        let mut w = Writer::new();
        w.str("hello");
        let buf = w.finish();
        let mut r = Reader::new(&buf[..3]);
        let err = r.str().unwrap_err();
        assert!(err.is_truncated(), "{err:?}");
    }

    #[test]
    fn error_classification_and_display() {
        // Bad UTF-8 is malformed, not truncated: more bytes can't fix it.
        let mut w = Writer::new();
        w.bytes(&[0xFF, 0xFE]);
        let buf = w.finish();
        let err = Reader::new(&buf).str().unwrap_err();
        assert!(!err.is_truncated(), "{err:?}");
        assert!(err.to_string().contains("malformed"));
        // Truncation reports where and how much.
        let err = Reader::new(&[1, 2]).u64().unwrap_err();
        assert_eq!(err, WireError::Truncated { at: 0, want: 8 });
        // Both convert into the legacy String error shape.
        let s: String = err.into();
        assert!(s.contains("truncated at byte 0"));
    }

    #[test]
    fn count_guard_rejects_lying_prefixes() {
        // A count prefix promising far more elements than the buffer can
        // hold errors before any capacity is sized from it.
        let mut w = Writer::new();
        w.u32(u32::MAX).str("x");
        let buf = w.finish();
        let err = Reader::new(&buf).count(4).unwrap_err();
        assert!(!err.is_truncated(), "{err:?}");
        // An honest count passes and leaves the cursor after the prefix.
        let mut w = Writer::new();
        w.u32(2).str("a").str("b");
        let buf = w.finish();
        let mut r = Reader::new(&buf);
        assert_eq!(r.count(4).unwrap(), 2);
        assert_eq!(r.str().unwrap(), "a");
        assert_eq!(r.str().unwrap(), "b");
        assert!(r.done());
    }

    #[test]
    fn property_roundtrip_random_strings() {
        check("codec-roundtrip", 32, |rng| {
            let n = rng.below(20);
            let vals: Vec<String> =
                (0..n).map(|i| format!("s{}-{}", i, rng.next_u64())).collect();
            let mut w = Writer::new();
            w.u32(n as u32);
            for v in &vals {
                w.str(v);
            }
            let buf = w.finish();
            let mut r = Reader::new(&buf);
            let m = r.u32().unwrap() as usize;
            assert_eq!(m, n);
            for v in &vals {
                assert_eq!(&r.str().unwrap(), v);
            }
            assert!(r.done());
        });
    }
}
