//! Length-prefixed binary codec for ledger payloads (model metadata,
//! chaincode values). Hand-rolled because serde's facade crate is not in the
//! offline vendor set; the format is versionless and internal to this repo.

/// Append-only binary writer.
#[derive(Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn u8(&mut self, v: u8) -> &mut Self {
        self.buf.push(v);
        self
    }

    pub fn u32(&mut self, v: u32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn f64(&mut self, v: f64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn bytes(&mut self, v: &[u8]) -> &mut Self {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
        self
    }

    pub fn str(&mut self, v: &str) -> &mut Self {
        self.bytes(v.as_bytes())
    }

    /// Append pre-encoded bytes verbatim (no length prefix). This is what
    /// makes shared-buffer serialization a memcpy: a payload already in
    /// canonical form (e.g. a [`crate::ledger::envelope::SharedEnvelope`]
    /// buffer) is spliced in without re-encoding.
    pub fn raw(&mut self, v: &[u8]) -> &mut Self {
        self.buf.extend_from_slice(v);
        self
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Cursor-based reader; all methods error (not panic) on truncation.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.pos + n > self.buf.len() {
            return Err(format!("truncated at byte {} (want {n})", self.pos));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn bytes(&mut self) -> Result<&'a [u8], String> {
        let n = self.u32()? as usize;
        self.take(n)
    }

    pub fn str(&mut self) -> Result<String, String> {
        let b = self.bytes()?;
        String::from_utf8(b.to_vec()).map_err(|e| e.to_string())
    }

    pub fn done(&self) -> bool {
        self.pos == self.buf.len()
    }

    /// Current cursor offset into the underlying buffer. Lets callers
    /// record section boundaries (e.g. to hash or splice a sub-slice of
    /// the encoding without copying it out).
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// The whole underlying buffer (cursor-independent). Paired with
    /// [`Reader::pos`] to carve out the exact byte span of a decoded
    /// value.
    pub fn underlying(&self) -> &'a [u8] {
        self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;

    #[test]
    fn roundtrip_all_types() {
        let mut w = Writer::new();
        w.u8(7).u32(1234).u64(u64::MAX).f64(1.25).str("hello").bytes(&[1, 2, 3]);
        let buf = w.finish();
        let mut r = Reader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 1234);
        assert_eq!(r.u64().unwrap(), u64::MAX);
        assert_eq!(r.f64().unwrap(), 1.25);
        assert_eq!(r.str().unwrap(), "hello");
        assert_eq!(r.bytes().unwrap(), &[1, 2, 3]);
        assert!(r.done());
    }

    #[test]
    fn truncation_errors() {
        let mut w = Writer::new();
        w.str("hello");
        let buf = w.finish();
        let mut r = Reader::new(&buf[..3]);
        assert!(r.str().is_err());
    }

    #[test]
    fn property_roundtrip_random_strings() {
        check("codec-roundtrip", 32, |rng| {
            let n = rng.below(20);
            let vals: Vec<String> =
                (0..n).map(|i| format!("s{}-{}", i, rng.next_u64())).collect();
            let mut w = Writer::new();
            w.u32(n as u32);
            for v in &vals {
                w.str(v);
            }
            let buf = w.finish();
            let mut r = Reader::new(&buf);
            let m = r.u32().unwrap() as usize;
            assert_eq!(m, n);
            for v in &vals {
                assert_eq!(&r.str().unwrap(), v);
            }
            assert!(r.done());
        });
    }
}
