//! The append-only block chain per channel, with integrity verification.

use crate::crypto::Digest;
use crate::ledger::block::Block;

/// A channel's chain of committed blocks.
#[derive(Clone, Debug, Default)]
pub struct Chain {
    blocks: Vec<Block>,
}

impl Chain {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn height(&self) -> u64 {
        self.blocks.len() as u64
    }

    pub fn tip_hash(&self) -> Digest {
        self.blocks.last().map(|b| b.hash()).unwrap_or(Digest::ZERO)
    }

    pub fn get(&self, number: u64) -> Option<&Block> {
        self.blocks.get(number as usize)
    }

    pub fn last(&self) -> Option<&Block> {
        self.blocks.last()
    }

    /// Append a block; enforces numbering and prev-hash linkage.
    pub fn append(&mut self, block: Block) -> Result<(), String> {
        if block.header.number != self.height() {
            return Err(format!(
                "block number {} != expected {}",
                block.header.number,
                self.height()
            ));
        }
        if block.header.prev_hash != self.tip_hash() {
            return Err("prev_hash mismatch".into());
        }
        if !block.verify_data_hash() {
            return Err("data hash mismatch".into());
        }
        self.blocks.push(block);
        Ok(())
    }

    /// Full-chain integrity verification.
    pub fn verify(&self) -> Result<(), String> {
        let mut prev = Digest::ZERO;
        for (i, b) in self.blocks.iter().enumerate() {
            if b.header.number != i as u64 {
                return Err(format!("block {i} has number {}", b.header.number));
            }
            if b.header.prev_hash != prev {
                return Err(format!("block {i} prev_hash mismatch"));
            }
            if !b.verify_data_hash() {
                return Err(format!("block {i} data tampered"));
            }
            prev = b.hash();
        }
        Ok(())
    }

    /// Total committed (valid) transactions across all blocks.
    pub fn total_valid_txs(&self) -> usize {
        self.blocks.iter().map(|b| b.valid_tx_count()).sum()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Block> {
        self.blocks.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::msp::MemberId;
    use crate::ledger::tx::{Envelope, Proposal, RwSet};

    fn env(nonce: u64) -> Envelope {
        Envelope {
            proposal: Proposal {
                channel: "c".into(),
                chaincode: "cc".into(),
                function: "f".into(),
                args: vec![],
                creator: MemberId::new("m"),
                nonce,
            },
            rw_set: RwSet::default(),
            endorsements: vec![],
        }
    }

    #[test]
    fn append_and_verify() {
        let mut chain = Chain::new();
        for n in 0..5u64 {
            let b = Block::new(n, chain.tip_hash(), vec![env(n)]);
            chain.append(b).unwrap();
        }
        assert_eq!(chain.height(), 5);
        chain.verify().unwrap();
    }

    #[test]
    fn rejects_bad_number_and_prev() {
        let mut chain = Chain::new();
        chain.append(Block::new(0, Digest::ZERO, vec![])).unwrap();
        assert!(chain.append(Block::new(2, chain.tip_hash(), vec![])).is_err());
        assert!(chain.append(Block::new(1, Digest::ZERO, vec![])).is_err());
    }

    #[test]
    fn verify_detects_mid_chain_tamper() {
        let mut chain = Chain::new();
        for n in 0..4u64 {
            chain.append(Block::new(n, chain.tip_hash(), vec![env(n)])).unwrap();
        }
        chain.blocks[2].txs[0].proposal.nonce = 777;
        assert!(chain.verify().is_err());
    }
}
