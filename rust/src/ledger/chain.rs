//! The append-only block chain per channel, with integrity verification.
//!
//! A chain can be *anchored* at a snapshot boundary
//! ([`Chain::with_base`]): blocks below the base height live only in the
//! durable block log, and the in-memory suffix chains off the recorded
//! base tip hash. Integrity failures are typed ([`ChainError`]) so the
//! recovery path can branch on the failure kind — a torn log tail
//! surfaces as `NumberMismatch`/`PrevHashMismatch` at a known block and
//! is truncated, while `DataHash` on a live append is a hard fault.

use crate::crypto::Digest;
use crate::ledger::block::Block;

/// Why a block failed the chain's integrity checks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChainError {
    /// Block numbering broke: `got` arrived where `expected` was next.
    NumberMismatch { expected: u64, got: u64 },
    /// `prev_hash` of block `number` does not match the predecessor's hash.
    PrevHashMismatch { number: u64 },
    /// Block `number`'s payload no longer matches its merkle data hash.
    DataHash { number: u64 },
}

impl std::fmt::Display for ChainError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ChainError::NumberMismatch { expected, got } => {
                write!(f, "block number {got} != expected {expected}")
            }
            ChainError::PrevHashMismatch { number } => {
                write!(f, "block {number} prev_hash mismatch")
            }
            ChainError::DataHash { number } => {
                write!(f, "block {number} data hash mismatch")
            }
        }
    }
}

impl std::error::Error for ChainError {}

/// A channel's chain of committed blocks.
#[derive(Clone, Debug)]
pub struct Chain {
    blocks: Vec<Block>,
    /// Blocks below this height were pruned to the block log (snapshot
    /// recovery); 0 for a genesis-rooted chain.
    base_height: u64,
    /// Hash of block `base_height - 1` (`Digest::ZERO` at genesis).
    base_tip: Digest,
}

impl Default for Chain {
    fn default() -> Self {
        Chain { blocks: Vec::new(), base_height: 0, base_tip: Digest::ZERO }
    }
}

impl Chain {
    pub fn new() -> Self {
        Self::default()
    }

    /// A chain resuming from a snapshot boundary: the next append must be
    /// block `height` chaining off `tip` (hash of block `height - 1`).
    pub fn with_base(height: u64, tip: Digest) -> Self {
        Chain { blocks: Vec::new(), base_height: height, base_tip: tip }
    }

    pub fn height(&self) -> u64 {
        self.base_height + self.blocks.len() as u64
    }

    /// Height below which blocks live only in the durable log.
    pub fn base_height(&self) -> u64 {
        self.base_height
    }

    pub fn tip_hash(&self) -> Digest {
        self.blocks.last().map(|b| b.hash()).unwrap_or(self.base_tip)
    }

    /// Block by number (None if below the base or beyond the tip).
    pub fn get(&self, number: u64) -> Option<&Block> {
        let idx = number.checked_sub(self.base_height)?;
        self.blocks.get(idx as usize)
    }

    pub fn last(&self) -> Option<&Block> {
        self.blocks.last()
    }

    /// Append a block; enforces numbering and prev-hash linkage.
    pub fn append(&mut self, block: Block) -> Result<(), ChainError> {
        let number = block.header.number;
        if number != self.height() {
            return Err(ChainError::NumberMismatch { expected: self.height(), got: number });
        }
        if block.header.prev_hash != self.tip_hash() {
            return Err(ChainError::PrevHashMismatch { number });
        }
        if !block.verify_data_hash() {
            return Err(ChainError::DataHash { number });
        }
        self.blocks.push(block);
        Ok(())
    }

    /// Integrity verification of the in-memory suffix (everything above
    /// the base anchor; pruned blocks were verified when recovered).
    pub fn verify(&self) -> Result<(), ChainError> {
        let mut prev = self.base_tip;
        for (i, b) in self.blocks.iter().enumerate() {
            let number = self.base_height + i as u64;
            if b.header.number != number {
                return Err(ChainError::NumberMismatch {
                    expected: number,
                    got: b.header.number,
                });
            }
            if b.header.prev_hash != prev {
                return Err(ChainError::PrevHashMismatch { number });
            }
            if !b.verify_data_hash() {
                return Err(ChainError::DataHash { number });
            }
            prev = b.hash();
        }
        Ok(())
    }

    /// Total committed (valid) transactions across the in-memory blocks.
    pub fn total_valid_txs(&self) -> usize {
        self.blocks.iter().map(|b| b.valid_tx_count()).sum()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Block> {
        self.blocks.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::msp::MemberId;
    use crate::ledger::tx::{Envelope, Proposal, RwSet};

    fn env(nonce: u64) -> Envelope {
        Envelope {
            proposal: Proposal {
                channel: "c".into(),
                chaincode: "cc".into(),
                function: "f".into(),
                args: vec![],
                creator: MemberId::new("m"),
                nonce,
            },
            rw_set: RwSet::default(),
            endorsements: vec![],
        }
    }

    #[test]
    fn append_and_verify() {
        let mut chain = Chain::new();
        for n in 0..5u64 {
            let b = Block::new(n, chain.tip_hash(), vec![env(n)]);
            chain.append(b).unwrap();
        }
        assert_eq!(chain.height(), 5);
        chain.verify().unwrap();
    }

    #[test]
    fn rejects_bad_number_and_prev() {
        let mut chain = Chain::new();
        chain.append(Block::new(0, Digest::ZERO, Vec::<Envelope>::new())).unwrap();
        assert_eq!(
            chain.append(Block::new(2, chain.tip_hash(), Vec::<Envelope>::new())),
            Err(ChainError::NumberMismatch { expected: 1, got: 2 })
        );
        assert_eq!(
            chain.append(Block::new(1, Digest::ZERO, Vec::<Envelope>::new())),
            Err(ChainError::PrevHashMismatch { number: 1 })
        );
    }

    #[test]
    fn rejects_tampered_data_hash() {
        let mut chain = Chain::new();
        let mut b = Block::new(0, Digest::ZERO, vec![env(1)]);
        b.txs[0] = env(9).into();
        assert_eq!(chain.append(b), Err(ChainError::DataHash { number: 0 }));
    }

    #[test]
    fn verify_detects_mid_chain_tamper() {
        let mut chain = Chain::new();
        for n in 0..4u64 {
            chain.append(Block::new(n, chain.tip_hash(), vec![env(n)])).unwrap();
        }
        chain.blocks[2].txs[0] = env(777).into();
        assert_eq!(chain.verify(), Err(ChainError::DataHash { number: 2 }));
    }

    #[test]
    fn based_chain_resumes_from_snapshot_boundary() {
        // Build the "pre-crash" chain to learn the tip at height 3.
        let mut full = Chain::new();
        for n in 0..3u64 {
            full.append(Block::new(n, full.tip_hash(), vec![env(n)])).unwrap();
        }
        let tip = full.tip_hash();
        let mut resumed = Chain::with_base(3, tip);
        assert_eq!(resumed.height(), 3);
        assert_eq!(resumed.base_height(), 3);
        assert_eq!(resumed.tip_hash(), tip);
        assert!(resumed.get(0).is_none(), "pruned blocks are log-only");
        // Appends must chain off the anchored tip, not ZERO.
        assert_eq!(
            resumed.append(Block::new(3, Digest::ZERO, Vec::<Envelope>::new())),
            Err(ChainError::PrevHashMismatch { number: 3 })
        );
        resumed.append(Block::new(3, tip, vec![env(3)])).unwrap();
        resumed.verify().unwrap();
        assert_eq!(resumed.height(), 4);
        assert_eq!(resumed.get(3).unwrap().header.number, 3);
        // The resumed suffix reaches the same tip as the genesis chain.
        full.append(Block::new(3, tip, vec![env(3)])).unwrap();
        assert_eq!(resumed.tip_hash(), full.tip_hash());
    }
}
