//! Versioned world state with MVCC validation (Fabric's commit rule) and a
//! cheap read-version API for lock-light staleness checks.
//!
//! Every committed write stamps its key with the (block, tx) version; at
//! commit time a transaction is valid only if every key it *read* during
//! endorsement still carries the version it observed. This is what lets
//! endorsement run in parallel ahead of ordering (execute–order–validate).
//!
//! Two commit-path refinements hang off this module:
//!
//! - **Write sequence** ([`WorldState::seq`]): a monotone counter bumped on
//!   every [`WorldState::apply`]. Readers that cached a verdict at sequence
//!   `s` know the verdict still holds while `seq() == s` — no key-by-key
//!   re-check needed. The mempool's pull-time staleness re-check keys off
//!   this, so an idle channel costs one integer compare per pulled tx.
//! - **[`StateView`]**: the read-only version oracle
//!   (`read_version`/`seq`) a [`crate::fabric::peer::PeerChannel`] exposes
//!   to the mempool for admission-side MVCC hinting. Versions only ever
//!   move forward, so a read-set observed stale through a `StateView` is
//!   *guaranteed* to fail MVCC at commit — dropping it early sheds load
//!   without changing any outcome.
//!
//! The commit-time validator itself ([`crate::fabric::peer`]) holds the
//! state write lock only for the serial MVCC-check + apply stage;
//! signature/policy verification runs before it, lock-free.

use std::collections::HashMap;

use crate::ledger::tx::RwSet;

/// Key version: the (block, tx-in-block) coordinates of the last write.
/// Ordered lexicographically — a later write always compares greater, and
/// no version ever recurs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Version {
    pub block: u64,
    pub tx: u32,
}

/// Read-only version oracle over a channel's committed state.
///
/// Implemented by `PeerChannel` (behind its state lock's read half) and
/// consumed by the mempool: admission rejects transactions whose read-set
/// is already stale, and batch pulls drop transactions that went stale
/// while queued — both before the orderer spends consensus bandwidth on a
/// doomed `MvccConflict`.
///
/// The view need not be perfectly current: [`StateView::any_stale`] only
/// flags reads that are *provably* overtaken (a strictly newer version
/// exists, which can never be un-written), so a replica lagging the
/// endorser degrades to fewer hints — never to rejecting a transaction
/// that could still commit `Valid`.
pub trait StateView: Send + Sync {
    /// Current version of `key` (None if absent).
    fn read_version(&self, key: &str) -> Option<Version>;

    /// Monotone write sequence: unchanged sequence ⇒ unchanged versions.
    fn seq(&self) -> u64;

    /// Does any read in `reads` observe a version this view has already
    /// seen overtaken? Conservative in the presence of lag: only verdicts
    /// that hold at every later state count as stale.
    fn any_stale(&self, reads: &[(String, Option<Version>)]) -> bool {
        reads.iter().any(|(key, observed)| {
            match (observed, self.read_version(key)) {
                // A strictly newer write exists. Versions are unique and
                // monotone, so `observed` can never match again: the
                // commit-time MVCC check must fail.
                (Some(v), Some(current)) => current > *v,
                // Read-as-absent but the key now exists: doomed unless an
                // intervening delete restores absence before commit; the
                // workload's chaincodes never delete contended keys, so
                // treat it as stale.
                (None, Some(_)) => true,
                // Key absent in this view (deleted, or the view simply
                // lags the key's creation): nothing provable — keep it.
                (_, None) => false,
            }
        })
    }
}

/// The channel's current key-value state.
#[derive(Clone, Debug, Default)]
pub struct WorldState {
    map: HashMap<String, (Vec<u8>, Version)>,
    /// Bumped on every `apply`; see the module docs.
    seq: u64,
}

impl WorldState {
    pub fn new() -> Self {
        Self::default()
    }

    /// Value + version for a key (None if absent).
    pub fn get(&self, key: &str) -> Option<(&[u8], Version)> {
        self.map.get(key).map(|(v, ver)| (v.as_slice(), *ver))
    }

    pub fn get_value(&self, key: &str) -> Option<&[u8]> {
        self.map.get(key).map(|(v, _)| v.as_slice())
    }

    /// Version of a key without touching the value (None if absent).
    pub fn read_version(&self, key: &str) -> Option<Version> {
        self.map.get(key).map(|(_, ver)| *ver)
    }

    /// Monotone write sequence: bumped once per [`WorldState::apply`].
    /// Equal sequences ⇒ identical versions for every key.
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// Range scan over keys with the given prefix (sorted by key). Returns
    /// borrowed entries — callers that need ownership clone at their own
    /// boundary instead of this method cloning every value eagerly.
    pub fn scan_prefix(&self, prefix: &str) -> Vec<(&str, &[u8])> {
        let mut out: Vec<(&str, &[u8])> = self
            .map
            .iter()
            .filter(|(k, _)| k.starts_with(prefix))
            .map(|(k, (v, _))| (k.as_str(), v.as_slice()))
            .collect();
        out.sort_by(|a, b| a.0.cmp(b.0));
        out
    }

    /// MVCC check: do all read versions still match current state?
    pub fn mvcc_valid(&self, rw: &RwSet) -> bool {
        rw.reads.iter().all(|(key, observed)| {
            let current = self.map.get(key).map(|(_, ver)| *ver);
            current == *observed
        })
    }

    /// Apply a write set at the given version (validator-only entry point).
    pub fn apply(&mut self, rw: &RwSet, version: Version) {
        for (key, val) in &rw.writes {
            match val {
                Some(v) => {
                    self.map.insert(key.clone(), (v.clone(), version));
                }
                None => {
                    self.map.remove(key);
                }
            }
        }
        self.seq += 1;
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Every (key, value, version) entry sorted by key — the canonical
    /// order the snapshot state root is computed over
    /// (`crate::ledger::snapshot`).
    pub fn entries(&self) -> Vec<(&str, &[u8], Version)> {
        let mut out: Vec<(&str, &[u8], Version)> = self
            .map
            .iter()
            .map(|(k, (v, ver))| (k.as_str(), v.as_slice(), *ver))
            .collect();
        out.sort_by(|a, b| a.0.cmp(b.0));
        out
    }

    /// Rebuild a state from snapshot entries at the recorded write
    /// sequence (recovery-only entry point; versions are restored as
    /// stamped at commit time, not re-derived).
    pub fn from_entries(
        entries: impl IntoIterator<Item = (String, Vec<u8>, Version)>,
        seq: u64,
    ) -> WorldState {
        let map = entries.into_iter().map(|(k, v, ver)| (k, (v, ver))).collect();
        WorldState { map, seq }
    }
}

impl StateView for WorldState {
    fn read_version(&self, key: &str) -> Option<Version> {
        WorldState::read_version(self, key)
    }

    fn seq(&self) -> u64 {
        WorldState::seq(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;

    fn w(key: &str, val: &[u8]) -> RwSet {
        RwSet { reads: vec![], writes: vec![(key.into(), Some(val.to_vec()))] }
    }

    #[test]
    fn apply_and_get() {
        let mut s = WorldState::new();
        s.apply(&w("k", b"v1"), Version { block: 1, tx: 0 });
        assert_eq!(s.get("k"), Some((b"v1".as_slice(), Version { block: 1, tx: 0 })));
        s.apply(&w("k", b"v2"), Version { block: 2, tx: 3 });
        assert_eq!(s.get("k").unwrap().1, Version { block: 2, tx: 3 });
        assert_eq!(s.read_version("k"), Some(Version { block: 2, tx: 3 }));
        assert_eq!(s.read_version("absent"), None);
    }

    #[test]
    fn delete_removes_key() {
        let mut s = WorldState::new();
        s.apply(&w("k", b"v"), Version { block: 1, tx: 0 });
        s.apply(
            &RwSet { reads: vec![], writes: vec![("k".into(), None)] },
            Version { block: 2, tx: 0 },
        );
        assert_eq!(s.get("k"), None);
    }

    #[test]
    fn seq_bumps_on_every_apply() {
        let mut s = WorldState::new();
        assert_eq!(s.seq(), 0);
        s.apply(&w("a", b"1"), Version { block: 1, tx: 0 });
        s.apply(&w("b", b"2"), Version { block: 1, tx: 1 });
        assert_eq!(s.seq(), 2);
        // Even an empty write set marks the state as touched (a block with
        // only deletes of absent keys still advances).
        s.apply(&RwSet::default(), Version { block: 2, tx: 0 });
        assert_eq!(s.seq(), 3);
    }

    #[test]
    fn state_view_detects_stale_reads() {
        let mut s = WorldState::new();
        s.apply(&w("k", b"v1"), Version { block: 1, tx: 0 });
        let fresh = [("k".to_string(), Some(Version { block: 1, tx: 0 }))];
        let absent_ok = [("nope".to_string(), None)];
        assert!(!StateView::any_stale(&s, &fresh));
        assert!(!StateView::any_stale(&s, &absent_ok));
        s.apply(&w("k", b"v2"), Version { block: 2, tx: 0 });
        assert!(StateView::any_stale(&s, &fresh));
        // A read-of-absent goes stale once the key exists.
        let phantom = [("k2".to_string(), None)];
        assert!(!StateView::any_stale(&s, &phantom));
        s.apply(&w("k2", b"x"), Version { block: 3, tx: 0 });
        assert!(StateView::any_stale(&s, &phantom));
        // Lag tolerance: an observation *newer* than this view (endorsed
        // on a replica that is ahead) is not provably stale — and neither
        // is a read of a key this view has never seen.
        let ahead = [("k".to_string(), Some(Version { block: 9, tx: 0 }))];
        assert!(!StateView::any_stale(&s, &ahead));
        let unseen = [("future-key".to_string(), Some(Version { block: 9, tx: 0 }))];
        assert!(!StateView::any_stale(&s, &unseen));
    }

    #[test]
    fn mvcc_detects_stale_read() {
        let mut s = WorldState::new();
        s.apply(&w("k", b"v1"), Version { block: 1, tx: 0 });
        // Endorsement observed (1, 0)…
        let rw = RwSet {
            reads: vec![("k".into(), Some(Version { block: 1, tx: 0 }))],
            writes: vec![("k".into(), Some(b"v2".to_vec()))],
        };
        assert!(s.mvcc_valid(&rw));
        // …but a competing tx commits first.
        s.apply(&w("k", b"other"), Version { block: 2, tx: 0 });
        assert!(!s.mvcc_valid(&rw));
    }

    #[test]
    fn mvcc_absent_key_semantics() {
        let s = WorldState::new();
        let rw = RwSet { reads: vec![("nope".into(), None)], writes: vec![] };
        assert!(s.mvcc_valid(&rw)); // read-of-absent stays valid while absent
        let rw2 = RwSet {
            reads: vec![("nope".into(), Some(Version { block: 1, tx: 0 }))],
            writes: vec![],
        };
        assert!(!s.mvcc_valid(&rw2));
    }

    #[test]
    fn scan_prefix_sorted_and_borrowed() {
        let mut s = WorldState::new();
        // Inserted out of order; the scan must come back key-sorted (the
        // deterministic iteration order chaincodes rely on).
        for k in ["models/r1/c2", "models/r1/c1", "global/r1", "models/r1/c0"] {
            s.apply(&w(k, k.as_bytes()), Version { block: 1, tx: 0 });
        }
        let hits = s.scan_prefix("models/r1/");
        assert_eq!(
            hits.iter().map(|(k, _)| *k).collect::<Vec<_>>(),
            vec!["models/r1/c0", "models/r1/c1", "models/r1/c2"]
        );
        // Values are borrowed straight from the map — no eager clone.
        for (k, v) in &hits {
            assert_eq!(*v, k.as_bytes());
        }
        assert!(s.scan_prefix("zzz").is_empty());
    }

    #[test]
    fn entries_roundtrip_through_from_entries() {
        let mut s = WorldState::new();
        for (i, k) in ["b", "a", "c"].iter().enumerate() {
            s.apply(&w(k, k.as_bytes()), Version { block: 1, tx: i as u32 });
        }
        let entries = s.entries();
        assert_eq!(entries.iter().map(|(k, _, _)| *k).collect::<Vec<_>>(), vec!["a", "b", "c"]);
        let owned: Vec<(String, Vec<u8>, Version)> =
            entries.iter().map(|(k, v, ver)| (k.to_string(), v.to_vec(), *ver)).collect();
        let back = WorldState::from_entries(owned, s.seq());
        assert_eq!(back.seq(), s.seq());
        assert_eq!(back.entries(), s.entries());
        assert_eq!(back.read_version("a"), Some(Version { block: 1, tx: 1 }));
    }

    #[test]
    fn property_serial_apply_keeps_latest() {
        check("state-latest-write-wins", 32, |rng| {
            let mut s = WorldState::new();
            let mut last: HashMap<String, Vec<u8>> = HashMap::new();
            for b in 0..rng.range(1, 30) as u64 {
                let key = format!("k{}", rng.below(5));
                let val = rng.next_u64().to_le_bytes().to_vec();
                s.apply(&w(&key, &val), Version { block: b, tx: 0 });
                last.insert(key, val);
            }
            for (k, v) in &last {
                assert_eq!(s.get_value(k), Some(v.as_slice()));
            }
        });
    }
}
