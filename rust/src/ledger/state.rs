//! Versioned world state with MVCC validation (Fabric's commit rule).
//!
//! Every committed write stamps its key with the (block, tx) version; at
//! commit time a transaction is valid only if every key it *read* during
//! endorsement still carries the version it observed. This is what lets
//! endorsement run in parallel ahead of ordering (execute–order–validate).

use std::collections::HashMap;

use crate::ledger::tx::RwSet;

/// Key version: the (block, tx-in-block) coordinates of the last write.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Version {
    pub block: u64,
    pub tx: u32,
}

/// The channel's current key-value state.
#[derive(Clone, Debug, Default)]
pub struct WorldState {
    map: HashMap<String, (Vec<u8>, Version)>,
}

impl WorldState {
    pub fn new() -> Self {
        Self::default()
    }

    /// Value + version for a key (None if absent).
    pub fn get(&self, key: &str) -> Option<(&[u8], Version)> {
        self.map.get(key).map(|(v, ver)| (v.as_slice(), *ver))
    }

    pub fn get_value(&self, key: &str) -> Option<&[u8]> {
        self.map.get(key).map(|(v, _)| v.as_slice())
    }

    /// Range scan over keys with the given prefix (sorted by key).
    pub fn scan_prefix(&self, prefix: &str) -> Vec<(String, Vec<u8>)> {
        let mut out: Vec<(String, Vec<u8>)> = self
            .map
            .iter()
            .filter(|(k, _)| k.starts_with(prefix))
            .map(|(k, (v, _))| (k.clone(), v.clone()))
            .collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// MVCC check: do all read versions still match current state?
    pub fn mvcc_valid(&self, rw: &RwSet) -> bool {
        rw.reads.iter().all(|(key, observed)| {
            let current = self.map.get(key).map(|(_, ver)| *ver);
            current == *observed
        })
    }

    /// Apply a write set at the given version (validator-only entry point).
    pub fn apply(&mut self, rw: &RwSet, version: Version) {
        for (key, val) in &rw.writes {
            match val {
                Some(v) => {
                    self.map.insert(key.clone(), (v.clone(), version));
                }
                None => {
                    self.map.remove(key);
                }
            }
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;

    fn w(key: &str, val: &[u8]) -> RwSet {
        RwSet { reads: vec![], writes: vec![(key.into(), Some(val.to_vec()))] }
    }

    #[test]
    fn apply_and_get() {
        let mut s = WorldState::new();
        s.apply(&w("k", b"v1"), Version { block: 1, tx: 0 });
        assert_eq!(s.get("k"), Some((b"v1".as_slice(), Version { block: 1, tx: 0 })));
        s.apply(&w("k", b"v2"), Version { block: 2, tx: 3 });
        assert_eq!(s.get("k").unwrap().1, Version { block: 2, tx: 3 });
    }

    #[test]
    fn delete_removes_key() {
        let mut s = WorldState::new();
        s.apply(&w("k", b"v"), Version { block: 1, tx: 0 });
        s.apply(
            &RwSet { reads: vec![], writes: vec![("k".into(), None)] },
            Version { block: 2, tx: 0 },
        );
        assert_eq!(s.get("k"), None);
    }

    #[test]
    fn mvcc_detects_stale_read() {
        let mut s = WorldState::new();
        s.apply(&w("k", b"v1"), Version { block: 1, tx: 0 });
        // Endorsement observed (1, 0)…
        let rw = RwSet {
            reads: vec![("k".into(), Some(Version { block: 1, tx: 0 }))],
            writes: vec![("k".into(), Some(b"v2".to_vec()))],
        };
        assert!(s.mvcc_valid(&rw));
        // …but a competing tx commits first.
        s.apply(&w("k", b"other"), Version { block: 2, tx: 0 });
        assert!(!s.mvcc_valid(&rw));
    }

    #[test]
    fn mvcc_absent_key_semantics() {
        let s = WorldState::new();
        let rw = RwSet { reads: vec![("nope".into(), None)], writes: vec![] };
        assert!(s.mvcc_valid(&rw)); // read-of-absent stays valid while absent
        let rw2 = RwSet {
            reads: vec![("nope".into(), Some(Version { block: 1, tx: 0 }))],
            writes: vec![],
        };
        assert!(!s.mvcc_valid(&rw2));
    }

    #[test]
    fn scan_prefix_sorted() {
        let mut s = WorldState::new();
        for k in ["models/r1/c2", "models/r1/c1", "global/r1"] {
            s.apply(&w(k, b"x"), Version { block: 1, tx: 0 });
        }
        let hits = s.scan_prefix("models/r1/");
        assert_eq!(
            hits.iter().map(|(k, _)| k.as_str()).collect::<Vec<_>>(),
            vec!["models/r1/c1", "models/r1/c2"]
        );
    }

    #[test]
    fn property_serial_apply_keeps_latest() {
        check("state-latest-write-wins", 32, |rng| {
            let mut s = WorldState::new();
            let mut last: HashMap<String, Vec<u8>> = HashMap::new();
            for b in 0..rng.range(1, 30) as u64 {
                let key = format!("k{}", rng.below(5));
                let val = rng.next_u64().to_le_bytes().to_vec();
                s.apply(&w(&key, &val), Version { block: b, tx: 0 });
                last.insert(key, val);
            }
            for (k, v) in &last {
                assert_eq!(s.get_value(k), Some(v.as_slice()));
            }
        });
    }
}
