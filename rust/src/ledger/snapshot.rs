//! Point-in-time `WorldState` snapshots for the durable ledger.
//!
//! A snapshot freezes everything the commit pipeline needs to resume a
//! channel without replaying from genesis: the sorted key/value/version
//! entries (stamped with a Merkle **state root** over them, reusing
//! `crypto::merkle`), the chain tip (height + tip hash) the state
//! corresponds to, the MVCC write sequence, and the committed-txid dedup
//! set (so a replayed `DuplicateTxId` verdict recomputes identically).
//!
//! On disk a snapshot is one CRC-framed record written atomically: encode
//! to a `.tmp` sibling, fsync, then `rename` over the live file — a crash
//! mid-write leaves the previous snapshot intact, and a torn/corrupt file
//! is detected by the CRC + recomputed state root and simply ignored
//! (recovery falls back to replaying the block log from its start).

use std::fs::{self, File, OpenOptions};
use std::io::Write as _;
use std::path::Path;

use crate::crypto::{merkle, sha256_parts, Digest};
use crate::ledger::codec::{Reader, Writer};
use crate::ledger::state::{Version, WorldState};
use crate::ledger::store::{crc32, FRAME_BYTES};
use crate::ledger::tx::TxId;

/// Merkle root over sorted (key, value, version) entries: one leaf per
/// entry, each a length-delimited hash of its fields. Two states agree on
/// every key, value, and version iff their roots match.
pub fn state_root(entries: &[(&str, &[u8], Version)]) -> Digest {
    let leaves: Vec<Digest> = entries
        .iter()
        .map(|(k, v, ver)| {
            sha256_parts(&[k.as_bytes(), v, &ver.block.to_le_bytes(), &ver.tx.to_le_bytes()])
        })
        .collect();
    merkle::root(&leaves)
}

/// A consistent cut of one channel's replica, as persisted.
#[derive(Clone, Debug, PartialEq)]
pub struct Snapshot {
    /// Chain height the cut was taken at (number of committed blocks).
    pub height: u64,
    /// Hash of block `height - 1` (`Digest::ZERO` for an empty chain).
    pub tip_hash: Digest,
    /// [`state_root`] over `entries`; verified on load.
    pub state_root: Digest,
    /// MVCC write sequence at the cut.
    pub seq: u64,
    /// World state entries, sorted by key.
    pub entries: Vec<(String, Vec<u8>, Version)>,
    /// Committed transaction ids (sorted; the duplicate-txid dedup set).
    pub committed_ids: Vec<TxId>,
}

impl Snapshot {
    /// Capture a snapshot from live replica structures. The caller must
    /// hold the channel's commit locks so chain, state, and dedup set are
    /// one consistent cut.
    pub fn capture(
        height: u64,
        tip_hash: Digest,
        state: &WorldState,
        committed_ids: impl IntoIterator<Item = TxId>,
    ) -> Snapshot {
        let borrowed = state.entries();
        let root = state_root(&borrowed);
        let entries =
            borrowed.into_iter().map(|(k, v, ver)| (k.to_string(), v.to_vec(), ver)).collect();
        let mut ids: Vec<TxId> = committed_ids.into_iter().collect();
        ids.sort_by(|a, b| a.0.cmp(&b.0));
        Snapshot {
            height,
            tip_hash,
            state_root: root,
            seq: state.seq(),
            entries,
            committed_ids: ids,
        }
    }

    /// Recompute the state root from the entries and compare with the
    /// stored one (load-time integrity check).
    pub fn verify(&self) -> bool {
        let borrowed: Vec<(&str, &[u8], Version)> =
            self.entries.iter().map(|(k, v, ver)| (k.as_str(), v.as_slice(), *ver)).collect();
        state_root(&borrowed) == self.state_root
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.u64(self.height);
        w.bytes(&self.tip_hash.0);
        w.bytes(&self.state_root.0);
        w.u64(self.seq);
        w.u32(self.entries.len() as u32);
        for (k, v, ver) in &self.entries {
            w.str(k).bytes(v).u64(ver.block).u32(ver.tx);
        }
        w.u32(self.committed_ids.len() as u32);
        for id in &self.committed_ids {
            w.bytes(&id.0);
        }
        w.finish()
    }

    pub fn decode(buf: &[u8]) -> Result<Snapshot, String> {
        let mut r = Reader::new(buf);
        let height = r.u64()?;
        let tip_hash = digest(&mut r)?;
        let state_root = digest(&mut r)?;
        let seq = r.u64()?;
        // Count prefixes are validated against the remaining bytes before
        // any capacity is sized from them (a corrupt file must not
        // over-allocate).
        let nentries = r.count(20)?;
        let mut entries = Vec::with_capacity(nentries);
        for _ in 0..nentries {
            let k = r.str()?;
            let v = r.bytes()?.to_vec();
            let ver = Version { block: r.u64()?, tx: r.u32()? };
            entries.push((k, v, ver));
        }
        let nids = r.count(36)?;
        let mut committed_ids = Vec::with_capacity(nids);
        for _ in 0..nids {
            committed_ids.push(digest(&mut r)?);
        }
        if !r.done() {
            return Err("trailing bytes in snapshot".into());
        }
        Ok(Snapshot { height, tip_hash, state_root, seq, entries, committed_ids })
    }
}

fn digest(r: &mut Reader<'_>) -> Result<Digest, String> {
    let b: [u8; 32] = r.bytes()?.try_into().map_err(|_| "bad digest length".to_string())?;
    Ok(Digest(b))
}

/// Atomically replace the snapshot at `path`: CRC-framed payload into
/// `path.tmp`, fsync, rename. The rename is the commit point.
pub fn write_atomic(path: &Path, snap: &Snapshot) -> Result<(), String> {
    let payload = snap.encode();
    let mut framed = Vec::with_capacity(FRAME_BYTES + payload.len());
    framed.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    framed.extend_from_slice(&crc32(&payload).to_le_bytes());
    framed.extend_from_slice(&payload);
    let tmp = path.with_extension("tmp");
    let mut f = OpenOptions::new()
        .write(true)
        .create(true)
        .truncate(true)
        .open(&tmp)
        .map_err(|e| format!("open {}: {e}", tmp.display()))?;
    f.write_all(&framed).map_err(|e| format!("write {}: {e}", tmp.display()))?;
    f.sync_data().map_err(|e| format!("sync {}: {e}", tmp.display()))?;
    drop(f);
    fs::rename(&tmp, path).map_err(|e| format!("rename {}: {e}", path.display()))?;
    // Persist the rename itself where the platform allows directory syncs.
    if let Some(dir) = path.parent() {
        if let Ok(d) = File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

/// Load the snapshot at `path`. `None` means "no usable snapshot" —
/// missing file, torn frame, CRC mismatch, undecodable payload, or a
/// state root that no longer matches the entries. Recovery treats all of
/// those identically: fall back to full log replay.
pub fn load(path: &Path) -> Option<Snapshot> {
    let buf = fs::read(path).ok()?;
    if buf.len() < FRAME_BYTES {
        return None;
    }
    let len = u32::from_le_bytes(buf[0..4].try_into().unwrap()) as usize;
    let crc = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    let payload = buf.get(FRAME_BYTES..FRAME_BYTES + len)?;
    if crc32(payload) != crc {
        return None;
    }
    let snap = Snapshot::decode(payload).ok()?;
    if !snap.verify() {
        return None;
    }
    Some(snap)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ledger::tx::RwSet;
    use crate::util::tempdir::TempDir;

    fn state_with(keys: &[&str]) -> WorldState {
        let mut s = WorldState::new();
        for (i, k) in keys.iter().enumerate() {
            let rw = RwSet {
                reads: vec![],
                writes: vec![(k.to_string(), Some(k.as_bytes().to_vec()))],
            };
            s.apply(&rw, Version { block: 1, tx: i as u32 });
        }
        s
    }

    #[test]
    fn state_root_is_order_canonical_and_content_sensitive() {
        // Insertion order does not matter — entries are key-sorted.
        let v = Version { block: 1, tx: 0 };
        let fwd = vec![
            ("x".to_string(), b"v".to_vec(), v),
            ("y".to_string(), b"w".to_vec(), v),
        ];
        let rev: Vec<_> = fwd.iter().rev().cloned().collect();
        assert_eq!(
            state_root(&WorldState::from_entries(fwd, 2).entries()),
            state_root(&WorldState::from_entries(rev, 2).entries())
        );
        // Same keys, different versions (apply order) → different roots.
        let a = state_with(&["x", "y", "z"]);
        let b = state_with(&["z", "x", "y"]);
        assert_ne!(state_root(&a.entries()), state_root(&b.entries()));
        assert_eq!(state_root(&[]), Digest::ZERO);
    }

    #[test]
    fn roundtrip_and_verify() {
        let s = state_with(&["a", "b"]);
        let ids = vec![Digest([1; 32]), Digest([2; 32])];
        let snap = Snapshot::capture(5, Digest([9; 32]), &s, ids.clone());
        assert!(snap.verify());
        assert_eq!(snap.seq, 2);
        assert_eq!(snap.committed_ids, ids);
        let back = Snapshot::decode(&snap.encode()).unwrap();
        assert_eq!(back, snap);
        // Tampering a value breaks the root check.
        let mut bad = back;
        bad.entries[0].1 = b"other".to_vec();
        assert!(!bad.verify());
    }

    #[test]
    fn atomic_write_load_and_corruption_fallback() {
        let dir = TempDir::new("snap");
        let path = dir.join("state.snap");
        assert!(load(&path).is_none(), "missing file is not an error");
        let s = state_with(&["k1", "k2", "k3"]);
        let snap = Snapshot::capture(3, Digest([7; 32]), &s, vec![Digest([4; 32])]);
        write_atomic(&path, &snap).unwrap();
        assert_eq!(load(&path), Some(snap.clone()));
        // Overwrite is atomic: the tmp sibling never lingers.
        let s2 = state_with(&["k1", "k2", "k3", "k4"]);
        let snap2 = Snapshot::capture(4, Digest([8; 32]), &s2, vec![Digest([4; 32])]);
        write_atomic(&path, &snap2).unwrap();
        assert!(!path.with_extension("tmp").exists());
        assert_eq!(load(&path).unwrap().height, 4);
        // Flip one payload byte: the CRC (or root) check rejects the file.
        let mut bytes = fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        fs::write(&path, &bytes).unwrap();
        assert!(load(&path).is_none());
        // Truncation is also just "no snapshot".
        fs::write(&path, &bytes[..bytes.len() / 3]).unwrap();
        assert!(load(&path).is_none());
    }
}
