//! The durable half of the ledger: an append-only, CRC-framed block log
//! with batched fsync, plus crash recovery by scan + snapshot reconcile.
//!
//! # On-disk layout
//!
//! One directory per (peer, channel):
//!
//! ```text
//! <dir>/blocks.log    [u32 len][u32 crc32(payload)][payload] ...
//! <dir>/state.snap    one CRC-framed snapshot record (atomic rename)
//! ```
//!
//! Each log payload is a full committed block (`fabric::wire::encode_block`:
//! header, envelopes, validation codes), so a cold peer can rebuild both
//! the hash chain and — by re-validating — the world state from the log
//! alone. Snapshots (`crate::ledger::snapshot`) bound the replay suffix.
//!
//! # Durability modes
//!
//! | mode | fsync cost per block | loss window on crash |
//! |------|----------------------|----------------------|
//! | [`DurabilityMode::Off`] | none | everything since the OS last flushed the page cache |
//! | [`DurabilityMode::Group`]`(t)` | amortized: the writer thread fsyncs at most once per `t` across all appends | ≤ `t` of committed blocks |
//! | [`DurabilityMode::Strict`] | one `fdatasync` per block, inline | none (single-machine) |
//!
//! `Group` is the group-commit pattern: appends write into the page cache
//! (cheap, in commit order, under the log lock) and mark the log dirty; a
//! dedicated writer thread wakes, lets a coalescing window pass, then
//! pays one fsync for every block that landed inside it. A graceful
//! shutdown (drop) flushes the window, so only a hard kill can lose the
//! tail — which recovery then truncates cleanly.
//!
//! # Recovery
//!
//! [`LedgerStore::open`] scans the log, accepting the longest prefix of
//! records that frame correctly (length + CRC), decode, and chain (block
//! numbering, prev-hash linkage, merkle data hash). Everything after the
//! first violation is a torn tail: it is truncated, never trusted. The
//! scan result is reconciled with the snapshot file (see
//! [`Recovery`]) and the caller — [`crate::fabric::peer::Peer::attach_store`]
//! — replays the suffix through the regular `BlockValidator` path.

use std::fs::{self, File, OpenOptions};
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread;
use std::time::{Duration, Instant};

use crate::fabric::wire;
use crate::ledger::block::Block;
use crate::ledger::chain::Chain;
use crate::ledger::codec::{Reader, Writer};
use crate::ledger::snapshot::{self, Snapshot};
use crate::telemetry::{self, Sample};
use crate::util::histogram::Histogram;
use crate::util::json::Json;

/// Bytes of framing per record: u32 payload length + u32 CRC32.
pub const FRAME_BYTES: usize = 8;

/// CRC-32 (IEEE 802.3, reflected, poly 0xEDB88320) — the ubiquitous
/// zlib/gzip polynomial, hand-rolled because no checksum crate is in the
/// offline vendor set.
pub fn crc32(data: &[u8]) -> u32 {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc = table[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    !crc
}

/// When appended blocks reach the disk (module docs for the tradeoffs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DurabilityMode {
    /// Write to the page cache only; never fsync.
    Off,
    /// Group commit: a writer thread batches fsyncs, at most one per
    /// interval. Bounded loss window, near-`Off` throughput.
    Group(Duration),
    /// `fdatasync` inline on every append.
    Strict,
}

/// Per-channel persistence configuration, carried by
/// [`crate::fabric::orderer::OrdererConfig::ledger`].
#[derive(Clone, Debug)]
pub struct LedgerConfig {
    /// Root directory; each peer channel stores under
    /// `<dir>/<member>/<channel>/`.
    pub dir: PathBuf,
    pub durability: DurabilityMode,
    /// Write a state snapshot every N blocks (0 = log only, full replay).
    pub snapshot_every: u64,
}

impl LedgerConfig {
    /// Group-commit defaults: 5 ms fsync window, snapshot every 64 blocks.
    pub fn new(dir: impl Into<PathBuf>) -> LedgerConfig {
        LedgerConfig {
            dir: dir.into(),
            durability: DurabilityMode::Group(Duration::from_millis(5)),
            snapshot_every: 64,
        }
    }
}

/// Store counters, atomics so the group-commit thread and the commit path
/// report without sharing locks (same pattern as `mempool::MempoolStats`).
#[derive(Debug, Default)]
pub struct StoreStats {
    blocks_appended: AtomicU64,
    bytes_appended: AtomicU64,
    fsyncs: AtomicU64,
    snapshots_written: AtomicU64,
    recovered_blocks: AtomicU64,
    torn_bytes_truncated: AtomicU64,
    fsync_latency: Mutex<Histogram>,
}

impl StoreStats {
    fn note_append(&self, bytes: u64) {
        self.blocks_appended.fetch_add(1, Ordering::Relaxed);
        self.bytes_appended.fetch_add(bytes, Ordering::Relaxed);
    }

    fn note_fsync(&self, seconds: f64) {
        self.fsyncs.fetch_add(1, Ordering::Relaxed);
        self.fsync_latency.lock().unwrap().record(seconds);
    }

    pub fn snapshot(&self) -> StoreSnapshot {
        let h = self.fsync_latency.lock().unwrap();
        StoreSnapshot {
            blocks_appended: self.blocks_appended.load(Ordering::Relaxed),
            bytes_appended: self.bytes_appended.load(Ordering::Relaxed),
            fsyncs: self.fsyncs.load(Ordering::Relaxed),
            fsync_mean_s: h.mean(),
            fsync_p99_s: h.quantile(0.99).unwrap_or(0.0),
            snapshots_written: self.snapshots_written.load(Ordering::Relaxed),
            recovered_blocks: self.recovered_blocks.load(Ordering::Relaxed),
            torn_bytes_truncated: self.torn_bytes_truncated.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of a store's counters.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StoreSnapshot {
    pub blocks_appended: u64,
    pub bytes_appended: u64,
    pub fsyncs: u64,
    pub fsync_mean_s: f64,
    pub fsync_p99_s: f64,
    pub snapshots_written: u64,
    pub recovered_blocks: u64,
    pub torn_bytes_truncated: u64,
}

impl StoreSnapshot {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("blocks_appended", self.blocks_appended)
            .set("bytes_appended", self.bytes_appended)
            .set("fsyncs", self.fsyncs)
            .set("fsync_mean_s", self.fsync_mean_s)
            .set("fsync_p99_s", self.fsync_p99_s)
            .set("snapshots_written", self.snapshots_written)
            .set("recovered_blocks", self.recovered_blocks)
            .set("torn_bytes_truncated", self.torn_bytes_truncated)
    }
}

/// What [`LedgerStore::open`] found on disk. The blocks in `replay` start
/// at the snapshot boundary (or genesis) and have passed framing, CRC,
/// decode, and hash-chain checks — but *not* re-validation; the peer
/// replays them through its `BlockValidator` before trusting the state.
#[derive(Debug)]
pub struct Recovery {
    /// Verified snapshot to restore state/chain-anchor from, if any.
    pub snapshot: Option<Snapshot>,
    /// Log blocks above the snapshot boundary, in order.
    pub replay: Vec<Block>,
    /// Bytes cut off the log tail (torn frame, bad CRC, broken linkage,
    /// or a whole log orphaned behind its snapshot).
    pub truncated_bytes: u64,
    /// True when a snapshot file existed but failed its integrity checks
    /// (the store fell back to full log replay).
    pub snapshot_fallback: bool,
}

impl Recovery {
    /// Chain height once snapshot + replay are applied.
    pub fn height(&self) -> u64 {
        match (&self.snapshot, self.replay.last()) {
            (_, Some(b)) => b.header.number + 1,
            (Some(s), None) => s.height,
            (None, None) => 0,
        }
    }
}

struct LogInner {
    file: File,
    /// Next block number the log accepts (appends must be in chain order).
    next_number: u64,
}

/// Append-only block log + snapshot writer for one peer channel.
pub struct LedgerStore {
    dir: PathBuf,
    durability: DurabilityMode,
    snapshot_every: u64,
    log: Mutex<LogInner>,
    stats: Arc<StoreStats>,
    /// Group-commit handshake: appends set `dirty`, the writer thread
    /// clears it around one fsync per window.
    group: Arc<(Mutex<GroupFlags>, Condvar)>,
    syncer: Mutex<Option<thread::JoinHandle<()>>>,
    /// Height of the last snapshot written (monotone guard).
    snap_height: Mutex<u64>,
}

#[derive(Default)]
struct GroupFlags {
    dirty: bool,
    closed: bool,
}

fn frame(block: &Block) -> Vec<u8> {
    let mut w = Writer::new();
    wire::encode_block(block, &mut w);
    let payload = w.finish();
    let mut rec = Vec::with_capacity(FRAME_BYTES + payload.len());
    rec.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    rec.extend_from_slice(&crc32(&payload).to_le_bytes());
    rec.extend_from_slice(&payload);
    rec
}

/// Longest valid record prefix of the raw log bytes: framing, CRC,
/// decode, and hash-chain linkage (anchored at the first record's own
/// prev-hash — the snapshot reconcile pins it down). Returns the blocks
/// and the byte offset where validity ends.
fn scan_log(buf: &[u8]) -> (Vec<Block>, usize) {
    let mut blocks: Vec<Block> = Vec::new();
    let mut chain: Option<Chain> = None;
    let mut offset = 0usize;
    loop {
        let Some(header) = buf.get(offset..offset + FRAME_BYTES) else { break };
        let len = u32::from_le_bytes(header[0..4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(header[4..8].try_into().unwrap());
        let Some(payload) = buf.get(offset + FRAME_BYTES..offset + FRAME_BYTES + len) else {
            break;
        };
        if crc32(payload) != crc {
            break;
        }
        let mut r = Reader::new(payload);
        let Ok(block) = wire::decode_block(&mut r) else { break };
        if !r.done() {
            break;
        }
        let c = chain.get_or_insert_with(|| {
            Chain::with_base(block.header.number, block.header.prev_hash)
        });
        if c.append(block.clone()).is_err() {
            break;
        }
        blocks.push(block);
        offset += FRAME_BYTES + len;
    }
    (blocks, offset)
}

impl LedgerStore {
    /// Open (creating if absent) the store in `dir`, recover whatever is
    /// on disk, and start the group-commit writer if configured.
    ///
    /// `channel`/`peer` label the store's telemetry series
    /// (`scalesfl_ledger_*`), registered weakly with the global registry.
    pub fn open(
        dir: &Path,
        channel: &str,
        peer: &str,
        durability: DurabilityMode,
        snapshot_every: u64,
    ) -> Result<(Arc<LedgerStore>, Recovery), String> {
        fs::create_dir_all(dir).map_err(|e| format!("create {}: {e}", dir.display()))?;
        let log_path = dir.join("blocks.log");
        let raw = match fs::read(&log_path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
            Err(e) => return Err(format!("read {}: {e}", log_path.display())),
        };
        let (mut blocks, mut good_end) = scan_log(&raw);
        let snap_path = dir.join("state.snap");
        let snapshot_fallback = snap_path.exists();
        let snap = snapshot::load(&snap_path);
        let snapshot_fallback = snapshot_fallback && snap.is_none();

        // Reconcile log and snapshot into (restore-from, replay-suffix).
        let base = blocks.first().map(|b| b.header.number);
        let (snapshot, replay) = match (snap, base) {
            // Empty log: the snapshot (if any) is the whole truth.
            (snap, None) => (snap, Vec::new()),
            // No usable snapshot: only a genesis-rooted log can replay.
            (None, Some(0)) => (None, blocks),
            (None, Some(b)) => {
                return Err(format!(
                    "log starts at block {b} but no valid snapshot anchors it"
                ));
            }
            (Some(s), Some(b)) => {
                let end = b + blocks.len() as u64; // exclusive log end
                if b > s.height || s.height > end {
                    // The log is disconnected from the snapshot (a gap
                    // ahead of it, or it ends behind the snapshot after a
                    // crash under `Off`). The snapshot is self-verifying
                    // and newer-or-equal in the second case; drop the log.
                    blocks.clear();
                    good_end = 0;
                    (Some(s), Vec::new())
                } else {
                    // s.height ∈ [b, end]: check the seam, then replay the
                    // suffix above the snapshot.
                    let at = (s.height - b) as usize;
                    let seam_ok = if at == 0 {
                        blocks[0].header.prev_hash == s.tip_hash
                    } else {
                        blocks[at - 1].hash() == s.tip_hash
                    };
                    if !seam_ok {
                        if b == 0 {
                            // Snapshot disagrees with a genesis-rooted
                            // log; the log is the longer-lived artifact —
                            // ignore the snapshot and replay everything.
                            (None, blocks)
                        } else {
                            return Err(format!(
                                "snapshot tip at height {} does not match the block log",
                                s.height
                            ));
                        }
                    } else {
                        (Some(s), blocks.split_off(at))
                    }
                }
            }
        };

        let truncated_bytes = (raw.len() - good_end) as u64;
        let next_number = match (&snapshot, replay.last()) {
            (_, Some(last)) => last.header.number + 1,
            (Some(s), None) => s.height,
            (None, None) => 0,
        };

        // Materialize the truncation (torn tail and/or orphaned log).
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(&log_path)
            .map_err(|e| format!("open {}: {e}", log_path.display()))?;
        if truncated_bytes > 0 {
            file.set_len(good_end as u64)
                .map_err(|e| format!("truncate {}: {e}", log_path.display()))?;
        }
        let mut inner = LogInner { file, next_number };
        use std::io::Seek as _;
        inner
            .file
            .seek(std::io::SeekFrom::End(0))
            .map_err(|e| format!("seek {}: {e}", log_path.display()))?;

        let stats = Arc::new(StoreStats::default());
        stats.recovered_blocks.fetch_add(replay.len() as u64, Ordering::Relaxed);
        stats.torn_bytes_truncated.fetch_add(truncated_bytes, Ordering::Relaxed);
        register_telemetry(&stats, channel, peer);

        let store = Arc::new(LedgerStore {
            dir: dir.to_path_buf(),
            durability,
            snapshot_every,
            log: Mutex::new(inner),
            stats,
            group: Arc::new((Mutex::new(GroupFlags::default()), Condvar::new())),
            syncer: Mutex::new(None),
            snap_height: Mutex::new(snapshot.as_ref().map(|s| s.height).unwrap_or(0)),
        });
        if let DurabilityMode::Group(interval) = durability {
            store.start_syncer(interval)?;
        }
        let recovery = Recovery { snapshot, replay, truncated_bytes, snapshot_fallback };
        Ok((store, recovery))
    }

    fn start_syncer(self: &Arc<Self>, interval: Duration) -> Result<(), String> {
        let file = self
            .log
            .lock()
            .unwrap()
            .file
            .try_clone()
            .map_err(|e| format!("clone log handle: {e}"))?;
        let group = Arc::clone(&self.group);
        let stats = Arc::clone(&self.stats);
        let handle = thread::Builder::new()
            .name("ledger-sync".into())
            .spawn(move || {
                let (lock, cv) = &*group;
                loop {
                    let mut g = lock.lock().unwrap();
                    while !g.dirty && !g.closed {
                        g = cv.wait(g).unwrap();
                    }
                    if g.dirty {
                        let closing = g.closed;
                        drop(g);
                        if !closing {
                            // Coalescing window: every append landing in
                            // here rides the same fsync.
                            thread::sleep(interval);
                        }
                        lock.lock().unwrap().dirty = false;
                        fsync(&file, &stats);
                        continue;
                    }
                    return; // closed and clean
                }
            })
            .map_err(|e| format!("spawn ledger-sync: {e}"))?;
        *self.syncer.lock().unwrap() = Some(handle);
        Ok(())
    }

    /// Append a committed block. Must be called in chain order (the
    /// caller holds the channel's chain lock, which serializes this).
    /// Durability per the configured mode; `Strict` pays its fsync here.
    pub fn append(&self, block: &Block) -> Result<(), String> {
        let rec = frame(block);
        let mut log = self.log.lock().unwrap();
        if block.header.number != log.next_number {
            return Err(format!(
                "out-of-order append: block {} where log expects {}",
                block.header.number, log.next_number
            ));
        }
        log.file.write_all(&rec).map_err(|e| format!("append block log: {e}"))?;
        log.next_number += 1;
        self.stats.note_append(rec.len() as u64);
        match self.durability {
            DurabilityMode::Off => {}
            DurabilityMode::Strict => fsync(&log.file, &self.stats),
            DurabilityMode::Group(_) => {
                let (lock, cv) = &*self.group;
                lock.lock().unwrap().dirty = true;
                cv.notify_one();
            }
        }
        Ok(())
    }

    /// Should the channel snapshot after committing block `height - 1`?
    pub fn should_snapshot(&self, height: u64) -> bool {
        self.snapshot_every > 0 && height > 0 && height % self.snapshot_every == 0
    }

    /// Persist a snapshot (atomic replace). Stale cuts — at or below the
    /// height already on disk — are skipped, so concurrent committers
    /// can race here harmlessly.
    pub fn write_snapshot(&self, snap: &Snapshot) -> Result<(), String> {
        let mut last = self.snap_height.lock().unwrap();
        if snap.height <= *last && *last > 0 {
            return Ok(());
        }
        snapshot::write_atomic(&self.dir.join("state.snap"), snap)?;
        *last = snap.height;
        self.stats.snapshots_written.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Force an fsync now (used by tests and graceful shutdown).
    pub fn sync(&self) {
        let log = self.log.lock().unwrap();
        fsync(&log.file, &self.stats);
    }

    pub fn stats(&self) -> StoreSnapshot {
        self.stats.snapshot()
    }

    /// Next block number the log will accept.
    pub fn height(&self) -> u64 {
        self.log.lock().unwrap().next_number
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

impl Drop for LedgerStore {
    fn drop(&mut self) {
        let (lock, cv) = &*self.group;
        lock.lock().unwrap().closed = true;
        cv.notify_all();
        if let Some(h) = self.syncer.lock().unwrap().take() {
            let _ = h.join();
        }
        // Graceful close makes Group durable through the final window;
        // `Off` keeps its contract (never fsync).
        if matches!(self.durability, DurabilityMode::Group(_)) {
            let log = self.log.lock().unwrap();
            let _ = log.file.sync_data();
        }
    }
}

fn fsync(file: &File, stats: &StoreStats) {
    let t0 = Instant::now();
    // An fsync error here would mean losing the durability claim, but the
    // commit itself already happened; surfacing it as a panic would take
    // down the committer thread. Count the attempt and move on — the
    // recovery path never trusts unverified bytes anyway.
    let _ = file.sync_data();
    stats.note_fsync(t0.elapsed().as_secs_f64());
}

fn register_telemetry(stats: &Arc<StoreStats>, channel: &str, peer: &str) {
    let labels = vec![
        ("channel".to_string(), channel.to_string()),
        ("peer".to_string(), peer.to_string()),
    ];
    let weak = Arc::downgrade(stats);
    telemetry::global().registry().register(move || {
        let stats = weak.upgrade()?;
        let s = stats.snapshot();
        let fsync_hist = stats.fsync_latency.lock().unwrap();
        Some(vec![
            Sample::counter(
                "scalesfl_ledger_blocks_appended_total",
                labels.clone(),
                s.blocks_appended as f64,
            ),
            Sample::counter(
                "scalesfl_ledger_bytes_appended_total",
                labels.clone(),
                s.bytes_appended as f64,
            ),
            Sample::counter("scalesfl_ledger_fsyncs_total", labels.clone(), s.fsyncs as f64),
            Sample::summary("scalesfl_ledger_fsync_seconds", labels.clone(), &fsync_hist),
            Sample::counter(
                "scalesfl_ledger_snapshots_written_total",
                labels.clone(),
                s.snapshots_written as f64,
            ),
            Sample::counter(
                "scalesfl_ledger_recovered_blocks_total",
                labels.clone(),
                s.recovered_blocks as f64,
            ),
            Sample::counter(
                "scalesfl_ledger_torn_bytes_truncated_total",
                labels.clone(),
                s.torn_bytes_truncated as f64,
            ),
        ])
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::msp::MemberId;
    use crate::crypto::Digest;
    use crate::ledger::block::ValidationCode;
    use crate::ledger::state::WorldState;
    use crate::ledger::tx::{Envelope, Proposal, RwSet};
    use crate::util::tempdir::TempDir;

    fn env(nonce: u64) -> Envelope {
        Envelope {
            proposal: Proposal {
                channel: "ch".into(),
                chaincode: "kv".into(),
                function: "Put".into(),
                args: vec![format!("k{nonce}")],
                creator: MemberId::new("client"),
                nonce,
            },
            rw_set: RwSet {
                reads: vec![],
                writes: vec![(format!("k{nonce}"), Some(vec![nonce as u8]))],
            },
            endorsements: vec![],
        }
    }

    fn blocks(n: u64) -> Vec<Block> {
        let mut chain = Chain::new();
        let mut out = Vec::new();
        for i in 0..n {
            let mut b = Block::new(i, chain.tip_hash(), vec![env(i)]);
            b.validation = vec![ValidationCode::Valid];
            chain.append(b.clone()).unwrap();
            out.push(b);
        }
        out
    }

    fn open_off(dir: &Path) -> (Arc<LedgerStore>, Recovery) {
        LedgerStore::open(dir, "ch", "p0", DurabilityMode::Off, 0).unwrap()
    }

    #[test]
    fn crc32_known_answer() {
        // Standard check value for CRC-32/ISO-HDLC.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn append_reopen_replays_all_modes() {
        for mode in [
            DurabilityMode::Off,
            DurabilityMode::Group(Duration::from_millis(1)),
            DurabilityMode::Strict,
        ] {
            let dir = TempDir::new("store");
            let bs = blocks(5);
            {
                let (store, rec) = LedgerStore::open(dir.path(), "ch", "p0", mode, 0).unwrap();
                assert!(rec.snapshot.is_none() && rec.replay.is_empty());
                for b in &bs {
                    store.append(b).unwrap();
                }
                assert_eq!(store.height(), 5);
                let s = store.stats();
                assert_eq!(s.blocks_appended, 5);
                assert!(s.bytes_appended > 0);
                match mode {
                    DurabilityMode::Strict => assert_eq!(s.fsyncs, 5),
                    DurabilityMode::Off => assert_eq!(s.fsyncs, 0),
                    DurabilityMode::Group(_) => {}
                }
            }
            let (store, rec) = LedgerStore::open(dir.path(), "ch", "p0", mode, 0).unwrap();
            assert_eq!(rec.replay, bs, "mode {mode:?}");
            assert_eq!(rec.truncated_bytes, 0);
            assert_eq!(rec.height(), 5);
            assert_eq!(store.height(), 5);
            assert_eq!(store.stats().recovered_blocks, 5);
        }
    }

    #[test]
    fn group_mode_batches_fsyncs() {
        let dir = TempDir::new("store");
        let (store, _) = LedgerStore::open(
            dir.path(),
            "ch",
            "p0",
            DurabilityMode::Group(Duration::from_millis(20)),
            0,
        )
        .unwrap();
        for b in blocks(10) {
            store.append(&b).unwrap();
        }
        // 10 back-to-back appends land inside very few 20 ms windows: the
        // writer thread coalesces them (Strict would have paid 10 here).
        assert!(store.stats().fsyncs < 10, "fsyncs = {}", store.stats().fsyncs);
        drop(store); // joins the syncer, flushing the final window
        let (_store, rec) = open_off(dir.path());
        assert_eq!(rec.replay.len(), 10);
    }

    #[test]
    fn out_of_order_append_rejected() {
        let dir = TempDir::new("store");
        let (store, _) = open_off(dir.path());
        let bs = blocks(3);
        store.append(&bs[0]).unwrap();
        let err = store.append(&bs[2]).unwrap_err();
        assert!(err.contains("out-of-order"), "{err}");
        store.append(&bs[1]).unwrap();
    }

    /// The torn-write property test from the issue: truncate a valid log
    /// at EVERY byte offset; recovery must never panic, always yield a
    /// verified prefix of whole blocks, and accept new appends that keep
    /// the chain consistent.
    #[test]
    fn property_torn_tail_recovery_at_every_offset() {
        let bs = blocks(4);
        let full: Vec<u8> = {
            let dir = TempDir::new("store");
            let (store, _) = open_off(dir.path());
            for b in &bs {
                store.append(b).unwrap();
            }
            drop(store);
            fs::read(dir.join("blocks.log")).unwrap()
        };
        // Record boundaries, to know how many whole blocks each cut keeps.
        let mut boundaries = vec![0usize];
        for b in &bs {
            boundaries.push(boundaries.last().unwrap() + frame(b).len());
        }
        assert_eq!(*boundaries.last().unwrap(), full.len());

        let dir = TempDir::new("torn");
        for cut in 0..=full.len() {
            let case = dir.join(&format!("cut{cut}"));
            fs::create_dir_all(&case).unwrap();
            fs::write(case.join("blocks.log"), &full[..cut]).unwrap();
            let (store, rec) =
                LedgerStore::open(&case, "ch", "p0", DurabilityMode::Off, 0).unwrap();
            let keep = boundaries.iter().filter(|&&b| b <= cut).count() - 1;
            assert_eq!(rec.replay.len(), keep, "cut at {cut}");
            assert_eq!(rec.replay[..], bs[..keep], "cut at {cut}");
            let torn = cut - boundaries[keep];
            assert_eq!(rec.truncated_bytes, torn as u64, "cut at {cut}");
            // The verified prefix forms a chain…
            let mut chain = Chain::new();
            for b in &rec.replay {
                chain.append(b.clone()).unwrap();
            }
            // …and re-appending after recovery stays consistent.
            let mut next = Block::new(keep as u64, chain.tip_hash(), vec![env(100 + cut as u64)]);
            next.validation = vec![ValidationCode::Valid];
            chain.append(next.clone()).unwrap();
            store.append(&next).unwrap();
            drop(store);
            let (_store2, rec2) =
                LedgerStore::open(&case, "ch", "p0", DurabilityMode::Off, 0).unwrap();
            assert_eq!(rec2.replay.len(), keep + 1, "cut at {cut}");
            assert_eq!(rec2.replay.last().unwrap(), &next);
            assert_eq!(rec2.truncated_bytes, 0, "truncation already healed");
        }
    }

    #[test]
    fn corrupt_mid_log_byte_truncates_from_there() {
        let dir = TempDir::new("store");
        let bs = blocks(4);
        {
            let (store, _) = open_off(dir.path());
            for b in &bs {
                store.append(b).unwrap();
            }
        }
        let path = dir.join("blocks.log");
        let mut raw = fs::read(&path).unwrap();
        // Flip a byte inside record 2's payload (skip records 0 and 1).
        let off = frame(&bs[0]).len() + frame(&bs[1]).len() + FRAME_BYTES + 10;
        raw[off] ^= 0x01;
        let total = raw.len();
        fs::write(&path, &raw).unwrap();
        let (_store, rec) = open_off(dir.path());
        assert_eq!(rec.replay, bs[..2], "CRC cut the log at the corrupt record");
        let kept = frame(&bs[0]).len() + frame(&bs[1]).len();
        assert_eq!(rec.truncated_bytes, (total - kept) as u64);
        assert_eq!(fs::metadata(&path).unwrap().len(), kept as u64);
    }

    #[test]
    fn snapshot_bounds_replay_and_orphaned_log_is_dropped() {
        let dir = TempDir::new("store");
        let bs = blocks(6);
        let snap_path = dir.join("state.snap");
        {
            let (store, _) = open_off(dir.path());
            for b in &bs {
                store.append(b).unwrap();
            }
            // Snapshot at height 4 (tip = hash of block 3). State content
            // is irrelevant to the seam logic; keep it empty.
            let snap =
                Snapshot::capture(4, bs[3].hash(), &WorldState::new(), Vec::<Digest>::new());
            store.write_snapshot(&snap).unwrap();
            assert_eq!(store.stats().snapshots_written, 1);
            // A stale snapshot write is skipped.
            let stale =
                Snapshot::capture(2, bs[1].hash(), &WorldState::new(), Vec::<Digest>::new());
            store.write_snapshot(&stale).unwrap();
            assert_eq!(store.stats().snapshots_written, 1);
        }
        let (_s, rec) = open_off(dir.path());
        let snap = rec.snapshot.expect("snapshot restored");
        assert_eq!(snap.height, 4);
        assert_eq!(rec.replay, bs[4..], "only the suffix above the snapshot replays");
        assert_eq!(rec.height(), 6);

        // Corrupt the snapshot: recovery falls back to full replay.
        let mut sb = fs::read(&snap_path).unwrap();
        let mid = sb.len() / 2;
        sb[mid] ^= 0xFF;
        fs::write(&snap_path, &sb).unwrap();
        let (_s, rec) = open_off(dir.path());
        assert!(rec.snapshot.is_none());
        assert!(rec.snapshot_fallback);
        assert_eq!(rec.replay, bs[..], "full replay covers for the bad snapshot");

        // Orphaned log: snapshot ahead of everything the log holds.
        let dir2 = TempDir::new("store");
        {
            let (store, _) = open_off(dir2.path());
            for b in &bs[..2] {
                store.append(b).unwrap();
            }
            let ahead =
                Snapshot::capture(5, bs[4].hash(), &WorldState::new(), Vec::<Digest>::new());
            store.write_snapshot(&ahead).unwrap();
        }
        let (store, rec) = open_off(dir2.path());
        assert_eq!(rec.snapshot.as_ref().unwrap().height, 5);
        assert!(rec.replay.is_empty());
        assert!(rec.truncated_bytes > 0, "behind-log is dropped");
        assert_eq!(store.height(), 5, "appends resume at the snapshot height");
        // The next append continues from the snapshot boundary (block 5
        // chains off the snapshot tip) and survives another reopen.
        store.append(&bs[5]).unwrap();
        drop(store);
        let (_s, rec) = open_off(dir2.path());
        assert_eq!(rec.snapshot.as_ref().unwrap().height, 5);
        assert_eq!(rec.replay, bs[5..]);
    }

    #[test]
    fn rebased_log_after_snapshot_boundary_reopens() {
        // A log whose first record is a non-genesis block is anchored by
        // the snapshot (the orphaned-log path above truncates to empty,
        // then appends continue at the boundary).
        let dir = TempDir::new("store");
        let bs = blocks(6);
        {
            let (store, _) = open_off(dir.path());
            for b in &bs[..4] {
                store.append(b).unwrap();
            }
            let snap =
                Snapshot::capture(4, bs[3].hash(), &WorldState::new(), Vec::<Digest>::new());
            store.write_snapshot(&snap).unwrap();
        }
        // Simulate log loss (e.g. Off-mode crash lost the file, snapshot
        // survived): the store rebases appends at the snapshot height.
        fs::remove_file(dir.join("blocks.log")).unwrap();
        {
            let (store, rec) = open_off(dir.path());
            assert_eq!(rec.height(), 4);
            assert!(rec.replay.is_empty());
            store.append(&bs[4]).unwrap();
            store.append(&bs[5]).unwrap();
        }
        let (_s, rec) = open_off(dir.path());
        assert_eq!(rec.snapshot.as_ref().unwrap().height, 4);
        assert_eq!(rec.replay, bs[4..], "rebased log replays above the snapshot");
        assert_eq!(rec.height(), 6);
    }
}
