//! Shared immutable envelope representation: the canonical wire encoding
//! behind an `Arc`, with lazily-computed, cached views.
//!
//! The hot path used to deep-clone [`Envelope`] structs through gateway →
//! mempool → relay → batch pull → validator, re-encoding at every
//! serialization point and re-hashing tx_id / rw-set digests at every hop.
//! [`SharedEnvelope`] replaces that with one canonical buffer:
//!
//! - **Clone = refcount bump.** Every pipeline stage holds an `Arc` to the
//!   same bytes; the only copy left is the final splice into a consensus
//!   payload or the ledger store (`Writer::raw`).
//! - **Hashes computed once, zero-copy.** `tx_id`, the rw-set digest and
//!   the envelope digest are derived directly from buffer slices (the wire
//!   layout is byte-identical to the digest preimages) and cached.
//! - **Decoding is lazy and fail-closed.** A buffer that arrived off the
//!   wire is not trusted until first access: every view returns
//!   `Err` on a corrupt buffer instead of panicking or yielding garbage.
//!   Buffers built from an in-memory [`Envelope`] pre-seed the decoded
//!   form, so trusted-path accessors never re-parse.
//!
//! The envelope wire codec itself ([`encode_envelope`] /
//! [`decode_envelope`]) lives here too; `fabric::wire` re-exports it and
//! splices pre-encoded buffers into batch and block payloads.

use std::ops::Range;
use std::sync::{Arc, OnceLock};

use sha2::{Digest as _, Sha256};

use crate::crypto::msp::{MemberId, Signature};
use crate::crypto::Digest;
use crate::ledger::codec::{Reader, WireError, Writer};
use crate::ledger::state::Version;
use crate::ledger::tx::{Endorsement, Envelope, Proposal, RwSet, TxId};

/// Serialize one proposal — the canonical envelope encoding's prefix, so a
/// proposal sent alone (e.g. in a remote `Endorse` request frame) is
/// byte-identical to the same fields inside a full envelope.
pub fn encode_proposal(p: &Proposal, w: &mut Writer) {
    w.str(&p.channel).str(&p.chaincode).str(&p.function);
    w.u32(p.args.len() as u32);
    for a in &p.args {
        w.str(a);
    }
    w.str(&p.creator.0).u64(p.nonce);
}

/// Deserialize one proposal (inverse of [`encode_proposal`]).
pub fn decode_proposal(r: &mut Reader<'_>) -> Result<Proposal, WireError> {
    let channel = r.str()?;
    let chaincode = r.str()?;
    let function = r.str()?;
    let nargs = r.count(4)?;
    let mut args = Vec::with_capacity(nargs);
    for _ in 0..nargs {
        args.push(r.str()?);
    }
    let creator = MemberId::new(r.str()?);
    let nonce = r.u64()?;
    Ok(Proposal { channel, chaincode, function, args, creator, nonce })
}

/// Serialize one envelope in canonical wire form.
pub fn encode_envelope(env: &Envelope, w: &mut Writer) {
    encode_proposal(&env.proposal, w);

    w.u32(env.rw_set.reads.len() as u32);
    for (k, ver) in &env.rw_set.reads {
        w.str(k);
        match ver {
            Some(v) => {
                w.u8(1).u64(v.block).u32(v.tx);
            }
            None => {
                w.u8(0);
            }
        }
    }
    w.u32(env.rw_set.writes.len() as u32);
    for (k, val) in &env.rw_set.writes {
        w.str(k);
        match val {
            Some(v) => {
                w.u8(1).bytes(v);
            }
            None => {
                w.u8(0);
            }
        }
    }
    w.u32(env.endorsements.len() as u32);
    for e in &env.endorsements {
        w.str(&e.endorser.0);
        w.bytes(&e.signature.0);
    }
}

/// Deserialize one envelope. Rejects non-canonical encodings (unknown
/// read/write tags, wrong signature length) so that decode acceptance
/// matches the zero-copy view parser exactly.
pub fn decode_envelope(r: &mut Reader<'_>) -> Result<Envelope, WireError> {
    // Count prefixes (here and in `decode_proposal`) are validated against
    // the remaining buffer (min wire size per element) before any capacity
    // is sized from them.
    let proposal = decode_proposal(r)?;

    let nreads = r.count(5)?;
    let mut reads = Vec::with_capacity(nreads);
    for _ in 0..nreads {
        let k = r.str()?;
        let ver = match r.u8()? {
            1 => Some(Version { block: r.u64()?, tx: r.u32()? }),
            0 => None,
            t => return Err(WireError::Malformed(format!("bad read-version tag {t}"))),
        };
        reads.push((k, ver));
    }
    let nwrites = r.count(5)?;
    let mut writes = Vec::with_capacity(nwrites);
    for _ in 0..nwrites {
        let k = r.str()?;
        let val = match r.u8()? {
            1 => Some(r.bytes()?.to_vec()),
            0 => None,
            t => return Err(WireError::Malformed(format!("bad write-value tag {t}"))),
        };
        writes.push((k, val));
    }
    let nend = r.count(40)?;
    let mut endorsements = Vec::with_capacity(nend);
    for _ in 0..nend {
        let endorser = MemberId::new(r.str()?);
        let sig_bytes = r.bytes()?;
        let sig: [u8; 32] =
            sig_bytes.try_into().map_err(|_| WireError::malformed("bad signature length"))?;
        endorsements.push(Endorsement { endorser, signature: Signature(sig) });
    }
    Ok(Envelope { proposal, rw_set: RwSet { reads, writes }, endorsements })
}

/// The hash views over one canonical buffer, computed in a single pass
/// without decoding (no allocation beyond the endorsement range list).
#[derive(Clone, Debug)]
struct Views {
    tx_id: TxId,
    rw_digest: Digest,
    digest: Digest,
    /// Byte range of the creator id inside the buffer.
    creator: Range<usize>,
}

/// Read a length-prefixed string field as a borrowed slice, validating
/// UTF-8 (matching `Reader::str` acceptance) without allocating.
fn str_slice<'a>(r: &mut Reader<'a>) -> Result<&'a [u8], WireError> {
    let b = r.bytes()?;
    std::str::from_utf8(b).map_err(|_| WireError::malformed("invalid utf-8 in string"))?;
    Ok(b)
}

/// Hash one `sha256_parts`-style part: u64-le length prefix, then bytes.
fn hash_part(h: &mut Sha256, part: &[u8]) {
    h.update((part.len() as u64).to_le_bytes());
    h.update(part);
}

/// Walk a canonical envelope buffer once, computing every cached view
/// directly from the wire bytes.
///
/// This leans on a deliberate layout identity: the wire encoding of the
/// read/write sections (minus their u32 counts) is byte-for-byte the
/// preimage `RwSet::digest` hashes, and the proposal fields appear in
/// exactly the order `Proposal::tx_id` feeds to `sha256_parts`. Accepts
/// precisely the buffers [`decode_envelope`] accepts (plus requiring the
/// buffer to end where the envelope does), so a corrupt buffer fails
/// closed at the first view access.
fn parse_views(bytes: &[u8]) -> Result<Views, WireError> {
    let mut r = Reader::new(bytes);

    // Proposal → tx_id (streamed sha256_parts over borrowed slices).
    // Count guards mirror `decode_envelope` exactly so acceptance stays
    // identical between the two parsers.
    let mut tx = Sha256::new();
    hash_part(&mut tx, str_slice(&mut r)?); // channel
    hash_part(&mut tx, str_slice(&mut r)?); // chaincode
    hash_part(&mut tx, str_slice(&mut r)?); // function
    let nargs = r.count(4)?;
    for _ in 0..nargs {
        hash_part(&mut tx, str_slice(&mut r)?);
    }
    let creator_bytes = str_slice(&mut r)?;
    let creator = r.pos() - creator_bytes.len()..r.pos();
    let nonce = r.u64()?;
    hash_part(&mut tx, creator_bytes);
    hash_part(&mut tx, &nonce.to_le_bytes());
    let tx_id = Digest(tx.finalize().into());

    // Read/write sections → rw-set digest over raw wire slices.
    let nreads = r.count(5)?;
    let reads_start = r.pos();
    for _ in 0..nreads {
        str_slice(&mut r)?;
        match r.u8()? {
            1 => {
                r.u64()?;
                r.u32()?;
            }
            0 => {}
            t => return Err(WireError::Malformed(format!("bad read-version tag {t}"))),
        }
    }
    let reads_end = r.pos();
    let nwrites = r.count(5)?;
    let writes_start = r.pos();
    for _ in 0..nwrites {
        str_slice(&mut r)?;
        match r.u8()? {
            1 => {
                r.bytes()?;
            }
            0 => {}
            t => return Err(WireError::Malformed(format!("bad write-value tag {t}"))),
        }
    }
    let writes_end = r.pos();
    let rw_len = (reads_end - reads_start) + 1 + (writes_end - writes_start);
    let mut rw = Sha256::new();
    rw.update((rw_len as u64).to_le_bytes());
    rw.update(&bytes[reads_start..reads_end]);
    rw.update([0xFFu8]);
    rw.update(&bytes[writes_start..writes_end]);
    let rw_digest = Digest(rw.finalize().into());

    // Endorsements → envelope digest.
    let nend = r.count(40)?;
    let mut ends: Vec<(Range<usize>, Range<usize>)> = Vec::with_capacity(nend);
    for _ in 0..nend {
        let endorser = str_slice(&mut r)?;
        let e_range = r.pos() - endorser.len()..r.pos();
        let sig = r.bytes()?;
        if sig.len() != 32 {
            return Err(WireError::malformed("bad signature length"));
        }
        let s_range = r.pos() - 32..r.pos();
        ends.push((e_range, s_range));
    }
    if !r.done() {
        return Err(WireError::malformed("trailing bytes after envelope"));
    }
    let total = 64 + ends.iter().map(|(e, s)| e.len() + s.len()).sum::<usize>();
    let mut h = Sha256::new();
    h.update((total as u64).to_le_bytes());
    h.update(tx_id.0);
    h.update(rw_digest.0);
    for (e, s) in &ends {
        h.update(&bytes[e.clone()]);
        h.update(&bytes[s.clone()]);
    }
    let digest = Digest(h.finalize().into());

    Ok(Views { tx_id, rw_digest, digest, creator })
}

struct Inner {
    bytes: Vec<u8>,
    views: OnceLock<Result<Views, WireError>>,
    decoded: OnceLock<Result<Envelope, WireError>>,
}

/// An envelope as the pipeline actually holds it: one canonical encoded
/// buffer behind an `Arc`, plus cached views. Cloning bumps a refcount;
/// serialization splices the buffer; hashes are computed once.
#[derive(Clone)]
pub struct SharedEnvelope {
    inner: Arc<Inner>,
}

impl SharedEnvelope {
    /// Wrap raw wire bytes without validating them. Every view is lazy and
    /// fails closed on first access if the buffer is corrupt.
    pub fn from_wire(bytes: Vec<u8>) -> SharedEnvelope {
        SharedEnvelope {
            inner: Arc::new(Inner {
                bytes,
                views: OnceLock::new(),
                decoded: OnceLock::new(),
            }),
        }
    }

    /// Wrap raw wire bytes and validate them eagerly (full decode + view
    /// pass), so downstream trusted accessors cannot fail.
    pub fn from_wire_checked(bytes: Vec<u8>) -> Result<SharedEnvelope, String> {
        let se = SharedEnvelope::from_wire(bytes);
        se.validate()?;
        Ok(se)
    }

    /// Wrap a canonical byte span whose decode already succeeded (batch /
    /// block payload decoding), pre-seeding the decoded form.
    pub(crate) fn from_wire_decoded(bytes: Vec<u8>, env: Envelope) -> SharedEnvelope {
        let inner = Inner { bytes, views: OnceLock::new(), decoded: OnceLock::new() };
        let _ = inner.decoded.set(Ok(env));
        SharedEnvelope { inner: Arc::new(inner) }
    }

    /// The canonical wire encoding.
    pub fn as_bytes(&self) -> &[u8] {
        &self.inner.bytes
    }

    /// Wire size — what batch byte budgets and forwarding stats count.
    /// A field read, not a re-encode.
    pub fn encoded_len(&self) -> usize {
        self.inner.bytes.len()
    }

    /// Splice the canonical encoding into a writer (buffer copy, no
    /// re-encode).
    pub fn write_to(&self, w: &mut Writer) {
        w.raw(&self.inner.bytes);
    }

    fn views(&self) -> Result<&Views, String> {
        self.inner
            .views
            .get_or_init(|| parse_views(&self.inner.bytes))
            .as_ref()
            .map_err(|e| e.to_string())
    }

    /// Force both the view pass and the full decode; `Ok` means every
    /// trusted accessor below is infallible from here on.
    pub fn validate(&self) -> Result<(), String> {
        self.views()?;
        self.try_envelope()?;
        Ok(())
    }

    /// Transaction id (cached; computed zero-copy from the buffer).
    pub fn try_tx_id(&self) -> Result<TxId, String> {
        Ok(self.views()?.tx_id)
    }

    /// Read/write-set digest (cached; the endorsement-payload component).
    pub fn try_rw_digest(&self) -> Result<Digest, String> {
        Ok(self.views()?.rw_digest)
    }

    /// Full envelope digest (cached; merkle leaf / verdict-cache key).
    pub fn try_digest(&self) -> Result<Digest, String> {
        Ok(self.views()?.digest)
    }

    /// Creator id as a borrowed view into the buffer.
    pub fn try_creator(&self) -> Result<&str, String> {
        let range = self.views()?.creator.clone();
        std::str::from_utf8(&self.inner.bytes[range]).map_err(|e| e.to_string())
    }

    /// Decoded envelope; parses (once) on first access.
    pub fn try_envelope(&self) -> Result<&Envelope, String> {
        self.inner
            .decoded
            .get_or_init(|| {
                let mut r = Reader::new(&self.inner.bytes);
                let env = decode_envelope(&mut r)?;
                if !r.done() {
                    return Err(WireError::malformed("trailing bytes after envelope"));
                }
                Ok(env)
            })
            .as_ref()
            .map_err(|e| e.to_string())
    }

    // Trusted accessors: valid on every envelope built from an in-memory
    // `Envelope` or admitted through `from_wire_checked` — i.e. everything
    // past a pipeline boundary. Panic on an unvalidated corrupt buffer.

    pub fn tx_id(&self) -> TxId {
        self.try_tx_id().expect("corrupt envelope buffer past validation boundary")
    }

    pub fn rw_digest(&self) -> Digest {
        self.try_rw_digest().expect("corrupt envelope buffer past validation boundary")
    }

    pub fn digest(&self) -> Digest {
        self.try_digest().expect("corrupt envelope buffer past validation boundary")
    }

    pub fn envelope(&self) -> &Envelope {
        self.try_envelope().expect("corrupt envelope buffer past validation boundary")
    }

    pub fn proposal(&self) -> &Proposal {
        &self.envelope().proposal
    }

    pub fn rw_set(&self) -> &RwSet {
        &self.envelope().rw_set
    }

    pub fn endorsements(&self) -> &[Endorsement] {
        &self.envelope().endorsements
    }

    /// Recover an owned [`Envelope`]. Moves the decoded form out when this
    /// is the last refcount; otherwise clones it (the only place a deep
    /// clone can still happen, at the very end of the pipeline).
    pub fn into_envelope(self) -> Envelope {
        match Arc::try_unwrap(self.inner) {
            Ok(inner) => match inner.decoded.into_inner() {
                Some(Ok(env)) => env,
                _ => {
                    let mut r = Reader::new(&inner.bytes);
                    decode_envelope(&mut r).expect("corrupt envelope buffer past validation boundary")
                }
            },
            Err(shared) => SharedEnvelope { inner: shared }.envelope().clone(),
        }
    }
}

impl From<Envelope> for SharedEnvelope {
    /// Encode once; the decoded form is pre-seeded so no accessor ever
    /// re-parses.
    fn from(env: Envelope) -> Self {
        let mut w = Writer::new();
        encode_envelope(&env, &mut w);
        let inner =
            Inner { bytes: w.finish(), views: OnceLock::new(), decoded: OnceLock::new() };
        let _ = inner.decoded.set(Ok(env));
        SharedEnvelope { inner: Arc::new(inner) }
    }
}

impl From<&Envelope> for SharedEnvelope {
    fn from(env: &Envelope) -> Self {
        SharedEnvelope::from(env.clone())
    }
}

impl PartialEq for SharedEnvelope {
    fn eq(&self, other: &Self) -> bool {
        Arc::ptr_eq(&self.inner, &other.inner) || self.inner.bytes == other.inner.bytes
    }
}

impl Eq for SharedEnvelope {}

impl std::fmt::Debug for SharedEnvelope {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.try_tx_id() {
            Ok(id) => write!(f, "SharedEnvelope(tx {})", id.short()),
            Err(_) => write!(f, "SharedEnvelope({} corrupt bytes)", self.encoded_len()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    use crate::util::prng::Prng;

    fn random_envelope(rng: &mut Prng) -> Envelope {
        let nargs = rng.below(4);
        Envelope {
            proposal: Proposal {
                channel: format!("shard{}", rng.below(8)),
                chaincode: "models".into(),
                function: "CreateModelUpdate".into(),
                args: (0..nargs).map(|i| format!("arg{i}-{}", rng.next_u64())).collect(),
                creator: MemberId::new(format!("org{}.client", rng.below(8))),
                nonce: rng.next_u64(),
            },
            rw_set: RwSet {
                reads: (0..rng.below(4))
                    .map(|i| {
                        let ver = if rng.below(2) == 0 {
                            None
                        } else {
                            Some(Version {
                                block: rng.next_u64() % 100,
                                tx: rng.below(10) as u32,
                            })
                        };
                        (format!("rk{i}"), ver)
                    })
                    .collect(),
                writes: (0..rng.below(4))
                    .map(|i| {
                        let val = if rng.below(4) == 0 {
                            None
                        } else {
                            Some(rng.next_u64().to_le_bytes().to_vec())
                        };
                        (format!("wk{i}"), val)
                    })
                    .collect(),
            },
            endorsements: (0..rng.below(4))
                .map(|i| {
                    let mut sig = [0u8; 32];
                    for c in sig.chunks_mut(8) {
                        c.copy_from_slice(&rng.next_u64().to_le_bytes()[..c.len()]);
                    }
                    Endorsement {
                        endorser: MemberId::new(format!("org{i}.peer")),
                        signature: Signature(sig),
                    }
                })
                .collect(),
        }
    }

    /// Satellite: every lazily-decoded view must equal the eager decode,
    /// for arbitrary valid envelopes.
    #[test]
    fn property_lazy_views_match_eager_decode() {
        check("lazy-views-match-eager", 60, |rng| {
            let env = random_envelope(rng);
            let mut w = Writer::new();
            encode_envelope(&env, &mut w);
            // The untrusted path: raw bytes, nothing pre-seeded.
            let se = SharedEnvelope::from_wire(w.finish());
            assert_eq!(se.try_tx_id().unwrap(), env.tx_id());
            assert_eq!(se.try_rw_digest().unwrap(), env.rw_set.digest());
            assert_eq!(se.try_digest().unwrap(), env.digest());
            assert_eq!(se.try_creator().unwrap(), env.proposal.creator.0);
            assert_eq!(se.try_envelope().unwrap(), &env);
            assert_eq!(se.encoded_len(), se.as_bytes().len());
            // And the trusted path agrees with itself.
            let trusted = SharedEnvelope::from(env.clone());
            assert_eq!(trusted, se);
            assert_eq!(trusted.tx_id(), env.tx_id());
            assert_eq!(trusted.digest(), env.digest());
        });
    }

    /// Satellite: corrupt buffers fail closed at first access — every
    /// truncation point errors on every view, and structural corruption
    /// (bad tags, bad signature length, trailing bytes) errors too.
    #[test]
    fn property_corrupt_buffers_fail_closed() {
        check("corrupt-fails-closed", 20, |rng| {
            let env = random_envelope(rng);
            let mut w = Writer::new();
            encode_envelope(&env, &mut w);
            let buf = w.finish();
            for cut in 0..buf.len() {
                let se = SharedEnvelope::from_wire(buf[..cut].to_vec());
                assert!(se.try_tx_id().is_err() || se.validate().is_err(), "cut {cut}");
                assert!(se.try_envelope().is_err(), "decode at cut {cut}");
                assert!(SharedEnvelope::from_wire_checked(buf[..cut].to_vec()).is_err());
            }
            // Trailing garbage is rejected even though the prefix parses.
            let mut extra = buf.clone();
            extra.push(0);
            let se = SharedEnvelope::from_wire(extra);
            assert!(se.try_digest().is_err());
            assert!(se.try_envelope().is_err());
        });
    }

    #[test]
    fn view_and_decode_acceptance_agree_under_mutation() {
        // Flip each byte in turn: the zero-copy view parser and the full
        // decoder must agree on whether the buffer is acceptable, and when
        // both accept, the views must match the decode's recomputed hashes.
        let mut rng = Prng::new(11);
        let env = random_envelope(&mut rng);
        let mut w = Writer::new();
        encode_envelope(&env, &mut w);
        let buf = w.finish();
        for i in 0..buf.len() {
            let mut mutated = buf.clone();
            mutated[i] ^= 0x01;
            let se = SharedEnvelope::from_wire(mutated);
            match (se.try_envelope().is_ok(), se.try_digest().is_ok()) {
                (true, true) => {
                    let back = se.try_envelope().unwrap();
                    assert_eq!(se.try_tx_id().unwrap(), back.tx_id(), "byte {i}");
                    assert_eq!(se.try_digest().unwrap(), back.digest(), "byte {i}");
                    assert_eq!(se.try_rw_digest().unwrap(), back.rw_set.digest(), "byte {i}");
                }
                (dec, view) => assert_eq!(dec, view, "acceptance diverged at byte {i}"),
            }
        }
    }

    #[test]
    fn clone_shares_the_buffer() {
        let mut rng = Prng::new(3);
        let se = SharedEnvelope::from(random_envelope(&mut rng));
        let c = se.clone();
        assert!(std::ptr::eq(se.as_bytes().as_ptr(), c.as_bytes().as_ptr()));
        assert_eq!(se, c);
    }

    #[test]
    fn into_envelope_moves_or_clones() {
        let mut rng = Prng::new(4);
        let env = random_envelope(&mut rng);
        let se = SharedEnvelope::from(env.clone());
        let other = se.clone();
        assert_eq!(se.into_envelope(), env); // shared: clones
        assert_eq!(other.into_envelope(), env); // last ref: moves
    }
}
