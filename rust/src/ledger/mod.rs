//! Permissioned-ledger substrate: transactions with read/write sets, blocks,
//! hash chains, and an MVCC-versioned world state — the Fabric-style
//! execute–order–validate data model ScaleSFL's chaincodes run on.

pub mod block;
pub mod chain;
pub mod codec;
pub mod state;
pub mod tx;

pub use block::{Block, BlockHeader, ValidationCode};
pub use chain::Chain;
pub use state::{StateView, Version, WorldState};
pub use tx::{Endorsement, Envelope, Proposal, ReadSet, RwSet, TxId, WriteSet};
