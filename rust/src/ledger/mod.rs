//! Permissioned-ledger substrate: transactions with read/write sets, blocks,
//! hash chains, an MVCC-versioned world state — the Fabric-style
//! execute–order–validate data model ScaleSFL's chaincodes run on — and
//! the durable store that lets all of it survive a crash.
//!
//! # Store / snapshot / recovery lifecycle
//!
//! In-memory structures ([`Chain`], [`WorldState`]) stay the source of
//! truth on the hot path; durability hangs off the commit pipeline:
//!
//! 1. **Append** — after a block passes validation and lands on the
//!    chain, the committing peer appends it (CRC-framed, via
//!    `fabric::wire::encode_block`) to the channel's append-only
//!    [`store::LedgerStore`] block log, still under the chain lock so log
//!    order always equals chain order. Fsync cost follows the configured
//!    [`DurabilityMode`] (table below).
//! 2. **Snapshot** — every [`store::LedgerConfig::snapshot_every`] blocks
//!    the peer captures a consistent cut ([`snapshot::Snapshot`]): sorted
//!    key/value/version entries stamped with a Merkle **state root**
//!    (`crypto::merkle`), the chain tip (height + hash), the MVCC write
//!    sequence, and the committed-txid dedup set. Written atomically
//!    (tmp + rename), after the commit locks are released.
//! 3. **Recover** — on restart, `Peer::attach_store` loads the latest
//!    *valid* snapshot (CRC + recomputed state root), anchors the chain
//!    at its boundary ([`Chain::with_base`]), replays the block-log
//!    suffix through the regular `BlockValidator` path (recomputed
//!    validation codes must match the logged ones), and truncates any
//!    torn tail instead of failing. A corrupt snapshot degrades to full
//!    log replay; a torn log degrades to the longest verified prefix.
//!
//! # `DurabilityMode` tradeoffs
//!
//! | mode | append cost | crash-loss window | use when |
//! |------|-------------|-------------------|----------|
//! | `Off` | memory write only | unbounded (page cache) | pure simulation runs |
//! | `Group(t)` | write + amortized fsync (≤ 1 per `t`) | ≤ `t` of blocks | the default: near-`Off` throughput, bounded loss |
//! | `Strict` | write + inline `fdatasync` | none | durability benchmarks, adversarial scenarios |

pub mod block;
pub mod chain;
pub mod codec;
pub mod envelope;
pub mod snapshot;
pub mod state;
pub mod store;
pub mod tx;

pub use block::{Block, BlockHeader, ValidationCode};
pub use chain::{Chain, ChainError};
pub use envelope::SharedEnvelope;
pub use snapshot::Snapshot;
pub use state::{StateView, Version, WorldState};
pub use store::{DurabilityMode, LedgerConfig, LedgerStore, Recovery, StoreSnapshot};
pub use tx::{Endorsement, Envelope, Proposal, ReadSet, RwSet, TxId, WriteSet};
