//! Blocks: header (number, prev hash, merkle data hash), envelope payloads,
//! and per-transaction validation metadata set by the commit-time validator.

use crate::crypto::{merkle, sha256_parts, Digest};
use crate::ledger::envelope::SharedEnvelope;

/// Why a transaction was (in)validated at commit time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ValidationCode {
    Valid,
    /// A read version no longer matches current state (phantom/conflict).
    MvccConflict,
    /// Endorsement policy unsatisfied (too few / invalid signatures).
    EndorsementPolicyFailure,
    /// Duplicate transaction id already committed.
    DuplicateTxId,
}

/// Block header; `hash()` chains blocks.
#[derive(Clone, Debug, PartialEq)]
pub struct BlockHeader {
    pub number: u64,
    pub prev_hash: Digest,
    /// Merkle root over envelope digests.
    pub data_hash: Digest,
}

impl BlockHeader {
    pub fn hash(&self) -> Digest {
        sha256_parts(&[&self.number.to_le_bytes(), &self.prev_hash.0, &self.data_hash.0])
    }
}

/// A block of ordered envelopes plus commit-time validation flags.
///
/// Transactions are held as [`SharedEnvelope`] refcounts: cutting a block
/// never copies envelope payloads, the merkle leaves reuse each envelope's
/// cached digest, and serializing the block splices the canonical buffers.
#[derive(Clone, Debug, PartialEq)]
pub struct Block {
    pub header: BlockHeader,
    pub txs: Vec<SharedEnvelope>,
    /// Parallel to `txs`; empty until the validator commits the block.
    pub validation: Vec<ValidationCode>,
}

impl Block {
    /// Assemble a block from ordered envelopes (anything convertible into
    /// a [`SharedEnvelope`]; plain [`crate::ledger::tx::Envelope`]s are
    /// encoded once on the way in).
    pub fn new<E: Into<SharedEnvelope>>(number: u64, prev_hash: Digest, txs: Vec<E>) -> Block {
        let txs: Vec<SharedEnvelope> = txs.into_iter().map(Into::into).collect();
        let leaves: Vec<Digest> = txs.iter().map(|e| e.digest()).collect();
        Block {
            header: BlockHeader { number, prev_hash, data_hash: merkle::root(&leaves) },
            txs,
            validation: Vec::new(),
        }
    }

    pub fn hash(&self) -> Digest {
        self.header.hash()
    }

    /// Recompute the merkle root and compare (tamper check).
    pub fn verify_data_hash(&self) -> bool {
        let leaves: Vec<Digest> = self.txs.iter().map(|e| e.digest()).collect();
        merkle::root(&leaves) == self.header.data_hash
    }

    pub fn valid_tx_count(&self) -> usize {
        self.validation.iter().filter(|c| **c == ValidationCode::Valid).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::msp::MemberId;
    use crate::ledger::tx::{Envelope, Proposal, RwSet};

    fn envelope(nonce: u64) -> Envelope {
        Envelope {
            proposal: Proposal {
                channel: "c".into(),
                chaincode: "models".into(),
                function: "f".into(),
                args: vec![],
                creator: MemberId::new("m"),
                nonce,
            },
            rw_set: RwSet::default(),
            endorsements: vec![],
        }
    }

    #[test]
    fn data_hash_detects_tampering() {
        let b = Block::new(1, Digest::ZERO, vec![envelope(1), envelope(2)]);
        assert!(b.verify_data_hash());
        let mut tampered = b.clone();
        tampered.txs[0] = envelope(99).into();
        assert!(!tampered.verify_data_hash());
    }

    #[test]
    fn header_hash_chains() {
        let b1 = Block::new(1, Digest::ZERO, vec![envelope(1)]);
        let b2 = Block::new(2, b1.hash(), vec![envelope(2)]);
        assert_eq!(b2.header.prev_hash, b1.hash());
        assert_ne!(b1.hash(), b2.hash());
    }

    #[test]
    fn empty_block_is_fine() {
        let b = Block::new(0, Digest::ZERO, Vec::<Envelope>::new());
        assert!(b.verify_data_hash());
        assert_eq!(b.valid_tx_count(), 0);
    }
}
