//! Transactions: proposals, read/write sets, endorsements, envelopes.
//!
//! Mirrors Fabric's transaction flow: a client *proposal* names a chaincode
//! function; endorsing peers *execute* it against their current state,
//! producing a read set (keys + observed versions) and a write set; the
//! client assembles endorsements into an *envelope* submitted for ordering.

use crate::crypto::msp::{MemberId, Signature};
use crate::crypto::{sha256_parts, Digest};
use crate::ledger::state::Version;

/// Transaction id: hash of the proposal.
pub type TxId = Digest;

/// A client proposal to invoke a chaincode function.
#[derive(Clone, Debug, PartialEq)]
pub struct Proposal {
    pub channel: String,
    pub chaincode: String,
    pub function: String,
    pub args: Vec<String>,
    pub creator: MemberId,
    /// Uniquifies otherwise-identical proposals.
    pub nonce: u64,
}

impl Proposal {
    pub fn tx_id(&self) -> TxId {
        let mut parts: Vec<&[u8]> = vec![
            self.channel.as_bytes(),
            self.chaincode.as_bytes(),
            self.function.as_bytes(),
        ];
        for a in &self.args {
            parts.push(a.as_bytes());
        }
        let nonce = self.nonce.to_le_bytes();
        parts.push(self.creator.0.as_bytes());
        parts.push(&nonce);
        sha256_parts(&parts)
    }
}

/// Keys read during simulation with the version observed (None = absent).
pub type ReadSet = Vec<(String, Option<Version>)>;
/// Keys written during simulation (None value = delete).
pub type WriteSet = Vec<(String, Option<Vec<u8>>)>;

/// The simulation result a peer endorses.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RwSet {
    pub reads: ReadSet,
    pub writes: WriteSet,
}

impl RwSet {
    /// Canonical digest of the rw-set (what endorsers sign).
    pub fn digest(&self) -> Digest {
        let mut buf = Vec::new();
        for (k, v) in &self.reads {
            buf.extend_from_slice(&(k.len() as u32).to_le_bytes());
            buf.extend_from_slice(k.as_bytes());
            match v {
                Some(ver) => {
                    buf.push(1);
                    buf.extend_from_slice(&ver.block.to_le_bytes());
                    buf.extend_from_slice(&ver.tx.to_le_bytes());
                }
                None => buf.push(0),
            }
        }
        buf.push(0xFF); // separator between reads and writes
        for (k, v) in &self.writes {
            buf.extend_from_slice(&(k.len() as u32).to_le_bytes());
            buf.extend_from_slice(k.as_bytes());
            match v {
                Some(val) => {
                    buf.push(1);
                    buf.extend_from_slice(&(val.len() as u32).to_le_bytes());
                    buf.extend_from_slice(val);
                }
                None => buf.push(0),
            }
        }
        sha256_parts(&[&buf])
    }
}

/// One endorsing peer's signed approval of a simulation result.
#[derive(Clone, Debug, PartialEq)]
pub struct Endorsement {
    pub endorser: MemberId,
    /// Signature over tx_id || rw_set digest.
    pub signature: Signature,
}

/// Bytes an endorser signs for (tx, rw_set).
pub fn endorsement_payload(tx_id: &TxId, rw_digest: &Digest) -> Vec<u8> {
    let mut buf = Vec::with_capacity(64);
    buf.extend_from_slice(&tx_id.0);
    buf.extend_from_slice(&rw_digest.0);
    buf
}

/// The ordered unit: proposal + agreed rw-set + endorsements.
#[derive(Clone, Debug, PartialEq)]
pub struct Envelope {
    pub proposal: Proposal,
    pub rw_set: RwSet,
    pub endorsements: Vec<Endorsement>,
}

impl Envelope {
    pub fn tx_id(&self) -> TxId {
        self.proposal.tx_id()
    }

    /// Digest covering the full envelope (block merkle leaf).
    pub fn digest(&self) -> Digest {
        let rw = self.rw_set.digest();
        let tx = self.tx_id();
        let mut buf = Vec::new();
        buf.extend_from_slice(&tx.0);
        buf.extend_from_slice(&rw.0);
        for e in &self.endorsements {
            buf.extend_from_slice(e.endorser.0.as_bytes());
            buf.extend_from_slice(&e.signature.0);
        }
        sha256_parts(&[&buf])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn proposal(nonce: u64) -> Proposal {
        Proposal {
            channel: "shard0".into(),
            chaincode: "models".into(),
            function: "CreateModelUpdate".into(),
            args: vec!["round-1".into(), "hash".into()],
            creator: MemberId::new("org1.client"),
            nonce,
        }
    }

    #[test]
    fn tx_id_depends_on_all_fields() {
        let base = proposal(1);
        assert_eq!(base.tx_id(), proposal(1).tx_id());
        assert_ne!(base.tx_id(), proposal(2).tx_id());
        let mut p = proposal(1);
        p.args[0] = "round-2".into();
        assert_ne!(base.tx_id(), p.tx_id());
        let mut p = proposal(1);
        p.channel = "shard1".into();
        assert_ne!(base.tx_id(), p.tx_id());
    }

    #[test]
    fn rw_digest_orders_matter() {
        let a = RwSet {
            reads: vec![("k1".into(), Some(Version { block: 1, tx: 0 }))],
            writes: vec![("k2".into(), Some(b"v".to_vec()))],
        };
        let mut b = a.clone();
        b.reads[0].1 = Some(Version { block: 2, tx: 0 });
        assert_ne!(a.digest(), b.digest());
        let mut c = a.clone();
        c.writes[0].1 = None;
        assert_ne!(a.digest(), c.digest());
    }

    #[test]
    fn read_write_boundary_unambiguous() {
        // A key appearing as a read vs as a write must hash differently.
        let a = RwSet { reads: vec![("k".into(), None)], writes: vec![] };
        let b = RwSet { reads: vec![], writes: vec![("k".into(), None)] };
        assert_ne!(a.digest(), b.digest());
    }

    #[test]
    fn envelope_digest_covers_endorsements() {
        let env = Envelope {
            proposal: proposal(1),
            rw_set: RwSet::default(),
            endorsements: vec![],
        };
        let mut env2 = env.clone();
        env2.endorsements.push(Endorsement {
            endorser: MemberId::new("org1.peer"),
            signature: Signature([7u8; 32]),
        });
        assert_ne!(env.digest(), env2.digest());
    }
}
