//! Full-system assembly: build a ScaleSFL deployment (S shard channels +
//! the mainchain, peers, orderer, chaincodes, FL clients) and drive
//! federated rounds end-to-end through the blockchain (paper §3.4 workflow).

pub mod fedavg;
pub mod network;

pub use fedavg::{aggregate_chunked, fedavg_baseline, BaselineRound, FedAvgConfig};
pub use network::{AggDefense, DefenseChoice, Partition, RoundReport, ScaleSfl, SimConfig};
