//! Flat FedAvg baseline (McMahan et al.) — the comparison line in Fig 9 /
//! Table 2: the same clients and hyperparameters, but a single trusted
//! aggregator and no blockchain/sharding.

use anyhow::Result;

use crate::fl::client::{Behavior, FlClient, TrainConfig};
use crate::fl::datasets::{self, SynthDataset};
use crate::fl::partition;
use crate::runtime::ops::{EvalResult, FlatParams, ModelOps};
use crate::util::prng::Prng;

use super::network::Partition;

/// Baseline configuration (mirrors the relevant SimConfig knobs).
#[derive(Clone, Debug)]
pub struct FedAvgConfig {
    pub clients: usize,
    pub train: TrainConfig,
    pub partition: Partition,
    pub samples_per_client: usize,
    pub test_samples: usize,
    pub seed: u64,
}

impl Default for FedAvgConfig {
    fn default() -> Self {
        FedAvgConfig {
            clients: 8,
            train: TrainConfig::default(),
            partition: Partition::Iid,
            samples_per_client: 100,
            test_samples: 512,
            seed: 42,
        }
    }
}

/// Per-round result of the baseline run.
#[derive(Clone, Debug)]
pub struct BaselineRound {
    pub round: u64,
    pub mean_train_loss: f64,
    pub global_eval: EvalResult,
}

/// Run `rounds` of flat FedAvg; aggregation is hierarchical in chunks of K
/// (the runtime's stacked-aggregation width) which is numerically identical
/// to the flat sample-weighted mean.
pub fn fedavg_baseline(
    cfg: &FedAvgConfig,
    ops: &ModelOps,
    rounds: u64,
) -> Result<Vec<BaselineRound>> {
    let mut rng = Prng::new(cfg.seed);
    let dim = ops.input_dim();
    let classes = 10;
    let client_data: Vec<SynthDataset> = match cfg.partition {
        Partition::Iid => {
            let pool = datasets::mnist_like(
                cfg.seed,
                cfg.seed.wrapping_add(1),
                cfg.clients * cfg.samples_per_client,
                dim,
                classes,
            );
            partition::iid(&pool, cfg.clients, &mut rng)
        }
        Partition::Dirichlet { alpha } => {
            let pool = datasets::mnist_like(
                cfg.seed,
                cfg.seed.wrapping_add(1),
                cfg.clients * cfg.samples_per_client,
                dim,
                classes,
            );
            partition::dirichlet(&pool, cfg.clients, alpha, &mut rng)
        }
        Partition::Writer => {
            partition::by_writer(cfg.seed, cfg.clients, cfg.samples_per_client, dim, classes)
        }
    };
    let test = datasets::mnist_like(cfg.seed, cfg.seed ^ 0xFEED, cfg.test_samples, dim, classes);
    let mut clients: Vec<FlClient> = client_data
        .into_iter()
        .enumerate()
        .map(|(i, d)| FlClient::new(i, d, Behavior::Honest, rng.fork(i as u64)))
        .collect();

    let mut global = ops.init_params(cfg.seed as i32)?;
    let mut reports = Vec::new();
    for round in 1..=rounds {
        let mut updates: Vec<(FlatParams, f64)> = Vec::new();
        let mut losses = Vec::new();
        for c in clients.iter_mut() {
            let up = c.train(ops, &global, &cfg.train)?;
            losses.push(up.train_loss);
            updates.push((up.params, up.samples as f64));
        }
        global = aggregate_chunked(ops, &updates)?;
        let global_eval = ops.evaluate(&global, &test.x, &test.y)?;
        reports.push(BaselineRound {
            round,
            mean_train_loss: crate::util::mean(&losses),
            global_eval,
        });
    }
    Ok(reports)
}

/// Sample-weighted mean of arbitrarily many updates via K-wide stacked
/// aggregation: chunk, aggregate each chunk, then aggregate the chunk
/// results weighted by their chunk sample totals (exact, by linearity).
pub fn aggregate_chunked(ops: &ModelOps, updates: &[(FlatParams, f64)]) -> Result<FlatParams> {
    let k = ops.k();
    if updates.len() <= k {
        let refs: Vec<&FlatParams> = updates.iter().map(|(p, _)| p).collect();
        let ws: Vec<f64> = updates.iter().map(|(_, w)| *w).collect();
        return ops.fedavg_agg(&refs, &ws);
    }
    let mut level: Vec<(FlatParams, f64)> = Vec::new();
    for chunk in updates.chunks(k) {
        let refs: Vec<&FlatParams> = chunk.iter().map(|(p, _)| p).collect();
        let ws: Vec<f64> = chunk.iter().map(|(_, w)| *w).collect();
        let agg = ops.fedavg_agg(&refs, &ws)?;
        level.push((agg, ws.iter().sum()));
    }
    aggregate_chunked(ops, &level)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_learns() {
        let Some(ops) = crate::runtime::shared_ops() else { return };
        let cfg = FedAvgConfig {
            clients: 4,
            samples_per_client: 60,
            test_samples: 128,
            train: TrainConfig { batch: 10, epochs: 2, lr: 0.05, dp: None },
            ..Default::default()
        };
        let rounds = fedavg_baseline(&cfg, &ops, 3).unwrap();
        assert_eq!(rounds.len(), 3);
        assert!(
            rounds[2].global_eval.accuracy > rounds[0].global_eval.accuracy * 0.9,
            "{rounds:?}"
        );
        assert!(rounds[2].global_eval.accuracy > 0.3);
    }

    #[test]
    fn chunked_aggregation_matches_flat_mean() {
        let Some(ops) = crate::runtime::shared_ops() else { return };
        let p = ops.p_pad();
        // 20 updates of constant vectors: weighted mean is analytic.
        let updates: Vec<(FlatParams, f64)> =
            (0..20).map(|i| (vec![i as f32; p], (i + 1) as f64)).collect();
        let total_w: f64 = updates.iter().map(|(_, w)| w).sum();
        let expect: f64 =
            updates.iter().map(|(u, w)| u[0] as f64 * w).sum::<f64>() / total_w;
        let agg = aggregate_chunked(&ops, &updates).unwrap();
        assert!(
            (agg[0] as f64 - expect).abs() < 1e-4,
            "{} vs {expect}",
            agg[0]
        );
    }
}
