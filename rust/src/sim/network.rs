//! The ScaleSFL network: S shard channels + mainchain, committee peers with
//! per-peer local eval splits, the Raft orderer, and the full §3.4 round
//! workflow (client training → off-chain storage → model submission →
//! endorsement/defence → shard aggregation → mainchain consensus → global
//! aggregation → pin + redistribute).

use anyhow::{anyhow, bail, Context, Result};
use std::sync::Arc;
use std::time::Duration;

use crate::chaincode::{CatalystChaincode, ModelMeta, ModelsChaincode};
use crate::crypto::msp::{CertificateAuthority, MemberId};
use crate::defense::endorse::{EndorsementDefense, NoDefense, NormBound, Roni};
use crate::defense::{detect_lazy, foolsgold_weights, multi_krum};
use crate::fabric::{EndorsementPolicy, Gateway, OrdererConfig, OrderingService, Peer};
use crate::fl::client::{Behavior, FlClient, LocalUpdate, TrainConfig};
use crate::fl::datasets::{self, SynthDataset};
use crate::fl::partition;
use crate::mempool::{MempoolConfig, MempoolRegistry, RelayConfig};
use crate::runtime::ops::{EvalResult, FlatParams, ModelOps};
use crate::storage::ModelStore;
use crate::util::prng::Prng;

/// Endorsement-time defence selection.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DefenseChoice {
    None,
    Roni { max_degradation: f64 },
    NormBound { max_norm: f64 },
}

/// Aggregation-time defence selection.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AggDefense {
    None,
    MultiKrum { f: usize },
    FoolsGold,
    /// FoolsGold weights over the Multi-Krum survivor set.
    Both { f: usize },
}

/// Dataset / partition selection (paper §4.2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Partition {
    Iid,
    Dirichlet { alpha: f64 },
    Writer,
}

/// Deployment + workload configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub shards: usize,
    /// Peers per shard; the paper relaxes P = P_E (every peer endorses).
    pub peers_per_shard: usize,
    /// Clients sampled per shard per round.
    pub clients_per_shard: usize,
    pub train: TrainConfig,
    pub defense: DefenseChoice,
    pub agg_defense: AggDefense,
    pub partition: Partition,
    pub samples_per_client: usize,
    /// Per-peer held-out split size (RONI baseline data).
    pub eval_samples: usize,
    /// Global test set size (reported accuracy).
    pub test_samples: usize,
    /// Mainchain endorsers verify the posted global numerically.
    pub verify_aggregate: bool,
    /// PN amplitude (0 disables the lazy-client defence).
    pub pn_amplitude: f32,
    pub seed: u64,
    /// Transaction timeout (paper: 30 s).
    pub timeout: Duration,
    /// Endorsing committee size per shard per round (None = every peer
    /// endorses, the paper's P = P_E relaxation). When set, a committee is
    /// re-elected each round (paper §2.2.1 committee consensus).
    pub committee_size: Option<usize>,
    /// Committee election policy (paper: randomized for simplicity, or
    /// score-based from the previous round).
    pub election: crate::sharding::Election,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            shards: 2,
            peers_per_shard: 2,
            clients_per_shard: 4,
            train: TrainConfig::default(),
            defense: DefenseChoice::None,
            agg_defense: AggDefense::None,
            partition: Partition::Iid,
            samples_per_client: 100,
            eval_samples: 64,
            test_samples: 512,
            verify_aggregate: true,
            pn_amplitude: 0.0,
            seed: 42,
            timeout: Duration::from_secs(30),
            committee_size: None,
            election: crate::sharding::Election::Random,
        }
    }
}

/// One shard: its channel name, committee peers, and clients.
pub struct Shard {
    pub id: usize,
    pub channel: String,
    pub peers: Vec<Arc<Peer>>,
    pub clients: Vec<FlClient>,
}

/// Per-round outcome (drives Fig 9 / Table 2 and the defence studies).
#[derive(Clone, Debug)]
pub struct RoundReport {
    pub round: u64,
    pub accepted_updates: usize,
    pub rejected_updates: usize,
    pub lazy_detected: usize,
    pub mean_train_loss: f64,
    pub global_eval: EvalResult,
}

/// A running ScaleSFL deployment.
pub struct ScaleSfl {
    pub cfg: SimConfig,
    pub ops: ModelOps,
    pub store: ModelStore,
    pub ca: CertificateAuthority,
    pub shards: Vec<Shard>,
    pub all_peers: Vec<Arc<Peer>>,
    pub orderer: Arc<OrderingService>,
    /// Cached per-shard gateways (rebuilt only when a committee election
    /// changes the endorser set) and the mainchain gateway: their commit
    /// demuxes persist across rounds, one subscription per channel for the
    /// whole run instead of per-round thread/listener churn. Each shard
    /// gateway is bound to its own shard's ingress pool.
    shard_gateways: Vec<Arc<Gateway>>,
    /// Per-shard gateways bound to the *neighbouring* shard's ingress:
    /// their submissions are misrouted on purpose and gossip home over
    /// the cross-shard relay (empty with a single shard).
    detour_gateways: Vec<Arc<Gateway>>,
    /// Per-shard uplinks to the mainchain: endorse with every peer (the
    /// mainchain policy) but enter at the shard's ingress pool, so shard
    /// aggregates reach the mainchain as relayed checkpoint messages.
    uplink_gateways: Vec<Arc<Gateway>>,
    main_gateway: Arc<Gateway>,
    pub test_set: SynthDataset,
    pub global: FlatParams,
    pub round: u64,
    rng: Prng,
    /// Endorsement-evaluation invocations per round (ablation metric:
    /// C x P_E / S^2 per shard — paper §3.2).
    pub eval_invocations: u64,
    /// Per-peer committee scores (successful endorsement participations).
    scores: std::collections::HashMap<usize, f64>,
    /// This round's elected committee per shard (peer indices).
    committees: Vec<Vec<usize>>,
}

pub const MAINCHAIN: &str = "mainchain";

impl ScaleSfl {
    /// Build the network: enrol identities, create channels, install
    /// chaincodes (per-peer instances with private eval splits), start the
    /// orderer, partition data, and initialise the global model.
    pub fn build(cfg: SimConfig, ops: ModelOps) -> Result<ScaleSfl> {
        let mut rng = Prng::new(cfg.seed);
        let ca = CertificateAuthority::new();
        let store = ModelStore::new();
        let dim = ops.input_dim();
        let classes = 10;

        // Global pool of client datasets.
        let total_clients = cfg.shards * cfg.clients_per_shard;
        let client_data: Vec<SynthDataset> = match cfg.partition {
            Partition::Iid => {
                let pool = datasets::mnist_like(
                    cfg.seed,
                    cfg.seed.wrapping_add(1),
                    total_clients * cfg.samples_per_client,
                    dim,
                    classes,
                );
                partition::iid(&pool, total_clients, &mut rng)
            }
            Partition::Dirichlet { alpha } => {
                let pool = datasets::mnist_like(
                    cfg.seed,
                    cfg.seed.wrapping_add(1),
                    total_clients * cfg.samples_per_client,
                    dim,
                    classes,
                );
                partition::dirichlet(&pool, total_clients, alpha, &mut rng)
            }
            Partition::Writer => partition::by_writer(
                cfg.seed,
                total_clients,
                cfg.samples_per_client,
                dim,
                classes,
            ),
        };
        let test_set = datasets::mnist_like(cfg.seed, cfg.seed ^ 0xFEED, cfg.test_samples, dim, classes);

        let mut shards = Vec::with_capacity(cfg.shards);
        let mut all_peers = Vec::new();
        let mut all_members = Vec::new();
        let mut channel_policies: Vec<(String, EndorsementPolicy)> = Vec::new();
        let mut client_iter = client_data.into_iter();
        for s in 0..cfg.shards {
            let channel = format!("shard{s}");
            let mut peers = Vec::with_capacity(cfg.peers_per_shard);
            let mut members = Vec::with_capacity(cfg.peers_per_shard);
            for p in 0..cfg.peers_per_shard {
                let cred =
                    ca.enroll(MemberId::new(format!("org{s}x{p}.peer")), &mut rng);
                let peer = Peer::new(cred, ca.clone());
                members.push(peer.member.clone());
                peers.push(peer);
            }
            all_members.extend(members.clone());
            let policy = EndorsementPolicy::MajorityOf(members);
            channel_policies.push((channel.clone(), policy.clone()));
            for (p, peer) in peers.iter().enumerate() {
                peer.join_channel(&channel, policy.clone());
                // Per-peer private eval split (paper: "potentially unique to
                // each endorsing peer").
                let eval_data = datasets::mnist_like(
                    cfg.seed,
                    cfg.seed ^ (0xE0 + s as u64 * 131 + p as u64),
                    cfg.eval_samples,
                    dim,
                    classes,
                );
                let defense: Arc<dyn EndorsementDefense> = match cfg.defense {
                    DefenseChoice::None => Arc::new(NoDefense),
                    DefenseChoice::Roni { max_degradation } => Arc::new(Roni { max_degradation }),
                    DefenseChoice::NormBound { max_norm } => Arc::new(NormBound { max_norm }),
                };
                peer.install_chaincode(
                    &channel,
                    Arc::new(ModelsChaincode {
                        store: store.clone(),
                        ops: ops.clone(),
                        defense,
                        eval_data,
                    }),
                )
                .map_err(|e| anyhow!(e))?;
            }
            let clients = (0..cfg.clients_per_shard)
                .map(|c| {
                    let data = client_iter.next().expect("client data");
                    FlClient::new(
                        s * cfg.clients_per_shard + c,
                        data,
                        Behavior::Honest,
                        rng.fork((s * 1000 + c) as u64),
                    )
                })
                .collect();
            shards.push(Shard { id: s, channel, peers: peers.clone(), clients });
            all_peers.extend(peers);
        }

        // Mainchain: every peer joins; catalyst chaincode installed on all.
        let main_policy = EndorsementPolicy::MajorityOf(all_members);
        for peer in &all_peers {
            peer.join_channel(MAINCHAIN, main_policy.clone());
            peer.install_chaincode(
                MAINCHAIN,
                Arc::new(CatalystChaincode {
                    store: store.clone(),
                    ops: ops.clone(),
                    verify_aggregate: cfg.verify_aggregate,
                }),
            )
            .map_err(|e| anyhow!(e))?;
        }

        // Ingress: per-channel pools verify endorsement signatures/policies
        // at admission, so garbage load is shed before consensus sees it.
        let mempool = MempoolRegistry::with_admission(
            MempoolConfig { verify_endorsements: true, ..Default::default() },
            ca.clone(),
        );
        for (channel, policy) in &channel_policies {
            mempool.set_policy(channel, policy.clone());
        }
        mempool.set_policy(MAINCHAIN, main_policy.clone());
        let orderer = OrderingService::start_with_mempool(
            OrdererConfig {
                batch_size: 16,
                batch_timeout: Duration::from_millis(20),
                // Shard committees are signature-heavy (majority of every
                // shard peer endorses each update): run the two-stage
                // commit pipeline with a small worker pool. The orderer
                // also wires each channel's mempool to a replica's state
                // view, so stale model updates shed at admission.
                validation_workers: 2,
                // Cross-shard relay: misrouted model updates gossip to
                // their home shard and shard checkpoints reach the
                // mainchain pool over per-link simnet latencies (small
                // ones — a LAN-scale consortium — so rounds stay fast
                // while block cutting still sees the arrival skew).
                relay: Some(RelayConfig {
                    base_latency: Duration::from_millis(2),
                    latency_spread: Duration::from_millis(3),
                    jitter: Duration::from_millis(1),
                    seed: cfg.seed,
                }),
                ..Default::default()
            },
            all_peers.clone(),
            cfg.seed ^ 0x0DDE,
            mempool,
        );
        let global = ops.init_params(cfg.seed as i32)?;
        let main_gateway = {
            let mut gw = Gateway::new(all_peers.clone(), Arc::clone(&orderer));
            gw.timeout = cfg.timeout;
            Arc::new(gw)
        };
        let mut net = ScaleSfl {
            cfg,
            ops,
            store,
            ca,
            shards,
            all_peers,
            orderer,
            shard_gateways: Vec::new(),
            detour_gateways: Vec::new(),
            uplink_gateways: Vec::new(),
            main_gateway,
            test_set,
            global,
            round: 1,
            rng,
            eval_invocations: 0,
            scores: std::collections::HashMap::new(),
            committees: Vec::new(),
        };
        net.rebuild_shard_gateways();
        // Uplinks never change: the mainchain endorser set is every peer.
        let uplinks: Vec<Arc<Gateway>> = (0..net.shards.len())
            .map(|s| {
                let mut gw = Gateway::new(net.all_peers.clone(), Arc::clone(&net.orderer));
                gw.timeout = net.cfg.timeout;
                gw.ingress = Some(net.shards[s].channel.clone());
                Arc::new(gw)
            })
            .collect();
        net.uplink_gateways = uplinks;
        // Pin the initial model as round 0 on every shard so round-1
        // endorsers have a baseline for RONI/norm-bound checks.
        let (gdigest, guri) = net.store.put(net.global.clone());
        net.pin_global_on_shards(0, &gdigest, &guri, 0)?;
        Ok(net)
    }

    /// Inject adversarial behaviour into specific clients (global ids).
    pub fn set_behavior(&mut self, client_id: usize, behavior: Behavior) {
        for shard in &mut self.shards {
            for c in &mut shard.clients {
                if c.id == client_id {
                    c.behavior = behavior;
                }
            }
        }
    }

    /// Replace a client's local dataset (Sybil injection: give several
    /// clients the same poisoned data so they share one objective).
    pub fn set_client_data(&mut self, client_id: usize, data: crate::fl::datasets::SynthDataset) {
        for shard in &mut self.shards {
            for c in &mut shard.clients {
                if c.id == client_id {
                    c.data = data.clone();
                }
            }
        }
    }

    /// Build a gateway endorsing with shard `s`'s current committee,
    /// submitting through shard `ingress`'s pool. `ingress == s` is the
    /// normal home path; anything else is a deliberately misrouted client
    /// whose envelopes ride the cross-shard relay home.
    fn make_shard_gateway_at(&self, s: usize, ingress: usize) -> Arc<Gateway> {
        // Restrict endorsement fan-out to this round's committee when one
        // has been elected; otherwise every shard peer endorses.
        let peers = match self.committees.get(s) {
            Some(c) if !c.is_empty() => {
                c.iter().map(|&i| Arc::clone(&self.shards[s].peers[i])).collect()
            }
            _ => self.shards[s].peers.clone(),
        };
        let mut gw = Gateway::new(peers, Arc::clone(&self.orderer));
        gw.timeout = self.cfg.timeout;
        gw.ingress = Some(self.shards[ingress].channel.clone());
        Arc::new(gw)
    }

    /// (Re)build the per-shard home and detour gateways from the current
    /// committee state.
    fn rebuild_shard_gateways(&mut self) {
        let n = self.shards.len();
        let home: Vec<Arc<Gateway>> = (0..n).map(|s| self.make_shard_gateway_at(s, s)).collect();
        let detour: Vec<Arc<Gateway>> = if n > 1 {
            (0..n).map(|s| self.make_shard_gateway_at(s, (s + 1) % n)).collect()
        } else {
            Vec::new()
        };
        self.shard_gateways = home;
        self.detour_gateways = detour;
    }

    fn shard_gateway(&self, s: usize) -> Arc<Gateway> {
        Arc::clone(&self.shard_gateways[s])
    }

    /// Re-elect each shard's endorsing committee and install the matching
    /// endorsement policy on every replica (paper §2.2.1 / §3.2).
    pub fn elect_committees(&mut self) {
        let Some(size) = self.cfg.committee_size else {
            return;
        };
        self.committees.clear();
        for shard in &self.shards {
            let peer_idx: Vec<usize> = (0..shard.peers.len()).collect();
            let committee = crate::sharding::elect_committee(
                &peer_idx,
                size,
                self.cfg.election,
                &self.scores,
                &mut self.rng,
            );
            let members: Vec<MemberId> =
                committee.iter().map(|&i| shard.peers[i].member.clone()).collect();
            let policy = EndorsementPolicy::MajorityOf(members);
            for p in &shard.peers {
                if let Some(ch) = p.channel(&shard.channel) {
                    ch.set_policy(policy.clone());
                }
            }
            // Keep the ingress admission precheck aligned with the newly
            // elected committee.
            self.orderer.mempool().set_policy(&shard.channel, policy.clone());
            // Participation score for the elected members.
            for &i in &committee {
                *self.scores.entry(shard.id * 1000 + i).or_insert(0.0) += 1.0;
            }
            self.committees.push(committee);
        }
        // The endorser sets changed: rebuild the cached shard gateways
        // (their demuxes re-subscribe on the new committees' peers).
        self.rebuild_shard_gateways();
    }

    /// Model provenance (paper §5): restore the global model pinned on the
    /// mainchain for `round` (checkpoint recovery after a poisoning event).
    pub fn restore_from_round(&mut self, round: u64) -> Result<()> {
        let main = self.all_peers[0]
            .channel(MAINCHAIN)
            .context("mainchain channel")?;
        let raw = main
            .query(&format!("global/{round:08}"))
            .with_context(|| format!("round {round} not finalised on the mainchain"))?;
        let meta = ModelMeta::decode(&raw).map_err(|e| anyhow!(e))?;
        let digest = crate::crypto::Digest::from_hex(&meta.hash)
            .ok_or_else(|| anyhow!("bad pinned hash"))?;
        let blob = self.store.get_verified(&meta.uri, &digest).map_err(|e| anyhow!(e))?;
        self.global = (*blob).clone();
        Ok(())
    }

    fn mainchain_gateway(&self) -> Arc<Gateway> {
        Arc::clone(&self.main_gateway)
    }

    /// Pin a finalised global model onto every shard chain — all shard
    /// checkpoint txs ride in flight together (disjoint channels).
    fn pin_global_on_shards(
        &mut self,
        round: u64,
        digest: &crate::crypto::Digest,
        uri: &str,
        total: u64,
    ) -> Result<()> {
        let nonces: Vec<u64> = (0..self.shards.len()).map(|_| self.rng.next_u64()).collect();
        let handles: Vec<_> = self
            .shard_gateways
            .iter()
            .enumerate()
            .map(|(s, gw)| {
                gw.submit(&crate::ledger::tx::Proposal {
                    channel: self.shards[s].channel.clone(),
                    chaincode: "models".into(),
                    function: "PinGlobalModel".into(),
                    args: vec![
                        round.to_string(),
                        digest.hex(),
                        uri.to_string(),
                        total.to_string(),
                    ],
                    creator: self.shards[s].peers[0].member.clone(),
                    nonce: nonces[s],
                })
            })
            .collect();
        for (s, h) in handles.into_iter().enumerate() {
            let outcome = h.wait();
            if !outcome.is_valid() {
                bail!("PinGlobalModel(round {round}) failed on shard {s}: {outcome:?}");
            }
        }
        Ok(())
    }

    /// One full federated round through the blockchain (paper §3.4).
    pub fn run_round(&mut self) -> Result<RoundReport> {
        let round = self.round;
        self.elect_committees();
        let mut accepted = 0usize;
        let mut rejected = 0usize;
        let mut lazy_detected = 0usize;
        let mut losses = Vec::new();
        // Shard aggregates are submitted to the mainchain as each shard
        // finishes and drained together after the loop: one gateway (and
        // one commit demux) for all of them, with every submission in
        // flight at once.
        let main_gw = self.mainchain_gateway();
        let mut pending_shard_models: Vec<(usize, FlatParams, u64, crate::fabric::SubmitHandle)> =
            Vec::new();

        for s in 0..self.shards.len() {
            // §3.4.2 client training (off-chain, real PJRT compute).
            let mut updates: Vec<LocalUpdate> = Vec::new();
            {
                let global = self.global.clone();
                let (train, ops) = (self.cfg.train, self.ops.clone());
                let pn_amp = self.cfg.pn_amplitude;
                let shard = &mut self.shards[s];
                let n_clients = shard.clients.len();
                let mut published: Vec<LocalUpdate> = Vec::new();
                for c in shard.clients.iter_mut() {
                    if let Behavior::Lazy { victim } = c.behavior {
                        // Lazy client: copy the victim's *published* update
                        // and stamp its own PN on top (paper §5).
                        let victim_up = published
                            .iter()
                            .find(|u| u.client_id % n_clients == victim)
                            .or_else(|| published.first());
                        if let Some(v) = victim_up {
                            let mut copied = v.clone();
                            copied.client_id = c.id;
                            copied.pn_seed = c.pn_seed;
                            let mut p = copied.clone();
                            if pn_amp > 0.0 {
                                crate::defense::apply_pn(&mut p.params, c.pn_seed, pn_amp);
                            }
                            published.push(p);
                            continue;
                        }
                    }
                    let up = c.train(&ops, &global, &train)?;
                    if !up.train_loss.is_nan() {
                        losses.push(up.train_loss);
                    }
                    let p =
                        if pn_amp > 0.0 { c.publish_with_pn(up, pn_amp) } else { up };
                    published.push(p);
                }
                updates.extend(published);
            }

            // §3.4.3-3.4.5 store off-chain, then submit every client's
            // metadata tx with all of them in flight at once (open-loop:
            // endorsements run back-to-back while earlier txs are still
            // being ordered/committed, as Caliper drives the real system).
            let gw = self.shard_gateway(s);
            let channel = self.shards[s].channel.clone();
            let endorsers = match self.committees.get(s) {
                Some(c) if !c.is_empty() => c.len(),
                _ => self.shards[s].peers.len(),
            };
            let mut proposals = Vec::with_capacity(updates.len());
            for up in &updates {
                let (digest, uri) = self.store.put(up.params.clone());
                proposals.push(crate::ledger::tx::Proposal {
                    channel: channel.clone(),
                    chaincode: "models".into(),
                    function: "CreateModelUpdate".into(),
                    args: vec![
                        round.to_string(),
                        format!("client{}", up.client_id),
                        digest.hex(),
                        uri,
                        up.samples.to_string(),
                    ],
                    creator: MemberId::new(format!("client{}", up.client_id)),
                    nonce: self.rng.next_u64(),
                });
                self.eval_invocations += endorsers as u64;
            }
            // Exercise the cross-shard relay every round: the first update
            // enters at the *neighbouring* shard's ingress (a misrouted /
            // failed-over client) and gossips home, while the rest use the
            // home ingress. Its commit must be indistinguishable from the
            // locally admitted ones — one extra simnet hop of latency.
            let outcomes = if proposals.len() > 1 && !self.detour_gateways.is_empty() {
                let detour = Arc::clone(&self.detour_gateways[s]);
                let misrouted = detour.submit(&proposals[0]);
                let mut all = Vec::with_capacity(proposals.len());
                let rest = gw.submit_all(&proposals[1..], proposals.len().max(1));
                all.push(misrouted.wait());
                all.extend(rest);
                all
            } else {
                gw.submit_all(&proposals, proposals.len().max(1))
            };
            for outcome in outcomes {
                if outcome.is_valid() {
                    accepted += 1;
                } else {
                    rejected += 1;
                }
            }

            // §3.4.7 shard aggregation over *committed* updates only
            // (queried from the peer's ledger, the paper's Flower-strategy
            // filter).
            let committed: Vec<ModelMeta> = self.shards[s].peers[0]
                .channel(&channel)
                .context("channel")?
                .scan(&format!("models/{round:08}/"))
                .into_iter()
                .filter(|(k, _)| !k.ends_with("/global"))
                .map(|(_, v)| ModelMeta::decode(&v))
                .collect::<Result<_, _>>()
                .map_err(|e| anyhow!(e))?;
            if committed.is_empty() {
                continue;
            }
            let blobs: Vec<Arc<Vec<f32>>> = committed
                .iter()
                .map(|m| {
                    let d = crate::crypto::Digest::from_hex(&m.hash)
                        .ok_or_else(|| anyhow!("bad hash"))?;
                    self.store.get_verified(&m.uri, &d).map_err(|e| anyhow!(e))
                })
                .collect::<Result<_>>()?;

            // PN-sequence lazy detection (revealed seeds).
            let mut keep: Vec<bool> = vec![true; committed.len()];
            if self.cfg.pn_amplitude > 0.0 {
                let seeds: Vec<u64> = committed
                    .iter()
                    .map(|m| {
                        updates
                            .iter()
                            .find(|u| format!("client{}", u.client_id) == m.client)
                            .map(|u| u.pn_seed)
                            .unwrap_or(0)
                    })
                    .collect();
                let deltas: Vec<Vec<f32>> = blobs
                    .iter()
                    .map(|b| {
                        b.iter().zip(&self.global).map(|(&p, &g)| p - g).collect()
                    })
                    .collect();
                for i in detect_lazy(&deltas, &seeds, self.cfg.pn_amplitude, 0.2) {
                    keep[i] = false;
                    lazy_detected += 1;
                }
            }

            // Aggregation-time defence weights.
            let kept: Vec<usize> =
                (0..committed.len()).filter(|&i| keep[i]).collect();
            if kept.is_empty() {
                continue;
            }
            let kept_blobs: Vec<&Vec<f32>> =
                kept.iter().map(|&i| blobs[i].as_ref()).collect();
            let mut weights: Vec<f64> =
                kept.iter().map(|&i| committed[i].samples as f64).collect();
            match self.cfg.agg_defense {
                AggDefense::None => {}
                AggDefense::MultiKrum { f } => {
                    let d = self.ops.pairwise_dist(&kept_blobs)?;
                    let sel = multi_krum(&d, f);
                    for (pos, w) in weights.iter_mut().enumerate() {
                        if !sel.contains(&pos) {
                            *w = 0.0;
                        }
                    }
                }
                AggDefense::FoolsGold => {
                    let deltas: Vec<Vec<f32>> = kept_blobs
                        .iter()
                        .map(|b| b.iter().zip(&self.global).map(|(&p, &g)| p - g).collect())
                        .collect();
                    let drefs: Vec<&Vec<f32>> = deltas.iter().collect();
                    let c = self.ops.cosine_sim(&drefs)?;
                    for (w, fg) in weights.iter_mut().zip(foolsgold_weights(&c)) {
                        *w *= fg;
                    }
                }
                AggDefense::Both { f } => {
                    let d = self.ops.pairwise_dist(&kept_blobs)?;
                    let sel = multi_krum(&d, f);
                    let deltas: Vec<Vec<f32>> = kept_blobs
                        .iter()
                        .map(|b| b.iter().zip(&self.global).map(|(&p, &g)| p - g).collect())
                        .collect();
                    let drefs: Vec<&Vec<f32>> = deltas.iter().collect();
                    let c = self.ops.cosine_sim(&drefs)?;
                    let fg = foolsgold_weights(&c);
                    for (pos, w) in weights.iter_mut().enumerate() {
                        *w *= if sel.contains(&pos) { fg[pos] } else { 0.0 };
                    }
                }
            }
            if weights.iter().sum::<f64>() <= 0.0 {
                continue;
            }
            let shard_model = self.ops.fedavg_agg(&kept_blobs, &weights)?;
            let shard_samples: u64 = kept
                .iter()
                .zip(&weights)
                .filter(|(_, &w)| w > 0.0)
                .map(|(&i, _)| committed[i].samples)
                .sum();

            // §3.4.7 publish the shard aggregate to the mainchain as a
            // relayed checkpoint: the tx enters at this shard's ingress
            // pool and hops to the mainchain channel as a first-class
            // cross-shard message (non-blocking: later shards keep
            // working while this commits).
            let (digest, uri) = self.store.put(shard_model.clone());
            let proposal = crate::ledger::tx::Proposal {
                channel: MAINCHAIN.into(),
                chaincode: "catalyst".into(),
                function: "SubmitShardModel".into(),
                args: vec![
                    round.to_string(),
                    format!("shard{s}"),
                    digest.hex(),
                    uri,
                    shard_samples.to_string(),
                ],
                creator: self.shards[s].peers[0].member.clone(),
                nonce: self.rng.next_u64(),
            };
            let handle = self.uplink_gateways[s].submit(&proposal);
            pending_shard_models.push((s, shard_model, shard_samples, handle));
        }

        let mut shard_models: Vec<(FlatParams, u64)> =
            Vec::with_capacity(pending_shard_models.len());
        for (s, model, samples, handle) in pending_shard_models {
            let outcome = handle.wait();
            if !outcome.is_valid() {
                bail!("shard {s} mainchain submission failed: {outcome:?}");
            }
            shard_models.push((model, samples));
        }

        if shard_models.is_empty() {
            bail!("round {round}: no shard produced a model");
        }

        // §3.4.8 global aggregation + finalisation on the mainchain.
        let refs: Vec<&FlatParams> = shard_models.iter().map(|(m, _)| m).collect();
        let ws: Vec<f64> = shard_models.iter().map(|(_, n)| *n as f64).collect();
        let new_global = self.ops.fedavg_agg(&refs, &ws)?;
        let (gdigest, guri) = self.store.put(new_global.clone());
        let proposal = crate::ledger::tx::Proposal {
            channel: MAINCHAIN.into(),
            chaincode: "catalyst".into(),
            function: "FinalizeGlobal".into(),
            args: vec![
                round.to_string(),
                gdigest.hex(),
                guri.clone(),
                shard_models.len().to_string(),
            ],
            creator: self.all_peers[0].member.clone(),
            nonce: self.rng.next_u64(),
        };
        let outcome = main_gw.submit(&proposal).wait();
        if !outcome.is_valid() {
            bail!("FinalizeGlobal failed: {outcome:?}");
        }

        // Pin the global model onto each shard chain (next round's
        // baseline).
        let total: u64 = shard_models.iter().map(|(_, n)| n).sum();
        self.pin_global_on_shards(round, &gdigest, &guri, total)?;

        self.global = new_global;
        self.round += 1;
        let global_eval = self.ops.evaluate(&self.global, &self.test_set.x, &self.test_set.y)?;
        Ok(RoundReport {
            round,
            accepted_updates: accepted,
            rejected_updates: rejected,
            lazy_detected,
            mean_train_loss: crate::util::mean(&losses),
            global_eval,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> SimConfig {
        SimConfig {
            shards: 2,
            peers_per_shard: 2,
            clients_per_shard: 2,
            samples_per_client: 60,
            eval_samples: 40,
            test_samples: 128,
            train: TrainConfig { batch: 10, epochs: 1, lr: 0.05, dp: None },
            ..Default::default()
        }
    }

    #[test]
    fn end_to_end_round_improves_model() {
        let Some(ops) = crate::runtime::shared_ops() else { return };
        let mut net = ScaleSfl::build(quick_cfg(), ops).unwrap();
        let before = net
            .ops
            .evaluate(&net.global, &net.test_set.x, &net.test_set.y)
            .unwrap();
        let mut last = None;
        for _ in 0..3 {
            last = Some(net.run_round().unwrap());
        }
        let report = last.unwrap();
        assert_eq!(report.accepted_updates, 4);
        assert_eq!(report.rejected_updates, 0);
        assert!(
            report.global_eval.accuracy > before.accuracy,
            "{} !> {}",
            report.global_eval.accuracy,
            before.accuracy
        );
        // Ledgers recorded the round on every shard + mainchain.
        for shard in &net.shards {
            let ch = shard.peers[0].channel(&shard.channel).unwrap();
            assert!(ch.height() > 0);
            assert!(ch.query("global/00000001").is_some());
        }
        let main = net.all_peers[0].channel(MAINCHAIN).unwrap();
        assert!(main.query("global/00000001").is_some());
        assert!(main.query("shards/00000001/shard0").is_some());
        // The relay carried real traffic: one misrouted update per shard
        // per round plus every shard checkpoint — and lost none of it.
        let stats = net.orderer.mempool().snapshot();
        assert!(stats.forwarded >= 4, "expected relayed traffic, got {stats:?}");
        assert_eq!(stats.relay_dropped, 0);
        let relay = net.orderer.relay().expect("sim runs the relay").snapshot();
        assert_eq!(relay.dropped, 0);
        assert!(relay.delivered >= 4);
    }

    #[test]
    fn norm_bound_defense_rejects_boosted_client() {
        let Some(ops) = crate::runtime::shared_ops() else { return };
        let mut cfg = quick_cfg();
        cfg.defense = DefenseChoice::NormBound { max_norm: 8.0 };
        let mut net = ScaleSfl::build(cfg, ops).unwrap();
        net.set_behavior(0, Behavior::Boost(100));
        let report = net.run_round().unwrap();
        assert_eq!(report.rejected_updates, 1, "{report:?}");
        assert_eq!(report.accepted_updates, 3);
    }
}
