//! Admission-control primitives: explicit backpressure verdicts and the
//! per-client token bucket behind the rate caps.

use std::fmt;

/// Why the mempool refused an envelope. Surfaced all the way to the client
/// (`fabric::CommitOutcome::Rejected`) and counted per reason in
/// `MempoolStats`, so overload shows up as *shed load* instead of an
/// unbounded queue (the paper's Figs. 6-7 knee).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Reject {
    /// The target priority lane is at capacity — shed load, try later.
    PoolFull,
    /// The submitting client exceeded its sustained admission rate.
    RateLimited,
    /// Content-hash replay: this tx id is queued or was recently admitted.
    Duplicate,
    /// No endorsement signature verified at admission precheck.
    BadSignature,
    /// The endorsements can never satisfy the channel's policy, so ordering
    /// the envelope would only waste a validation slot.
    PolicyUnsatisfiable,
    /// MVCC hint: a read-set version is already stale against committed
    /// state. Versions only move forward, so the transaction is guaranteed
    /// `MvccConflict` at commit — the client should re-endorse instead of
    /// burning consensus bandwidth.
    StaleReadSet,
    /// The ordering service is shutting down.
    Shutdown,
}

impl fmt::Display for Reject {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Reject::PoolFull => "mempool lane full (backpressure)",
            Reject::RateLimited => "client rate cap exceeded",
            Reject::Duplicate => "duplicate transaction (replay)",
            Reject::BadSignature => "endorsement signature invalid",
            Reject::PolicyUnsatisfiable => "endorsement policy unsatisfiable",
            Reject::StaleReadSet => "read-set already stale (re-endorse)",
            Reject::Shutdown => "ordering service stopped",
        };
        f.write_str(s)
    }
}

/// Token bucket: refills at `rate` tokens/s up to `burst`, one token per
/// admitted transaction. Times are clock seconds (injectable clock, so
/// tests drive it virtually).
#[derive(Clone, Copy, Debug)]
pub struct TokenBucket {
    tokens: f64,
    last: f64,
}

impl TokenBucket {
    /// A fresh bucket starts full (allows an initial burst).
    pub fn new(burst: f64, now: f64) -> TokenBucket {
        TokenBucket { tokens: burst, last: now }
    }

    /// Take one token if available; refills lazily from elapsed time.
    pub fn try_take(&mut self, now: f64, rate: f64, burst: f64) -> bool {
        self.tokens = (self.tokens + (now - self.last).max(0.0) * rate).min(burst);
        self.last = now;
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_then_refill() {
        let mut b = TokenBucket::new(3.0, 0.0);
        assert!(b.try_take(0.0, 10.0, 3.0));
        assert!(b.try_take(0.0, 10.0, 3.0));
        assert!(b.try_take(0.0, 10.0, 3.0));
        // Burst exhausted.
        assert!(!b.try_take(0.0, 10.0, 3.0));
        // 0.1 s at 10 tx/s refills one token.
        assert!(b.try_take(0.1, 10.0, 3.0));
        assert!(!b.try_take(0.1, 10.0, 3.0));
    }

    #[test]
    fn refill_caps_at_burst() {
        let mut b = TokenBucket::new(2.0, 0.0);
        assert!(b.try_take(0.0, 1.0, 2.0));
        // A very long idle period refills to the burst cap only.
        assert!(b.try_take(1000.0, 1.0, 2.0));
        assert!(b.try_take(1000.0, 1.0, 2.0));
        assert!(!b.try_take(1000.0, 1.0, 2.0));
    }

    #[test]
    fn reject_reasons_render() {
        assert!(Reject::PoolFull.to_string().contains("backpressure"));
        assert!(Reject::RateLimited.to_string().contains("rate"));
        assert_ne!(Reject::PoolFull, Reject::RateLimited);
    }
}
