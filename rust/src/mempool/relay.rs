//! Cross-shard relay: gossip/forwarding between shard mempools.
//!
//! ScaleSFL's shards only scale independently if transactions can *reach*
//! their home shard from wherever they enter the system: a client pinned
//! to one shard's ingress still produces mainchain checkpoint traffic, a
//! misconfigured (or failed-over) gateway submits model updates to the
//! wrong pool, and layered designs route every shard aggregate through
//! the mainchain. The relay makes that path explicit:
//!
//! - [`Relay::ingress`] is the per-shard entry point. An envelope whose
//!   home channel (its `proposal.channel`, assigned by the `sharding`
//!   module when proposals are built) matches the local pool is admitted
//!   in place; anything else passes the local pool's forwarding admission
//!   ([`admit_forward`](super::ShardMempool::admit_forward): dedup + rate
//!   caps, no lane slot) and is scheduled one hop toward its home pool.
//! - Every hop pays a [`LinkLatency`] sample for the `(src, dst)` link —
//!   the `network::simnet` latency oracle — so cross-shard traffic
//!   arrives with realistic skew relative to locally admitted load.
//! - The ordering service's driver calls [`Relay::pump`] every tick,
//!   delivering due envelopes into their home pools *before* batches are
//!   pulled: block cutting sees the skewed arrivals, not an idealized
//!   zero-latency router.
//! - Delivery runs the home pool's full admission. `Reject::Duplicate` on
//!   arrival means another copy of the transaction already made it home
//!   (gossip from several ingress pools): the loser is counted as
//!   `deduped` and the transaction still commits exactly once. Any other
//!   rejection kills that copy: the source pool records `relay_dropped`
//!   and forgets the id so a resubmission passes dedup, and once the
//!   *last* in-flight copy dies — a surviving copy could still land and
//!   commit — every registered [`RelayDropSink`] is notified so the
//!   originating [`SubmitHandle`](crate::fabric::SubmitHandle) resolves
//!   instead of waiting out its timeout. The last-copy check covers every
//!   copy the relay has accepted (admission and hop insertion are atomic
//!   under one lock); a copy a client has *not yet submitted* when the
//!   notification fires is unknowable — its handle resolves `Rejected`
//!   and, if that late copy goes on to commit, the client's resubmission
//!   bounces as `Duplicate`, preserving exactly-once on chain.
//!
//! A forwarded envelope is a [`SharedEnvelope`]: each hop moves one
//! refcount on the envelope's canonical buffer — ingress encodes (at
//! most) once, and delivery hands the same buffer to the home pool. The
//! relay's `forwarded_bytes` counter measures the wire bytes those hops
//! represent without any per-hop re-encode.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};
use std::time::Duration;

use crate::ledger::envelope::SharedEnvelope;
use crate::ledger::tx::TxId;
use crate::network::simnet::LinkLatency;
use crate::telemetry::{self, Sample};
use crate::util::clock::Clock;

use super::admission::Reject;
use super::pool::MempoolRegistry;

/// Link-latency shape for the relay's hops (see [`LinkLatency`]).
#[derive(Clone, Debug)]
pub struct RelayConfig {
    /// Floor latency of every inter-shard link.
    pub base_latency: Duration,
    /// Stable per-link spread above the floor (hashed per `(src, dst)`).
    pub latency_spread: Duration,
    /// Per-message jitter bound.
    pub jitter: Duration,
    /// Topology seed: same seed, same per-link means.
    pub seed: u64,
}

impl Default for RelayConfig {
    fn default() -> Self {
        RelayConfig {
            base_latency: Duration::from_millis(8),
            latency_spread: Duration::from_millis(8),
            jitter: Duration::from_millis(2),
            seed: 0xCA11,
        }
    }
}

/// Receives relay drop notifications (a gateway's commit waiter, a test
/// probe). Registered weakly ([`Relay::on_drop`]): the relay prunes a
/// sink as soon as its owner is gone — no notification required — so
/// rebuilt gateways never accumulate dead entries.
pub trait RelayDropSink: Send + Sync {
    /// The relay dropped the last in-flight copy of `tx_id` before
    /// ordering; any handle awaiting it should resolve as `Rejected`.
    fn relay_dropped(&self, tx_id: &TxId, reject: Reject);
}

/// Orderable f64 wrapper for the delivery heap.
#[derive(Clone, Copy, PartialEq, PartialOrd)]
struct Due(f64);

impl Eq for Due {}
#[allow(clippy::derive_ord_xor_partial_ord)]
impl Ord for Due {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).expect("NaN due time")
    }
}

/// One forwarded envelope in flight between two pools.
struct Hop {
    sent: f64,
    src: String,
    tx_id: TxId,
    env: SharedEnvelope,
}

#[derive(Default)]
struct Inner {
    heap: BinaryHeap<Reverse<(Due, u64)>>,
    hops: std::collections::HashMap<u64, Hop>,
    seq: u64,
}

/// Point-in-time relay counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RelaySnapshot {
    /// Envelopes accepted for forwarding (one per scheduled hop).
    pub forwarded: u64,
    /// Wire bytes those hops moved (the envelopes' canonical buffer
    /// lengths — one refcount bump each, never a re-encode).
    pub forwarded_bytes: u64,
    /// Hops that landed in their home pool's queue.
    pub delivered: u64,
    /// Hops refused as `Duplicate` at home: another copy already made it,
    /// the transaction still commits exactly once.
    pub deduped: u64,
    /// Hops refused at home for any other reason — the transaction died.
    pub dropped: u64,
    /// Sum of the link latency paid by delivered hops, in microseconds.
    pub hop_latency_us: u64,
}

impl RelaySnapshot {
    /// Mean link latency per delivered hop, in seconds.
    pub fn mean_hop_latency_s(&self) -> f64 {
        if self.delivered == 0 {
            0.0
        } else {
            self.hop_latency_us as f64 / 1e6 / self.delivered as f64
        }
    }
}

/// The cross-shard forwarding fabric between one registry's pools.
pub struct Relay {
    registry: Arc<MempoolRegistry>,
    links: LinkLatency,
    clock: Arc<dyn Clock>,
    inner: Mutex<Inner>,
    sinks: Mutex<Vec<Weak<dyn RelayDropSink>>>,
    forwarded: AtomicU64,
    forwarded_bytes: AtomicU64,
    delivered: AtomicU64,
    deduped: AtomicU64,
    dropped: AtomicU64,
    hop_latency_us: AtomicU64,
}

impl Relay {
    pub fn new(
        registry: Arc<MempoolRegistry>,
        cfg: RelayConfig,
        clock: Arc<dyn Clock>,
    ) -> Arc<Relay> {
        Arc::new(Relay {
            registry,
            links: LinkLatency::new(cfg.base_latency, cfg.latency_spread, cfg.jitter, cfg.seed),
            clock,
            inner: Mutex::new(Inner::default()),
            sinks: Mutex::new(Vec::new()),
            forwarded: AtomicU64::new(0),
            forwarded_bytes: AtomicU64::new(0),
            delivered: AtomicU64::new(0),
            deduped: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            hop_latency_us: AtomicU64::new(0),
        })
    }

    /// The per-link latency oracle in use.
    pub fn links(&self) -> &LinkLatency {
        &self.links
    }

    /// Register a drop sink. Held weakly: once the owner drops its `Arc`
    /// the entry is pruned on the next registration or notification, so
    /// short-lived gateways cannot leak sinks into a long-lived relay.
    pub fn on_drop(&self, sink: Weak<dyn RelayDropSink>) {
        let mut sinks = self.sinks.lock().unwrap();
        sinks.retain(|s| s.strong_count() > 0);
        sinks.push(sink);
    }

    /// Submit an envelope at `local`'s ingress pool. Home traffic is
    /// admitted in place; foreign traffic passes the local pool's
    /// forwarding admission and is scheduled one latency-priced hop
    /// toward its home channel. `Err` is explicit backpressure — the
    /// envelope was neither queued nor forwarded.
    pub fn ingress(
        &self,
        local: &str,
        env: impl Into<SharedEnvelope>,
    ) -> Result<(), Reject> {
        let env: SharedEnvelope = env.into();
        let home = env.proposal().channel.clone();
        if home == local {
            return self.registry.pool(local).submit_shared(env);
        }
        // Validate against the HOME policy before paying the hop: the
        // local pool may serve a different committee, and forwarding a
        // policy-dead envelope only wastes the link.
        let tx_id = env.tx_id();
        self.registry.pool(&home).policy_precheck(&env)?;
        let local_pool = self.registry.pool(local);
        let now = self.clock.now();
        let bytes = env.encoded_len() as u64;
        // Admission and hop insertion are atomic under `inner`: a
        // concurrently pumped drop of another copy of this tx must either
        // see this hop in flight (and stay silent) or run before this copy
        // was accepted at all. Lock order is relay.inner -> pool locks;
        // the delivery path never holds a pool lock while taking `inner`.
        let mut inner = self.inner.lock().unwrap();
        local_pool.admit_forward(&env)?;
        inner.seq += 1;
        let seq = inner.seq;
        let latency = self.links.sample_s(local, &home, seq);
        inner.hops.insert(seq, Hop { sent: now, src: local.to_string(), tx_id, env });
        inner.heap.push(Reverse((Due(now + latency), seq)));
        self.forwarded.fetch_add(1, Ordering::Relaxed);
        self.forwarded_bytes.fetch_add(bytes, Ordering::Relaxed);
        Ok(())
    }

    /// Deliver every due hop into its home pool; returns how many landed
    /// in a queue. The ordering service calls this each driver tick, ahead
    /// of batch pulls, so block cutting sees relayed arrivals.
    pub fn pump(&self) -> usize {
        let now = self.clock.now();
        let mut landed = 0usize;
        loop {
            let hop = {
                let mut inner = self.inner.lock().unwrap();
                match inner.heap.peek() {
                    Some(Reverse((Due(t), _))) if *t <= now => {
                        let Reverse((_, seq)) = inner.heap.pop().expect("peeked");
                        Some(inner.hops.remove(&seq).expect("hop payload"))
                    }
                    _ => None,
                }
            };
            let Some(hop) = hop else { break };
            if self.deliver(hop, now) {
                landed += 1;
            }
        }
        landed
    }

    /// Hand one arrived hop to its home pool; true when it was queued.
    fn deliver(&self, hop: Hop, now: f64) -> bool {
        let tx_id = hop.tx_id;
        let home = hop.env.proposal().channel.clone();
        let latency_us = ((now - hop.sent).max(0.0) * 1e6) as u64;
        match self.registry.pool(&home).submit_shared(hop.env) {
            Ok(()) => {
                self.delivered.fetch_add(1, Ordering::Relaxed);
                self.hop_latency_us.fetch_add(latency_us, Ordering::Relaxed);
                telemetry::global().stamp_hop(&tx_id);
                true
            }
            Err(Reject::Duplicate) => {
                // Another copy of this tx already reached home (gossip from
                // several ingress pools, or a direct submission): it will
                // commit exactly once, and the commit event resolves every
                // waiting handle. Not a loss.
                self.deduped.fetch_add(1, Ordering::Relaxed);
                false
            }
            Err(reject) => {
                self.dropped.fetch_add(1, Ordering::Relaxed);
                if let Some(src) = self.registry.get(&hop.src) {
                    src.forward_dropped(&tx_id);
                }
                // Another gossiped copy of this tx may still be in flight
                // and can land once the home pool drains — resolving the
                // handles now would report Rejected for a transaction that
                // later commits. Only the LAST copy's death notifies.
                let another_in_flight =
                    self.inner.lock().unwrap().hops.values().any(|h| h.tx_id == tx_id);
                if !another_in_flight {
                    telemetry::global().abort(&tx_id, "relay_drop");
                    self.notify_drop(&tx_id, reject);
                }
                false
            }
        }
    }

    fn notify_drop(&self, tx_id: &TxId, reject: Reject) {
        // Every live sink sees every drop; a sink with no waiter for this
        // id ignores it. Dead sinks (owner gone) are pruned in place.
        self.sinks.lock().unwrap().retain(|weak| match weak.upgrade() {
            Some(sink) => {
                sink.relay_dropped(tx_id, reject);
                true
            }
            None => false,
        });
    }

    /// Forwarded envelopes still in flight between pools.
    pub fn in_flight(&self) -> usize {
        self.inner.lock().unwrap().hops.len()
    }

    /// Flush every in-flight hop as a `Shutdown` drop (orderer teardown):
    /// no handle is left eternally pending on a hop that will never land.
    pub fn close(&self) {
        let hops: Vec<Hop> = {
            let mut inner = self.inner.lock().unwrap();
            inner.heap.clear();
            inner.hops.drain().map(|(_, h)| h).collect()
        };
        for hop in hops {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            if let Some(src) = self.registry.get(&hop.src) {
                src.forward_dropped(&hop.tx_id);
            }
            telemetry::global().abort(&hop.tx_id, "shutdown");
            self.notify_drop(&hop.tx_id, Reject::Shutdown);
        }
    }

    pub fn snapshot(&self) -> RelaySnapshot {
        RelaySnapshot {
            forwarded: self.forwarded.load(Ordering::Relaxed),
            forwarded_bytes: self.forwarded_bytes.load(Ordering::Relaxed),
            delivered: self.delivered.load(Ordering::Relaxed),
            deduped: self.deduped.load(Ordering::Relaxed),
            dropped: self.dropped.load(Ordering::Relaxed),
            hop_latency_us: self.hop_latency_us.load(Ordering::Relaxed),
        }
    }

    /// Register the relay's metrics with a telemetry registry (weakly —
    /// pruned once the owning ordering service is gone).
    pub fn register_telemetry(self: &Arc<Self>, registry: &telemetry::Registry) {
        let weak = Arc::downgrade(self);
        registry.register(move || {
            let relay = weak.upgrade()?;
            let snap = relay.snapshot();
            Some(vec![
                Sample::counter("scalesfl_relay_forwarded_total", Vec::new(), snap.forwarded as f64),
                Sample::counter(
                    "scalesfl_relay_forwarded_bytes_total",
                    Vec::new(),
                    snap.forwarded_bytes as f64,
                ),
                Sample::counter("scalesfl_relay_delivered_total", Vec::new(), snap.delivered as f64),
                Sample::counter("scalesfl_relay_deduped_total", Vec::new(), snap.deduped as f64),
                Sample::counter("scalesfl_relay_dropped_total", Vec::new(), snap.dropped as f64),
                Sample::counter(
                    "scalesfl_relay_hop_latency_seconds_total",
                    Vec::new(),
                    snap.hop_latency_us as f64 / 1e6,
                ),
                Sample::gauge("scalesfl_relay_in_flight", Vec::new(), relay.in_flight() as f64),
            ])
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::msp::MemberId;
    use crate::fabric::endorsement::EndorsementPolicy;
    use crate::ledger::block::ValidationCode;
    use crate::ledger::tx::{Envelope, Proposal, RwSet};
    use crate::mempool::MempoolConfig;
    use crate::util::clock::VirtualClock;

    /// Test sink: records every notification it receives.
    #[derive(Default)]
    struct RecordSink(Mutex<Vec<(TxId, Reject)>>);

    impl RelayDropSink for RecordSink {
        fn relay_dropped(&self, tx_id: &TxId, reject: Reject) {
            self.0.lock().unwrap().push((*tx_id, reject));
        }
    }

    impl RecordSink {
        fn drops(&self) -> Vec<(TxId, Reject)> {
            self.0.lock().unwrap().clone()
        }
    }

    fn envelope(channel: &str, key: &str, nonce: u64) -> Envelope {
        Envelope {
            proposal: Proposal {
                channel: channel.into(),
                chaincode: "kv".into(),
                function: "Put".into(),
                args: vec![key.into()],
                creator: MemberId::new("client"),
                nonce,
            },
            rw_set: RwSet::default(),
            endorsements: Vec::new(),
        }
    }

    fn fixture(cfg: MempoolConfig) -> (Arc<MempoolRegistry>, Arc<Relay>, Arc<VirtualClock>) {
        let clock = Arc::new(VirtualClock::new());
        let registry = MempoolRegistry::with_parts(
            cfg,
            Arc::clone(&clock) as Arc<dyn Clock>,
            None,
        );
        let relay = Relay::new(
            Arc::clone(&registry),
            RelayConfig::default(),
            Arc::clone(&clock) as Arc<dyn Clock>,
        );
        (registry, relay, clock)
    }

    /// Advance past any possible link latency and deliver.
    fn settle(relay: &Relay, clock: &VirtualClock) -> usize {
        clock.advance(Duration::from_secs_f64(relay.links().max_s() + 1e-6));
        relay.pump()
    }

    #[test]
    fn home_traffic_is_admitted_in_place() {
        let (registry, relay, _clock) = fixture(MempoolConfig::default());
        relay.ingress("shard0", envelope("shard0", "k", 1)).unwrap();
        assert_eq!(registry.pool("shard0").pending(), 1);
        assert_eq!(relay.in_flight(), 0);
        assert_eq!(relay.snapshot().forwarded, 0);
        assert_eq!(registry.snapshot().forwarded, 0);
    }

    #[test]
    fn foreign_traffic_pays_a_link_latency_hop() {
        let (registry, relay, clock) = fixture(MempoolConfig::default());
        let env = envelope("shard0", "k", 1);
        let wire_len = SharedEnvelope::from(&env).encoded_len() as u64;
        relay.ingress("shard1", env).unwrap();
        // Forwarded, not queued locally — and not home yet.
        assert_eq!(registry.pool("shard1").pending(), 0);
        assert_eq!(registry.pool("shard0").pending(), 0);
        assert_eq!(relay.in_flight(), 1);
        assert_eq!(registry.pool("shard1").stats().forwarded, 1);
        assert_eq!(relay.snapshot().forwarded_bytes, wire_len, "hop bytes counted at ingress");
        // The link floor is 8 ms: pumping before that delivers nothing.
        clock.advance(Duration::from_millis(7));
        assert_eq!(relay.pump(), 0);
        assert_eq!(settle(&relay, &clock), 1);
        assert_eq!(registry.pool("shard0").pending(), 1);
        let snap = relay.snapshot();
        assert_eq!(snap.delivered, 1);
        assert!(snap.mean_hop_latency_s() >= 0.008, "{}", snap.mean_hop_latency_s());
    }

    #[test]
    fn gossip_from_many_pools_commits_exactly_once() {
        // The dedup property, concurrently: one tx injected at k ingress
        // pools (home included) lands in the home queue exactly once and
        // commits exactly once; every counter reconciles.
        let k = 4usize;
        let (registry, relay, clock) = fixture(MempoolConfig::default());
        let env = envelope("shard0", "ctr", 9);
        let results: Vec<Result<(), Reject>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..k)
                .map(|i| {
                    let relay = Arc::clone(&relay);
                    let env = env.clone();
                    s.spawn(move || relay.ingress(&format!("shard{i}"), env))
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("ingress panicked")).collect()
        });
        // Every ingress accepted it: its own pool had never seen the id.
        for r in &results {
            assert_eq!(*r, Ok(()));
        }
        settle(&relay, &clock);
        // Exactly one copy in the home queue; the k-1 forwards deduped.
        let batch = registry.pool("shard0").take_batch(16, 0);
        assert_eq!(batch.len(), 1);
        let snap = relay.snapshot();
        assert_eq!(snap.forwarded, (k - 1) as u64);
        assert_eq!(snap.delivered + snap.deduped, (k - 1) as u64);
        assert_eq!(snap.dropped, 0);
        let stats = registry.snapshot();
        assert_eq!(stats.forwarded, (k - 1) as u64);
        assert_eq!(stats.relay_dropped, 0);
        assert_eq!(stats.admitted, 1 + snap.delivered);
        // ...and it commits exactly once.
        let ca = crate::crypto::msp::CertificateAuthority::new();
        let mut rng = crate::util::prng::Prng::new(5);
        let cred = ca.enroll(MemberId::new("org0.peer"), &mut rng);
        let peer = crate::fabric::Peer::new(cred, ca);
        peer.join_channel("shard0", EndorsementPolicy::AnyOf(0, vec![]));
        let block = peer.commit_batch("shard0", batch).unwrap();
        let valid =
            block.validation.iter().filter(|c| **c == ValidationCode::Valid).count();
        assert_eq!(valid, 1);
    }

    #[test]
    fn concurrent_distinct_forwards_all_arrive() {
        let (registry, relay, clock) = fixture(MempoolConfig::default());
        std::thread::scope(|s| {
            for i in 0..16u64 {
                let relay = Arc::clone(&relay);
                s.spawn(move || {
                    let src = format!("shard{}", 1 + i % 3);
                    relay.ingress(&src, envelope("shard0", &format!("k{i}"), i)).unwrap();
                });
            }
        });
        assert_eq!(relay.in_flight(), 16);
        assert_eq!(settle(&relay, &clock), 16);
        assert_eq!(registry.pool("shard0").pending(), 16);
        let snap = relay.snapshot();
        assert_eq!(snap.forwarded, 16);
        assert_eq!(snap.delivered, 16);
        assert_eq!(snap.deduped + snap.dropped, 0);
    }

    #[test]
    fn relay_drop_notifies_sinks_and_forgets_dedup() {
        let cfg = MempoolConfig { lane_capacity: 1, ..Default::default() };
        let (registry, relay, clock) = fixture(cfg);
        let sink = Arc::new(RecordSink::default());
        relay.on_drop(Arc::downgrade(&sink));
        // Fill the home lane, then forward a second tx into the full pool.
        registry.pool("shard0").submit(envelope("shard0", "a", 1)).unwrap();
        let doomed = envelope("shard0", "b", 2);
        let doomed_id = doomed.tx_id();
        relay.ingress("shard1", doomed.clone()).unwrap();
        settle(&relay, &clock);
        // Dropped at home, counted on the source pool, sink notified.
        assert_eq!(relay.snapshot().dropped, 1);
        assert_eq!(registry.pool("shard1").stats().relay_dropped, 1);
        assert_eq!(registry.pool("shard0").pending(), 1);
        assert_eq!(sink.drops(), vec![(doomed_id, Reject::PoolFull)]);
        // The source pool forgot the id: a resubmission is forwarded
        // again, not bounced as a replay.
        registry.pool("shard0").take_batch(16, 0);
        relay.ingress("shard1", doomed).unwrap();
        assert_eq!(settle(&relay, &clock), 1);
        assert_eq!(registry.pool("shard0").pending(), 1);
    }

    #[test]
    fn only_the_last_copys_death_notifies() {
        // Two gossiped copies of one tx race into a full home lane in the
        // same pump: the first drop must NOT resolve handles (the second
        // copy was still in flight and could have landed); the second —
        // last — drop notifies exactly once.
        let cfg = MempoolConfig { lane_capacity: 1, ..Default::default() };
        let (registry, relay, clock) = fixture(cfg);
        let sink = Arc::new(RecordSink::default());
        relay.on_drop(Arc::downgrade(&sink));
        registry.pool("shard0").submit(envelope("shard0", "a", 1)).unwrap();
        let gossiped = envelope("shard0", "b", 2);
        relay.ingress("shard1", gossiped.clone()).unwrap();
        relay.ingress("shard2", gossiped.clone()).unwrap();
        settle(&relay, &clock);
        assert_eq!(relay.snapshot().dropped, 2, "both copies died");
        assert_eq!(
            sink.drops(),
            vec![(gossiped.tx_id(), Reject::PoolFull)],
            "exactly one notification, from the last copy"
        );
    }

    #[test]
    fn dead_sinks_are_pruned_without_being_invoked() {
        let cfg = MempoolConfig { lane_capacity: 1, ..Default::default() };
        let (registry, relay, clock) = fixture(cfg);
        let dead = Arc::new(RecordSink::default());
        relay.on_drop(Arc::downgrade(&dead));
        drop(dead);
        // Registration prunes entries whose owner is already gone.
        let live = Arc::new(RecordSink::default());
        relay.on_drop(Arc::downgrade(&live));
        assert_eq!(relay.sinks.lock().unwrap().len(), 1);
        // Notification reaches the live sink and keeps it registered.
        registry.pool("shard0").submit(envelope("shard0", "a", 1)).unwrap();
        relay.ingress("shard1", envelope("shard0", "b", 2)).unwrap();
        settle(&relay, &clock);
        assert_eq!(relay.snapshot().dropped, 1);
        assert_eq!(live.drops().len(), 1);
        assert_eq!(relay.sinks.lock().unwrap().len(), 1);
    }

    #[test]
    fn forward_checks_home_policy_not_local() {
        // Registry with signature prechecks: the home pool's policy is the
        // one that must pass, and an unsigned envelope dies at ingress —
        // before the link is paid — not after the hop.
        let ca = crate::crypto::msp::CertificateAuthority::new();
        let mut rng = crate::util::prng::Prng::new(11);
        let cred = ca.enroll(MemberId::new("org0.peer"), &mut rng);
        let clock = Arc::new(VirtualClock::new());
        let registry = MempoolRegistry::with_parts(
            MempoolConfig { verify_endorsements: true, ..Default::default() },
            Arc::clone(&clock) as Arc<dyn Clock>,
            Some(ca),
        );
        registry.set_policy("shard0", EndorsementPolicy::AnyOf(1, vec![cred.member.clone()]));
        let relay = Relay::new(
            Arc::clone(&registry),
            RelayConfig::default(),
            Arc::clone(&clock) as Arc<dyn Clock>,
        );
        let unsigned = envelope("shard0", "k", 1);
        assert_eq!(
            relay.ingress("shard1", unsigned),
            Err(Reject::PolicyUnsatisfiable)
        );
        assert_eq!(relay.in_flight(), 0);
        assert_eq!(registry.pool("shard1").stats().forwarded, 0);
        // A properly endorsed envelope forwards fine.
        let mut signed = envelope("shard0", "k", 2);
        let payload = crate::ledger::tx::endorsement_payload(
            &signed.tx_id(),
            &signed.rw_set.digest(),
        );
        signed.endorsements.push(crate::ledger::tx::Endorsement {
            endorser: cred.member.clone(),
            signature: cred.sign(&payload),
        });
        relay.ingress("shard1", signed).unwrap();
        assert_eq!(relay.in_flight(), 1);
    }

    #[test]
    fn close_flushes_in_flight_as_shutdown_drops() {
        let (registry, relay, _clock) = fixture(MempoolConfig::default());
        let sink = Arc::new(RecordSink::default());
        relay.on_drop(Arc::downgrade(&sink));
        let env = envelope("shard0", "k", 1);
        let tx_id = env.tx_id();
        relay.ingress("shard1", env).unwrap();
        relay.close();
        assert_eq!(relay.in_flight(), 0);
        assert_eq!(sink.drops(), vec![(tx_id, Reject::Shutdown)]);
        assert_eq!(registry.pool("shard1").stats().relay_dropped, 1);
    }

    #[test]
    fn shed_and_committed_reconcile_across_shards() {
        // Two distinct txs race into a 1-slot home lane through the relay:
        // one lands, one is shed — and forwarded == delivered + dropped,
        // injected == queued + deduped + dropped.
        let cfg = MempoolConfig { lane_capacity: 1, ..Default::default() };
        let (registry, relay, clock) = fixture(cfg);
        relay.ingress("shard1", envelope("shard0", "x", 1)).unwrap();
        relay.ingress("shard2", envelope("shard0", "y", 2)).unwrap();
        settle(&relay, &clock);
        let snap = relay.snapshot();
        assert_eq!(snap.forwarded, 2);
        assert_eq!(snap.delivered, 1);
        assert_eq!(snap.dropped, 1);
        assert_eq!(snap.deduped, 0);
        let stats = registry.snapshot();
        assert_eq!(stats.forwarded, 2);
        assert_eq!(stats.relay_dropped, 1);
        assert_eq!(stats.admitted, 1);
        assert_eq!(registry.pool("shard0").pending(), 1);
    }
}
