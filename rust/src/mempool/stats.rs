//! Overflow / reject / throughput counters for the mempool, exported into
//! the Caliper-style reports so surge figures show shed load explicitly.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;

use super::admission::Reject;

/// Live atomic counters owned by one `ShardMempool`.
#[derive(Debug, Default)]
pub struct MempoolStats {
    admitted: AtomicU64,
    pool_full: AtomicU64,
    rate_limited: AtomicU64,
    duplicate: AtomicU64,
    bad_signature: AtomicU64,
    policy_unsatisfiable: AtomicU64,
    stale_read_set: AtomicU64,
    stale_dropped: AtomicU64,
    forwarded: AtomicU64,
    relay_dropped: AtomicU64,
    expired: AtomicU64,
    batches_cut: AtomicU64,
    txs_ordered: AtomicU64,
    bytes_ordered: AtomicU64,
    depth_high_water: AtomicU64,
}

impl MempoolStats {
    pub fn note_admitted(&self, depth_after: u64) {
        self.admitted.fetch_add(1, Ordering::Relaxed);
        self.depth_high_water.fetch_max(depth_after, Ordering::Relaxed);
    }

    pub fn note_reject(&self, r: Reject) {
        let counter = match r {
            Reject::PoolFull => &self.pool_full,
            Reject::RateLimited => &self.rate_limited,
            Reject::Duplicate => &self.duplicate,
            Reject::BadSignature => &self.bad_signature,
            Reject::PolicyUnsatisfiable => &self.policy_unsatisfiable,
            Reject::StaleReadSet => &self.stale_read_set,
            // Shutdown races are not a workload signal; don't count them.
            Reject::Shutdown => return,
        };
        counter.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_expired(&self) {
        self.expired.fetch_add(1, Ordering::Relaxed);
    }

    /// A queued transaction went stale between admission and batch pull
    /// and was shed before consensus saw it (a guaranteed `MvccConflict`
    /// avoided).
    pub fn note_stale_dropped(&self) {
        self.stale_dropped.fetch_add(1, Ordering::Relaxed);
    }

    /// An envelope was admitted at this pool's ingress but belongs to
    /// another channel: handed to the relay for a cross-shard hop instead
    /// of a lane slot.
    pub fn note_forwarded(&self) {
        self.forwarded.fetch_add(1, Ordering::Relaxed);
    }

    /// A forwarded envelope died in the relay (home pool refused it on
    /// arrival, or the link dropped it) — the originating client must
    /// resubmit.
    pub fn note_relay_dropped(&self) {
        self.relay_dropped.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_ordered(&self, txs: u64, bytes: u64) {
        self.batches_cut.fetch_add(1, Ordering::Relaxed);
        self.txs_ordered.fetch_add(txs, Ordering::Relaxed);
        self.bytes_ordered.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Roll back one `note_ordered` after a failed consensus proposal
    /// (the batch went back into the pool).
    pub fn note_restored(&self, txs: u64, bytes: u64) {
        self.batches_cut.fetch_sub(1, Ordering::Relaxed);
        self.txs_ordered.fetch_sub(txs, Ordering::Relaxed);
        self.bytes_ordered.fetch_sub(bytes, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            admitted: self.admitted.load(Ordering::Relaxed),
            pool_full: self.pool_full.load(Ordering::Relaxed),
            rate_limited: self.rate_limited.load(Ordering::Relaxed),
            duplicate: self.duplicate.load(Ordering::Relaxed),
            bad_signature: self.bad_signature.load(Ordering::Relaxed),
            policy_unsatisfiable: self.policy_unsatisfiable.load(Ordering::Relaxed),
            stale_read_set: self.stale_read_set.load(Ordering::Relaxed),
            stale_dropped: self.stale_dropped.load(Ordering::Relaxed),
            forwarded: self.forwarded.load(Ordering::Relaxed),
            relay_dropped: self.relay_dropped.load(Ordering::Relaxed),
            expired: self.expired.load(Ordering::Relaxed),
            batches_cut: self.batches_cut.load(Ordering::Relaxed),
            txs_ordered: self.txs_ordered.load(Ordering::Relaxed),
            bytes_ordered: self.bytes_ordered.load(Ordering::Relaxed),
            depth_high_water: self.depth_high_water.load(Ordering::Relaxed),
        }
    }

    /// Snapshot-with-reset: read every counter and zero it in one atomic
    /// swap each, so successive measurement windows (caliper rounds, the
    /// telemetry exposition's per-round deltas) report what happened
    /// *inside* the window instead of monotone process totals.
    /// `depth_high_water` resets too — the next window records its own
    /// peak. Counts noted concurrently with the swap land in exactly one
    /// window (swap is atomic per counter; cross-counter skew is at most
    /// one in-flight transaction).
    pub fn snapshot_and_reset(&self) -> StatsSnapshot {
        StatsSnapshot {
            admitted: self.admitted.swap(0, Ordering::Relaxed),
            pool_full: self.pool_full.swap(0, Ordering::Relaxed),
            rate_limited: self.rate_limited.swap(0, Ordering::Relaxed),
            duplicate: self.duplicate.swap(0, Ordering::Relaxed),
            bad_signature: self.bad_signature.swap(0, Ordering::Relaxed),
            policy_unsatisfiable: self.policy_unsatisfiable.swap(0, Ordering::Relaxed),
            stale_read_set: self.stale_read_set.swap(0, Ordering::Relaxed),
            stale_dropped: self.stale_dropped.swap(0, Ordering::Relaxed),
            forwarded: self.forwarded.swap(0, Ordering::Relaxed),
            relay_dropped: self.relay_dropped.swap(0, Ordering::Relaxed),
            expired: self.expired.swap(0, Ordering::Relaxed),
            batches_cut: self.batches_cut.swap(0, Ordering::Relaxed),
            txs_ordered: self.txs_ordered.swap(0, Ordering::Relaxed),
            bytes_ordered: self.bytes_ordered.swap(0, Ordering::Relaxed),
            depth_high_water: self.depth_high_water.swap(0, Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of the counters (mergeable across pools).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    pub admitted: u64,
    pub pool_full: u64,
    pub rate_limited: u64,
    pub duplicate: u64,
    pub bad_signature: u64,
    pub policy_unsatisfiable: u64,
    /// Rejected at admission because the read-set was already stale.
    pub stale_read_set: u64,
    /// Dropped at batch pull after going stale while queued.
    pub stale_dropped: u64,
    /// Admitted at this pool's ingress and forwarded to the envelope's
    /// home channel over a relay hop (never occupied a lane here).
    pub forwarded: u64,
    /// Forwarded envelopes that died in the relay instead of reaching
    /// their home pool's queue.
    pub relay_dropped: u64,
    pub expired: u64,
    pub batches_cut: u64,
    pub txs_ordered: u64,
    pub bytes_ordered: u64,
    pub depth_high_water: u64,
}

impl StatsSnapshot {
    /// Backpressure sheds: envelopes refused because of load (not because
    /// they were invalid or replays).
    pub fn shed(&self) -> u64 {
        self.pool_full + self.rate_limited
    }

    /// Every admission refusal, whatever the reason.
    pub fn rejected_total(&self) -> u64 {
        self.pool_full
            + self.rate_limited
            + self.duplicate
            + self.bad_signature
            + self.policy_unsatisfiable
            + self.stale_read_set
    }

    /// Transactions shed by MVCC hinting before ordering (admission
    /// rejects + pull-time drops): each one is an `MvccConflict` that
    /// never reached consensus.
    pub fn stale_shed(&self) -> u64 {
        self.stale_read_set + self.stale_dropped
    }

    /// Accumulate another pool's counters (high-water keeps the max).
    pub fn merge(&mut self, other: &StatsSnapshot) {
        self.admitted += other.admitted;
        self.pool_full += other.pool_full;
        self.rate_limited += other.rate_limited;
        self.duplicate += other.duplicate;
        self.bad_signature += other.bad_signature;
        self.policy_unsatisfiable += other.policy_unsatisfiable;
        self.stale_read_set += other.stale_read_set;
        self.stale_dropped += other.stale_dropped;
        self.forwarded += other.forwarded;
        self.relay_dropped += other.relay_dropped;
        self.expired += other.expired;
        self.batches_cut += other.batches_cut;
        self.txs_ordered += other.txs_ordered;
        self.bytes_ordered += other.bytes_ordered;
        self.depth_high_water = self.depth_high_water.max(other.depth_high_water);
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("admitted", self.admitted)
            .set("rejected_pool_full", self.pool_full)
            .set("rejected_rate_limited", self.rate_limited)
            .set("rejected_duplicate", self.duplicate)
            .set("rejected_bad_signature", self.bad_signature)
            .set("rejected_policy", self.policy_unsatisfiable)
            .set("rejected_stale_read_set", self.stale_read_set)
            .set("stale_dropped", self.stale_dropped)
            .set("forwarded", self.forwarded)
            .set("relay_dropped", self.relay_dropped)
            .set("expired_ttl", self.expired)
            .set("batches_cut", self.batches_cut)
            .set("txs_ordered", self.txs_ordered)
            .set("bytes_ordered", self.bytes_ordered)
            .set("depth_high_water", self.depth_high_water)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_snapshot() {
        let s = MempoolStats::default();
        s.note_admitted(3);
        s.note_admitted(7);
        s.note_admitted(5);
        s.note_reject(Reject::PoolFull);
        s.note_reject(Reject::RateLimited);
        s.note_reject(Reject::Duplicate);
        s.note_reject(Reject::StaleReadSet);
        s.note_reject(Reject::Shutdown); // not counted
        s.note_expired();
        s.note_stale_dropped();
        s.note_forwarded();
        s.note_forwarded();
        s.note_relay_dropped();
        s.note_ordered(10, 1000);
        let snap = s.snapshot();
        assert_eq!(snap.admitted, 3);
        assert_eq!(snap.shed(), 2);
        assert_eq!(snap.rejected_total(), 4);
        assert_eq!(snap.stale_shed(), 2);
        assert_eq!(snap.forwarded, 2);
        assert_eq!(snap.relay_dropped, 1);
        assert_eq!(snap.depth_high_water, 7);
        assert_eq!(snap.txs_ordered, 10);
        assert_eq!(snap.expired, 1);
    }

    #[test]
    fn restore_rolls_back_ordered() {
        let s = MempoolStats::default();
        s.note_ordered(10, 1000);
        s.note_ordered(4, 400);
        s.note_restored(4, 400);
        let snap = s.snapshot();
        assert_eq!(snap.batches_cut, 1);
        assert_eq!(snap.txs_ordered, 10);
        assert_eq!(snap.bytes_ordered, 1000);
    }

    #[test]
    fn snapshot_and_reset_windows() {
        let s = MempoolStats::default();
        s.note_admitted(9);
        s.note_reject(Reject::PoolFull);
        s.note_ordered(4, 400);
        let w1 = s.snapshot_and_reset();
        assert_eq!(w1.admitted, 1);
        assert_eq!(w1.pool_full, 1);
        assert_eq!(w1.txs_ordered, 4);
        assert_eq!(w1.depth_high_water, 9);
        // The window boundary zeroed everything, including the high-water
        // mark: the next window records only its own activity.
        let empty = s.snapshot();
        assert_eq!(empty, StatsSnapshot::default());
        s.note_admitted(2);
        let w2 = s.snapshot_and_reset();
        assert_eq!(w2.admitted, 1);
        assert_eq!(w2.depth_high_water, 2);
        assert_eq!(w2.pool_full, 0);
    }

    #[test]
    fn merge_sums_and_maxes() {
        let mut a = StatsSnapshot { admitted: 1, depth_high_water: 5, ..Default::default() };
        let b = StatsSnapshot { admitted: 2, depth_high_water: 3, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.admitted, 3);
        assert_eq!(a.depth_high_water, 5);
    }

    #[test]
    fn json_export_names_reject_reasons() {
        let snap = StatsSnapshot { pool_full: 4, ..Default::default() };
        let j = snap.to_json();
        assert_eq!(j.get("rejected_pool_full").unwrap().as_f64(), Some(4.0));
        assert!(j.get("depth_high_water").is_some());
    }
}
