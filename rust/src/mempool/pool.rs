//! The per-shard transaction pool and the per-channel registry the
//! ordering service drains.
//!
//! Ingress path: gateway/client → [`ShardMempool::submit`] /
//! [`ShardMempool::submit_batch`] (admission control, bounded priority
//! lanes, explicit backpressure) → the orderer driver pulls
//! size-and-byte-bounded batches with [`ShardMempool::take_batch`].
//! The pool owns all batching state, so batch cutting, consensus, and
//! validation pipeline against each other.
//!
//! **Shared-buffer envelopes**: every queued entry holds a
//! [`SharedEnvelope`] — the envelope's canonical wire bytes behind an
//! `Arc`, with tx id / rw digest / decoded form computed once and cached.
//! Admission reads the cached views (no re-hash), the byte bound for
//! block cutting is the buffer length (no re-encode), and handing a batch
//! to the orderer moves refcounts, not bytes. The single copy of envelope
//! bytes after admission happens when a block is framed for the wire or
//! the durable store (`fabric::wire` splices the buffers).
//!
//! **Striped admission**: there is no big pool mutex. Each priority lane
//! has its own queue lock, the replay-dedup window is striped into
//! [`SEEN_SHARDS`] independently locked shards keyed by tx id, and the
//! rate-limit buckets sit behind their own lock. A submission claims its
//! dedup slot, reserves lane capacity, pays the rate token, runs the
//! (lock-free) crypto precheck, and only then takes the lane lock again
//! to enqueue — so concurrent submitters on different transactions touch
//! disjoint locks, and signature verification never serializes behind the
//! queue. Every check that fails after the claim rolls the claim back, so
//! rejected transactions are never remembered (exactly as before).
//!
//! **Batched admission crypto**: [`ShardMempool::submit_batch`] admits a
//! whole pull in three phases — per-envelope load checks, then *one*
//! batched signature/policy pass over all survivors (through the shared
//! [`BlockValidator`] verdict cache when wired with
//! [`ShardMempool::set_validator`], amortizing MSP/policy lookups across
//! the batch and pre-seeding commit-time prevalidation), then the lane
//! pushes. Verdicts are identical to the serial path: both funnel into
//! the same per-envelope predicate.
//!
//! **MVCC hinting**: when a channel's pool is wired to a replica's
//! [`StateView`] (the ordering service does this for every channel its
//! peers joined), transactions whose read-set is already stale are
//! rejected at admission ([`Reject::StaleReadSet`]), and transactions that
//! went stale *while queued* are dropped at batch pull — both before the
//! orderer spends consensus bandwidth on a guaranteed `MvccConflict`.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

use crate::crypto::msp::CertificateAuthority;
use crate::fabric::endorsement::EndorsementPolicy;
use crate::fabric::validator::BlockValidator;
use crate::ledger::codec::Writer;
use crate::ledger::envelope::SharedEnvelope;
use crate::ledger::state::StateView;
use crate::ledger::tx::{endorsement_payload, Envelope, Proposal, TxId};
use crate::telemetry::{self, Sample, Stage};
use crate::util::clock::{Clock, SystemClock};

use super::admission::{Reject, TokenBucket};
use super::stats::{MempoolStats, StatsSnapshot};

/// Priority lanes, drained highest-priority-first when a block is cut:
/// checkpoint/aggregation traffic must not starve behind bulk model
/// updates, and queries yield to both.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Lane {
    /// Mainchain catalyst txs and global-model pins (checkpoint traffic).
    Catalyst,
    /// Client model-update submissions (`CreateModelUpdate`, shard models).
    ModelUpdate,
    /// Everything else (generic chaincode invocations, queries).
    Query,
}

impl Lane {
    pub const COUNT: usize = 3;

    /// Classify a proposal into its ingress lane.
    pub fn classify(proposal: &Proposal) -> Lane {
        if proposal.chaincode == "catalyst" || proposal.function == "PinGlobalModel" {
            Lane::Catalyst
        } else if proposal.function.starts_with("Create") || proposal.function.starts_with("Submit")
        {
            Lane::ModelUpdate
        } else {
            Lane::Query
        }
    }

    pub fn index(self) -> usize {
        match self {
            Lane::Catalyst => 0,
            Lane::ModelUpdate => 1,
            Lane::Query => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Lane::Catalyst => "catalyst",
            Lane::ModelUpdate => "model-update",
            Lane::Query => "query",
        }
    }
}

/// Dedup stripes. Sixteen shards keep the claim lock uncontended at any
/// realistic submitter count while the per-shard window (`dedup_window /
/// 16`) still spans thousands of transactions.
const SEEN_SHARDS: usize = 16;

/// Pool sizing and admission-control knobs.
#[derive(Clone, Debug)]
pub struct MempoolConfig {
    /// Max queued envelopes per priority lane (the bounded queue).
    pub lane_capacity: usize,
    /// Queued envelopes older than this are evicted (counted as expired).
    pub ttl: Duration,
    /// Per-client sustained admission rate in tx/s (`None` = uncapped).
    pub rate_limit: Option<f64>,
    /// Token-bucket burst allowance when rate limiting.
    pub rate_burst: f64,
    /// Verify endorsement signatures / policy quorum at admission (needs a
    /// CA handle on the pool; silently skipped otherwise).
    pub verify_endorsements: bool,
    /// Recently-admitted tx ids remembered for replay rejection.
    pub dedup_window: usize,
}

impl Default for MempoolConfig {
    fn default() -> Self {
        MempoolConfig {
            lane_capacity: 4096,
            ttl: Duration::from_secs(30),
            rate_limit: None,
            rate_burst: 64.0,
            verify_endorsements: false,
            dedup_window: 1 << 16,
        }
    }
}

struct Entry {
    env: SharedEnvelope,
    tx_id: TxId,
    /// Wire size — the envelope's canonical buffer length (no re-encode).
    bytes: usize,
    enqueued: f64,
    /// State write sequence at which this entry's read-set was last known
    /// fresh. Batch pulls skip the key-by-key re-check while the state's
    /// current sequence still matches.
    checked_seq: u64,
}

/// One priority lane's queue plus in-flight capacity reservations:
/// admission reserves a slot before the (lock-free) crypto phase and
/// converts it to a real entry afterwards, so concurrent submitters can
/// never overshoot `lane_capacity` between check and push.
#[derive(Default)]
struct LaneQueue {
    q: VecDeque<Entry>,
    reserved: usize,
}

/// One stripe of the replay-dedup window.
#[derive(Default)]
struct SeenShard {
    set: HashSet<TxId>,
    order: VecDeque<TxId>,
}

/// Wire-encoded size of an envelope (what consensus replicates; the byte
/// bound for block cutting).
pub fn encoded_len(env: &Envelope) -> usize {
    let mut w = Writer::new();
    crate::ledger::envelope::encode_envelope(env, &mut w);
    w.finish().len()
}

/// One channel's bounded ingress pool.
pub struct ShardMempool {
    pub channel: String,
    cfg: MempoolConfig,
    clock: Arc<dyn Clock>,
    ca: Option<CertificateAuthority>,
    policy: RwLock<Option<Arc<EndorsementPolicy>>>,
    /// Shared verdict cache for admission crypto: when wired, batched
    /// admission runs through [`BlockValidator::admission_verify`], so an
    /// envelope verified at admission is a cache hit at commit.
    validator: RwLock<Option<Arc<BlockValidator>>>,
    /// Read-version oracle for MVCC hinting (None = hinting off).
    state_view: RwLock<Option<Arc<dyn StateView>>>,
    lanes: [Mutex<LaneQueue>; Lane::COUNT],
    seen: [Mutex<SeenShard>; SEEN_SHARDS],
    buckets: Mutex<HashMap<String, TokenBucket>>,
    open: AtomicBool,
    /// Queued entries across all lanes (kept for the admission-time
    /// high-water mark without summing three lane locks per submit).
    depth: AtomicUsize,
    stats: MempoolStats,
}

impl ShardMempool {
    pub fn new(channel: &str, cfg: MempoolConfig) -> ShardMempool {
        ShardMempool::with_parts(channel, cfg, SystemClock::shared(), None)
    }

    pub fn with_parts(
        channel: &str,
        cfg: MempoolConfig,
        clock: Arc<dyn Clock>,
        ca: Option<CertificateAuthority>,
    ) -> ShardMempool {
        ShardMempool {
            channel: channel.to_string(),
            cfg,
            clock,
            ca,
            policy: RwLock::new(None),
            validator: RwLock::new(None),
            state_view: RwLock::new(None),
            lanes: std::array::from_fn(|_| Mutex::new(LaneQueue::default())),
            seen: std::array::from_fn(|_| Mutex::new(SeenShard::default())),
            buckets: Mutex::new(HashMap::new()),
            open: AtomicBool::new(true),
            depth: AtomicUsize::new(0),
            stats: MempoolStats::default(),
        }
    }

    /// Install/replace the endorsement policy used by the admission
    /// precheck (e.g. after a committee re-election).
    pub fn set_policy(&self, policy: EndorsementPolicy) {
        *self.policy.write().unwrap() = Some(Arc::new(policy));
    }

    /// Route admission crypto through a block validator's verdict cache:
    /// signatures verified here are cache hits at commit prevalidation,
    /// and batched submissions fan out over the validator's worker pool.
    pub fn set_validator(&self, validator: Arc<BlockValidator>) {
        *self.validator.write().unwrap() = Some(validator);
    }

    /// Wire the channel's read-version oracle (usually one replica's
    /// `PeerChannel`) to enable MVCC staleness hinting at admission and
    /// batch pull. The view does not have to be the most current replica:
    /// `StateView::any_stale` only flags observations this view has seen
    /// strictly overtaken, so a lagging view yields fewer hints, never
    /// false rejections.
    pub fn set_state_view(&self, view: Arc<dyn StateView>) {
        *self.state_view.write().unwrap() = Some(view);
    }

    /// Is MVCC hinting active on this pool?
    pub fn has_state_view(&self) -> bool {
        self.state_view.read().unwrap().is_some()
    }

    pub fn stats(&self) -> StatsSnapshot {
        self.stats.snapshot()
    }

    /// Snapshot and zero the counters — per-window deltas for successive
    /// caliper rounds (`depth_high_water` restarts per window too).
    pub fn snapshot_and_reset(&self) -> StatsSnapshot {
        self.stats.snapshot_and_reset()
    }

    /// Queued envelopes across all lanes.
    pub fn pending(&self) -> usize {
        self.lanes.iter().map(|l| l.lock().unwrap().q.len()).sum()
    }

    /// Admission control + enqueue. Every `Err` is explicit backpressure
    /// the caller can act on (retry later, slow down, drop).
    ///
    /// Wraps the envelope into its canonical shared buffer once (hashing
    /// and encoding it), then runs [`ShardMempool::submit_shared`].
    /// Callers that already hold a [`SharedEnvelope`] (relay deliveries,
    /// gateways) should submit it directly — no re-encode.
    pub fn submit(&self, env: Envelope) -> Result<(), Reject> {
        self.submit_shared(env.into())
    }

    /// Admission control + enqueue for an envelope already in shared-buffer
    /// form. Checks run cheapest-first so overload floods shed without
    /// wasting work: MVCC staleness (outside all pool locks), replay-dedup
    /// claim, lane-capacity reservation, rate cap (tokens are only debited
    /// once the envelope would otherwise fit), the HMAC signature/policy
    /// precheck, and finally the lane push. Any failure after the dedup
    /// claim rolls the claim (and reservation) back.
    pub fn submit_shared(&self, env: SharedEnvelope) -> Result<(), Reject> {
        let now = self.clock.now();
        let (lane, checked_seq) = self.admit_load(&env, now)?;
        if let Err(r) = self.policy_precheck(&env) {
            self.unreserve(lane);
            self.forget(&env.tx_id());
            return Err(r);
        }
        self.push_entry(env, lane, checked_seq, now);
        Ok(())
    }

    /// Batched admission: one verified-admission pass for a whole pull.
    ///
    /// Three phases: (1) per-envelope load admission — staleness, dedup
    /// claim, capacity reservation, rate cap; (2) one batched
    /// signature/policy pass over every survivor (a single verdict-cache
    /// probe and one fan-out over the validator's workers when wired);
    /// (3) lane pushes. Per-envelope results are positional. Verdicts are
    /// byte-for-byte identical to submitting the same envelopes serially:
    /// both paths evaluate the same predicate per envelope.
    pub fn submit_batch(
        &self,
        envs: impl IntoIterator<Item = SharedEnvelope>,
    ) -> Vec<Result<(), Reject>> {
        let now = self.clock.now();
        let mut results: Vec<Result<(), Reject>> = Vec::new();
        let mut live: Vec<(usize, SharedEnvelope, Lane, u64)> = Vec::new();
        for (i, env) in envs.into_iter().enumerate() {
            match self.admit_load(&env, now) {
                Ok((lane, seq)) => {
                    results.push(Ok(()));
                    live.push((i, env, lane, seq));
                }
                Err(r) => results.push(Err(r)),
            }
        }
        if live.is_empty() {
            return results;
        }
        let shared: Vec<SharedEnvelope> = live.iter().map(|(_, e, _, _)| e.clone()).collect();
        let verdicts = self.crypto_verdicts(&shared);
        for ((i, env, lane, seq), verdict) in live.into_iter().zip(verdicts) {
            match verdict {
                Ok(()) => self.push_entry(env, lane, seq, now),
                Err(r) => {
                    self.unreserve(lane);
                    self.forget(&env.tx_id());
                    results[i] = Err(r);
                }
            }
        }
        results
    }

    /// Phase-1 admission: everything except crypto. On success the dedup
    /// claim and a lane-capacity reservation are held; the caller must
    /// either push the entry or roll both back.
    fn admit_load(&self, env: &SharedEnvelope, now: f64) -> Result<(Lane, u64), Reject> {
        let r = self.admit_load_inner(env, now);
        if let Err(rej) = r {
            self.stats.note_reject(rej);
        }
        r
    }

    fn admit_load_inner(&self, env: &SharedEnvelope, now: f64) -> Result<(Lane, u64), Reject> {
        // Racing a commit here is fine: the verdict is only a hint, and
        // the batch pull re-checks under the entry's recorded sequence.
        // Runs outside every pool lock: it probes the channel state's read
        // lock, and holding a lane lock across that would serialize
        // admission behind a concurrent block apply.
        let mut checked_seq = 0u64;
        if !env.rw_set().reads.is_empty() {
            let view = self.state_view.read().unwrap().clone();
            if let Some(view) = view {
                checked_seq = view.seq();
                if view.any_stale(&env.rw_set().reads) {
                    return Err(Reject::StaleReadSet);
                }
            }
        }
        if !self.open.load(Ordering::Acquire) {
            return Err(Reject::Shutdown);
        }
        let tx_id = env.tx_id();
        let lane = Lane::classify(env.proposal());
        self.claim(&tx_id, lane, now)?;
        if let Err(r) = self.reserve(lane, now) {
            self.forget(&tx_id);
            return Err(r);
        }
        if let Err(r) = self.take_rate_token(&env.proposal().creator.0, now) {
            self.unreserve(lane);
            self.forget(&tx_id);
            return Err(r);
        }
        Ok((lane, checked_seq))
    }

    /// Convert a reservation into a queued entry. The Admit stamp lands
    /// before the lane lock drops: once it is released a concurrent
    /// `take_batch` may pop this entry and stamp BatchPull, and Admit must
    /// already be in place for the trace to stay monotone.
    fn push_entry(&self, env: SharedEnvelope, lane: Lane, checked_seq: u64, now: f64) {
        let tx_id = env.tx_id();
        let bytes = env.encoded_len();
        let mut q = self.lanes[lane.index()].lock().unwrap();
        q.reserved -= 1;
        q.q.push_back(Entry { env, tx_id, bytes, enqueued: now, checked_seq });
        let depth = self.depth.fetch_add(1, Ordering::Relaxed) + 1;
        self.stats.note_admitted(depth as u64);
        telemetry::global().stamp(&tx_id, Stage::Admit);
    }

    fn seen_shard(&self, tx_id: &TxId) -> &Mutex<SeenShard> {
        &self.seen[tx_id.0[0] as usize % SEEN_SHARDS]
    }

    /// Claim `tx_id` in the striped dedup window. A claim that collides
    /// with an entry that TTL-expired in place evicts the lane and retries
    /// once, so expiry always frees the id for resubmission.
    fn claim(&self, tx_id: &TxId, lane: Lane, now: f64) -> Result<(), Reject> {
        if self.try_claim(tx_id) {
            return Ok(());
        }
        self.evict_lane(lane, now);
        if self.try_claim(tx_id) {
            return Ok(());
        }
        Err(Reject::Duplicate)
    }

    fn try_claim(&self, tx_id: &TxId) -> bool {
        let mut shard = self.seen_shard(tx_id).lock().unwrap();
        if !shard.set.insert(*tx_id) {
            return false;
        }
        shard.order.push_back(*tx_id);
        let window = (self.cfg.dedup_window.max(1) / SEEN_SHARDS).max(1);
        while shard.order.len() > window {
            if let Some(old) = shard.order.pop_front() {
                shard.set.remove(&old);
            }
        }
        true
    }

    /// Drop a dedup claim (rejection rollback, TTL expiry, stale drop, relay
    /// death) so a resubmission of the id passes dedup.
    fn forget(&self, tx_id: &TxId) {
        self.seen_shard(tx_id).lock().unwrap().set.remove(tx_id);
    }

    /// Reserve one slot in `lane`, evicting TTL-expired entries at its
    /// front first (same lock acquisition) so capacity is measured against
    /// live entries only.
    fn reserve(&self, lane: Lane, now: f64) -> Result<(), Reject> {
        let mut expired = Vec::new();
        let ok = {
            let mut q = self.lanes[lane.index()].lock().unwrap();
            self.drain_expired(&mut q.q, now, &mut expired);
            if q.q.len() + q.reserved >= self.cfg.lane_capacity.max(1) {
                false
            } else {
                q.reserved += 1;
                true
            }
        };
        self.finish_expired(expired);
        if ok {
            Ok(())
        } else {
            Err(Reject::PoolFull)
        }
    }

    fn unreserve(&self, lane: Lane) {
        self.lanes[lane.index()].lock().unwrap().reserved -= 1;
    }

    /// The endorsement signature / policy precheck exactly as admission
    /// runs it (a no-op without a CA handle or with verification off).
    /// Reads the envelope's cached tx id and rw digest — nothing is
    /// re-hashed. Public because the relay validates a forwarded envelope
    /// against its *home* pool's policy before paying the hop — the local
    /// ingress pool may serve a different committee. Rejections are
    /// counted on the pool whose policy refused them.
    pub fn policy_precheck(&self, env: &SharedEnvelope) -> Result<(), Reject> {
        if !self.cfg.verify_endorsements {
            return Ok(());
        }
        self.crypto_verdicts(std::slice::from_ref(env)).remove(0)
    }

    /// One signature/policy pass over a slice of envelopes. With a policy
    /// installed and a validator wired, verdicts come from the shared
    /// (digest, policy-fingerprint) cache — missing entries are verified
    /// over the validator's worker set and inserted, so commit-time
    /// prevalidation of the same envelopes is pure cache hits.
    fn crypto_verdicts(&self, envs: &[SharedEnvelope]) -> Vec<Result<(), Reject>> {
        if !self.cfg.verify_endorsements || envs.is_empty() {
            return vec![Ok(()); envs.len()];
        }
        let Some(ca) = &self.ca else {
            return vec![Ok(()); envs.len()];
        };
        let policy = self.policy.read().unwrap().clone();
        match policy {
            Some(p) => {
                let validator = self.validator.read().unwrap().clone();
                let oks: Vec<bool> = match validator {
                    Some(v) => v.admission_verify(&p, ca, envs),
                    None => envs
                        .iter()
                        .map(|e| {
                            let payload = endorsement_payload(&e.tx_id(), &e.rw_digest());
                            p.satisfied_prehashed(&payload, e.endorsements(), ca)
                        })
                        .collect(),
                };
                oks.into_iter()
                    .map(|ok| {
                        if ok {
                            Ok(())
                        } else {
                            self.stats.note_reject(Reject::PolicyUnsatisfiable);
                            Err(Reject::PolicyUnsatisfiable)
                        }
                    })
                    .collect()
            }
            None => {
                // No policy installed: any valid signature from an enrolled
                // member admits. One registry lock covers the whole slice.
                let verifier = ca.batch_verifier();
                envs.iter()
                    .map(|e| {
                        let payload = endorsement_payload(&e.tx_id(), &e.rw_digest());
                        let any = e
                            .endorsements()
                            .iter()
                            .any(|en| verifier.verify(&en.endorser, &payload, &en.signature));
                        if any {
                            Ok(())
                        } else {
                            self.stats.note_reject(Reject::BadSignature);
                            Err(Reject::BadSignature)
                        }
                    })
                    .collect()
            }
        }
    }

    /// Admission for an envelope this pool will hand to the relay instead
    /// of enqueueing: it arrived at this shard's ingress but belongs to
    /// another channel. Replay dedup and the per-client rate cap run
    /// exactly as in [`ShardMempool::submit`] — gossip must not bypass
    /// ingress limits — but no lane slot is consumed, and MVCC staleness
    /// is left to the home pool (only its state view is authoritative).
    /// Counted as `forwarded`.
    pub fn admit_forward(&self, env: &SharedEnvelope) -> Result<(), Reject> {
        let now = self.clock.now();
        if !self.open.load(Ordering::Acquire) {
            return Err(Reject::Shutdown);
        }
        let tx_id = env.tx_id();
        let lane = Lane::classify(env.proposal());
        if let Err(r) = self.claim(&tx_id, lane, now) {
            self.stats.note_reject(r);
            return Err(r);
        }
        if let Err(r) = self.take_rate_token(&env.proposal().creator.0, now) {
            self.forget(&tx_id);
            self.stats.note_reject(r);
            return Err(r);
        }
        self.stats.note_forwarded();
        // Admission happened here, before any relay hop — stamp it so the
        // lifecycle's admit → relay-hop ordering holds for forwards too.
        telemetry::global().stamp(&tx_id, Stage::Admit);
        Ok(())
    }

    /// Debit one rate-cap token for `creator` (a no-op when the pool is
    /// uncapped). Shared by [`ShardMempool::submit`] and
    /// [`ShardMempool::admit_forward`] so gossip traffic can never bypass
    /// a fix to the ingress limits.
    fn take_rate_token(&self, creator: &str, now: f64) -> Result<(), Reject> {
        let Some(rate) = self.cfg.rate_limit else {
            return Ok(());
        };
        let burst = self.cfg.rate_burst.max(1.0);
        let mut buckets = self.buckets.lock().unwrap();
        let bucket = buckets
            .entry(creator.to_string())
            .or_insert_with(|| TokenBucket::new(burst, now));
        if !bucket.try_take(now, rate, burst) {
            return Err(Reject::RateLimited);
        }
        Ok(())
    }

    /// A forwarded envelope died in the relay (home pool refused it, link
    /// dropped it): count the loss and forget the id in this pool's dedup
    /// set so the client's resubmission is admitted, exactly as TTL expiry
    /// and stale drops do.
    pub(crate) fn forward_dropped(&self, tx_id: &TxId) {
        self.stats.note_relay_dropped();
        self.forget(tx_id);
    }

    /// Is a block due? Same cut rule the orderer used to own: pending count
    /// reached `batch_size`, or the oldest queued envelope has waited
    /// `batch_timeout`.
    pub fn ready(&self, batch_size: usize, batch_timeout: Duration) -> bool {
        let now = self.clock.now();
        let mut expired = Vec::new();
        let mut pending = 0usize;
        let mut oldest = f64::INFINITY;
        for lane in &self.lanes {
            let mut q = lane.lock().unwrap();
            self.drain_expired(&mut q.q, now, &mut expired);
            pending += q.q.len();
            if let Some(e) = q.q.front() {
                oldest = oldest.min(e.enqueued);
            }
        }
        self.finish_expired(expired);
        if pending == 0 {
            return false;
        }
        if pending >= batch_size.max(1) {
            return true;
        }
        now - oldest >= batch_timeout.as_secs_f64()
    }

    /// Pull the next block's worth of envelopes: priority lanes drained in
    /// order, bounded by `max_txs` and `max_bytes` (`max_bytes == 0` means
    /// unbounded). A lone envelope larger than `max_bytes` still ships
    /// (blocks never starve on the byte bound alone). The returned
    /// envelopes are refcount moves of the queued shared buffers — the
    /// orderer serializes them by splicing, never re-encoding.
    ///
    /// With a state view wired, entries whose read-set went stale while
    /// queued are dropped here (counted as `stale_dropped`) instead of
    /// being handed to consensus; the per-entry re-check only runs when
    /// the state's write sequence moved past the entry's `checked_seq`.
    ///
    /// A pull-time drop has no commit event: a client holding a
    /// `SubmitHandle` on a dropped tx learns through its timeout (the tx
    /// was doomed to `MvccConflict` either way — the failure is the same,
    /// only slower to surface). The dropped id is forgotten by dedup
    /// immediately, so re-endorsing and resubmitting works at once;
    /// contended read-modify-write workloads should pair hinting with
    /// modest client timeouts.
    pub fn take_batch(&self, max_txs: usize, max_bytes: usize) -> Vec<SharedEnvelope> {
        let now = self.clock.now();
        let view = self.state_view.read().unwrap().clone();
        let cur_seq = view.as_ref().map(|v| v.seq()).unwrap_or(0);
        let mut out = Vec::new();
        let mut bytes = 0usize;
        let mut stale: Vec<TxId> = Vec::new();
        let mut expired: Vec<TxId> = Vec::new();
        'lanes: for lane in &self.lanes {
            let mut q = lane.lock().unwrap();
            self.drain_expired(&mut q.q, now, &mut expired);
            while out.len() < max_txs.max(1) {
                let front = match q.q.front() {
                    Some(e) => e,
                    None => break,
                };
                if let Some(view) = &view {
                    if front.checked_seq != cur_seq
                        && !front.env.rw_set().reads.is_empty()
                        && view.any_stale(&front.env.rw_set().reads)
                    {
                        let e = q.q.pop_front().expect("front checked");
                        self.stats.note_stale_dropped();
                        stale.push(e.tx_id);
                        continue;
                    }
                }
                if !out.is_empty() && max_bytes > 0 && bytes + front.bytes > max_bytes {
                    break 'lanes;
                }
                let e = q.q.pop_front().expect("front checked");
                bytes += e.bytes;
                telemetry::global().stamp(&e.tx_id, Stage::BatchPull);
                out.push(e.env);
            }
            if out.len() >= max_txs.max(1) {
                break;
            }
        }
        if out.len() + stale.len() > 0 {
            self.depth.fetch_sub(out.len() + stale.len(), Ordering::Relaxed);
        }
        // A stale-dropped envelope was never ordered: forget it in the
        // dedup set so the client's re-endorsed retry (same tx id, fresh
        // read-set) is admitted instead of bounced as a replay.
        for tx_id in stale {
            self.forget(&tx_id);
            telemetry::global().abort(&tx_id, "stale_drop");
        }
        self.finish_expired(expired);
        if !out.is_empty() {
            self.stats.note_ordered(out.len() as u64, bytes as u64);
        }
        out
    }

    /// Put a taken batch back (consensus proposal failed, e.g. leadership
    /// moved); order is preserved at the lane fronts.
    pub fn restore(&self, envs: Vec<SharedEnvelope>) {
        if envs.is_empty() {
            return;
        }
        let now = self.clock.now();
        let mut total_bytes = 0u64;
        let n = envs.len() as u64;
        for env in envs.into_iter().rev() {
            let lane = Lane::classify(env.proposal());
            let tx_id = env.tx_id();
            let bytes = env.encoded_len();
            total_bytes += bytes as u64;
            // checked_seq 0 forces a fresh staleness check on the next
            // pull: versions may have moved while the batch was out.
            self.lanes[lane.index()]
                .lock()
                .unwrap()
                .q
                .push_front(Entry { env, tx_id, bytes, enqueued: now, checked_seq: 0 });
            self.depth.fetch_add(1, Ordering::Relaxed);
        }
        self.stats.note_restored(n, total_bytes);
    }

    /// Refuse all further submissions (orderer shutdown).
    pub fn close(&self) {
        self.open.store(false, Ordering::Release);
    }

    /// Pop TTL-expired entries off a lane front into `out` (caller holds
    /// the lane lock; dedup forgetting happens in [`Self::finish_expired`]
    /// after it drops — the seen-shard locks are never nested inside a
    /// lane lock).
    fn drain_expired(&self, q: &mut VecDeque<Entry>, now: f64, out: &mut Vec<TxId>) {
        let ttl = self.cfg.ttl.as_secs_f64();
        if ttl <= 0.0 {
            return;
        }
        while q.front().is_some_and(|e| now - e.enqueued > ttl) {
            if let Some(e) = q.pop_front() {
                out.push(e.tx_id);
            }
            self.stats.note_expired();
        }
    }

    fn evict_lane(&self, lane: Lane, now: f64) {
        let mut expired = Vec::new();
        {
            let mut q = self.lanes[lane.index()].lock().unwrap();
            self.drain_expired(&mut q.q, now, &mut expired);
        }
        self.finish_expired(expired);
    }

    /// An expired envelope was never ordered: forget it in the dedup set
    /// so the client's retry is admitted instead of rejected as a replay.
    fn finish_expired(&self, expired: Vec<TxId>) {
        if expired.is_empty() {
            return;
        }
        self.depth.fetch_sub(expired.len(), Ordering::Relaxed);
        for tx_id in &expired {
            self.forget(tx_id);
            telemetry::global().abort(tx_id, "ttl_expired");
        }
    }
}

/// Per-channel pool registry shared between gateways (producers) and the
/// ordering service (consumer). Pools are created lazily on first use and
/// share one config/clock/CA.
pub struct MempoolRegistry {
    cfg: MempoolConfig,
    clock: Arc<dyn Clock>,
    ca: Option<CertificateAuthority>,
    pools: RwLock<HashMap<String, Arc<ShardMempool>>>,
}

impl MempoolRegistry {
    pub fn new(cfg: MempoolConfig) -> Arc<MempoolRegistry> {
        MempoolRegistry::with_parts(cfg, SystemClock::shared(), None)
    }

    /// Registry whose pools verify endorsement signatures/policies at
    /// admission using `ca`.
    pub fn with_admission(cfg: MempoolConfig, ca: CertificateAuthority) -> Arc<MempoolRegistry> {
        MempoolRegistry::with_parts(cfg, SystemClock::shared(), Some(ca))
    }

    pub fn with_parts(
        cfg: MempoolConfig,
        clock: Arc<dyn Clock>,
        ca: Option<CertificateAuthority>,
    ) -> Arc<MempoolRegistry> {
        Arc::new(MempoolRegistry { cfg, clock, ca, pools: RwLock::new(HashMap::new()) })
    }

    /// Get or create the pool for `channel`.
    pub fn pool(&self, channel: &str) -> Arc<ShardMempool> {
        if let Some(p) = self.pools.read().unwrap().get(channel) {
            return Arc::clone(p);
        }
        let mut pools = self.pools.write().unwrap();
        let entry = pools.entry(channel.to_string()).or_insert_with(|| {
            Arc::new(ShardMempool::with_parts(
                channel,
                self.cfg.clone(),
                Arc::clone(&self.clock),
                self.ca.clone(),
            ))
        });
        Arc::clone(entry)
    }

    pub fn get(&self, channel: &str) -> Option<Arc<ShardMempool>> {
        self.pools.read().unwrap().get(channel).cloned()
    }

    /// Channels with a pool (sorted for deterministic drain order).
    pub fn channels(&self) -> Vec<String> {
        let mut names: Vec<String> = self.pools.read().unwrap().keys().cloned().collect();
        names.sort();
        names
    }

    /// Install the admission policy for a channel's pool.
    pub fn set_policy(&self, channel: &str, policy: EndorsementPolicy) {
        self.pool(channel).set_policy(policy);
    }

    /// Route a channel's admission crypto through a shared block-validator
    /// verdict cache (creating the pool if needed).
    pub fn set_validator(&self, channel: &str, validator: Arc<BlockValidator>) {
        self.pool(channel).set_validator(validator);
    }

    /// Wire a channel's read-version oracle for MVCC staleness hinting
    /// (creating the pool if needed).
    pub fn set_state_view(&self, channel: &str, view: Arc<dyn StateView>) {
        self.pool(channel).set_state_view(view);
    }

    /// Route an envelope to its channel's pool.
    pub fn submit(&self, env: Envelope) -> Result<(), Reject> {
        self.submit_shared(env.into())
    }

    /// Route an already-encoded envelope to its channel's pool without
    /// re-encoding (the orderer's submit path — envelopes arrive here
    /// carrying their canonical wire bytes from endorsement or a socket).
    pub fn submit_shared(&self, env: SharedEnvelope) -> Result<(), Reject> {
        let pool = self.pool(&env.proposal().channel);
        pool.submit_shared(env)
    }

    /// Aggregate counters across every pool.
    pub fn snapshot(&self) -> StatsSnapshot {
        let mut total = StatsSnapshot::default();
        for pool in self.pools.read().unwrap().values() {
            total.merge(&pool.stats());
        }
        total
    }

    /// Aggregate counters across every pool, zeroing each pool's window
    /// (see [`ShardMempool::snapshot_and_reset`]).
    pub fn snapshot_and_reset(&self) -> StatsSnapshot {
        let mut total = StatsSnapshot::default();
        for pool in self.pools.read().unwrap().values() {
            total.merge(&pool.snapshot_and_reset());
        }
        total
    }

    /// Register per-channel mempool metrics with a telemetry registry.
    /// Held weakly: once the last orderer/gateway drops this registry of
    /// pools, the collector prunes itself.
    pub fn register_telemetry(self: &Arc<Self>, registry: &telemetry::Registry) {
        let weak = Arc::downgrade(self);
        registry.register(move || {
            let reg = weak.upgrade()?;
            let pools = reg.pools.read().unwrap();
            let mut names: Vec<&String> = pools.keys().collect();
            names.sort();
            let mut out = Vec::new();
            for name in names {
                let pool = &pools[name];
                let s = pool.stats();
                let label = || Sample::channel_label(name);
                let reason_label = |reason: &str| {
                    vec![
                        ("channel".to_string(), name.to_string()),
                        ("reason".to_string(), reason.to_string()),
                    ]
                };
                out.push(Sample::counter(
                    "scalesfl_mempool_admitted_total",
                    label(),
                    s.admitted as f64,
                ));
                for (reason, n) in [
                    ("pool_full", s.pool_full),
                    ("rate_limited", s.rate_limited),
                    ("duplicate", s.duplicate),
                    ("bad_signature", s.bad_signature),
                    ("policy", s.policy_unsatisfiable),
                    ("stale_read_set", s.stale_read_set),
                ] {
                    out.push(Sample::counter(
                        "scalesfl_mempool_rejected_total",
                        reason_label(reason),
                        n as f64,
                    ));
                }
                out.push(Sample::counter(
                    "scalesfl_mempool_forwarded_total",
                    label(),
                    s.forwarded as f64,
                ));
                out.push(Sample::counter(
                    "scalesfl_mempool_relay_dropped_total",
                    label(),
                    s.relay_dropped as f64,
                ));
                out.push(Sample::counter(
                    "scalesfl_mempool_stale_dropped_total",
                    label(),
                    s.stale_dropped as f64,
                ));
                out.push(Sample::counter(
                    "scalesfl_mempool_expired_total",
                    label(),
                    s.expired as f64,
                ));
                out.push(Sample::counter(
                    "scalesfl_mempool_txs_ordered_total",
                    label(),
                    s.txs_ordered as f64,
                ));
                out.push(Sample::counter(
                    "scalesfl_mempool_batches_cut_total",
                    label(),
                    s.batches_cut as f64,
                ));
                out.push(Sample::counter(
                    "scalesfl_mempool_bytes_ordered_total",
                    label(),
                    s.bytes_ordered as f64,
                ));
                out.push(Sample::gauge(
                    "scalesfl_mempool_depth",
                    label(),
                    pool.pending() as f64,
                ));
                out.push(Sample::gauge(
                    "scalesfl_mempool_depth_high_water",
                    label(),
                    s.depth_high_water as f64,
                ));
            }
            Some(out)
        });
    }

    /// Close every pool (orderer shutdown).
    pub fn close_all(&self) {
        for pool in self.pools.read().unwrap().values() {
            pool.close();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::msp::MemberId;
    use crate::ledger::tx::{endorsement_payload, Endorsement, RwSet};
    use crate::util::clock::VirtualClock;
    use crate::util::prng::Prng;

    fn envelope(
        channel: &str,
        chaincode: &str,
        function: &str,
        creator: &str,
        nonce: u64,
    ) -> Envelope {
        Envelope {
            proposal: Proposal {
                channel: channel.into(),
                chaincode: chaincode.into(),
                function: function.into(),
                args: vec!["a".into(), "b".into()],
                creator: MemberId::new(creator),
                nonce,
            },
            rw_set: RwSet::default(),
            endorsements: Vec::new(),
        }
    }

    fn query_env(nonce: u64) -> Envelope {
        envelope("ch", "kv", "Put", "client", nonce)
    }

    #[test]
    fn lanes_classify_by_traffic_class() {
        let cat = envelope("main", "catalyst", "SubmitShardModel", "c", 1);
        let pin = envelope("shard0", "models", "PinGlobalModel", "c", 2);
        let upd = envelope("shard0", "models", "CreateModelUpdate", "c", 3);
        let q = envelope("shard0", "kv", "Get", "c", 4);
        assert_eq!(Lane::classify(&cat.proposal), Lane::Catalyst);
        assert_eq!(Lane::classify(&pin.proposal), Lane::Catalyst);
        assert_eq!(Lane::classify(&upd.proposal), Lane::ModelUpdate);
        assert_eq!(Lane::classify(&q.proposal), Lane::Query);
        assert_eq!(Lane::Catalyst.name(), "catalyst");
    }

    #[test]
    fn priority_lanes_drain_in_order() {
        let pool = ShardMempool::new("ch", MempoolConfig::default());
        pool.submit(envelope("ch", "kv", "Get", "c", 1)).unwrap();
        pool.submit(envelope("ch", "models", "CreateModelUpdate", "c", 2)).unwrap();
        pool.submit(envelope("ch", "catalyst", "SubmitShardModel", "c", 3)).unwrap();
        let batch = pool.take_batch(10, 0);
        assert_eq!(batch.len(), 3);
        assert_eq!(batch[0].proposal().chaincode, "catalyst");
        assert_eq!(batch[1].proposal().function, "CreateModelUpdate");
        assert_eq!(batch[2].proposal().function, "Get");
        assert_eq!(pool.pending(), 0);
    }

    #[test]
    fn bounded_lane_rejects_pool_full() {
        let cfg = MempoolConfig { lane_capacity: 3, ..Default::default() };
        let pool = ShardMempool::new("ch", cfg);
        for n in 0..3 {
            pool.submit(query_env(n)).unwrap();
        }
        assert_eq!(pool.submit(query_env(99)), Err(Reject::PoolFull));
        // A different lane still has room: backpressure is per-class.
        pool.submit(envelope("ch", "catalyst", "X", "c", 100)).unwrap();
        let snap = pool.stats();
        assert_eq!(snap.admitted, 4);
        assert_eq!(snap.pool_full, 1);
        assert_eq!(snap.shed(), 1);
        assert_eq!(snap.depth_high_water, 4);
    }

    #[test]
    fn duplicate_replay_rejected_even_after_ordering() {
        let pool = ShardMempool::new("ch", MempoolConfig::default());
        pool.submit(query_env(1)).unwrap();
        assert_eq!(pool.submit(query_env(1)), Err(Reject::Duplicate));
        let batch = pool.take_batch(10, 0);
        assert_eq!(batch.len(), 1);
        // Still remembered after the batch was pulled.
        assert_eq!(pool.submit(query_env(1)), Err(Reject::Duplicate));
        assert_eq!(pool.stats().duplicate, 2);
    }

    #[test]
    fn rate_cap_rejects_then_refills_on_clock() {
        let clock = Arc::new(VirtualClock::new());
        let cfg = MempoolConfig {
            rate_limit: Some(10.0),
            rate_burst: 2.0,
            ..Default::default()
        };
        let pool =
            ShardMempool::with_parts("ch", cfg, Arc::clone(&clock) as Arc<dyn Clock>, None);
        pool.submit(query_env(1)).unwrap();
        pool.submit(query_env(2)).unwrap();
        assert_eq!(pool.submit(query_env(3)), Err(Reject::RateLimited));
        // Another client is not throttled by the first's bucket.
        pool.submit(envelope("ch", "kv", "Put", "other", 50)).unwrap();
        // 0.1 virtual seconds at 10 tx/s refills one token.
        clock.advance(Duration::from_millis(100));
        pool.submit(query_env(4)).unwrap();
        assert_eq!(pool.stats().rate_limited, 1);
    }

    #[test]
    fn ttl_evicts_stale_entries() {
        let clock = Arc::new(VirtualClock::new());
        let cfg = MempoolConfig { ttl: Duration::from_secs(5), ..Default::default() };
        let pool =
            ShardMempool::with_parts("ch", cfg, Arc::clone(&clock) as Arc<dyn Clock>, None);
        pool.submit(query_env(1)).unwrap();
        clock.advance(Duration::from_secs(3));
        pool.submit(query_env(2)).unwrap();
        clock.advance(Duration::from_secs(3));
        // nonce 1 is now 6 s old (> 5 s TTL); nonce 2 is 3 s old.
        let batch = pool.take_batch(10, 0);
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].proposal().nonce, 2);
        assert_eq!(pool.stats().expired, 1);
    }

    #[test]
    fn ttl_expiry_allows_resubmission() {
        let clock = Arc::new(VirtualClock::new());
        let cfg = MempoolConfig { ttl: Duration::from_secs(5), ..Default::default() };
        let pool =
            ShardMempool::with_parts("ch", cfg, Arc::clone(&clock) as Arc<dyn Clock>, None);
        pool.submit(query_env(1)).unwrap();
        clock.advance(Duration::from_secs(6));
        // The original expired un-ordered, so the retry must be admitted —
        // not bounced as a replay.
        pool.submit(query_env(1)).unwrap();
        let snap = pool.stats();
        assert_eq!(snap.expired, 1);
        assert_eq!(snap.admitted, 2);
        assert_eq!(snap.duplicate, 0);
    }

    #[test]
    fn pool_full_rejection_does_not_burn_rate_tokens() {
        let cfg = MempoolConfig {
            lane_capacity: 1,
            rate_limit: Some(1.0),
            rate_burst: 2.0,
            ..Default::default()
        };
        let pool = ShardMempool::new("ch", cfg);
        pool.submit(query_env(1)).unwrap(); // burns token 1, fills the lane
        assert_eq!(pool.submit(query_env(2)), Err(Reject::PoolFull));
        pool.take_batch(10, 0);
        // The PoolFull bounce must not have debited the bucket: one token
        // remains for the retry, and only the tx after it is rate-capped.
        pool.submit(query_env(3)).unwrap();
        assert_eq!(pool.submit(query_env(4)), Err(Reject::RateLimited));
    }

    #[test]
    fn batches_are_size_and_byte_bounded() {
        let pool = ShardMempool::new("ch", MempoolConfig::default());
        for n in 0..10 {
            pool.submit(query_env(n)).unwrap();
        }
        let one_len = encoded_len(&query_env(999));
        // Size bound.
        assert_eq!(pool.take_batch(4, 0).len(), 4);
        // Byte bound: room for two envelopes only.
        assert_eq!(pool.take_batch(10, 2 * one_len).len(), 2);
        // A lone oversized envelope still ships.
        assert_eq!(pool.take_batch(10, 1).len(), 1);
        assert_eq!(pool.pending(), 3);
        let snap = pool.stats();
        assert_eq!(snap.txs_ordered, 7);
        assert_eq!(snap.batches_cut, 3);
    }

    #[test]
    fn ready_respects_size_and_timeout_cuts() {
        let clock = Arc::new(VirtualClock::new());
        let pool = ShardMempool::with_parts(
            "ch",
            MempoolConfig::default(),
            Arc::clone(&clock) as Arc<dyn Clock>,
            None,
        );
        assert!(!pool.ready(2, Duration::from_millis(100)));
        pool.submit(query_env(1)).unwrap();
        assert!(!pool.ready(2, Duration::from_millis(100)));
        pool.submit(query_env(2)).unwrap();
        assert!(pool.ready(2, Duration::from_millis(100)));
        pool.take_batch(10, 0);
        pool.submit(query_env(3)).unwrap();
        clock.advance(Duration::from_millis(150));
        assert!(pool.ready(100, Duration::from_millis(100)), "timeout cut due");
    }

    #[test]
    fn restore_preserves_order_and_counters() {
        let pool = ShardMempool::new("ch", MempoolConfig::default());
        for n in 0..4 {
            pool.submit(query_env(n)).unwrap();
        }
        let batch = pool.take_batch(3, 0);
        pool.restore(batch);
        let again = pool.take_batch(10, 0);
        let nonces: Vec<u64> = again.iter().map(|e| e.proposal().nonce).collect();
        assert_eq!(nonces, vec![0, 1, 2, 3]);
        let snap = pool.stats();
        assert_eq!(snap.txs_ordered, 4);
        assert_eq!(snap.batches_cut, 1);
    }

    #[test]
    fn admission_precheck_rejects_unsigned_envelopes() {
        let ca = CertificateAuthority::new();
        let mut rng = Prng::new(1);
        let cred = ca.enroll(MemberId::new("org0.peer"), &mut rng);
        let outsider = ca.enroll(MemberId::new("mallory"), &mut rng);
        let cfg = MempoolConfig { verify_endorsements: true, ..Default::default() };
        let pool =
            ShardMempool::with_parts("ch", cfg, SystemClock::shared(), Some(ca.clone()));
        pool.set_policy(EndorsementPolicy::AnyOf(1, vec![cred.member.clone()]));

        // No endorsements at all -> policy can never be satisfied.
        assert_eq!(pool.submit(query_env(1)), Err(Reject::PolicyUnsatisfiable));

        // Properly endorsed envelope is admitted.
        let mut env = query_env(2);
        let payload = endorsement_payload(&env.tx_id(), &env.rw_set.digest());
        env.endorsements.push(Endorsement {
            endorser: cred.member.clone(),
            signature: cred.sign(&payload),
        });
        pool.submit(env).unwrap();

        // Signature from outside the policy set does not count.
        let mut env = query_env(3);
        let payload = endorsement_payload(&env.tx_id(), &env.rw_set.digest());
        env.endorsements.push(Endorsement {
            endorser: outsider.member.clone(),
            signature: outsider.sign(&payload),
        });
        assert_eq!(pool.submit(env), Err(Reject::PolicyUnsatisfiable));
        assert_eq!(pool.stats().policy_unsatisfiable, 2);
        assert_eq!(pool.stats().admitted, 1);
    }

    /// A peer whose channel doubles as the pool's state view, plus direct
    /// commit access so tests can advance versions deterministically.
    fn staleness_fixture() -> (Arc<crate::fabric::Peer>, Arc<crate::fabric::PeerChannel>) {
        let ca = CertificateAuthority::new();
        let mut rng = Prng::new(21);
        let cred = ca.enroll(MemberId::new("org0.peer"), &mut rng);
        let peer = crate::fabric::Peer::new(cred, ca);
        // Zero-of-zero policy: commit validity hinges on MVCC alone.
        let ch = peer.join_channel("ch", EndorsementPolicy::AnyOf(0, vec![]));
        (peer, ch)
    }

    /// A tx that read `ctr` as absent and writes it — the classic
    /// read-modify-write contention shape.
    fn contended_env(nonce: u64) -> Envelope {
        Envelope {
            proposal: Proposal {
                channel: "ch".into(),
                chaincode: "kv".into(),
                function: "Put".into(),
                args: vec!["ctr".into()],
                creator: MemberId::new("client"),
                nonce,
            },
            rw_set: RwSet {
                reads: vec![("ctr".into(), None)],
                writes: vec![("ctr".into(), Some(nonce.to_le_bytes().to_vec()))],
            },
            endorsements: Vec::new(),
        }
    }

    #[test]
    fn stale_read_set_rejected_at_admission() {
        let (peer, ch) = staleness_fixture();
        let pool = ShardMempool::new("ch", MempoolConfig::default());
        pool.set_state_view(Arc::clone(&ch) as Arc<dyn StateView>);
        assert!(pool.has_state_view());
        // Fresh read-set: admitted.
        pool.submit(contended_env(1)).unwrap();
        // Another tx commits a write to the contended key...
        let batch = pool.take_batch(10, 0);
        peer.commit_batch("ch", batch).unwrap();
        // ...so the same observation is now provably stale at admission.
        assert_eq!(pool.submit(contended_env(2)), Err(Reject::StaleReadSet));
        let snap = pool.stats();
        assert_eq!(snap.stale_read_set, 1);
        assert_eq!(snap.rejected_total(), 1);
        assert_eq!(pool.pending(), 0);
        // A re-endorsed retry observing the committed version is admitted.
        let mut fresh = contended_env(2);
        fresh.rw_set.reads =
            vec![("ctr".into(), ch.read_version("ctr"))];
        pool.submit(fresh).unwrap();
    }

    #[test]
    fn queued_tx_dropped_at_pull_when_read_overwritten() {
        let (peer, ch) = staleness_fixture();
        let pool = ShardMempool::new("ch", MempoolConfig::default());
        pool.set_state_view(Arc::clone(&ch) as Arc<dyn StateView>);
        // Three contending txs admitted against the same (absent) version.
        for nonce in 1..=3 {
            pool.submit(contended_env(nonce)).unwrap();
        }
        // The first ships and commits, bumping the key's version.
        let batch = pool.take_batch(1, 0);
        assert_eq!(batch.len(), 1);
        peer.commit_batch("ch", batch).unwrap();
        // The queued rest went stale in place: dropped at pull, never
        // ordered, and forgotten by dedup so re-endorsed retries pass.
        assert_eq!(pool.take_batch(10, 0).len(), 0);
        let snap = pool.stats();
        assert_eq!(snap.stale_dropped, 2);
        assert_eq!(snap.stale_shed(), 2);
        assert_eq!(pool.pending(), 0);
        let mut retry = contended_env(2);
        retry.rw_set.reads = vec![("ctr".into(), ch.read_version("ctr"))];
        pool.submit(retry).unwrap();
        assert_eq!(pool.stats().duplicate, 0);
    }

    /// The acceptance scenario: contended keys through the hinted pool
    /// shed stale txs before ordering, cutting commit-time MvccConflicts
    /// versus the pre-refactor (no state view) path.
    #[test]
    fn hinting_reduces_commit_mvcc_conflicts() {
        use crate::ledger::block::ValidationCode;
        let count_conflicts = |with_view: bool| -> (u64, u64) {
            let (peer, ch) = staleness_fixture();
            let pool = ShardMempool::new("ch", MempoolConfig::default());
            if with_view {
                pool.set_state_view(Arc::clone(&ch) as Arc<dyn StateView>);
            }
            for nonce in 0..6 {
                pool.submit(contended_env(nonce)).unwrap();
            }
            let mut conflicts = 0u64;
            loop {
                let batch = pool.take_batch(1, 0);
                if batch.is_empty() {
                    break;
                }
                let block = peer.commit_batch("ch", batch).unwrap();
                conflicts += block
                    .validation
                    .iter()
                    .filter(|c| **c == ValidationCode::MvccConflict)
                    .count() as u64;
            }
            (conflicts, pool.stats().stale_dropped)
        };
        let (old_conflicts, old_dropped) = count_conflicts(false);
        let (new_conflicts, new_dropped) = count_conflicts(true);
        // Pre-refactor: every loser is ordered and invalidated at commit.
        assert_eq!(old_conflicts, 5);
        assert_eq!(old_dropped, 0);
        // Hinted: the losers are shed before consensus ever sees them.
        assert_eq!(new_conflicts, 0);
        assert_eq!(new_dropped, 5);
    }

    #[test]
    fn registry_isolates_channels_and_aggregates_stats() {
        let registry = MempoolRegistry::new(MempoolConfig {
            lane_capacity: 1,
            ..Default::default()
        });
        registry.submit(envelope("shard0", "kv", "Put", "c", 1)).unwrap();
        registry.submit(envelope("shard1", "kv", "Put", "c", 2)).unwrap();
        // shard0's query lane is full; shard1 unaffected.
        assert_eq!(
            registry.submit(envelope("shard0", "kv", "Put", "c", 3)),
            Err(Reject::PoolFull)
        );
        assert_eq!(registry.channels(), vec!["shard0".to_string(), "shard1".to_string()]);
        let total = registry.snapshot();
        assert_eq!(total.admitted, 2);
        assert_eq!(total.pool_full, 1);
        registry.close_all();
        assert_eq!(
            registry.submit(envelope("shard1", "kv", "Put", "c", 9)),
            Err(Reject::Shutdown)
        );
    }

    #[test]
    fn registry_snapshot_and_reset_windows() {
        let registry = MempoolRegistry::new(MempoolConfig::default());
        registry.submit(envelope("shard0", "kv", "Put", "c", 1)).unwrap();
        registry.submit(envelope("shard1", "kv", "Put", "c", 2)).unwrap();
        let w1 = registry.snapshot_and_reset();
        assert_eq!(w1.admitted, 2);
        assert_eq!(w1.depth_high_water, 1, "per-pool high water, merged by max");
        // The window restarted: totals are zero until new traffic arrives.
        assert_eq!(registry.snapshot(), StatsSnapshot::default());
        registry.submit(envelope("shard0", "kv", "Put", "c", 3)).unwrap();
        assert_eq!(registry.snapshot_and_reset().admitted, 1);
    }

    #[test]
    fn telemetry_collector_emits_labelled_series_and_prunes() {
        let registry = MempoolRegistry::new(MempoolConfig::default());
        let treg = telemetry::Registry::new();
        registry.register_telemetry(&treg);
        registry.submit(envelope("shard0", "kv", "Put", "c", 1)).unwrap();
        let text = treg.render_prometheus();
        assert!(text.contains("scalesfl_mempool_admitted_total{channel=\"shard0\"} 1"), "{text}");
        assert!(text.contains("scalesfl_mempool_depth{channel=\"shard0\"} 1"), "{text}");
        assert!(
            text.contains(
                "scalesfl_mempool_rejected_total{channel=\"shard0\",reason=\"pool_full\"} 0"
            ),
            "{text}"
        );
        drop(registry);
        assert!(treg.render_prometheus().is_empty(), "collector pruned with its registry");
        assert_eq!(treg.collector_count(), 0);
    }

    /// Contention proof for the striped pool: many threads hammer the same
    /// pool with an overlapping envelope set. No admission may be lost
    /// (every distinct tx admitted exactly once across all threads) and
    /// none duplicated (the drained queue holds each id exactly once).
    #[test]
    fn striped_pool_no_lost_or_duplicated_admissions_under_contention() {
        const THREADS: usize = 8;
        const TXS: usize = 200;
        let pool = Arc::new(ShardMempool::new("ch", MempoolConfig::default()));
        let envs: Vec<SharedEnvelope> =
            (0..TXS).map(|n| SharedEnvelope::from(query_env(n as u64))).collect();
        let admitted: Vec<usize> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..THREADS)
                .map(|t| {
                    let pool = Arc::clone(&pool);
                    let envs = envs.clone();
                    s.spawn(move || {
                        // Each thread walks the set from a different offset
                        // (and half the threads use the batch path), so
                        // every tx is contended by several threads at once.
                        if t % 2 == 0 {
                            (0..TXS)
                                .filter(|i| {
                                    let e = envs[(i + t * 17) % TXS].clone();
                                    pool.submit_shared(e).is_ok()
                                })
                                .count()
                        } else {
                            let rotated: Vec<SharedEnvelope> = (0..TXS)
                                .map(|i| envs[(i + t * 17) % TXS].clone())
                                .collect();
                            pool.submit_batch(rotated)
                                .into_iter()
                                .filter(|r| r.is_ok())
                                .count()
                        }
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("submitter panicked")).collect()
        });
        let total_admitted: usize = admitted.iter().sum();
        assert_eq!(total_admitted, TXS, "each tx admitted exactly once across threads");
        let drained = pool.take_batch(TXS * 2, 0);
        assert_eq!(drained.len(), TXS);
        let mut ids: Vec<[u8; 32]> = drained.iter().map(|e| e.tx_id().0).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), TXS, "no duplicated entries in the queue");
        let snap = pool.stats();
        assert_eq!(snap.admitted, TXS as u64);
        assert_eq!(snap.duplicate, (THREADS * TXS - TXS) as u64);
    }

    /// Serial and batched admission must produce byte-for-byte identical
    /// verdicts for the same envelope sequence — including crypto failures.
    #[test]
    fn batched_admission_verdicts_match_serial() {
        let ca = CertificateAuthority::new();
        let mut rng = Prng::new(7);
        let cred = ca.enroll(MemberId::new("org0.peer"), &mut rng);
        let outsider = ca.enroll(MemberId::new("mallory"), &mut rng);
        let make_pool = || {
            let cfg = MempoolConfig {
                verify_endorsements: true,
                lane_capacity: 4,
                ..Default::default()
            };
            let pool =
                ShardMempool::with_parts("ch", cfg, SystemClock::shared(), Some(ca.clone()));
            pool.set_policy(EndorsementPolicy::AnyOf(1, vec![cred.member.clone()]));
            pool
        };
        let endorse = |mut env: Envelope, cred: &crate::crypto::msp::Credential| {
            let payload = endorsement_payload(&env.tx_id(), &env.rw_set.digest());
            env.endorsements
                .push(Endorsement { endorser: cred.member.clone(), signature: cred.sign(&payload) });
            env
        };
        // Mix of outcomes: valid, unsigned, outsider-signed, duplicate,
        // valid beyond lane capacity.
        let mut envs: Vec<SharedEnvelope> = Vec::new();
        for n in 0..4 {
            envs.push(endorse(query_env(n), &cred).into());
        }
        envs.push(query_env(10).into()); // unsigned
        envs.push(endorse(query_env(11), &outsider).into()); // wrong signer
        envs.push(envs[0].clone()); // duplicate
        envs.push(endorse(query_env(12), &cred).into()); // lane full

        let serial_pool = make_pool();
        let serial: Vec<Result<(), Reject>> =
            envs.iter().map(|e| serial_pool.submit_shared(e.clone())).collect();
        let batch_pool = make_pool();
        let batched = batch_pool.submit_batch(envs.clone());
        assert_eq!(serial, batched);
        assert_eq!(serial_pool.stats(), batch_pool.stats());
        // And the queues drained in the same order with identical bytes.
        let a = serial_pool.take_batch(16, 0);
        let b = batch_pool.take_batch(16, 0);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.as_bytes(), y.as_bytes());
        }
    }

    /// Admission crypto through a wired validator pre-seeds the shared
    /// verdict cache *and* rejects exactly as the direct path does.
    #[test]
    fn validator_wired_admission_matches_direct() {
        let ca = CertificateAuthority::new();
        let mut rng = Prng::new(13);
        let cred = ca.enroll(MemberId::new("org0.peer"), &mut rng);
        let cfg = MempoolConfig { verify_endorsements: true, ..Default::default() };
        let pool = ShardMempool::with_parts(
            "ch",
            cfg,
            SystemClock::shared(),
            Some(ca.clone()),
        );
        pool.set_policy(EndorsementPolicy::AnyOf(1, vec![cred.member.clone()]));
        let validator = Arc::new(BlockValidator::serial());
        pool.set_validator(Arc::clone(&validator));
        let endorse = |mut env: Envelope| {
            let payload = endorsement_payload(&env.tx_id(), &env.rw_set.digest());
            env.endorsements
                .push(Endorsement { endorser: cred.member.clone(), signature: cred.sign(&payload) });
            env
        };
        let good: Vec<SharedEnvelope> =
            (0..5).map(|n| SharedEnvelope::from(endorse(query_env(n)))).collect();
        let mut batch = good.clone();
        batch.push(query_env(50).into()); // unsigned → rejected
        let results = pool.submit_batch(batch);
        assert_eq!(results.iter().filter(|r| r.is_ok()).count(), 5);
        assert_eq!(results[5], Err(Reject::PolicyUnsatisfiable));
        // The validator's verdict cache was primed by admission.
        let snap = validator.snapshot();
        assert_eq!(snap.admit_txs, 6);
        assert_eq!(snap.admit_cache_hits, 0);
        let policy = EndorsementPolicy::AnyOf(1, vec![cred.member.clone()]);
        let verdicts = validator.prevalidate(&policy, &ca, &good);
        assert!(verdicts.iter().all(|v| *v));
        let snap = validator.snapshot();
        assert_eq!(snap.cache_hits, 5, "commit prevalidation hit the admission verdicts");
        assert_eq!(snap.cache_misses, 0);
    }
}
