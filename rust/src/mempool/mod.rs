//! Sharded mempool: the ingress path between clients/gateways and the
//! ordering service.
//!
//! The paper's evaluation (Figs. 5-7) is about the saturation knee —
//! throughput tracks sent TPS until shard capacity, then latency spikes.
//! The prototype submitted envelopes straight into the orderer's driver
//! thread over an unbounded channel, so overload was only modeled
//! implicitly. This subsystem makes the ingress path real:
//!
//! 1. **Admission control** ([`admission`]): endorsement-signature and
//!    policy prechecks, content-hash dedup / replay rejection, and
//!    per-client token-bucket rate caps.
//! 2. **Priority lanes** ([`pool::Lane`]): catalyst/checkpoint traffic >
//!    model updates > queries, each lane a bounded queue with TTL
//!    eviction and explicit backpressure ([`Reject::PoolFull`],
//!    [`Reject::RateLimited`]) surfaced as counters ([`stats`]).
//! 3. **Pipelined block production**: the orderer pulls
//!    size-and-byte-bounded batches ([`ShardMempool::take_batch`]) instead
//!    of owning batching state, so batch cutting, consensus, and
//!    validation overlap.
//! 4. **MVCC staleness hinting** ([`ShardMempool::set_state_view`]): with
//!    a replica's read-version oracle wired in, transactions whose
//!    read-set is already stale are rejected at admission
//!    ([`Reject::StaleReadSet`]) and transactions that go stale while
//!    queued are dropped at batch pull (`stale_dropped`) — versions only
//!    move forward, so both are `MvccConflict`s shed before consensus
//!    spends bandwidth on them.
//!
//! One [`ShardMempool`] serves one channel (shard chains + the mainchain);
//! a [`MempoolRegistry`] routes by channel and aggregates counters.

pub mod admission;
pub mod pool;
pub mod stats;

pub use admission::{Reject, TokenBucket};
pub use pool::{encoded_len, Lane, MempoolConfig, MempoolRegistry, ShardMempool};
pub use stats::{MempoolStats, StatsSnapshot};
