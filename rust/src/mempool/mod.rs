//! Sharded mempool: the ingress path between clients/gateways and the
//! ordering service.
//!
//! The paper's evaluation (Figs. 5-7) is about the saturation knee —
//! throughput tracks sent TPS until shard capacity, then latency spikes.
//! The prototype submitted envelopes straight into the orderer's driver
//! thread over an unbounded channel, so overload was only modeled
//! implicitly. This subsystem makes the ingress path real:
//!
//! 1. **Admission control** ([`admission`]): endorsement-signature and
//!    policy prechecks, content-hash dedup / replay rejection, and
//!    per-client token-bucket rate caps.
//! 2. **Priority lanes** ([`pool::Lane`]): catalyst/checkpoint traffic >
//!    model updates > queries, each lane a bounded queue with TTL
//!    eviction and explicit backpressure ([`Reject::PoolFull`],
//!    [`Reject::RateLimited`]) surfaced as counters ([`stats`]).
//! 3. **Pipelined block production**: the orderer pulls
//!    size-and-byte-bounded batches ([`ShardMempool::take_batch`]) instead
//!    of owning batching state, so batch cutting, consensus, and
//!    validation overlap.
//! 4. **MVCC staleness hinting** ([`ShardMempool::set_state_view`]): with
//!    a replica's read-version oracle wired in, transactions whose
//!    read-set is already stale are rejected at admission
//!    ([`Reject::StaleReadSet`]) and transactions that go stale while
//!    queued are dropped at batch pull (`stale_dropped`) — versions only
//!    move forward, so both are `MvccConflict`s shed before consensus
//!    spends bandwidth on them.
//! 5. **Cross-shard relay / gossip** ([`relay`]): each shard's pool is an
//!    ingress point for *any* traffic, not just its own channel's. A
//!    transaction arriving at the wrong shard (misrouted client,
//!    failed-over gateway) passes the local pool's forwarding admission
//!    ([`ShardMempool::admit_forward`]) and hops to its home pool over a
//!    `network::simnet` link latency; shard-produced checkpoint/catalyst
//!    transactions reach the mainchain pool the same way. Dedup at the
//!    home pool makes a transaction gossiped through several ingress
//!    pools commit exactly once; relay losses resolve the originating
//!    `SubmitHandle` through the gateway's drop sinks and are counted as
//!    `forwarded` / `relay_dropped` in [`stats`].
//!
//! One [`ShardMempool`] serves one channel (shard chains + the mainchain);
//! a [`MempoolRegistry`] routes by channel and aggregates counters; one
//! [`Relay`] spans a registry's pools and is pumped by the orderer driver.

pub mod admission;
pub mod pool;
pub mod relay;
pub mod stats;

pub use admission::{Reject, TokenBucket};
pub use pool::{encoded_len, Lane, MempoolConfig, MempoolRegistry, ShardMempool};
pub use relay::{Relay, RelayConfig, RelayDropSink, RelaySnapshot};
pub use stats::{MempoolStats, StatsSnapshot};
