//! Merkle tree over transaction digests: block data hashes and inclusion
//! proofs (used by light verification of pinned model updates).

use super::{sha256_pair, Digest};

/// Merkle root of a list of leaf digests. Odd levels duplicate the last node
/// (Bitcoin-style). Empty input hashes to Digest::ZERO.
pub fn root(leaves: &[Digest]) -> Digest {
    if leaves.is_empty() {
        return Digest::ZERO;
    }
    let mut level: Vec<Digest> = leaves.to_vec();
    while level.len() > 1 {
        let mut next = Vec::with_capacity(level.len().div_ceil(2));
        for pair in level.chunks(2) {
            let b = if pair.len() == 2 { &pair[1] } else { &pair[0] };
            next.push(sha256_pair(&pair[0], b));
        }
        level = next;
    }
    level[0]
}

/// An inclusion proof: sibling hashes bottom-up with left/right markers.
#[derive(Clone, Debug, PartialEq)]
pub struct Proof {
    /// (sibling, sibling_is_left)
    pub path: Vec<(Digest, bool)>,
}

/// Build the inclusion proof for `index`.
pub fn prove(leaves: &[Digest], index: usize) -> Option<Proof> {
    if index >= leaves.len() {
        return None;
    }
    let mut path = Vec::new();
    let mut level: Vec<Digest> = leaves.to_vec();
    let mut idx = index;
    while level.len() > 1 {
        let sib = if idx % 2 == 0 {
            // right sibling (or self-duplicate at the edge)
            let s = if idx + 1 < level.len() { level[idx + 1] } else { level[idx] };
            (s, false)
        } else {
            (level[idx - 1], true)
        };
        path.push(sib);
        let mut next = Vec::with_capacity(level.len().div_ceil(2));
        for pair in level.chunks(2) {
            let b = if pair.len() == 2 { &pair[1] } else { &pair[0] };
            next.push(sha256_pair(&pair[0], b));
        }
        level = next;
        idx /= 2;
    }
    Some(Proof { path })
}

/// Verify an inclusion proof against a root.
pub fn verify(leaf: &Digest, proof: &Proof, expected_root: &Digest) -> bool {
    let mut acc = *leaf;
    for (sib, sib_is_left) in &proof.path {
        acc = if *sib_is_left { sha256_pair(sib, &acc) } else { sha256_pair(&acc, sib) };
    }
    acc == *expected_root
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::sha256;
    use crate::util::check::check;

    fn leaves(n: usize) -> Vec<Digest> {
        (0..n).map(|i| sha256(format!("tx-{i}").as_bytes())).collect()
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(root(&[]), Digest::ZERO);
        let l = leaves(1);
        assert_eq!(root(&l), l[0]);
    }

    #[test]
    fn root_changes_with_any_leaf() {
        let l = leaves(7);
        let r = root(&l);
        for i in 0..7 {
            let mut l2 = l.clone();
            l2[i] = sha256(b"tampered");
            assert_ne!(root(&l2), r, "leaf {i}");
        }
    }

    #[test]
    fn proofs_verify_for_all_indices() {
        for n in [1usize, 2, 3, 4, 5, 8, 13] {
            let l = leaves(n);
            let r = root(&l);
            for i in 0..n {
                let p = prove(&l, i).unwrap();
                assert!(verify(&l[i], &p, &r), "n={n} i={i}");
                // Wrong leaf fails.
                assert!(!verify(&sha256(b"other"), &p, &r));
            }
        }
    }

    #[test]
    fn proof_out_of_range() {
        assert!(prove(&leaves(3), 3).is_none());
    }

    #[test]
    fn property_random_trees() {
        check("merkle-roundtrip", 32, |rng| {
            let n = rng.range(1, 40);
            let l: Vec<Digest> =
                (0..n).map(|_| sha256(&rng.next_u64().to_le_bytes())).collect();
            let r = root(&l);
            let i = rng.below(n);
            let p = prove(&l, i).unwrap();
            assert!(verify(&l[i], &p, &r));
        });
    }
}
