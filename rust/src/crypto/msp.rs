//! Membership Service Provider analogue: identity issuance + HMAC signatures.
//!
//! A `CertificateAuthority` issues per-member secrets; members sign payloads
//! with HMAC-SHA256; any holder of the CA registry can verify. This stands in
//! for Fabric's x509/ECDSA MSP (DESIGN.md §2): what the pipeline needs is
//! that endorsements and envelopes are unforgeable by parties without the
//! member's credential, which HMAC provides within the simulation.

use hmac::{Hmac, Mac};
use sha2::Sha256;
use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use crate::util::prng::Prng;

type HmacSha256 = Hmac<Sha256>;

/// A member identity (org + role), e.g. `org3.peer`.
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct MemberId(pub String);

impl MemberId {
    pub fn new(s: impl Into<String>) -> Self {
        MemberId(s.into())
    }
}

impl std::fmt::Display for MemberId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

/// An HMAC-SHA256 signature.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Signature(pub [u8; 32]);

/// Signing credential held by a member. Carries a pre-keyed MAC state so
/// each signature clones two hashed blocks instead of re-running the
/// HMAC key schedule.
#[derive(Clone)]
pub struct Credential {
    pub member: MemberId,
    mac: HmacSha256,
}

impl Credential {
    pub fn sign(&self, payload: &[u8]) -> Signature {
        let mut mac = self.mac.clone();
        mac.update(payload);
        Signature(mac.finalize().into_bytes().into())
    }
}

/// CA registry: issues credentials, verifies signatures.
///
/// The registry stores each member's *pre-keyed* HMAC state next to the
/// secret: verifying clones that state (two cached SHA-256 blocks)
/// instead of paying `new_from_slice`'s key schedule per call — roughly
/// half the compressions on the admission hot path.
#[derive(Clone, Default)]
pub struct CertificateAuthority {
    registry: Arc<RwLock<HashMap<MemberId, HmacSha256>>>,
}

impl CertificateAuthority {
    pub fn new() -> Self {
        Self::default()
    }

    /// Enrol a member; returns their signing credential.
    pub fn enroll(&self, member: MemberId, rng: &mut Prng) -> Credential {
        let mut secret = [0u8; 32];
        for chunk in secret.chunks_mut(8) {
            chunk.copy_from_slice(&rng.next_u64().to_le_bytes()[..chunk.len()]);
        }
        let mac = HmacSha256::new_from_slice(&secret).expect("hmac key");
        self.registry.write().unwrap().insert(member.clone(), mac.clone());
        Credential { member, mac }
    }

    /// Verify a member's signature over a payload.
    pub fn verify(&self, member: &MemberId, payload: &[u8], sig: &Signature) -> bool {
        let reg = self.registry.read().unwrap();
        let Some(mac) = reg.get(member) else {
            return false;
        };
        let mut mac = mac.clone();
        mac.update(payload);
        mac.verify_slice(&sig.0).is_ok()
    }

    /// A verifier holding the registry read lock once for a whole batch
    /// of checks — what admission and block validation use to amortize
    /// per-signature lock traffic.
    pub fn batch_verifier(&self) -> BatchVerifier<'_> {
        BatchVerifier { registry: self.registry.read().unwrap() }
    }

    pub fn is_enrolled(&self, member: &MemberId) -> bool {
        self.registry.read().unwrap().contains_key(member)
    }

    pub fn member_count(&self) -> usize {
        self.registry.read().unwrap().len()
    }
}

/// Amortized signature verification: one registry lock acquisition for
/// arbitrarily many checks. Obtained from
/// [`CertificateAuthority::batch_verifier`].
pub struct BatchVerifier<'a> {
    registry: std::sync::RwLockReadGuard<'a, HashMap<MemberId, HmacSha256>>,
}

impl BatchVerifier<'_> {
    pub fn verify(&self, member: &MemberId, payload: &[u8], sig: &Signature) -> bool {
        let Some(mac) = self.registry.get(member) else {
            return false;
        };
        let mut mac = mac.clone();
        mac.update(payload);
        mac.verify_slice(&sig.0).is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sign_verify_roundtrip() {
        let ca = CertificateAuthority::new();
        let mut rng = Prng::new(1);
        let cred = ca.enroll(MemberId::new("org1.peer"), &mut rng);
        let sig = cred.sign(b"payload");
        assert!(ca.verify(&cred.member, b"payload", &sig));
        assert!(!ca.verify(&cred.member, b"tampered", &sig));
    }

    #[test]
    fn cross_member_forgery_fails() {
        let ca = CertificateAuthority::new();
        let mut rng = Prng::new(2);
        let a = ca.enroll(MemberId::new("org1.peer"), &mut rng);
        let b = ca.enroll(MemberId::new("org2.peer"), &mut rng);
        let sig = a.sign(b"msg");
        assert!(!ca.verify(&b.member, b"msg", &sig));
    }

    #[test]
    fn unknown_member_rejected() {
        let ca = CertificateAuthority::new();
        let mut rng = Prng::new(3);
        let a = ca.enroll(MemberId::new("org1.peer"), &mut rng);
        let sig = a.sign(b"msg");
        assert!(!ca.verify(&MemberId::new("ghost"), b"msg", &sig));
        assert!(!ca.is_enrolled(&MemberId::new("ghost")));
        assert_eq!(ca.member_count(), 1);
    }
}
