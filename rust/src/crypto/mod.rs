//! Cryptographic primitives for the permissioned ledger: SHA-256 digests,
//! Merkle trees over transaction hashes, and an HMAC-SHA256 membership
//! service (MSP analogue).
//!
//! Hyperledger Fabric uses x509 certificates + ECDSA; offline we substitute
//! HMAC-SHA256 identities issued by a certificate-authority analogue that
//! holds per-member secrets (DESIGN.md §2). Unforgeability against members
//! without the secret is preserved, which is the property the endorsement
//! and validation logic relies on.

pub mod merkle;
pub mod msp;

use sha2::{Digest as _, Sha256};

/// A SHA-256 digest.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Digest(pub [u8; 32]);

impl Digest {
    pub const ZERO: Digest = Digest([0u8; 32]);

    pub fn hex(&self) -> String {
        self.0.iter().map(|b| format!("{b:02x}")).collect()
    }

    pub fn short(&self) -> String {
        self.hex()[..12].to_string()
    }

    pub fn from_hex(s: &str) -> Option<Digest> {
        if s.len() != 64 {
            return None;
        }
        let mut out = [0u8; 32];
        for (i, chunk) in s.as_bytes().chunks(2).enumerate() {
            let hi = (chunk[0] as char).to_digit(16)?;
            let lo = (chunk[1] as char).to_digit(16)?;
            out[i] = (hi * 16 + lo) as u8;
        }
        Some(Digest(out))
    }
}

impl std::fmt::Debug for Digest {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Digest({})", self.short())
    }
}

/// SHA-256 of a byte string.
pub fn sha256(data: &[u8]) -> Digest {
    let mut h = Sha256::new();
    h.update(data);
    Digest(h.finalize().into())
}

/// SHA-256 over several segments (length-prefixed to avoid ambiguity).
pub fn sha256_parts(parts: &[&[u8]]) -> Digest {
    let mut h = Sha256::new();
    for p in parts {
        h.update((p.len() as u64).to_le_bytes());
        h.update(p);
    }
    Digest(h.finalize().into())
}

/// SHA-256 of the concatenation of two digests (Merkle interior node).
pub fn sha256_pair(a: &Digest, b: &Digest) -> Digest {
    let mut h = Sha256::new();
    h.update(a.0);
    h.update(b.0);
    Digest(h.finalize().into())
}

/// Hash an f32 parameter vector (the off-chain model blob identity).
pub fn hash_f32(data: &[f32]) -> Digest {
    let mut h = Sha256::new();
    for v in data {
        h.update(v.to_le_bytes());
    }
    Digest(h.finalize().into())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sha256_known_vector() {
        // SHA-256("abc")
        assert_eq!(
            sha256(b"abc").hex(),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
    }

    #[test]
    fn hex_roundtrip() {
        let d = sha256(b"hello");
        assert_eq!(Digest::from_hex(&d.hex()), Some(d));
        assert_eq!(Digest::from_hex("zz"), None);
    }

    #[test]
    fn parts_is_unambiguous() {
        assert_ne!(sha256_parts(&[b"ab", b"c"]), sha256_parts(&[b"a", b"bc"]));
    }

    #[test]
    fn f32_hash_is_stable_and_sensitive() {
        let a = hash_f32(&[1.0, 2.0, 3.0]);
        assert_eq!(a, hash_f32(&[1.0, 2.0, 3.0]));
        assert_ne!(a, hash_f32(&[1.0, 2.0, 3.0001]));
    }
}
