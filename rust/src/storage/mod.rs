//! Off-chain content-addressed model store (IPFS analogue).
//!
//! Clients upload full model weight vectors here (paper §3.4.3); only the
//! hash + URI go on-chain. Endorsing peers fetch by URI and verify the hash
//! before evaluating (§3.4.6). A configurable fetch latency models the
//! network hop to the peer-worker gRPC cache of the paper's testbed; the
//! delay goes through an injectable [`Clock`], so surge tests can use a
//! [`crate::util::clock::VirtualClock`] and never stall real threads.

use std::collections::HashMap;
use std::sync::{Arc, RwLock};
use std::time::Duration;

use crate::crypto::{hash_f32, Digest};
use crate::util::clock::{Clock, SystemClock};

/// URI scheme for stored blobs.
pub const SCHEME: &str = "sim://";

/// Content-addressed store for flat f32 model blobs.
#[derive(Clone)]
pub struct ModelStore {
    blobs: Arc<RwLock<HashMap<Digest, Arc<Vec<f32>>>>>,
    /// Simulated per-fetch latency (0 in tests).
    fetch_latency: Duration,
    /// Clock the fetch latency elapses on (wall or virtual).
    clock: Arc<dyn Clock>,
}

impl Default for ModelStore {
    fn default() -> Self {
        ModelStore {
            blobs: Arc::default(),
            fetch_latency: Duration::ZERO,
            clock: SystemClock::shared(),
        }
    }
}

impl ModelStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_fetch_latency(latency: Duration) -> Self {
        ModelStore { fetch_latency: latency, ..Default::default() }
    }

    /// Store with a simulated fetch latency elapsing on `clock` — pass a
    /// `VirtualClock` to model slow fetches without blocking threads.
    pub fn with_clock(latency: Duration, clock: Arc<dyn Clock>) -> Self {
        ModelStore { blobs: Arc::default(), fetch_latency: latency, clock }
    }

    /// Store a blob; returns (content hash, URI).
    pub fn put(&self, params: Vec<f32>) -> (Digest, String) {
        let digest = hash_f32(&params);
        self.blobs.write().unwrap().insert(digest, Arc::new(params));
        (digest, format!("{SCHEME}{}", digest.hex()))
    }

    /// Fetch by URI; verifies the URI is well-formed.
    pub fn get(&self, uri: &str) -> Option<Arc<Vec<f32>>> {
        let digest = Self::parse_uri(uri)?;
        if !self.fetch_latency.is_zero() {
            self.clock.sleep(self.fetch_latency);
        }
        self.blobs.read().unwrap().get(&digest).cloned()
    }

    /// Fetch + integrity check against an expected hash (endorsement step 6).
    pub fn get_verified(&self, uri: &str, expected: &Digest) -> Result<Arc<Vec<f32>>, String> {
        let blob = self.get(uri).ok_or_else(|| format!("blob not found: {uri}"))?;
        let actual = hash_f32(&blob);
        if actual != *expected {
            return Err(format!(
                "hash mismatch: expected {} got {}",
                expected.short(),
                actual.short()
            ));
        }
        Ok(blob)
    }

    pub fn parse_uri(uri: &str) -> Option<Digest> {
        uri.strip_prefix(SCHEME).and_then(Digest::from_hex)
    }

    pub fn len(&self) -> usize {
        self.blobs.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::VirtualClock;
    use std::time::Instant;

    #[test]
    fn put_get_roundtrip() {
        let store = ModelStore::new();
        let data = vec![1.0, 2.0, 3.0];
        let (digest, uri) = store.put(data.clone());
        assert_eq!(*store.get(&uri).unwrap(), data);
        assert_eq!(store.get_verified(&uri, &digest).map(|b| (*b).clone()), Ok(data));
    }

    #[test]
    fn verification_catches_wrong_hash() {
        let store = ModelStore::new();
        let (_, uri) = store.put(vec![1.0]);
        let wrong = hash_f32(&[2.0]);
        assert!(store.get_verified(&uri, &wrong).is_err());
    }

    #[test]
    fn missing_and_malformed_uris() {
        let store = ModelStore::new();
        assert!(store.get("sim://deadbeef").is_none()); // short hex
        assert!(store.get("http://x").is_none());
        let fake = format!("{SCHEME}{}", hash_f32(&[9.0]).hex());
        assert!(store.get(&fake).is_none());
    }

    #[test]
    fn content_addressing_dedupes() {
        let store = ModelStore::new();
        let (d1, u1) = store.put(vec![1.0, 2.0]);
        let (d2, u2) = store.put(vec![1.0, 2.0]);
        assert_eq!(d1, d2);
        assert_eq!(u1, u2);
        assert_eq!(store.len(), 1);
    }

    #[test]
    fn virtual_clock_fetch_latency_does_not_stall_threads() {
        let clock = Arc::new(VirtualClock::new());
        // A 10-second simulated fetch hop per get().
        let store = ModelStore::with_clock(
            Duration::from_secs(10),
            Arc::clone(&clock) as Arc<dyn Clock>,
        );
        let (_, uri) = store.put(vec![1.0, 2.0]);
        let t0 = Instant::now();
        assert!(store.get(&uri).is_some());
        assert!(store.get(&uri).is_some());
        // 20 s of simulated latency elapsed on the virtual clock...
        assert!((clock.now() - 20.0).abs() < 1e-9);
        // ...while the real thread never slept.
        assert!(t0.elapsed() < Duration::from_secs(5));
    }

    #[test]
    fn system_clock_fetch_latency_still_blocks() {
        let store = ModelStore::with_fetch_latency(Duration::from_millis(20));
        let (_, uri) = store.put(vec![3.0]);
        let t0 = Instant::now();
        assert!(store.get(&uri).is_some());
        assert!(t0.elapsed() >= Duration::from_millis(20));
    }
}
