//! ScaleSFL launcher.
//!
//! Subcommands (hand-rolled arg parsing; clap is not in the offline vendor
//! set):
//!
//!   scalesfl info                         — artifact manifest + runtime info
//!   scalesfl train   [--shards N] [--rounds N] [--clients N] [--batch B]
//!                    [--epochs E] [--lr F] [--dirichlet A | --writer]
//!                    [--dp] [--defense none|roni|norm] [--agg none|krum|fg]
//!   scalesfl figures [fig4|fig5|fig6|fig7|fig8|fig9|ablation|all] [--full]
//!   scalesfl calibrate                    — print DES calibration numbers
//!   scalesfl telemetry [--txs N] [--json] — drive a small sharded pipeline
//!            [--ledger DIR]                 and dump the metrics registry;
//!            [--durability off|group|strict]  with --ledger, commits are
//!                                           persisted under DIR and the
//!                                           run recovers whatever a
//!                                           previous run left there
//!   scalesfl node orderer|gateway         — run one fabric process
//!            [--listen tcp:H:P|uds:/PATH]    speaking wire frames over a
//!            [--channels a,b] [--peers N]    socket; prints `LISTENING
//!            [--seed N] [--batch-size N]     <endpoint>` once bound and
//!            [--upstream ch=EP,...]          serves until stdin closes

use std::sync::Arc;
use std::time::Duration;

use scalesfl::caliper::figures;
use scalesfl::fl::client::{DpConfig, TrainConfig};
use scalesfl::sim::{AggDefense, DefenseChoice, Partition, ScaleSfl, SimConfig};

fn arg_value(args: &[String], key: &str) -> Option<String> {
    args.iter().position(|a| a == key).and_then(|i| args.get(i + 1).cloned())
}

fn parse<T: std::str::FromStr>(args: &[String], key: &str, default: T) -> T {
    arg_value(args, key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn has_flag(args: &[String], key: &str) -> bool {
    args.iter().any(|a| a == key)
}

/// `--telemetry` end-of-run dump: everything the pipeline registered into
/// the process-wide metrics registry, plus the tracer's stage summary.
fn dump_telemetry() {
    let t = scalesfl::telemetry::global();
    println!("\n# telemetry registry (end of run)");
    print!("{}", t.registry().render_prometheus());
    println!("# per-stage lifecycle latencies");
    println!("{}", t.tracer().stage_snapshot().to_json());
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if args.is_empty() { &args[..] } else { &args[1..] };
    let code = match cmd {
        "info" => cmd_info(),
        "train" => cmd_train(rest),
        "figures" => cmd_figures(rest),
        "calibrate" => cmd_calibrate(),
        "telemetry" => cmd_telemetry(rest),
        "node" => cmd_node(rest),
        _ => {
            print_help();
            0
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "scalesfl — sharded blockchain-based federated learning (paper reproduction)

USAGE:
  scalesfl info
  scalesfl train   [--shards N] [--rounds N] [--clients N] [--batch B] [--epochs E]
                   [--lr F] [--dirichlet ALPHA | --writer] [--dp]
                   [--defense none|roni|norm] [--agg none|krum|fg] [--pn]
  scalesfl figures [fig4|fig5|fig6|fig7|fig8|fig9|ablation|all] [--full]
  scalesfl calibrate
  scalesfl telemetry [--txs N] [--json] [--ledger DIR] [--durability off|group|strict]
  scalesfl node orderer [--listen EP] [--channels a,b] [--peers N] [--seed N] [--batch-size N]
  scalesfl node gateway [--listen EP] [--upstream ch=EP,ch2=EP2]

`telemetry` drives a small ingress->relay->order->validate->commit pipeline
and dumps the process-wide metrics registry (Prometheus text, or JSON with
--json) plus the per-stage lifecycle latencies from the tracer. `train` and
`figures` accept `--telemetry` to dump the same registry when the run ends.
With `--ledger DIR` every committed block is persisted to an append-only
log (plus periodic Merkle-rooted state snapshots) under DIR, and a rerun
against the same DIR first recovers the previous run's chain by replay —
so driving it twice demonstrates crash recovery end to end.

`node` runs one fabric process over a real socket (TCP or Unix-domain):
`orderer` hosts an ordering service plus endorsing peers for its channels,
`gateway` fronts one or more orderers and relays by channel. Each prints
`LISTENING <endpoint>` to stdout once bound (port 0 resolves to the
ephemeral port picked) and serves until stdin reaches EOF — so a parent
process can spawn, address, and cleanly stop a topology of children.

Run `make artifacts` before anything that touches the model runtime."
    );
}

fn cmd_info() -> i32 {
    let Some(rt) = scalesfl::runtime::shared() else {
        eprintln!("artifacts not built — run `make artifacts`");
        return 1;
    };
    let m = rt.manifest();
    println!("model: {} params ({} padded), input {}, hidden {:?}, {} classes",
        m.p, m.p_pad, m.input_dim, m.hidden, m.num_classes);
    println!("aggregation width K = {}, eval batch = {}", m.k, m.b_eval);
    println!("train batch sizes: {:?}", m.train_batch_sizes);
    println!("artifacts: {}", m.artifacts.join(", "));
    0
}

fn cmd_calibrate() -> i32 {
    let Some(ops) = scalesfl::runtime::shared_ops() else {
        eprintln!("artifacts not built — run `make artifacts`");
        return 1;
    };
    for samples in [512usize, 2048, 10000] {
        match ops.calibrate(samples, 3) {
            Ok(c) => println!(
                "eval({} samples) = {:.1} ms    fedavg_agg(K=8) = {:.1} ms",
                samples,
                c.eval_s * 1e3,
                c.agg_s * 1e3
            ),
            Err(e) => {
                eprintln!("calibration failed: {e}");
                return 1;
            }
        }
    }
    0
}

/// Drive a small but complete sharded pipeline — foreign-ingress
/// submissions hop the cross-shard relay, get ordered, validated, and
/// committed — then dump everything the telemetry layer collected: the
/// metrics registry (all subsystems' labelled series) and the tracer's
/// per-stage latency summary.
fn cmd_telemetry(args: &[String]) -> i32 {
    use scalesfl::crypto::msp::{CertificateAuthority, MemberId};
    use scalesfl::fabric::chaincode::{Chaincode, TxContext};
    use scalesfl::fabric::endorsement::EndorsementPolicy;
    use scalesfl::fabric::orderer::{OrdererConfig, OrderingService};
    use scalesfl::fabric::peer::Peer;
    use scalesfl::fabric::Gateway;
    use scalesfl::ledger::tx::Proposal;
    use scalesfl::util::prng::Prng;

    struct Put;
    impl Chaincode for Put {
        fn name(&self) -> &str {
            "kv"
        }
        fn invoke(
            &self,
            ctx: &mut TxContext<'_>,
            _f: &str,
            args: &[String],
        ) -> Result<Vec<u8>, String> {
            ctx.put(&args[0], b"v".to_vec());
            Ok(vec![])
        }
    }

    use scalesfl::ledger::store::{DurabilityMode, LedgerConfig};

    let txs = parse(args, "--txs", 24usize).max(1);
    // --ledger DIR: persist commits under DIR; reruns recover from it.
    // The CA seed is fixed, so credentials are identical across runs and
    // logged endorsements verify on replay.
    let ledger = arg_value(args, "--ledger").map(|dir| {
        let mut lc = LedgerConfig::new(dir);
        lc.durability = match arg_value(args, "--durability").as_deref() {
            Some("off") => DurabilityMode::Off,
            Some("strict") => DurabilityMode::Strict,
            _ => lc.durability, // group commit
        };
        lc
    });
    let ca = CertificateAuthority::new();
    let mut rng = Prng::new(7);
    let peers: Vec<Arc<Peer>> = (0..2)
        .map(|i| {
            let cred = ca.enroll(MemberId::new(format!("org{i}.peer")), &mut rng);
            Peer::new(cred, ca.clone())
        })
        .collect();
    let members: Vec<MemberId> = peers.iter().map(|p| p.member.clone()).collect();
    for p in &peers {
        p.join_channel("ch", EndorsementPolicy::MajorityOf(members.clone()));
        p.install_chaincode("ch", Arc::new(Put)).unwrap();
    }
    if let Some(lc) = &ledger {
        for p in &peers {
            match p.attach_store("ch", lc) {
                Ok(rep) => eprintln!(
                    "{}: recovered height {} (snapshot {}, replayed {}, root {})",
                    p.member,
                    rep.height,
                    rep.snapshot_height,
                    rep.replayed_blocks,
                    rep.state_root.short()
                ),
                Err(e) => {
                    eprintln!("{}: ledger attach failed: {e}", p.member);
                    return 1;
                }
            }
        }
    }
    let cfg = OrdererConfig {
        batch_timeout: Duration::from_millis(10),
        tick: Duration::from_millis(1),
        relay: Some(scalesfl::mempool::RelayConfig {
            base_latency: Duration::from_millis(2),
            latency_spread: Duration::from_millis(2),
            jitter: Duration::from_millis(1),
            seed: 7,
        }),
        ledger: ledger.clone(),
        ..OrdererConfig::default()
    };
    let orderer = OrderingService::start(cfg, peers.clone(), 7);
    let mut gw = Gateway::new(peers.clone(), orderer);
    // A foreign ingress shard, so every transaction pays a relay hop and
    // the relay/trace series are non-trivial.
    gw.ingress = Some("edge".into());
    // Key/nonce space offset by the recovered height, so a rerun against
    // the same --ledger DIR submits fresh transactions instead of
    // tripping the recovered duplicate-txid set.
    let base = peers[0].channel("ch").map(|ch| ch.height()).unwrap_or(0) * 10_000;
    eprintln!("driving {txs} txs through edge -> relay -> ch -> commit ...");
    for i in 0..txs as u64 {
        let out = gw
            .submit(&Proposal {
                channel: "ch".into(),
                chaincode: "kv".into(),
                function: "Put".into(),
                args: vec![format!("k{}", base + i)],
                creator: MemberId::new("client"),
                nonce: base + i,
            })
            .wait();
        if !out.is_valid() {
            eprintln!("tx {i} did not commit: {out:?}");
            return 1;
        }
    }

    if ledger.is_some() {
        eprintln!("\n# ledger stores");
        for p in &peers {
            if let Some(store) = p.channel("ch").and_then(|ch| ch.store()) {
                eprintln!("{}: height {} {}", p.member, store.height(), store.stats().to_json());
            }
        }
    }

    let t = scalesfl::telemetry::global();
    if has_flag(args, "--json") {
        println!("{}", t.registry().render_json());
    } else {
        print!("{}", t.registry().render_prometheus());
    }
    eprintln!("\n# per-stage lifecycle latencies (tracer snapshot)");
    eprintln!("{}", t.tracer().stage_snapshot().to_json());
    eprintln!("# flight recorder");
    eprintln!("{}", t.flight().to_json());
    0
}

/// `scalesfl node <role>`: one fabric process over a real socket. Prints
/// `LISTENING <endpoint>` once bound and serves until stdin reaches EOF
/// (the parent closing the pipe is the shutdown signal — robust even if
/// the parent dies without killing us).
fn cmd_node(args: &[String]) -> i32 {
    use scalesfl::network::node::{bind_and_serve, bind_and_serve_relay, FabricNode, NodeConfig};
    use scalesfl::network::transport::Endpoint;
    use std::io::{BufRead, Write};

    let Some(role) = args.first().map(|s| s.as_str()) else {
        eprintln!("usage: scalesfl node orderer|gateway [flags]");
        return 2;
    };
    let listen = arg_value(args, "--listen").unwrap_or_else(|| "tcp:127.0.0.1:0".into());
    let ep = match Endpoint::parse(&listen) {
        Ok(ep) => ep,
        Err(e) => {
            eprintln!("--listen: {e}");
            return 2;
        }
    };
    let bound = match role {
        "orderer" => {
            let channels: Vec<String> = arg_value(args, "--channels")
                .unwrap_or_else(|| "ch".into())
                .split(',')
                .filter(|s| !s.is_empty())
                .map(str::to_string)
                .collect();
            let cfg = NodeConfig {
                channels,
                peers: parse(args, "--peers", 2usize),
                seed: parse(args, "--seed", 7u64),
                batch_size: parse(args, "--batch-size", 1usize),
                ..NodeConfig::default()
            };
            bind_and_serve(FabricNode::build(&cfg), &ep)
        }
        "gateway" => {
            let mut upstreams = std::collections::HashMap::new();
            for pair in arg_value(args, "--upstream").unwrap_or_default().split(',') {
                let Some((ch, addr)) = pair.split_once('=') else { continue };
                match Endpoint::parse(addr) {
                    Ok(up) => {
                        upstreams.insert(ch.to_string(), up);
                    }
                    Err(e) => {
                        eprintln!("--upstream {ch}: {e}");
                        return 2;
                    }
                }
            }
            if upstreams.is_empty() {
                eprintln!("gateway needs --upstream ch=tcp:HOST:PORT[,ch2=...]");
                return 2;
            }
            bind_and_serve_relay(upstreams, &ep)
        }
        other => {
            eprintln!("unknown node role {other:?}: expected orderer or gateway");
            return 2;
        }
    };
    let local = match bound {
        Ok((local, _accept_thread)) => local,
        Err(e) => {
            eprintln!("bind {ep}: {e}");
            return 1;
        }
    };
    // The parent parses this line to learn the resolved (port-0) address.
    println!("LISTENING {local}");
    let _ = std::io::stdout().flush();
    // Serve until the parent closes our stdin.
    let stdin = std::io::stdin();
    let mut line = String::new();
    while matches!(stdin.lock().read_line(&mut line), Ok(n) if n > 0) {
        line.clear();
    }
    // Exiting via return skips the accept thread's destructors, so unlink
    // the socket file here; `Listener::bind` also clears stale ones.
    if let Endpoint::Uds(path) = &local {
        let _ = std::fs::remove_file(path);
    }
    0
}

fn cmd_train(args: &[String]) -> i32 {
    let Some(ops) = scalesfl::runtime::shared_ops() else {
        eprintln!("artifacts not built — run `make artifacts`");
        return 1;
    };
    let shards = parse(args, "--shards", 2usize);
    let rounds = parse(args, "--rounds", 3usize);
    let clients = parse(args, "--clients", 4usize);
    let batch = parse(args, "--batch", 10usize);
    let epochs = parse(args, "--epochs", 1usize);
    let lr = parse(args, "--lr", 0.05f32);
    let dp = has_flag(args, "--dp");
    let partition = if has_flag(args, "--writer") {
        Partition::Writer
    } else if let Some(a) = arg_value(args, "--dirichlet") {
        Partition::Dirichlet { alpha: a.parse().unwrap_or(0.5) }
    } else {
        Partition::Iid
    };
    let defense = match arg_value(args, "--defense").as_deref() {
        Some("roni") => DefenseChoice::Roni { max_degradation: 0.05 },
        Some("norm") => DefenseChoice::NormBound { max_norm: 10.0 },
        _ => DefenseChoice::None,
    };
    let agg_defense = match arg_value(args, "--agg").as_deref() {
        Some("krum") => AggDefense::MultiKrum { f: 2 },
        Some("fg") => AggDefense::FoolsGold,
        _ => AggDefense::None,
    };
    let train = TrainConfig {
        batch: if dp { 32 } else { batch },
        epochs,
        lr,
        dp: dp.then(DpConfig::default),
    };
    let cfg = SimConfig {
        shards,
        peers_per_shard: 2,
        clients_per_shard: clients,
        train,
        defense,
        agg_defense,
        partition,
        samples_per_client: 100,
        eval_samples: 64,
        test_samples: 512,
        verify_aggregate: true,
        pn_amplitude: if has_flag(args, "--pn") { 1e-3 } else { 0.0 },
        seed: parse(args, "--seed", 42u64),
        timeout: Duration::from_secs(30),
        ..Default::default()
    };
    println!("ScaleSFL: {shards} shards x {clients} clients, {rounds} rounds, B={batch} E={epochs} lr={lr}");
    let mut net = match ScaleSfl::build(cfg, ops) {
        Ok(n) => n,
        Err(e) => {
            eprintln!("build failed: {e}");
            return 1;
        }
    };
    for _ in 0..rounds {
        match net.run_round() {
            Ok(r) => println!(
                "round {:>3}: loss {:.4} acc {:.4} | accepted {}/{} lazy {}",
                r.round,
                r.mean_train_loss,
                r.global_eval.accuracy,
                r.accepted_updates,
                r.accepted_updates + r.rejected_updates,
                r.lazy_detected
            ),
            Err(e) => {
                eprintln!("round failed: {e}");
                return 1;
            }
        }
    }
    if dp {
        let steps: u64 =
            net.shards.iter().flat_map(|s| s.clients.iter().map(|c| c.dp_steps)).max().unwrap_or(0);
        let q = batch as f64 / 100.0;
        let eps = scalesfl::fl::dp::epsilon(q, 0.4, steps, 1e-5);
        println!("DP accountant: worst-case client {steps} steps -> epsilon ~= {eps:.2} (delta 1e-5)");
    }
    if has_flag(args, "--telemetry") {
        dump_telemetry();
    }
    0
}

fn cmd_figures(args: &[String]) -> i32 {
    let which = args.first().map(|s| s.as_str()).unwrap_or("all");
    let quick = !(has_flag(args, "--full") || figures::full_requested());
    if matches!(which, "ablation" | "all") {
        println!("# ablation — endorsement computations (C=64, P_E=8)");
        for s in [1usize, 2, 4, 8] {
            let (flat, per_shard, global) = figures::ablation_eval_count(64, 8, s);
            println!("shards={s}: flat={flat} per-shard={per_shard} global={global}");
        }
    }
    let needs_env = matches!(which, "fig4" | "fig5" | "fig6" | "fig7" | "fig8" | "all");
    if needs_env {
        let Some(env) = figures::env(quick) else {
            eprintln!("artifacts not built — run `make artifacts`");
            return 1;
        };
        if matches!(which, "fig4" | "all") {
            println!("\n# fig4");
            for (s, r) in figures::fig4(&env) {
                println!("shards={s} {}", r.row());
            }
        }
        if matches!(which, "fig5" | "all") {
            println!("\n# fig5");
            for (s, tps, r) in figures::fig5(&env) {
                println!("shards={s} sent={tps:.2} {}", r.row());
            }
        }
        if matches!(which, "fig6" | "fig7" | "all") {
            println!("\n# fig6+fig7");
            for (txs, r) in figures::fig6_7(&env) {
                println!("txs={txs} {}", r.row());
            }
        }
        if matches!(which, "fig8" | "all") {
            println!("\n# fig8");
            for (s, w, r) in figures::fig8(&env) {
                println!("shards={s} workers={w} {}", r.row());
            }
        }
    }
    if matches!(which, "fig9" | "all") {
        let Some(ops) = scalesfl::runtime::shared_ops() else {
            eprintln!("artifacts not built — run `make artifacts`");
            return 1;
        };
        match figures::fig9_table2(&ops, quick) {
            Ok(cells) => figures::print_table2(&cells),
            Err(e) => {
                eprintln!("fig9 failed: {e}");
                return 1;
            }
        }
    }
    if has_flag(args, "--telemetry") {
        dump_telemetry();
    }
    0
}
