//! Commit-event demultiplexer: one subscription per (gateway, channel),
//! routing each event to the single in-flight transaction waiting on it.
//!
//! Before this existed every in-flight transaction owned its own
//! `Peer::subscribe` stream and scanned *every* commit event for its own
//! tx id, so N concurrent transactions cost O(N) subscriptions and O(N²)
//! event clones under load. The [`CommitWaiter`] owns the channel's single
//! [`Subscription`]: a background thread receives each [`CommitEvent`]
//! once and hands it to the waiter registered under that tx id (a
//! one-shot `mpsc` slot per `SubmitHandle`). Waiters register *before*
//! their envelope reaches the orderer — a commit can never race past its
//! waiter — and deregister on drop, so the table is sized by in-flight
//! transactions only.
//!
//! A waiter can resolve through two doors, both carried by
//! [`WaiterEvent`]: the channel's commit event (the demux thread), or a
//! [`CommitWaiter::reject`] pushed by the cross-shard relay when a
//! forwarded envelope is dropped before ordering. Without the second
//! door, a handle whose transaction died in the relay would pend until
//! its timeout with no event ever arriving — the `Subscription` /
//! `CommitWaiter` slot leak the relay work exposed.
//!
//! The multi-process split reuses this table on both sides of the socket:
//! the node server registers *callbacks* ([`CommitWaiter::register_callback`])
//! that turn commit events into outbound `Event` frames without a thread
//! per in-flight transaction, and the remote client holds a thread-less
//! [`CommitWaiter::external`] table whose events are fed by its connection
//! reader through [`CommitWaiter::complete`] — so `SubmitHandle` semantics
//! are identical in-process and across a socket.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::ledger::tx::TxId;
use crate::mempool::Reject;

use super::peer::{CommitEvent, Subscription};

/// How often the demux thread re-checks the shutdown flag while idle.
const IDLE_TICK: Duration = Duration::from_millis(25);

/// What resolves a registered waiter. Events are stamped with their
/// routing time so latency measurements reflect when the outcome *landed*,
/// not when the handle was drained.
pub enum WaiterEvent {
    /// The transaction committed (any validation code).
    Committed(CommitEvent, Instant),
    /// The transaction died before ordering: the relay dropped its
    /// forwarded envelope (home pool full, rate capped, shutdown, …).
    Dropped(Reject, Instant),
}

/// One registered waiter: either a one-shot channel drained by a
/// `SubmitHandle`, or a callback invoked on the dispatching thread (the
/// node server's frame writer path).
enum Slot {
    Chan(mpsc::Sender<WaiterEvent>),
    Callback(Box<dyn FnOnce(WaiterEvent) + Send>),
}

impl Slot {
    fn resolve(self, ev: WaiterEvent) {
        match self {
            Slot::Chan(tx) => {
                let _ = tx.send(ev);
            }
            Slot::Callback(cb) => cb(ev),
        }
    }
}

struct WaiterTable {
    waiters: Mutex<HashMap<TxId, Slot>>,
    high_water: AtomicUsize,
    shutdown: AtomicBool,
}

impl WaiterTable {
    fn fresh() -> Arc<WaiterTable> {
        Arc::new(WaiterTable {
            waiters: Mutex::new(HashMap::new()),
            high_water: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
        })
    }

    /// Route one commit event to the waiter registered under its tx id
    /// (events for unknown ids — handle dropped, other gateways' traffic —
    /// are discarded without cloning further).
    fn dispatch_commit(&self, ev: CommitEvent) -> bool {
        // Stamp the commit-event receive time and close the lifecycle
        // trace. First dispatcher to see the event wins; replica/peer
        // fan-out makes later calls no-ops.
        crate::telemetry::global().complete_commit(&ev.tx_id);
        // Take the slot out before resolving it: callbacks must run with
        // the table unlocked (a callback is free to register new waiters).
        let slot = self.waiters.lock().unwrap().remove(&ev.tx_id);
        match slot {
            Some(slot) => {
                slot.resolve(WaiterEvent::Committed(ev, Instant::now()));
                true
            }
            None => false,
        }
    }
}

/// Per-channel commit-event router. Owned by a [`super::Gateway`] (one per
/// channel it has submitted on) and kept alive by any outstanding
/// [`super::SubmitHandle`], so pending handles stay resolvable even after
/// the gateway itself is dropped.
pub struct CommitWaiter {
    shared: Arc<WaiterTable>,
    /// Detached on drop: the thread notices the shutdown flag within
    /// [`IDLE_TICK`] and exits on its own (joining here would stall
    /// gateway teardown by up to a tick per channel). `None` for
    /// [`CommitWaiter::external`] tables, whose events arrive from an
    /// outside dispatcher.
    _thread: Option<thread::JoinHandle<()>>,
}

impl CommitWaiter {
    /// Take ownership of `sub` (the channel's single commit-event stream)
    /// and start the demux thread.
    pub fn start(channel: &str, sub: Subscription) -> CommitWaiter {
        let shared = WaiterTable::fresh();
        let table = Arc::clone(&shared);
        let thread = thread::Builder::new()
            .name(format!("commit-demux-{channel}"))
            .spawn(move || loop {
                if table.shutdown.load(Ordering::Relaxed) {
                    return;
                }
                match sub.recv_timeout(IDLE_TICK) {
                    Ok(ev) => {
                        table.dispatch_commit(ev);
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => return,
                }
            })
            .expect("spawn commit demux");
        CommitWaiter { shared, _thread: Some(thread) }
    }

    /// A waiter table with no subscription and no demux thread: commit
    /// events arrive from outside through [`CommitWaiter::complete`] /
    /// [`CommitWaiter::reject`]. The remote client library uses this —
    /// its connection reader thread *is* the demux.
    pub fn external() -> CommitWaiter {
        CommitWaiter { shared: WaiterTable::fresh(), _thread: None }
    }

    /// Register a waiter for `tx_id`; must happen before the envelope is
    /// handed to the orderer. `None` means the tx is already awaited
    /// through this demux (a duplicate in-flight submission).
    pub fn register(&self, tx_id: TxId) -> Option<mpsc::Receiver<WaiterEvent>> {
        let (tx, rx) = mpsc::channel();
        let mut waiters = self.shared.waiters.lock().unwrap();
        if waiters.contains_key(&tx_id) {
            return None;
        }
        waiters.insert(tx_id, Slot::Chan(tx));
        self.shared.high_water.fetch_max(waiters.len(), Ordering::Relaxed);
        Some(rx)
    }

    /// Register a callback for `tx_id` instead of a drainable channel:
    /// invoked exactly once, on the dispatching thread, when the commit
    /// event (or a relay drop) arrives. The node server uses this to turn
    /// commit events into outbound socket frames without a thread per
    /// in-flight transaction. Returns `false` (registering nothing) if
    /// the tx is already awaited.
    pub fn register_callback(
        &self,
        tx_id: TxId,
        cb: Box<dyn FnOnce(WaiterEvent) + Send>,
    ) -> bool {
        let mut waiters = self.shared.waiters.lock().unwrap();
        if waiters.contains_key(&tx_id) {
            return false;
        }
        waiters.insert(tx_id, Slot::Callback(cb));
        self.shared.high_water.fetch_max(waiters.len(), Ordering::Relaxed);
        true
    }

    /// Forget a waiter (submission rejected, or its handle was dropped
    /// before the commit event arrived).
    pub fn deregister(&self, tx_id: &TxId) {
        self.shared.waiters.lock().unwrap().remove(tx_id);
    }

    /// Route one commit event to its registered waiter. This is the demux
    /// thread's dispatch path, public so an external dispatcher (the
    /// remote client's connection reader, turning `Event::Committed`
    /// frames back into [`CommitEvent`]s) can resolve waiters the same
    /// way. Returns whether a waiter was registered for the event's tx.
    pub fn complete(&self, ev: CommitEvent) -> bool {
        self.shared.dispatch_commit(ev)
    }

    /// Resolve a waiter with a pre-ordering failure (relay drop): the
    /// handle sees `CommitOutcome::Rejected` instead of pending until its
    /// timeout. Returns whether a waiter was registered for `tx_id`.
    pub fn reject(&self, tx_id: &TxId, reject: Reject) -> bool {
        let slot = self.shared.waiters.lock().unwrap().remove(tx_id);
        match slot {
            Some(slot) => {
                slot.resolve(WaiterEvent::Dropped(reject, Instant::now()));
                true
            }
            None => false,
        }
    }

    /// Transactions currently awaiting their commit event.
    pub fn pending(&self) -> usize {
        self.shared.waiters.lock().unwrap().len()
    }

    /// Most waiters ever registered at once (in-flight depth high-water).
    pub fn high_water(&self) -> usize {
        self.shared.high_water.load(Ordering::Relaxed)
    }
}

impl Drop for CommitWaiter {
    fn drop(&mut self) {
        // No join: the detached demux thread sees the flag within one idle
        // tick, drops its subscription (pruning the peer listener), and
        // exits; teardown never blocks submitters.
        self.shared.shutdown.store(true, Ordering::Relaxed);
    }
}

/// The relay's drop-notification door: a forwarded transaction whose last
/// in-flight copy died resolves its waiter as `Rejected` (the gateway
/// registers each waiter with the orderer's relay, weakly).
impl crate::mempool::relay::RelayDropSink for CommitWaiter {
    fn relay_dropped(&self, tx_id: &TxId, reject: Reject) {
        self.reject(tx_id, reject);
    }
}
