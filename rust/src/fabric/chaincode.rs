//! Chaincode (smart contract) interface and the transaction simulation
//! context that records read/write sets during endorsement.

use std::sync::RwLock;

use crate::ledger::state::WorldState;
use crate::ledger::tx::{ReadSet, RwSet, WriteSet};

/// A deployed smart contract.
///
/// `invoke` runs during endorsement simulation; reads/writes go through the
/// [`TxContext`] so the peer can endorse the exact effect set. Returning
/// `Err` rejects the proposal (e.g. the defence policy refused the model
/// update), which surfaces to the client as an endorsement failure.
pub trait Chaincode: Send + Sync {
    /// Contract name as deployed on the channel.
    fn name(&self) -> &str;
    /// Execute `function(args)` against the simulation context.
    fn invoke(&self, ctx: &mut TxContext<'_>, function: &str, args: &[String])
        -> Result<Vec<u8>, String>;
}

/// Transaction simulation context: reads hit committed state (recording the
/// observed version), writes are buffered. Read-your-writes is supported
/// within a single simulation.
///
/// Simulation only ever takes the state's *read* lock, so any number of
/// endorsements (and the commit pipeline's pre-validation stage) proceed
/// concurrently; the write lock belongs to the serial apply stage alone.
pub struct TxContext<'a> {
    state: &'a RwLock<WorldState>,
    reads: ReadSet,
    writes: WriteSet,
}

impl<'a> TxContext<'a> {
    pub fn new(state: &'a RwLock<WorldState>) -> Self {
        TxContext { state, reads: Vec::new(), writes: Vec::new() }
    }

    /// Read a key. Buffered writes from this simulation win; otherwise the
    /// committed value is returned and the observed version recorded.
    pub fn get(&mut self, key: &str) -> Option<Vec<u8>> {
        if let Some((_, v)) = self.writes.iter().rev().find(|(k, _)| k == key) {
            return v.clone();
        }
        let guard = self.state.read().unwrap();
        let hit = guard.get(key);
        self.reads.push((key.to_string(), hit.map(|(_, ver)| ver)));
        hit.map(|(v, _)| v.to_vec())
    }

    /// Buffer a write.
    pub fn put(&mut self, key: &str, value: Vec<u8>) {
        self.writes.push((key.to_string(), Some(value)));
    }

    /// Buffer a delete.
    pub fn delete(&mut self, key: &str) {
        self.writes.push((key.to_string(), None));
    }

    /// Prefix scan over committed state; records a read per hit so MVCC
    /// catches concurrent modification of any returned key. Ownership is
    /// taken here (the chaincode API hands values to contracts), off the
    /// borrowed entries `scan_prefix` returns.
    pub fn scan(&mut self, prefix: &str) -> Vec<(String, Vec<u8>)> {
        let guard = self.state.read().unwrap();
        let mut out = Vec::new();
        for (k, v) in guard.scan_prefix(prefix) {
            self.reads.push((k.to_string(), guard.read_version(k)));
            out.push((k.to_string(), v.to_vec()));
        }
        out
    }

    /// Finish simulation, yielding the endorsed effect set.
    pub fn into_rw_set(self) -> RwSet {
        RwSet { reads: self.reads, writes: self.writes }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ledger::state::Version;
    use crate::ledger::tx::RwSet;

    fn seeded_state() -> RwLock<WorldState> {
        let mut s = WorldState::new();
        s.apply(
            &RwSet { reads: vec![], writes: vec![("k".into(), Some(b"v1".to_vec()))] },
            Version { block: 3, tx: 1 },
        );
        RwLock::new(s)
    }

    #[test]
    fn records_read_versions() {
        let state = seeded_state();
        let mut ctx = TxContext::new(&state);
        assert_eq!(ctx.get("k"), Some(b"v1".to_vec()));
        assert_eq!(ctx.get("absent"), None);
        let rw = ctx.into_rw_set();
        assert_eq!(rw.reads.len(), 2);
        assert_eq!(rw.reads[0], ("k".into(), Some(Version { block: 3, tx: 1 })));
        assert_eq!(rw.reads[1], ("absent".into(), None));
    }

    #[test]
    fn read_your_writes() {
        let state = seeded_state();
        let mut ctx = TxContext::new(&state);
        ctx.put("k", b"v2".to_vec());
        assert_eq!(ctx.get("k"), Some(b"v2".to_vec()));
        ctx.delete("k");
        assert_eq!(ctx.get("k"), None);
        // Neither buffered read recorded a version (no MVCC dependency).
        let rw = ctx.into_rw_set();
        assert!(rw.reads.is_empty());
        assert_eq!(rw.writes.len(), 2);
    }

    #[test]
    fn scan_records_reads() {
        let state = seeded_state();
        let mut ctx = TxContext::new(&state);
        let hits = ctx.scan("k");
        assert_eq!(hits.len(), 1);
        let rw = ctx.into_rw_set();
        assert_eq!(rw.reads.len(), 1);
    }
}
